"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs the ref.py
pure-jnp oracles, across shapes (aligned, ragged, tiny) and dtypes; plus
triangulation against the QTensor XLA paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QAPoT, QM2Q, QUniform, quantize_act, select_schemes
from repro.core.packing import apot_encode, pack_int4
from repro.core.quant import apot_quantize, uniform_quantize
from repro.kernels import ops, ref

SHAPES = [(128, 128, 128), (256, 384, 512), (96, 72, 136), (8, 16, 32),
          (130, 258, 514)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rng(seed=0):
    return np.random.default_rng(seed)


def _mk_int8_weights(rng, K, N):
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    qt = QUniform.quantize(jnp.asarray(w), bits=8)
    return qt


@pytest.mark.parametrize("M,K,N", SHAPES)
def test_int8_matmul_vs_ref(M, K, N):
    rng = _rng(M + K + N)
    qt = _mk_int8_weights(rng, K, N)
    x = rng.normal(0, 1, (M, K)).astype(np.float32)
    sa = jnp.float32(np.abs(x).max() / 127.0)
    xq = quantize_act(jnp.asarray(x), sa)
    y_ker = ops.int8_matmul_op(xq, qt.payload, sa, qt.scale.reshape(-1),
                               qt.zero_point.reshape(-1), interpret=True)
    y_ref = ref.int8_matmul_ref(xq, qt.payload, sa, qt.scale.reshape(-1),
                                qt.zero_point.reshape(-1))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # triangulate vs QTensor serving path
    qt.act_scale = sa
    y_qt = qt.matmul(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_qt),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_int4_matmul_vs_ref(M, K, N, dtype):
    N = N + (N % 2)  # packing needs even N
    rng = _rng(M + K + N + 1)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    qt = QUniform.quantize(jnp.asarray(w), bits=4)
    x = jnp.asarray(rng.normal(0, 1, (M, K)).astype(np.float32), dtype)
    y_ker = ops.int4_matmul_op(x.astype(jnp.float32), qt.payload,
                               qt.scale.reshape(-1),
                               qt.zero_point.reshape(-1), interpret=True)
    y_ref = ref.int4_matmul_ref(x.astype(jnp.float32), qt.payload,
                                qt.scale.reshape(-1),
                                qt.zero_point.reshape(-1))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    y_qt = qt.matmul(x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_qt),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("M,K,N", SHAPES)
def test_apot_matmul_vs_ref(M, K, N):
    rng = _rng(M * 3 + K + N)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    qt = QAPoT.quantize(jnp.asarray(w))
    x = rng.normal(0, 1, (M, K)).astype(np.float32)
    y_ker = ops.apot_matmul_op(jnp.asarray(x), qt.codes, qt.scale.reshape(-1),
                               interpret=True)
    y_ref = ref.apot_matmul_ref(jnp.asarray(x), qt.codes,
                                qt.scale.reshape(-1))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    y_qt = qt.matmul(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_qt),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (64, 96, 200),
                                   (16, 32, 48), (130, 514, 254)])
def test_m2q_matmul_vs_ref_and_qtensor(M, K, N):
    rng = _rng(M + 7 * K + N)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    asn = select_schemes(jnp.asarray(w), ratio=0.5)
    x = rng.normal(0, 1, (M, K)).astype(np.float32)
    sa = jnp.float32(np.abs(x).max() / 127.0)
    qt = QM2Q.quantize(jnp.asarray(w), asn.apot_idx, asn.uniform_idx,
                       act_max_abs=jnp.float32(np.abs(x).max()))
    xq = quantize_act(jnp.asarray(x), qt.uniform.act_scale)
    yu_k, ya_k = ops.m2q_matmul_op(
        xq, qt.uniform.act_scale, qt.uniform.payload,
        qt.uniform.scale.reshape(-1), qt.uniform.zero_point.reshape(-1),
        qt.apot.codes, qt.apot.scale.reshape(-1), interpret=True)
    yu_r, ya_r = ref.m2q_matmul_ref(
        xq, qt.uniform.act_scale, qt.uniform.payload,
        qt.uniform.scale.reshape(-1), qt.uniform.zero_point.reshape(-1),
        qt.apot.codes, qt.apot.scale.reshape(-1))
    np.testing.assert_allclose(np.asarray(yu_k), np.asarray(yu_r),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ya_k), np.asarray(ya_r),
                               rtol=1e-5, atol=1e-5)
    # full fused path vs QTensor path (includes inverse permutation)
    y_full = ops.qtensor_matmul(jnp.asarray(x), qt, interpret=True)
    y_qt = qt.matmul(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_qt),
                               rtol=5e-3, atol=5e-3)


@pytest.mark.parametrize("B,H,W,C", [(2, 8, 8, 32), (1, 14, 14, 64),
                                     (3, 7, 9, 16), (1, 16, 16, 130)])
def test_dwconv_w4_vs_ref(B, H, W, C):
    C = C + (C % 2)
    rng = _rng(B + H + W + C)
    w = rng.normal(0, 0.2, (3, 3, C)).astype(np.float32)
    u = uniform_quantize(jnp.asarray(w), bits=4, axis=-1)
    packed = pack_int4(u.q.reshape(9, C))
    scale = u.scale.reshape(-1)
    zp = u.zero_point.reshape(-1)
    x = rng.normal(0, 1, (B, H, W, C)).astype(np.float32)
    y_ker = ops.dwconv_w4_op(jnp.asarray(x), packed, scale, zp,
                             interpret=True)
    y_ref = ref.dwconv_w4_ref(jnp.asarray(x), packed, scale, zp)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


def test_qtensor_matmul_dispatch_uniform4_apot():
    rng = _rng(99)
    w = rng.normal(0, 0.05, (64, 48)).astype(np.float32)
    x = jnp.asarray(rng.normal(0, 1, (3, 5, 64)).astype(np.float32))
    q4 = QUniform.quantize(jnp.asarray(w), bits=4)
    np.testing.assert_allclose(
        np.asarray(ops.qtensor_matmul(x, q4, interpret=True)),
        np.asarray(q4.matmul(x)), rtol=1e-4, atol=1e-4)
    qa = QAPoT.quantize(jnp.asarray(w))
    np.testing.assert_allclose(
        np.asarray(ops.qtensor_matmul(x, qa, interpret=True)),
        np.asarray(qa.matmul(x)), rtol=1e-4, atol=1e-4)
