"""Per-kernel allclose sweeps: Pallas (interpret=True on CPU) vs the ref.py
pure-jnp oracles, across shapes (aligned, ragged, tiny) and dtypes; plus
triangulation against the QTensor XLA paths, parity of the permutation-free
merged M2Q layout against the legacy concat+gather epilogue and the float
reference, HLO cleanliness of the fused path, and the block autotuner."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import QAPoT, QM2Q, QUniform, quantize_act, select_schemes
from repro.core.packing import apot_decode_values, apot_encode, pack_int4
from repro.core.quant import apot_quantize, fake_quant_act, uniform_quantize
from repro.kernels import autotune, ops, ref

SHAPES = [(128, 128, 128), (256, 384, 512), (96, 72, 136), (8, 16, 32),
          (130, 258, 514)]
DTYPES = [jnp.float32, jnp.bfloat16]


def _rng(seed=0):
    return np.random.default_rng(seed)


def _mk_int8_weights(rng, K, N):
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    qt = QUniform.quantize(jnp.asarray(w), bits=8)
    return qt


@pytest.mark.parametrize("M,K,N", SHAPES)
def test_int8_matmul_vs_ref(M, K, N):
    rng = _rng(M + K + N)
    qt = _mk_int8_weights(rng, K, N)
    x = rng.normal(0, 1, (M, K)).astype(np.float32)
    sa = jnp.float32(np.abs(x).max() / 127.0)
    # the kernel quantizes the float tile in its prologue; the oracle takes
    # the pre-quantized activation — identical rounding by construction
    y_ker = ops.int8_matmul_op(jnp.asarray(x), qt.payload, sa,
                               qt.scale.reshape(-1),
                               qt.zero_point.reshape(-1), interpret=True)
    xq = quantize_act(jnp.asarray(x), sa)
    y_ref = ref.int8_matmul_ref(xq, qt.payload, sa, qt.scale.reshape(-1),
                                qt.zero_point.reshape(-1))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # triangulate vs QTensor serving path
    qt.act_scale = sa
    y_qt = qt.matmul(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_qt),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_int4_matmul_vs_ref(M, K, N, dtype):
    N = N + (N % 2)  # packing needs even N
    rng = _rng(M + K + N + 1)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    qt = QUniform.quantize(jnp.asarray(w), bits=4)
    x = jnp.asarray(rng.normal(0, 1, (M, K)).astype(np.float32), dtype)
    y_ker = ops.int4_matmul_op(x.astype(jnp.float32), qt.payload,
                               qt.scale.reshape(-1),
                               qt.zero_point.reshape(-1), interpret=True)
    y_ref = ref.int4_matmul_ref(x.astype(jnp.float32), qt.payload,
                                qt.scale.reshape(-1),
                                qt.zero_point.reshape(-1))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=2e-5, atol=2e-5)
    y_qt = qt.matmul(x.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_qt),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("M,K,N", SHAPES)
def test_apot_matmul_vs_ref(M, K, N):
    rng = _rng(M * 3 + K + N)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    qt = QAPoT.quantize(jnp.asarray(w))
    x = rng.normal(0, 1, (M, K)).astype(np.float32)
    y_ker = ops.apot_matmul_op(jnp.asarray(x), qt.codes, qt.scale.reshape(-1),
                               interpret=True)
    y_ref = ref.apot_matmul_ref(jnp.asarray(x), qt.codes,
                                qt.scale.reshape(-1))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    y_qt = qt.matmul(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_qt),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("M,K,N", [(128, 128, 128), (64, 96, 200),
                                   (16, 32, 48), (130, 514, 254)])
def test_m2q_matmul_vs_ref_and_qtensor(M, K, N):
    rng = _rng(M + 7 * K + N)
    w = rng.normal(0, 0.05, (K, N)).astype(np.float32)
    asn = select_schemes(jnp.asarray(w), ratio=0.5)
    x = rng.normal(0, 1, (M, K)).astype(np.float32)
    qt = QM2Q.quantize(jnp.asarray(w), asn.apot_idx, asn.uniform_idx,
                       act_max_abs=jnp.float32(np.abs(x).max()))
    y_ker = ops.m2q_matmul_op(
        jnp.asarray(x), qt.act_scale, qt.payload, qt.u_scale.reshape(-1),
        qt.u_zp.reshape(-1), qt.a_scale.reshape(-1), interpret=True)
    y_ref = ref.m2q_merged_ref(
        jnp.asarray(x), qt.act_scale, qt.payload, qt.u_scale.reshape(-1),
        qt.u_zp.reshape(-1), qt.a_scale.reshape(-1))
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # full fused dispatch vs QTensor XLA path (both permutation-free)
    y_full = ops.qtensor_matmul(jnp.asarray(x), qt, interpret=True)
    y_qt = qt.matmul(jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y_full), np.asarray(y_qt),
                               rtol=5e-3, atol=5e-3)


def _legacy_m2q(w, asn, x, act_scale):
    """Pre-refactor oracle: quantize the halves separately, run both engine
    matmuls, CONCATENATE, then inverse-permutation GATHER — the epilogue the
    merged layout deleted."""
    ui = jnp.asarray(asn.uniform_idx, jnp.int32)
    ai = jnp.asarray(asn.apot_idx, jnp.int32)
    inv_perm = jnp.argsort(jnp.concatenate([ui, ai]))
    xq = quantize_act(x, act_scale)
    qu = QUniform.quantize(w[:, ui], bits=8)
    yu = ref.int8_matmul_ref(xq, qu.payload, act_scale,
                             qu.scale.reshape(-1), qu.zero_point.reshape(-1))
    t = apot_quantize(w[:, ai], axis=-1)
    ya = ref.apot_matmul_ref(xq.astype(jnp.float32) * act_scale,
                             apot_encode(t), t.scale.reshape(-1))
    y = jnp.concatenate([yu, ya], axis=-1)
    return jnp.take(y, inv_perm, axis=-1)


@pytest.mark.parametrize("M,K,N", [(32, 64, 48), (16, 96, 130)])
def test_m2q_permutation_free_parity_vs_legacy_and_float(M, K, N):
    """The permutation-free merged path must match (a) the legacy
    concat+gather path bit-for-bit and (b) the float reference to
    quantization tolerance."""
    rng = _rng(11 * M + K + N)
    w = jnp.asarray(rng.normal(0, 0.05, (K, N)).astype(np.float32))
    asn = select_schemes(w, ratio=0.5)
    x = jnp.asarray(rng.normal(0, 1, (M, K)).astype(np.float32))
    amax = jnp.float32(np.abs(np.asarray(x)).max())
    qt = QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx, act_max_abs=amax)

    y_legacy = _legacy_m2q(w, asn, x, qt.act_scale)
    y_merged = qt.matmul(x)
    np.testing.assert_allclose(np.asarray(y_merged), np.asarray(y_legacy),
                               rtol=1e-5, atol=1e-5)
    y_fused = ops.m2q_matmul_op(x, qt.act_scale, qt.payload,
                                qt.u_scale.reshape(-1), qt.u_zp.reshape(-1),
                                qt.a_scale.reshape(-1), interpret=True)
    np.testing.assert_allclose(np.asarray(y_fused), np.asarray(y_legacy),
                               rtol=1e-5, atol=1e-5)
    # float reference: error is quantization-level, not path-level
    y_float = fake_quant_act(x, qt.act_scale) @ qt.dequant()
    rel = float(jnp.linalg.norm(y_merged - y_float)
                / jnp.linalg.norm(y_float))
    assert rel < 5e-3, rel


def test_m2q_hlo_emits_no_gather_or_concat():
    """Acceptance (qlint no-gather-concat rule): zero gather/concatenate
    reachable from the quantized payloads before their contraction, on
    BOTH serving paths (XLA QTensor matmul and the fused Pallas dispatch),
    counting fusion interiors too.  The QTensor is passed as a jit
    ARGUMENT so its payloads are entry parameters the rule can seed from."""
    from repro.analysis import lint
    from repro.analysis.traces import trace_fn
    from repro.launch.hlo_analysis import op_histogram
    rng = _rng(21)
    w = jnp.asarray(rng.normal(0, 0.05, (128, 96)).astype(np.float32))
    asn = select_schemes(w, ratio=0.5)
    qt = QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx,
                       act_max_abs=jnp.float32(3.0))
    x = jnp.zeros((8, 128), jnp.float32)
    for tag, fn in (("xla", lambda q, v: q.matmul(v)),
                    ("fused", lambda q, v: ops.qtensor_matmul(
                        v, q, interpret=True))):
        tr = trace_fn(fn, (qt, x), name=f"m2q/matmul/{tag}",
                      dispatch=False, meta={"quantized": True})
        assert lint(tr, "no-gather-concat") == []
    # the legacy epilogue DOES emit them (guards against a vacuous check;
    # op_histogram, not the rule — the legacy path contracts a FLOAT
    # weight, so there is no quantized entry param for the rule to seed
    # from, which is exactly why the merged layout exists)
    txt = jax.jit(
        lambda v: _legacy_m2q(w, asn, v, jnp.float32(3.0) / 127.0)
    ).lower(x).compile().as_text()
    hist = op_histogram(txt, include_fused=True)
    assert hist.get("gather", 0) >= 1 and hist.get("concatenate", 0) >= 1
    # seeded rule violation: a weight-side permutation gather BEFORE the
    # contraction — the epilogue shape the rule exists to catch
    def permuted(q, v):
        return v @ q.dequant()[jnp.argsort(jnp.argsort(w[:, 0]))]

    trv = trace_fn(permuted, (qt, x), name="m2q/matmul/permuted",
                   dispatch=False, meta={"quantized": True})
    vs = lint(trv, "no-gather-concat")
    assert vs and all(v.rule == "no-gather-concat" for v in vs)


@pytest.mark.parametrize("B,H,W,C", [(2, 8, 8, 32), (1, 14, 14, 64),
                                     (3, 7, 9, 16), (1, 16, 16, 130)])
@pytest.mark.parametrize("kh,kw,stride", [(3, 3, 1), (5, 5, 1), (3, 3, 2),
                                          (5, 5, 2), (3, 5, 1)])
def test_dwconv_w4_vs_ref(B, H, W, C, kh, kw, stride):
    """Generalized window/stride sweep (MBConv 3x3 incl. stride-2 stage
    entries, MSA 5x5 aggregation), triangulated kernel == ref == XLA conv."""
    C = C + (C % 2)
    rng = _rng(B + H + W + C + 7 * kh + stride)
    w = rng.normal(0, 0.2, (kh, kw, C)).astype(np.float32)
    u = uniform_quantize(jnp.asarray(w), bits=4, axis=-1)
    packed = pack_int4(u.q.reshape(kh * kw, C))
    scale = u.scale.reshape(-1)
    zp = u.zero_point.reshape(-1)
    x = rng.normal(0, 1, (B, H, W, C)).astype(np.float32)
    y_ker = ops.dwconv_w4_op(jnp.asarray(x), packed, scale, zp, kh=kh, kw=kw,
                             stride=stride, interpret=True)
    y_ref = ref.dwconv_w4_ref(jnp.asarray(x), packed, scale, zp, kh=kh,
                              kw=kw, stride=stride)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # triangulate against the dequantized-weight XLA conv (SAME semantics)
    wd = ((u.q.astype(np.float32) - np.asarray(u.zero_point))
          * np.asarray(u.scale)).reshape(kh, kw, 1, C)
    y_xla = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(wd), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=C)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_xla),
                               rtol=1e-4, atol=1e-4)


def test_qtensor_matmul_dispatch_uniform4_apot():
    rng = _rng(99)
    w = rng.normal(0, 0.05, (64, 48)).astype(np.float32)
    x = jnp.asarray(rng.normal(0, 1, (3, 5, 64)).astype(np.float32))
    q4 = QUniform.quantize(jnp.asarray(w), bits=4)
    np.testing.assert_allclose(
        np.asarray(ops.qtensor_matmul(x, q4, interpret=True)),
        np.asarray(q4.matmul(x)), rtol=1e-4, atol=1e-4)
    qa = QAPoT.quantize(jnp.asarray(w))
    np.testing.assert_allclose(
        np.asarray(ops.qtensor_matmul(x, qa, interpret=True)),
        np.asarray(qa.matmul(x)), rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# nn.dense kernel dispatch wiring
# ---------------------------------------------------------------------------


def test_dense_routes_qtensors_through_kernels_when_enabled(monkeypatch):
    """With dispatch forced on, the model-facing nn.dense runs the fused
    Pallas path for supported leaves and matches the XLA QTensor path; the
    CPU default leaves dispatch off."""
    from repro import nn
    from repro.core import qmatmul

    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "0")
    assert not ops.dispatch_enabled()  # forced off -> XLA path
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "1")
    assert ops.dispatch_enabled()

    rng = _rng(31)
    w = jnp.asarray(rng.normal(0, 0.05, (64, 48)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (4, 64)).astype(np.float32))
    amax = jnp.float32(np.abs(np.asarray(x)).max())

    asn = select_schemes(w, ratio=0.5)
    qm = QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx, act_max_abs=amax)
    assert ops.kernel_supported(qm)
    np.testing.assert_allclose(np.asarray(nn.dense(x, qm)),
                               np.asarray(qmatmul(x, qm)),
                               rtol=1e-4, atol=1e-4)
    q8 = QUniform.quantize(w, bits=8, act_max_abs=amax)
    assert ops.kernel_supported(q8)
    np.testing.assert_allclose(np.asarray(nn.dense(x, q8)),
                               np.asarray(qmatmul(x, q8)),
                               rtol=1e-4, atol=1e-4)
    # uncalibrated leaves stay on the XLA path (kernel would quantize
    # activations the XLA dequant path does not)
    assert not ops.kernel_supported(QM2Q.quantize(w, asn.apot_idx,
                                                  asn.uniform_idx))
    assert not ops.kernel_supported(QUniform.quantize(w, bits=8))
    # embeddings (axis=0 per-row scales) never dispatch
    assert not ops.kernel_supported(QUniform.quantize(w, bits=8, axis=0))


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


def test_autotune_interpret_falls_back_to_heuristic():
    assert autotune.blocks_for("int8_matmul", 130, 258, 514,
                               interpret=True) == \
        autotune.heuristic_blocks(130, 258, 514)
    # no bench_fn -> heuristic even when "tunable"
    assert autotune.blocks_for("int8_matmul", 128, 128, 128,
                               interpret=False) == (128, 128, 128)


def test_autotune_cache_roundtrip(tmp_path):
    path = str(tmp_path / "tune.json")
    key = autotune.cache_key("k", 1, 2, 3)
    cache = autotune.AutotuneCache(path)
    assert cache.get(key) is None
    cache.put(key, (8, 16, 32))
    reloaded = autotune.AutotuneCache(path).load()
    assert reloaded.get(key) == (8, 16, 32)
    assert len(reloaded) == 1
    # corrupt file degrades to empty, not an exception
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.warns(RuntimeWarning):
        assert autotune.AutotuneCache(path).load().get(key) is None


def test_autotune_cache_key_salts_backend_and_version():
    """The committed-cache contract: a key names kernel version AND
    backend, so caches can never leak block choices across either."""
    k_cpu = autotune.cache_key("int8_matmul", 8, 16, 32, backend="cpu")
    k_tpu = autotune.cache_key("int8_matmul", 8, 16, 32, backend="tpu")
    assert k_cpu != k_tpu
    assert k_cpu == "int8_matmul@v1:8x16x32:cpu"
    # dwconv_w4 was re-gridded (H-tiling) — its salt must be bumped so
    # whole-map-era caches orphan instead of mis-steering the new grid
    assert autotune.KERNEL_VERSIONS["dwconv_w4"] >= 2
    assert "@v2" in autotune.cache_key("dwconv_w4", 8, 16, 32)


def test_autotune_cache_drops_foreign_and_legacy_keys(tmp_path):
    """Old-format (unsalted) and foreign entries are dropped through the
    RuntimeWarning salvage path; valid salted entries survive."""
    import json

    path = str(tmp_path / "tune.json")
    good = autotune.cache_key("k", 1, 2, 3)
    with open(path, "w") as f:
        json.dump({good: [8, 16, 32],
                   "k:1x2x3:cpu": [8, 8, 8],         # legacy unsalted
                   "not a key at all": [8, 8, 8],    # foreign junk
                   autotune.cache_key("k", 9, 9, 9): [8, "x", 8]}, f)
    with pytest.warns(RuntimeWarning, match="3 corrupt"):
        cache = autotune.AutotuneCache(path).load()
    assert cache.get(good) == (8, 16, 32)
    assert len(cache) == 1


def test_autotune_never_benches_inside_a_trace(tmp_path):
    """Benching under jit tracing would 'time' tracer construction and
    poison the persistent cache; inside a trace the tuner must return the
    heuristic (or a warm cache hit) without calling bench_fn."""
    path = str(tmp_path / "tune.json")
    calls = []

    def bench(blocks):
        calls.append(blocks)
        return np.zeros(())

    def traced(x):
        blocks = autotune.blocks_for("fake_traced", 64, 64, 64,
                                     interpret=False, bench_fn=bench,
                                     cache_path=path, force_tune=True)
        assert blocks == autotune.heuristic_blocks(64, 64, 64)
        return x

    jax.jit(traced)(jnp.zeros((2,)))
    assert calls == []
    assert autotune.AutotuneCache(path).load().get(
        autotune.cache_key("fake_traced", 64, 64, 64)) is None


def test_autotune_all_failures_do_not_poison_cache(tmp_path):
    path = str(tmp_path / "tune.json")

    def bench(blocks):
        raise RuntimeError("kernel launch failed")

    best = autotune.blocks_for("fake_broken", 64, 64, 64, interpret=False,
                               bench_fn=bench, cache_path=path,
                               candidates=[(8, 8, 8)], force_tune=True)
    assert best == autotune.heuristic_blocks(64, 64, 64)
    # the untuned fallback must NOT be persisted under the tuned key
    assert autotune.AutotuneCache(path).load().get(
        autotune.cache_key("fake_broken", 64, 64, 64)) is None


def test_autotune_times_candidates_and_persists(tmp_path):
    path = str(tmp_path / "tune.json")
    import time
    calls = []
    cands = [(8, 8, 8), (16, 16, 16), (32, 32, 32)]
    times = {(8, 8, 8): 3.0, (16, 16, 16): 1.0, (32, 32, 32): 2.0}

    def bench(blocks):
        calls.append(blocks)
        time.sleep(times[blocks] / 1000.0)
        return np.zeros(())

    autotune.reset_probe_count()
    best = autotune.blocks_for("fake_kernel", 64, 64, 64, interpret=False,
                               bench_fn=bench, cache_path=path,
                               candidates=cands, force_tune=True)
    assert best == (16, 16, 16)
    assert set(calls) == set(cands)
    assert autotune.tuning_probe_count() == len(cands)
    # second call (no force): served from the persisted cache — no
    # re-benchmarking, no new probes
    calls.clear()
    again = autotune.blocks_for("fake_kernel", 64, 64, 64, interpret=False,
                                bench_fn=bench, cache_path=path,
                                candidates=cands)
    assert again == (16, 16, 16) and calls == []
    assert autotune.tuning_probe_count() == len(cands)
    # and it survives a fresh cache object reading the JSON file
    fresh = autotune.AutotuneCache(path).load()
    assert fresh.get(autotune.cache_key("fake_kernel", 64, 64, 64)) == \
        (16, 16, 16)
