"""qlint rule engine: seeded-violation tests proving every rule fires on
a deliberately broken graph, plus the def-use Graph machinery and the
baseline ledger.  The handcrafted-HLO tests exercise the text-only layer
(no jax trace needed); the jax-traced tests seed real violations through
deliberately wrong lowerings."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import (RULES_BY_NAME, Trace, baseline, lint, run_rules)
from repro.core import QUniform
from repro.launch.hlo_analysis import Graph


def _rng(seed):
    return np.random.default_rng(seed)


# ---------------------------------------------------------------------------
# handcrafted HLO: the text-only rules and the Graph machinery
# ---------------------------------------------------------------------------


_LOOP_HLO = """\
HloModule m

%body (p: (s32[])) -> (s32[]) {{
  %p = (s32[]) parameter(0)
  %g = s32[] get-tuple-element(%p), index=0
  %c1 = s32[] constant(1)
  %a = s32[] add(%g, %c1)
{extra}  ROOT %t = (s32[]) tuple(%a)
}}

%cond (q: (s32[])) -> pred[] {{
  %q = (s32[]) parameter(0)
  %g.1 = s32[] get-tuple-element(%q), index=0
  %c8 = s32[] constant(8)
  ROOT %lt = pred[] compare(%g.1, %c8), direction=LT
}}

ENTRY %main (x: s32[]) -> s32[] {{
  %x = s32[] parameter(0)
  %t0 = (s32[]) tuple(%x)
  %w = (s32[]) while(%t0), condition=%cond, body=%body
  ROOT %out = s32[] get-tuple-element(%w), index=0
}}
"""

_OUTFEED = ("  %tok = token[] after-all()\n"
            "  %of = token[] outfeed(%a, %tok)\n")


def test_no_d2h_in_loop_fires_on_outfeed_in_while_body():
    tr = Trace(name="seeded/outfeed", text=_LOOP_HLO.format(extra=_OUTFEED))
    vs = lint(tr, "no-d2h-in-loop")
    assert [v.rule for v in vs] == ["no-d2h-in-loop"]
    assert "outfeed" in vs[0].message and vs[0].path == "body"
    # the same loop without the transfer is clean
    clean = Trace(name="seeded/clean", text=_LOOP_HLO.format(extra=""))
    assert lint(clean, "no-d2h-in-loop") == []


def test_graph_resolves_loop_carry_tuple_elements():
    g = Graph(_LOOP_HLO.format(extra=""))
    assert g.entry == "main"
    assert g.loop_comps() >= {"body", "cond"}
    # a fresh tuple resolves to its operand ...
    assert g.tuple_element("t0", 0) == ["x"]
    # ... and the while's element 0 resolves BOTH to the init value and to
    # the body root's element (the loop carry), element-precisely
    assert set(g.tuple_element("w", 0)) == {"x", "a"}
    # the entry gte consumes exactly those values (no blanket carry edges)
    assert set(g.redges["out"]) == {"x", "a"}


def test_graph_stitches_fusion_interiors():
    text = """\
HloModule f

%fused (fp0: s8[4,8], fp1: f32[8,2]) -> f32[4,2] {
  %fp0 = s8[4,8] parameter(0)
  %fp1 = f32[8,2] parameter(1)
  %cv = f32[4,8] convert(%fp0)
  ROOT %d = f32[4,2] dot(%cv, %fp1), lhs_contracting_dims={1}
}

ENTRY %main (a: s8[4,8], b: f32[8,2]) -> f32[4,2] {
  %a = s8[4,8] parameter(0)
  %b = f32[8,2] parameter(1)
  ROOT %fu = f32[4,2] fusion(%a, %b), kind=kLoop, calls=%fused
}
"""
    g = Graph(text)
    # caller operand -> callee parameter, callee root -> call result
    assert "fp0" in g.edges["a"]
    assert "fu" in g.edges["d"]
    assert g.dtype_of("cv") == "f32" and g.dtype_of("a") == "s8"


def test_no_dequant_matmul_sees_through_fusions_textually():
    # the fusion interior above IS a dequantized matmul: s8 param ->
    # convert f32 -> dot, inside a fusion
    text = """\
HloModule f

%fused (fp0: s8[4,8], fp1: f32[8,2]) -> f32[4,2] {
  %fp0 = s8[4,8] parameter(0)
  %fp1 = f32[8,2] parameter(1)
  %cv = f32[4,8] convert(%fp0)
  ROOT %d = f32[4,2] dot(%cv, %fp1), lhs_contracting_dims={1}
}

ENTRY %main (a: s8[4,8], b: f32[8,2]) -> f32[4,2] {
  %a = s8[4,8] parameter(0)
  %b = f32[8,2] parameter(1)
  ROOT %fu = f32[4,2] fusion(%a, %b), kind=kLoop, calls=%fused
}
"""
    tr = Trace(name="seeded/fused-dequant", text=text,
               meta={"quantized": True,
                     "param_leaves": [("w/payload", "s8", [4, 8]),
                                      ("x", "f32", [8, 2])]})
    vs = lint(tr, "no-dequant-matmul")
    assert [v.rule for v in vs] == ["no-dequant-matmul"]
    assert "w/payload" in vs[0].message


def test_no_f32_dot_vacuity_guard_fires_without_dots():
    tr = Trace(name="seeded/no-dots", text=_LOOP_HLO.format(extra=""),
               meta={"expect_no_f32_dot": True})
    vs = lint(tr, "no-f32-dot")
    assert len(vs) == 1 and "vacuous" in vs[0].message
    # expect_dots=False waives the vacuity sub-check
    tr.meta["expect_dots"] = False
    assert lint(tr, "no-f32-dot") == []


def test_sharding_conformance_fires_on_spec_drift():
    recs = [{"path": "0/embed/0", "expected": "(None, 'model')",
             "actual": "(None, 'model')"},
            {"path": "0/layers/wq/0", "expected": "(None, 'model')",
             "actual": "()"}]
    tr = Trace(name="seeded/shard", text="HloModule s\n",
               meta={"sharding": recs})
    vs = lint(tr, "sharding-conformance")
    assert [v.path for v in vs] == ["0/layers/wq/0"]
    assert "dist.sharding" in vs[0].message
    # the rule only applies when sharding metadata was recorded
    assert not RULES_BY_NAME["sharding-conformance"].applies({})


def test_suppressions_are_reported_not_dropped():
    text = """\
HloModule g

ENTRY %main (e: s8[16,4], i: s32[2]) -> f32[2,4] {
  %e = s8[16,4] parameter(0)
  %i = s32[2] parameter(1)
  %ga = s8[2,4] gather(%e, %i), offset_dims={1}
  ROOT %cv = f32[2,4] convert(%ga)
}
"""
    tr = Trace(name="seeded/embed-gather", text=text,
               meta={"quantized": True,
                     "param_leaves": [("0/embed/0", "s8", [16, 4]),
                                      ("ids", "s32", [2])]})
    # the default (^|/)embed suppression swallows the embedding gather —
    # run_rules returns it on the suppressed channel, lint drops it
    vs, supp = run_rules(tr, rules=[RULES_BY_NAME["no-gather-concat"]])
    assert vs == [] and [v.path for v in supp] == ["0/embed/0"]
    assert lint(tr, "no-gather-concat") == []
    # a custom suppression channels any rule the same way
    vs2, supp2 = run_rules(
        tr, rules=[RULES_BY_NAME["no-gather-concat"]],
        suppressions={"no-gather-concat": [r"^ids$"]})
    assert vs2 == [] and len(supp2) == 1


def test_lint_rejects_unknown_rule_names():
    tr = Trace(name="x", text="HloModule x\n")
    with pytest.raises(KeyError):
        lint(tr, "no-such-rule")


def test_trace_param_alignment_survives_dropped_and_sharded_leaves():
    text = """\
HloModule a

ENTRY %main (p0: s8[2,8], p1: f32[8]) -> f32[8] {
  %p0 = s8[2,8] parameter(0)
  %p1 = f32[8] parameter(1)
  %cv = f32[2,8] convert(%p0)
  %rd = f32[8] reduce(%cv, %p1), dimensions={0}, to_apply=%main
  ROOT %o = f32[8] add(%rd, %p1)
}
"""
    # leaf 'dropped' was optimized out of the executable; param 0 is the
    # PER-PARTITION shard [2,8] of the global [4,8] payload
    tr = Trace(name="align", text=text,
               meta={"param_leaves": [("dropped", "f32", [3]),
                                      ("w/payload", "s8", [4, 8]),
                                      ("bias", "f32", [8])]})
    assert tr.param_path(0) == "w/payload"
    assert tr.param_path(1) == "bias"


# ---------------------------------------------------------------------------
# jax-traced seeds: dequant matmul and (un)guarded activation quantization
# ---------------------------------------------------------------------------


def test_no_dequant_matmul_fires_on_traced_dequant_contraction():
    from repro.analysis.traces import trace_fn
    w = jnp.asarray(_rng(3).normal(0, 0.1, (32, 16)).astype(np.float32))
    qt = QUniform.quantize(w, bits=8)
    x = jnp.zeros((4, 32), jnp.float32)

    def broken(q, v):  # decode the payload to f32 and contract at f32
        return v @ q.dequant()

    tr = trace_fn(broken, (qt, x), name="seeded/dequant-matmul",
                  dispatch=False, meta={"quantized": True})
    vs = lint(tr, "no-dequant-matmul")
    assert vs and all(v.rule == "no-dequant-matmul" for v in vs)
    # the CALIBRATED integer path is clean: int8 x int8 -> s32, with the
    # accumulator rescaled to f32 only AFTER the dot.  (A weights-only
    # QTensor dequantizes by design — that is what the rwkv baseline
    # entry records — so the clean case needs an act_scale.)
    qt_cal = QUniform.quantize(w, bits=8, act_max_abs=jnp.float32(3.0))
    tr_ok = trace_fn(lambda q, v: q.matmul(v), (qt_cal, x),
                     name="seeded/int-matmul", dispatch=False,
                     meta={"quantized": True})
    assert lint(tr_ok, "no-dequant-matmul") == []


def test_unguarded_act_quant_distinguishes_guarded_converts():
    from repro.analysis.traces import trace_fn
    x = jnp.zeros((8, 16), jnp.float32)
    s = jnp.float32(0.05)

    def unguarded(v):
        return jnp.clip(jnp.round(v / s), -127, 127).astype(jnp.int8)

    def guarded(v):
        v = jnp.where(jnp.isfinite(v), v, 0.0)
        return jnp.clip(jnp.round(v / s), -127, 127).astype(jnp.int8)

    tr = trace_fn(unguarded, (x,), name="seeded/unguarded",
                  dispatch=False, meta={"quantized": True})
    vs = lint(tr, "unguarded-act-quant")
    assert vs and vs[0].severity == "warn"
    # the is-finite select upstream of the convert silences the warning —
    # proving the rule is non-vacuous in BOTH directions
    tr_ok = trace_fn(guarded, (x,), name="seeded/guarded",
                     dispatch=False, meta={"quantized": True})
    assert lint(tr_ok, "unguarded-act-quant") == []


# ---------------------------------------------------------------------------
# baseline ledger: diff semantics and persistence
# ---------------------------------------------------------------------------


def _viol(trace, rule, path, n=1):
    from repro.analysis import Violation
    return [Violation(rule=rule, severity="error", trace=trace, path=path,
                      message="m")] * n


def test_baseline_diff_flags_new_and_grown_only():
    old = _viol("t/a", "no-f32-dot", "", 1) + _viol("t/a", "conv-budget",
                                                    "w", 2)
    cur = (_viol("t/a", "no-f32-dot", "", 1)          # unchanged
           + _viol("t/a", "conv-budget", "w", 3)      # grew 2 -> 3
           + _viol("t/b", "no-d2h-in-loop", "body"))  # new
    regress = baseline.diff(baseline.to_ledger(cur), baseline.to_ledger(old))
    assert any("GREW" in r and "conv-budget" in r for r in regress)
    assert any("NEW" in r and "t/b" in r for r in regress)
    assert not any("no-f32-dot" in r for r in regress)
    # shrinking / disappearing entries are improvements, not regressions:
    # the current run is a superset of the baseline, so nothing is GONE
    assert baseline.improvements(baseline.to_ledger(cur),
                                 baseline.to_ledger(old)) == []
    gone = baseline.improvements(baseline.to_ledger([]),
                                 baseline.to_ledger(old))
    assert len(gone) == 2 and all("GONE" in g for g in gone)


def test_baseline_save_load_roundtrip_and_version_gate(tmp_path):
    led = baseline.to_ledger(_viol("t/a", "no-f32-dot", "", 2))
    p = tmp_path / "base.json"
    baseline.save(p, led)
    assert baseline.load(p) == led
    blob = json.loads(p.read_text())
    blob["version"] = 999
    p.write_text(json.dumps(blob))
    with pytest.raises(ValueError, match="version"):
        baseline.load(p)


def test_committed_baseline_is_loadable_and_canonical():
    """The checked-in ledger parses, and re-saving it is byte-identical
    (sorted keys, stable formatting) so diffs stay reviewable."""
    from pathlib import Path
    p = Path(__file__).resolve().parents[1] / "results/qlint_baseline.json"
    led = baseline.load(p)
    assert led, "committed baseline is empty — regenerate with " \
                "--update-baseline"
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        q = Path(d) / "b.json"
        baseline.save(q, led)
        assert q.read_text() == p.read_text()


def test_qlint_cli_fail_on_gone(tmp_path, monkeypatch, capsys):
    """ISSUE 8 satellite: ``--fail-on-gone`` turns stale ledger rows into
    a CI failure (the ratchet must be re-tightened with
    --update-baseline), while a ledger the run still reproduces stays
    green with the flag on.  Traces/rules are monkeypatched — this tests
    the CLI contract, not the (slow) HLO sweep."""
    from repro.launch import qlint as Q

    class FakeTrace:
        name = "t/a"

    monkeypatch.setattr(Q, "build_traces",
                        lambda configs, sharded=True, **kw: [FakeTrace()])
    p = tmp_path / "base.json"

    # run with one real violation -> write the ledger via the CLI
    monkeypatch.setattr(Q, "run_rules",
                        lambda tr: (_viol("t/a", "no-f32-dot", ""), []))
    assert Q.main(["--baseline", str(p), "--update-baseline"]) == 0
    # the run still reproduces the ledger: clean either way
    assert Q.main(["--baseline", str(p)]) == 0
    assert Q.main(["--baseline", str(p), "--fail-on-gone"]) == 0
    # the violation disappears: advisory by default, FAIL under the flag
    monkeypatch.setattr(Q, "run_rules", lambda tr: ([], []))
    assert Q.main(["--baseline", str(p)]) == 0
    assert Q.main(["--baseline", str(p), "--fail-on-gone"]) == 1
    assert "re-tighten" in capsys.readouterr().err
    # a NEW violation still beats the gone-check (exit 1 either way)
    monkeypatch.setattr(Q, "run_rules",
                        lambda tr: (_viol("t/a", "conv-budget", "w"), []))
    assert Q.main(["--baseline", str(p), "--fail-on-gone"]) == 1


def test_registry_trace_names_and_rule_expectations():
    """One real registry sweep entry end-to-end (the cheapest vision
    config): trace names are stable keys and the m2q forward carries the
    documented by-design violations — exactly what the committed baseline
    records, nothing more."""
    from repro.analysis.traces import registry_traces
    traces = registry_traces("efficientvit-b1-r224", recipes=("m2q-w8a8",))
    assert [t.name for t in traces] == [
        "efficientvit-b1-r224/m2q/forward",
        "efficientvit-b1-r224/m2q/forward-r384",
        "efficientvit-b1-r224/m2q/forward-r512",
    ]
    vs = lint(traces[0])
    by_rule = {}
    for v in vs:
        by_rule.setdefault(v.rule, []).append(v.path)
    # packed-w4 depthwise (3x3 w_dw + 5x5 w_agg): nibble-unpack concats
    # + one in-kernel dequant conv
    assert set(by_rule) == {"no-gather-concat", "no-dequant-matmul",
                            "unguarded-act-quant"}
    assert all("w_dw" in p or "w_agg" in p
               for p in by_rule["no-gather-concat"])


def test_forward_jax_roundtrip_matches_graph_dtypes():
    """trace_fn records param_leaves that align against the compiled
    entry: quantized payload leaves are found as s8 entry params."""
    from repro.analysis.traces import trace_fn
    w = jnp.asarray(_rng(11).normal(0, 0.1, (16, 8)).astype(np.float32))
    qt = QUniform.quantize(w, bits=8)
    x = jnp.zeros((2, 16), jnp.float32)
    tr = trace_fn(lambda q, v: q.matmul(v), (qt, x), name="align/jax",
                  dispatch=False)
    g = tr.graph
    pay = [i for i, p in enumerate(g.entry_params())
           if p and g.dtype_of(p) == "s8"]
    assert pay, "int8 payload did not survive as an entry parameter"
    # QTensor children flatten positionally, so the payload attributes to
    # the qtensor argument (tuple slot 0), the activation to slot 1
    assert all(tr.param_path(i).startswith("0/") for i in pay)
    f32_acts = [i for i, p in enumerate(g.entry_params())
                if p and g.dtype_of(p) == "f32"
                and tr.param_path(i) == "1"]
    assert f32_acts, "activation arg did not attribute to tuple slot 1"
