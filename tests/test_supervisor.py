"""Supervised serving (ISSUE 10): the write-ahead request journal, the
crash/hang fault kinds, the daemon's crash-recording surface, and the
Supervisor's detect -> teardown -> backoff -> restart -> replay cycle.

Layering mirrors the daemon tests: pure-unit layers (journal on tmp
files, spec parsing, backoff math, no engine) first, then wall-clock
layers driving real reduced token engines under injected uncontained
faults, and finally a slow subprocess test that SIGKILLs a serving
process and proves the journal replays it to exact completion.

Engine factories in the wall-clock tests warm every jit shape the
workload drives BEFORE arming the injector (``eng.faults = ...``): the
engines jit per instance, so a rebuilt engine's cold first step can run
seconds — long enough to masquerade as a hung step if the watchdog
threshold had to stay tight.
"""
import json
import os
import signal
import subprocess
import sys
import threading
import time
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.models import get_model
from repro.serving.daemon import ServingDaemon
from repro.serving.errors import (CircuitOpenError, EngineCrashError,
                                  HungStepError)
from repro.serving.faults import (FaultAction, FaultInjector, FaultSpec,
                                  InjectedFault, UncontainedCrash)
from repro.serving.journal import RequestJournal
from repro.serving.scheduler import DONE, TIMED_OUT
from repro.serving.supervisor import RestartPolicy, Supervisor


@pytest.fixture(scope="module")
def lm():
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(lm, **kw):
    from repro.serving.engine import Engine
    cfg, params = lm
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    return Engine(cfg, params, **kw)


def _prompts(n, start_len=4):
    return [np.arange(1, start_len + 1 + i, dtype=np.int32)
            for i in range(n)]


def _warmed_factory(lm, prompts, max_new, arm=None, builds=None,
                    arm_every=False):
    """Factory building engines pre-warmed on the workload's shapes;
    ``arm`` (a fault-spec string) is attached AFTER warmup, to the first
    build only unless ``arm_every``."""
    builds = builds if builds is not None else []

    def factory():
        eng = _engine(lm)
        for p in prompts:
            eng.submit(p, max_new_tokens=max_new)
        eng.run()
        if arm is not None and (arm_every or not builds):
            eng.faults = FaultInjector([FaultSpec.parse(arm)])
        builds.append(eng)
        return eng

    return factory


def _reference(lm, prompts, max_new):
    eng = _engine(lm)
    reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run()
    return [r.handle.result() for r in reqs]


_FAST = RestartPolicy(hang_threshold_s=5.0, backoff_base_s=0.01,
                      poll_interval_s=0.02)


# ---------------------------------------------------------------------------
# RequestJournal: unit layer (tmp files, no engine)
# ---------------------------------------------------------------------------

def test_journal_submit_terminal_pending_reconcile(tmp_path):
    j = RequestJournal(tmp_path / "j.jsonl")
    assert j.record_submit("a", [1, 2], slo="interactive",
                           kw={"max_new_tokens": 4})
    assert j.record_submit("b", [3])
    # duplicate while outstanding: idempotent no-op
    assert not j.record_submit("a", [1, 2])
    assert [r["rid"] for r in j.pending()] == ["a", "b"]
    assert j.reconcile() == {"submitted": 2, "terminal": 0, "pending": 2,
                             "exact": False, "torn_records": 0}
    assert j.record_terminal("a", DONE)
    assert not j.record_terminal("a", "FAILED")   # exactly one terminal
    assert not j.record_terminal("ghost", DONE)   # never submitted
    assert j.terminal_state("a") == DONE
    assert j.terminal_state("b") is None
    assert [r["rid"] for r in j.pending()] == ["b"]
    j.record_terminal("b", TIMED_OUT, error="deadline")
    rec = j.reconcile()
    assert rec["exact"] and rec["pending"] == 0
    j.close()
    # reopen resumes the same state from disk
    j2 = RequestJournal(tmp_path / "j.jsonl")
    assert j2.reconcile()["exact"] and not j2.pending()
    assert j2.terminal_state("b") == TIMED_OUT
    j2.close()


def test_journal_truncates_torn_tail(tmp_path):
    p = tmp_path / "j.jsonl"
    with RequestJournal(p) as j:
        j.record_submit("a", [1])
        j.record_submit("b", [2])
    with open(p, "a") as f:  # crash mid-append: no trailing newline
        f.write('{"e": "terminal", "rid": "a", "st')
    with pytest.warns(RuntimeWarning, match="torn tail"):
        j2 = RequestJournal(p)
    assert j2.torn_records == 1
    # the torn terminal never happened: both rids still pending, and the
    # next append starts on a clean record boundary
    assert [r["rid"] for r in j2.pending()] == ["a", "b"]
    j2.record_terminal("a", DONE)
    j2.close()
    lines = p.read_text().splitlines()
    assert all(json.loads(ln)["rid"] in ("a", "b") for ln in lines)


def test_journal_rotate_drops_terminals_keeps_live(tmp_path):
    p = tmp_path / "j.jsonl"
    j = RequestJournal(p)
    for i in range(4):
        j.record_submit(f"r{i}", [i])
    j.record_terminal("r0", DONE)
    j.record_terminal("r2", "FAILED", error="boom")
    dropped = j.rotate()
    assert dropped == 4  # 2 terminated submits + their 2 terminal events
    assert [r["rid"] for r in j.pending()] == ["r1", "r3"]
    # still appendable after rotation, and the on-disk file is compacted
    j.record_terminal("r1", DONE)
    j.close()
    rids = [json.loads(ln)["rid"] for ln in p.read_text().splitlines()]
    assert rids == ["r1", "r3", "r1"]
    j2 = RequestJournal(p)
    assert [r["rid"] for r in j2.pending()] == ["r3"]
    j2.close()


def test_journal_fsync_policies_and_lag(tmp_path):
    with pytest.raises(ValueError, match="fsync policy"):
        RequestJournal(tmp_path / "x.jsonl", fsync="sometimes")
    j = RequestJournal(tmp_path / "b.jsonl", fsync="batch")
    j.record_submit("a", [1])
    j.record_submit("b", [2])
    assert j.lag() == 2  # appended, flushed, not yet fsync'd
    j.rotate()
    assert j.lag() == 0
    j.close()
    ja = RequestJournal(tmp_path / "a.jsonl", fsync="always")
    ja.record_submit("a", [1])
    assert ja.lag() == 0
    ja.close()


def test_journal_resubmit_after_terminal_is_new_lifecycle(tmp_path):
    p = tmp_path / "j.jsonl"
    j = RequestJournal(p)
    j.record_submit("a", [1])
    j.record_terminal("a", "FAILED", error="transient")
    assert j.record_submit("a", [1])  # terminal rid: resubmission allowed
    assert [r["rid"] for r in j.pending()] == ["a"]
    j.close()
    j2 = RequestJournal(p)  # the scan agrees with the live view
    assert [r["rid"] for r in j2.pending()] == ["a"]
    j2.close()


# ---------------------------------------------------------------------------
# Fault kinds + policy math (unit)
# ---------------------------------------------------------------------------

def test_fault_spec_hang_and_crash_parse_and_fire():
    hang = FaultSpec.parse("hang@decode:2")
    assert hang.kind == "hang" and hang.delay_ms == 30_000.0
    assert FaultSpec.parse("hang@decode:2:150").delay_ms == 150.0
    crash = FaultSpec.parse("crash@decode:1")
    assert crash.kind == "crash"
    inj = FaultInjector([crash])
    act = inj.on_call("decode")
    with pytest.raises(UncontainedCrash):
        act.fire()
    # UncontainedCrash must NOT be containable by `except Exception`
    assert not issubclass(UncontainedCrash, Exception)
    assert issubclass(InjectedFault, Exception)


def test_fault_hang_blocks_until_released():
    inj = FaultInjector([FaultSpec.parse("hang@decode:1:10000")])
    act = inj.on_call("decode")
    assert isinstance(act, FaultAction) and act.hang_ms == 10000.0
    done = threading.Event()

    def worker():
        act.fire()  # blocks on the injector's latch
        done.set()

    th = threading.Thread(target=worker, daemon=True)
    th.start()
    assert not done.wait(0.15)  # genuinely stuck
    inj.release_hangs()
    assert done.wait(2.0)       # released long before the 10s timeout
    th.join()


def test_restart_policy_backoff_deterministic_and_bounded():
    p = RestartPolicy(backoff_base_s=0.1, backoff_max_s=1.0, jitter=0.25,
                      seed=7)
    delays = [p.backoff(k) for k in range(8)]
    assert delays == [p.backoff(k) for k in range(8)]  # deterministic
    for k, d in enumerate(delays):
        base = min(1.0, 0.1 * 2 ** k)
        assert base * 0.75 <= d <= base * 1.25
    assert RestartPolicy(seed=8).backoff(0) != RestartPolicy(seed=9).backoff(0)
    with pytest.raises(ValueError):
        RestartPolicy(hang_threshold_s=0.0)
    with pytest.raises(ValueError):
        RestartPolicy(jitter=1.5)
    with pytest.raises(ValueError):
        RestartPolicy(max_restarts=0)


# ---------------------------------------------------------------------------
# Uncontained faults through the engine + daemon crash surface
# ---------------------------------------------------------------------------

def test_uncontained_crash_escapes_engine_step_containment(lm):
    eng = _engine(lm)
    eng.faults = FaultInjector([FaultSpec.parse("crash@decode:1")])
    eng.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
    with pytest.raises(UncontainedCrash):  # per-batch containment is
        for _ in range(20):                # `except Exception` — this
            eng.step()                     # sails straight through
    # whereas a contained fault only fails its own request
    eng2 = _engine(lm)
    eng2.faults = FaultInjector([FaultSpec.parse("raise@decode:1")])
    r = eng2.submit(np.arange(1, 5, dtype=np.int32), max_new_tokens=4)
    eng2.run()
    with pytest.raises(InjectedFault):
        r.handle.result()


def test_daemon_records_crash_and_abort_returns_leftovers(lm):
    eng = _engine(lm)
    eng.faults = FaultInjector([FaultSpec.parse("crash@decode:1")])
    daemon = ServingDaemon(eng).start()
    req = daemon.submit(np.arange(1, 5, dtype=np.int32),
                        max_new_tokens=4)
    deadline = time.monotonic() + 30
    while daemon.crashed is None and time.monotonic() < deadline:
        time.sleep(0.01)
    assert isinstance(daemon.crashed, UncontainedCrash)
    assert not daemon.running
    # the dead daemon rejects new work with a clear error
    with pytest.raises(RuntimeError, match="crashed"):
        daemon.submit(np.arange(1, 4, dtype=np.int32))
    # the in-flight handle was NOT resolved by the crash (that is the
    # supervisor's call: fail it or replay it)
    assert not req.handle.done()
    leftovers = daemon.abort()
    assert req.handle in leftovers
    for h in leftovers:
        h.set_exception(EngineCrashError("torn down"))
    with pytest.raises(EngineCrashError):
        req.handle.result()
    daemon.shutdown()  # idempotent on an aborted daemon


# ---------------------------------------------------------------------------
# Supervisor: recovery end to end (wall clock, real engines)
# ---------------------------------------------------------------------------

def test_supervisor_crash_recovery_replays_to_identical_results(lm, tmp_path):
    max_new = 5
    prompts = _prompts(3)
    expected = _reference(lm, prompts, max_new)
    builds = []
    sup = Supervisor(
        _warmed_factory(lm, prompts, max_new, arm="crash@decode:2",
                        builds=builds),
        journal=RequestJournal(tmp_path / "j.jsonl"), policy=_FAST)
    sup.start()
    handles = [sup.submit(p, request_id=f"r{i}", max_new_tokens=max_new)
               for i, p in enumerate(prompts)]
    outs = [h.result(timeout=60) for h in handles]
    assert sup.restarts == 1 and len(builds) == 2
    assert sup.restart_log[0]["reason"] == "EngineCrashError"
    assert sup.last_recovery_s is not None and sup.last_recovery_s > 0
    # deterministic greedy decode: replayed results are IDENTICAL to an
    # uninterrupted run
    assert all(list(a) == list(b) for a, b in zip(outs, expected))
    rec = sup.journal.reconcile()
    assert rec["exact"] and rec["submitted"] == 3
    assert sup.ready()["ready"]
    sup.shutdown()
    # reconciliation invariant extends across restarts: every journaled
    # submit has exactly one journaled terminal
    with RequestJournal(tmp_path / "j.jsonl") as j2:
        assert j2.reconcile()["exact"] and not j2.pending()


def test_supervisor_hang_watchdog_detects_and_recovers(lm):
    max_new = 5
    prompts = _prompts(2)
    expected = _reference(lm, prompts, max_new)
    policy = RestartPolicy(hang_threshold_s=0.5, backoff_base_s=0.01,
                           poll_interval_s=0.05)
    sup = Supervisor(
        _warmed_factory(lm, prompts, max_new, arm="hang@decode:2"),
        policy=policy)
    sup.start()
    handles = [sup.submit(p, max_new_tokens=max_new) for p in prompts]
    outs = [h.result(timeout=60) for h in handles]
    assert sup.restarts == 1
    assert sup.restart_log[0]["reason"] == "HungStepError"
    assert all(list(a) == list(b) for a, b in zip(outs, expected))
    sup.shutdown()


def test_supervisor_streaming_dedup_across_restart(lm):
    """A streaming client sees each token EXACTLY once even though the
    replayed attempt re-decodes the whole sequence."""
    max_new = 6
    prompts = _prompts(1)
    expected = _reference(lm, prompts, max_new)
    streamed = []
    sup = Supervisor(
        _warmed_factory(lm, prompts, max_new, arm="crash@decode:3"),
        policy=_FAST)
    sup.start()
    h = sup.submit(prompts[0], max_new_tokens=max_new,
                   on_token=streamed.append)
    out = h.result(timeout=60)
    assert sup.restarts == 1
    assert list(out) == list(expected[0])
    assert streamed == list(out)  # no duplicated replayed tokens
    sup.shutdown()


def test_supervisor_circuit_breaker_opens_after_restart_budget(lm):
    max_new = 3
    prompts = _prompts(2)
    policy = RestartPolicy(hang_threshold_s=5.0, backoff_base_s=0.005,
                           poll_interval_s=0.02, max_restarts=2,
                           restart_window_s=300.0)
    # EVERY build is armed: the daemon can never serve the workload, so
    # restarts burn through the budget and the breaker must open
    sup = Supervisor(
        _warmed_factory(lm, prompts, max_new, arm="crash@decode:1",
                        arm_every=True),
        policy=policy)
    sup.start()
    handles = [sup.submit(p, max_new_tokens=max_new) for p in prompts]
    for h in handles:
        with pytest.raises(CircuitOpenError):
            h.result(timeout=60)
    assert sup.restarts == policy.max_restarts + 1
    assert sup.ready() == {"ready": False, "reason": "circuit_open"}
    with pytest.raises(CircuitOpenError):  # NOT_READY rejects new work
        sup.submit(prompts[0], max_new_tokens=max_new)
    health = sup.health()
    assert health["state"] == "not_ready"
    sup.shutdown()


def test_supervisor_cold_start_replays_journal(lm, tmp_path):
    """start() adopts a dead process's journal: non-terminal entries are
    resubmitted (original order), already-expired deadlines resolve
    TIMED_OUT without re-running."""
    max_new = 4
    prompts = _prompts(3)
    expected = _reference(lm, prompts, max_new)
    jpath = tmp_path / "j.jsonl"
    with RequestJournal(jpath) as j:  # what the dead process left behind
        j.record_submit("done-before", [1, 2, 3],
                        kw={"max_new_tokens": max_new})
        j.record_terminal("done-before", DONE)
        for i, p in enumerate(prompts):
            j.record_submit(f"lost-{i}", p.tolist(),
                            kw={"max_new_tokens": max_new})
        j.record_submit("expired", prompts[0].tolist(),
                        kw={"max_new_tokens": max_new},
                        deadline_unix=time.time() - 5.0)
    sup = Supervisor(_warmed_factory(lm, prompts, max_new),
                     journal=RequestJournal(jpath), policy=_FAST)
    sup.start()
    handles = sup.handles()
    assert set(handles) == {f"lost-{i}" for i in range(3)} | {"expired"}
    assert sup.replayed == 4
    with pytest.raises(TimeoutError):
        handles["expired"].result(timeout=10)
    assert handles["expired"].state == TIMED_OUT
    for i in range(3):
        out = handles[f"lost-{i}"].result(timeout=60)
        assert list(out) == list(expected[i])
    sup.shutdown()
    with RequestJournal(jpath) as j2:
        assert j2.reconcile()["exact"]
        assert j2.terminal_state("expired") == TIMED_OUT


def test_supervisor_duplicate_request_id_is_idempotent(lm, tmp_path):
    max_new = 3
    prompts = _prompts(1)
    sup = Supervisor(_warmed_factory(lm, prompts, max_new),
                     journal=RequestJournal(tmp_path / "j.jsonl"),
                     policy=_FAST)
    sup.start()
    h1 = sup.submit(prompts[0], request_id="same", max_new_tokens=max_new)
    h2 = sup.submit(prompts[0], request_id="same", max_new_tokens=max_new)
    assert h1 is h2  # one outstanding lifecycle per rid
    h1.result(timeout=60)
    rec = sup.journal.reconcile()
    assert rec["submitted"] == 1 and rec["exact"]
    # after the terminal, the same rid may start a NEW lifecycle
    h3 = sup.submit(prompts[0], request_id="same", max_new_tokens=max_new)
    assert h3 is not h1
    h3.result(timeout=60)
    sup.shutdown()
    assert sup.stats.submitted == 2 == sup.stats.resolved


def test_supervisor_health_and_ready_surface(lm, tmp_path):
    max_new = 3
    prompts = _prompts(1)
    sup = Supervisor(_warmed_factory(lm, prompts, max_new),
                     journal=RequestJournal(tmp_path / "j.jsonl",
                                            fsync="batch"),
                     policy=_FAST)
    assert sup.ready() == {"ready": False, "reason": "stopped"}
    sup.start()
    h = sup.submit(prompts[0], request_id="hc", max_new_tokens=max_new)
    h.result(timeout=60)
    health = sup.health()
    assert health["state"] == "running" and health["ready"]["ready"]
    assert health["restarts"] == 0 and health["crashed"] is None
    assert health["supervised_outstanding"] == 0
    assert health["daemon_outstanding"] == 0 and health["queue_depth"] == 0
    assert health["heartbeat_age_s"] is None or \
        health["heartbeat_age_s"] >= 0
    assert health["journal"]["pending"] == 0
    assert health["journal"]["fsync"] == "batch"
    assert "axes" in health["trip_latches"]
    assert "guard" in health["trip_latches"]
    assert health["stats"]["submitted"] == 1
    json.dumps(health)  # the probe snapshot must be JSON-serializable
    sup.shutdown()
    assert sup.ready()["ready"] is False


# ---------------------------------------------------------------------------
# Process-level kill: journal replay across a REAL restart (slow)
# ---------------------------------------------------------------------------

_PHASE1 = """
import os, signal, sys, time
import numpy as np
from repro.configs.registry import REDUCED
from repro.models import get_model
from repro.serving.engine import Engine
from repro.serving.journal import RequestJournal
from repro.serving.supervisor import Supervisor, RestartPolicy
import jax

jpath = sys.argv[1]
cfg = REDUCED["qwen1.5-0.5b"]
params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
def factory():
    return Engine(cfg, params, max_batch=2, max_len=64)
sup = Supervisor(factory, journal=RequestJournal(jpath))
sup.start()
prompts = [np.arange(1, 5 + i, dtype=np.int32) for i in range(4)]
hs = [sup.submit(p, request_id=f"req-{i}", max_new_tokens=5)
      for i, p in enumerate(prompts)]
hs[0].result(timeout=120)  # at least one completes pre-kill
print("PHASE1-READY", flush=True)
os.kill(os.getpid(), signal.SIGKILL)  # hard death: no shutdown, no drain
"""

_PHASE2 = """
import sys, time, json
import numpy as np
from repro.configs.registry import REDUCED
from repro.models import get_model
from repro.serving.engine import Engine
from repro.serving.journal import RequestJournal
from repro.serving.supervisor import Supervisor
import jax

jpath = sys.argv[1]
cfg = REDUCED["qwen1.5-0.5b"]
params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
def factory():
    return Engine(cfg, params, max_batch=2, max_len=64)
sup = Supervisor(factory, journal=RequestJournal(jpath))
sup.start()  # cold-start replay from the journal
results = {rid: list(int(t) for t in h.result(timeout=120))
           for rid, h in sup.handles().items()}
rec = sup.journal.reconcile()
sup.shutdown()
print("PHASE2-RESULT " + json.dumps(
    {"results": results, "reconcile": rec, "replayed": sup.replayed}),
    flush=True)
"""


@pytest.mark.slow
def test_journal_replays_across_process_kill(lm, tmp_path):
    """SIGKILL a serving process mid-flight; a fresh process opening the
    same journal replays the lost requests to exact completion."""
    env = {**os.environ, "PYTHONPATH": "src",
           "XLA_FLAGS": "--xla_force_host_platform_device_count=1"}
    jpath = str(tmp_path / "journal.jsonl")
    p1 = subprocess.run([sys.executable, "-c", _PHASE1, jpath], env=env,
                        cwd=Path(__file__).resolve().parent.parent,
                        capture_output=True, text=True, timeout=600)
    assert "PHASE1-READY" in p1.stdout, (p1.stdout, p1.stderr)
    assert p1.returncode == -signal.SIGKILL
    with RequestJournal(jpath) as j:
        rec = j.reconcile()
        assert rec["submitted"] == 4 and rec["pending"] >= 1
    p2 = subprocess.run([sys.executable, "-c", _PHASE2, jpath], env=env,
                        cwd=Path(__file__).resolve().parent.parent,
                        capture_output=True, text=True, timeout=600)
    assert p2.returncode == 0, (p2.stdout, p2.stderr)
    line = [ln for ln in p2.stdout.splitlines()
            if ln.startswith("PHASE2-RESULT ")][0]
    payload = json.loads(line.split(" ", 1)[1])
    assert payload["reconcile"]["exact"]
    assert payload["replayed"] == len(payload["results"]) >= 1
    # replayed results are identical to an uninterrupted greedy decode
    prompts = [np.arange(1, 5 + i, dtype=np.int32) for i in range(4)]
    expected = _reference(lm, prompts, 5)
    for rid, out in payload["results"].items():
        i = int(rid.split("-")[1])
        assert out == [int(t) for t in expected[i]], rid
    # and the journal on disk closes the loop: every submit terminal
    with RequestJournal(jpath) as j:
        assert j.reconcile()["exact"] and not j.pending()
