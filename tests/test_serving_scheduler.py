"""The shared scheduler core behind both serving engines: deadline-based
flushing, handle-delivered results, unified ServeStats, and the token
engine's scheduler-driven admission."""
import jax
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.models import get_model
from repro.serving.batching import ServeStats, pow2_bucket
from repro.serving.scheduler import (FLUSH_DEADLINE, FLUSH_DRAIN, FLUSH_FULL,
                                     FlushPolicy, Scheduler)


class FakeClock:
    """Virtual seconds: tests drive deadlines without sleeping."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1000.0


# ---------------------------------------------------------------------------
# batching primitives
# ---------------------------------------------------------------------------


def test_pow2_bucket():
    assert [pow2_bucket(n) for n in (0, 1, 2, 3, 5, 8, 9)] == \
        [1, 1, 2, 4, 8, 8, 16]
    assert pow2_bucket(3, min_bucket=4) == 4      # sharded floor
    assert pow2_bucket(9, cap=8) == 8             # max_batch cap
    assert pow2_bucket(5, min_bucket=4, cap=32) == 8
    with pytest.raises(ValueError):
        pow2_bucket(-1)


def test_servestats_percentiles_occupancy_padding():
    s = ServeStats()
    assert s.p50_ms == 0.0 and s.batch_occupancy == 0.0
    s.queue_ms.extend(float(v) for v in range(1, 101))  # 1..100 ms
    assert s.latency_ms(50) == pytest.approx(50.0, abs=1.0)
    assert s.p99_ms == pytest.approx(99.0, abs=1.0)
    s.record_batch(items=6, padded=2, capacity=8, bucket=8)
    s.record_batch(items=2, padded=2, capacity=8, bucket=4)
    assert s.batch_occupancy == pytest.approx(8 / 16)
    assert s.padded_fraction == pytest.approx(4 / 12)
    assert s.buckets_used == {4, 8}
    s.record_flush("deadline")
    s.record_flush("deadline")
    assert s.flush_reasons == {"deadline": 2}
    s.reset()
    assert s.queue_ms == [] and s.batches == 0 and s.buckets_used == set()
    assert s.flush_reasons == {}


# ---------------------------------------------------------------------------
# scheduler core (dummy executor)
# ---------------------------------------------------------------------------


def _echo_executor(record):
    def run(handles, reason):
        record.append((reason, [h.payload for h in handles]))
        for h in handles:
            h.set_result(h.payload * 10)
    return run


def test_scheduler_flush_policy_reasons():
    clk = FakeClock()
    ran = []
    sched = Scheduler(policy=FlushPolicy(max_batch=3, max_delay_ms=50.0),
                      executor=_echo_executor(ran), clock=clk)
    h1 = sched.submit(1)
    h2 = sched.submit(2)
    assert sched.due() is None and not ran          # 2 < max_batch, young
    sched.poll()
    assert not ran and not h1.done()
    with pytest.raises(RuntimeError, match="no result yet"):
        h1.result()
    clk.advance_ms(49)
    assert sched.due() is None
    clk.advance_ms(2)                                # oldest age > 50 ms
    assert sched.due() == FLUSH_DEADLINE
    assert sched.poll() == 2
    assert ran == [(FLUSH_DEADLINE, [1, 2])]
    assert h1.result() == 10 and h2.result() == 20
    # a full batch executes inline on submit, no poll needed
    hs = [sched.submit(v) for v in (3, 4, 5)]
    assert ran[-1] == (FLUSH_FULL, [3, 4, 5])
    assert [h.result() for h in hs] == [30, 40, 50]
    assert sched.stats.flush_reasons == {FLUSH_DEADLINE: 1, FLUSH_FULL: 1}


def test_scheduler_drain_and_fifo_order():
    clk = FakeClock()
    ran = []
    sched = Scheduler(policy=FlushPolicy(max_batch=4, max_delay_ms=None),
                      executor=_echo_executor(ran), clock=clk)
    handles = [sched.submit(v) for v in range(6)]   # 6 > max_batch: one
    assert ran == [(FLUSH_FULL, [0, 1, 2, 3])]      # full flush fired inline
    flushed = sched.drain()
    assert [h.payload for h in flushed] == [4, 5]   # submit order
    assert ran[-1] == (FLUSH_DRAIN, [4, 5])
    assert all(h.done() for h in handles)
    assert sched.pending == 0
    assert sched.drain() == []                      # idle drain is a no-op
    # max_delay_ms=None never deadline-flushes
    sched.submit(99)
    clk.advance_ms(1e9)
    assert sched.due() is None


def test_scheduler_next_deadline_and_latency_recording():
    clk = FakeClock()
    sched = Scheduler(policy=FlushPolicy(max_batch=8, max_delay_ms=10.0),
                      clock=clk)
    assert sched.next_deadline() is None
    clk.t = 1.0
    h = sched.submit("x")
    assert sched.next_deadline() == pytest.approx(1.010)
    clk.advance_ms(25)
    sched.pop([h], FLUSH_DEADLINE)
    assert sched.stats.queue_ms[0] == pytest.approx(25.0)
    assert sched.pending == 0


# ---------------------------------------------------------------------------
# vision engine on the scheduler
# ---------------------------------------------------------------------------


def _vision_setup(max_batch=8, max_delay_ms=None, clock=None):
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    from repro.serving.vision import VisionEngine
    kw = {} if clock is None else {"clock": clock}
    eng = VisionEngine(cfg, params, max_batch=max_batch,
                       max_delay_ms=max_delay_ms, **kw)
    return cfg, model, params, eng


def test_vision_deadline_flush_executes_without_explicit_flush():
    """ISSUE 4 acceptance: a sub-max_batch batch executes once max_delay_ms
    elapses — no flush() call anywhere."""
    clk = FakeClock()
    cfg, model, params, eng = _vision_setup(max_batch=8, max_delay_ms=15.0,
                                            clock=clk)
    rng = np.random.default_rng(0)
    imgs = rng.normal(0, 1, (3, cfg.img_res, cfg.img_res, 3)).astype(
        np.float32)
    handles = [eng.submit(im) for im in imgs]
    assert eng.poll() == 0 and not any(h.done() for h in handles)
    clk.advance_ms(14)
    assert eng.poll() == 0                           # not due yet
    clk.advance_ms(2)                                # oldest age > 15 ms
    assert eng.poll() == 3
    assert all(h.done() for h in handles)
    ref = np.asarray(model.forward(cfg, params, np.asarray(imgs)))
    got = np.stack([h.result() for h in handles])
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)
    assert eng.stats.flush_reasons == {"deadline": 1}
    assert eng.stats.buckets_used == {4}             # 3 -> pow2 bucket 4
    assert eng.stats.p99_ms >= 15.0


def test_vision_full_batch_flushes_inline_on_submit():
    clk = FakeClock()
    cfg, model, params, eng = _vision_setup(max_batch=2, max_delay_ms=1e6,
                                            clock=clk)
    rng = np.random.default_rng(1)
    imgs = rng.normal(0, 1, (2, cfg.img_res, cfg.img_res, 3)).astype(
        np.float32)
    h1 = eng.submit(imgs[0])
    assert not h1.done()
    h2 = eng.submit(imgs[1])                         # fills the batch
    assert h1.done() and h2.done()                   # executed inline
    assert eng.stats.flush_reasons == {"full": 1}
    ref = np.asarray(model.forward(cfg, params, np.asarray(imgs)))
    np.testing.assert_allclose(np.stack([h1.result(), h2.result()]), ref,
                               rtol=1e-4, atol=1e-4)


def test_vision_flush_drains_in_submit_order():
    cfg, model, params, eng = _vision_setup(max_batch=8)
    rng = np.random.default_rng(2)
    imgs = rng.normal(0, 1, (3, cfg.img_res, cfg.img_res, 3)).astype(
        np.float32)
    handles = [eng.submit(im) for im in imgs]
    out = eng.flush()
    ref = np.asarray(model.forward(cfg, params, np.asarray(imgs)))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.stack([h.result() for h in handles]), ref,
                               rtol=1e-4, atol=1e-4)
    assert eng.flush() is None
    assert eng.stats.flush_reasons == {"drain": 1}


# ---------------------------------------------------------------------------
# token engine admission on the scheduler
# ---------------------------------------------------------------------------


def _token_engine(max_batch=3, max_delay_ms=0.0, clock=None):
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    from repro.serving.engine import Engine
    kw = {} if clock is None else {"clock": clock}
    return cfg, Engine(cfg, params, max_batch=max_batch, max_len=64,
                       max_delay_ms=max_delay_ms, **kw)


def test_engine_rejects_max_new_tokens_below_one():
    """ISSUE 4 satellite: max_new_tokens=0 used to burn a prefill+sample
    and retire with empty output; now it is rejected up front."""
    cfg, eng = _token_engine()
    for bad in (0, -3):
        with pytest.raises(ValueError, match="max_new_tokens must be >= 1"):
            eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=bad)
    assert eng.scheduler.pending == 0                # nothing half-enqueued
    req = eng.submit(np.arange(4, dtype=np.int32), max_new_tokens=1)
    eng.run()
    assert req.done and len(req.out_tokens) == 1


def test_engine_admission_deadline_coalesces_prefills():
    """max_delay_ms > 0 holds admission until the deadline (or a full
    batch), so two staggered arrivals share ONE prefill batch."""
    clk = FakeClock()
    cfg, eng = _token_engine(max_batch=3, max_delay_ms=50.0, clock=clk)
    rng = np.random.default_rng(0)
    r1 = eng.submit(rng.integers(0, cfg.vocab_size, 5, dtype=np.int32),
                    max_new_tokens=2)
    clk.advance_ms(5)
    r2 = eng.submit(rng.integers(0, cfg.vocab_size, 7, dtype=np.int32),
                    max_new_tokens=2)
    assert eng.step() == 0                           # young queue: no admit
    assert eng.stats.prefill_batches == 0 and len(eng.queue) == 2
    clk.advance_ms(50)                               # oldest over deadline
    assert eng.step() == 2                           # both admitted together
    assert eng.stats.prefill_batches == 1
    assert eng.stats.flush_reasons == {"deadline": 1}
    eng.run()
    assert r1.done and r2.done
    # queue latency was recorded on the virtual clock at admission
    assert sorted(round(q) for q in eng.stats.queue_ms) == [50, 55]


def test_engine_full_batch_admits_before_deadline():
    clk = FakeClock()
    cfg, eng = _token_engine(max_batch=2, max_delay_ms=1e6, clock=clk)
    rng = np.random.default_rng(1)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 5, dtype=np.int32),
                       max_new_tokens=2) for _ in range(2)]
    assert eng.step() == 2                           # full: admits at once
    assert eng.stats.flush_reasons == {"full": 1}
    eng.run()
    assert all(r.done for r in reqs)


def test_engine_request_handle_resolves_on_completion():
    cfg, eng = _token_engine(max_batch=2)
    req = eng.submit(np.arange(5, dtype=np.int32), max_new_tokens=3)
    assert req.handle is not None and not req.handle.done()
    eng.run()
    assert req.handle.done()
    assert req.handle.result() == req.out_tokens
    assert len(req.out_tokens) == 3
    # unified stats: queue latency recorded, prefill occupancy tracked
    assert len(eng.stats.queue_ms) == 1
    assert 0 < eng.stats.batch_occupancy <= 1.0
