"""Elastic launcher (ISSUE 10 satellite): process-level kill -> restart
-> EXACT resume from the latest published checkpoint, driven through
``launch.elastic.run_supervised`` with real ``launch.train`` subprocesses.

Deterministic failure injection (``--crash-at-step`` hard-kills via
``os._exit`` so the final sync save never runs; ``--stop-at-step`` exits
rc==0 early) replaces wall-clock SIGTERM timing, so each scenario
reproduces exactly.  Exact resume is proven from the metrics JSONL: the
file appends across runs and ``--log-every 1`` logs every step, so the
steps both runs executed appear twice — with IDENTICAL losses iff the
restarted worker restored the exact (params, opt_state, data-cursor)
state the dead one had published.
"""
import json
from collections import defaultdict
from pathlib import Path

import pytest

from repro.ckpt.checkpoint import latest_step
from repro.launch.elastic import run_supervised

_REPO = Path(__file__).resolve().parent.parent
_ARCH, _STEPS, _EVERY = "qwen1.5-0.5b", 12, 3


@pytest.fixture(autouse=True)
def _subprocess_env(monkeypatch):
    # the worker subprocess needs the same import path / host-device
    # setup the test process got from test.sh
    monkeypatch.setenv("PYTHONPATH", str(_REPO / "src"))
    monkeypatch.setenv("XLA_FLAGS", "--xla_force_host_platform_device_count=1")
    monkeypatch.chdir(_REPO)


def _losses_by_step(metrics):
    by_step = defaultdict(list)
    for line in Path(metrics).read_text().splitlines():
        rec = json.loads(line)
        by_step[rec["step"]].append(rec["loss"])
    return by_step


@pytest.mark.slow
def test_crash_restart_resumes_exactly(tmp_path):
    """Hard-kill (os._exit — the finally-block save never runs) after
    step 7: the launcher restarts, the worker resumes from the step-6
    async checkpoint, and the overlap steps replay IDENTICALLY."""
    ckpt_dir = str(tmp_path / "ckpt")
    metrics = str(tmp_path / "metrics.jsonl")
    restarts = run_supervised(
        _ARCH, _STEPS, ckpt_dir, metrics, batch=2, seq=16,
        ckpt_every=_EVERY, log_every=1, crash_at_step=7, max_restarts=2)
    assert restarts == 1
    # the final step's checkpoint is PUBLISHED (completion criterion)
    assert latest_step(ckpt_dir) == _STEPS - 1
    by_step = _losses_by_step(metrics)
    # every step of the schedule was trained (and logged) at least once
    assert sorted(by_step) == list(range(_STEPS))
    # crash at 7, latest published async ckpt at 6 -> resume starts at 7:
    # step 7 ran in BOTH processes, steps 8.. only in the second
    assert len(by_step[7]) == 2 and len(by_step[8]) == 1
    # EXACT resume: the replayed step consumed the same data from the
    # same restored (params, opt_state) -> bitwise-equal loss
    for step, losses in by_step.items():
        assert len(set(losses)) == 1, (step, losses)


@pytest.mark.slow
def test_clean_but_incomplete_exit_counts_as_restart(tmp_path, capfd):
    """A worker that exits rc==0 WITHOUT publishing the final step (an
    early ``--stop-at-step`` exit, i.e. a preemption save) is not
    completion: the launcher counts it as a restart, logs it, and the
    resumed worker finishes the schedule."""
    ckpt_dir = str(tmp_path / "ckpt")
    metrics = str(tmp_path / "metrics.jsonl")
    restarts = run_supervised(
        _ARCH, _STEPS, ckpt_dir, metrics, batch=2, seq=16,
        ckpt_every=_EVERY, log_every=1, stop_at_step=4, max_restarts=2)
    out = capfd.readouterr().out
    assert restarts == 1
    assert latest_step(ckpt_dir) == _STEPS - 1
    assert "[train] clean early exit at step 4" in out
    assert "exited cleanly (rc=0)" in out and "counted restart #1" in out
    # the stop-step save published step 4 -> the resumed run starts at 5
    assert "[train] resumed from step 4" in out
    by_step = _losses_by_step(metrics)
    assert sorted(by_step) == list(range(_STEPS))
    assert len(by_step[4]) == 1 and len(by_step[5]) == 1
    for step, losses in by_step.items():
        assert len(set(losses)) == 1, (step, losses)
