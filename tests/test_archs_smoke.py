"""Per-architecture smoke tests: REDUCED config of each assigned family runs
one forward + one train (grad) step + a decode step on CPU, asserting output
shapes and finiteness.  Full-size configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ASSIGNED, REDUCED
from repro.models import get_model

B, S = 2, 16


def _inputs(cfg):
    rng = np.random.default_rng(0)
    kw = {}
    if cfg.family == "whisper":
        kw["frames"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_audio_ctx, cfg.d_model)).astype("float32"))
    elif cfg.n_patches:
        kw["prefix_embeds"] = jnp.asarray(
            rng.normal(0, 1, (B, cfg.n_patches, cfg.d_model)).astype("float32"))
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)).astype("int32"))
    return toks, kw


@pytest.fixture(scope="module")
def arch_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = REDUCED[name]
            params = get_model(cfg).init(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("name", ASSIGNED)
def test_forward_shapes_finite(name, arch_state):
    cfg, params = arch_state(name)
    model = get_model(cfg)
    toks, kw = _inputs(cfg)
    logits = model.forward(cfg, params, toks, **kw)
    total = S + (cfg.n_patches if cfg.n_patches else 0)
    assert logits.shape == (B, total, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ASSIGNED)
def test_train_step_grads_finite(name, arch_state):
    cfg, params = arch_state(name)
    model = get_model(cfg)
    toks, kw = _inputs(cfg)

    def loss_fn(p):
        logits = model.forward(cfg, p, toks, **kw)
        lp = jax.nn.log_softmax(logits[:, : S - 1].astype(jnp.float32))
        tgt = toks[:, 1:]
        nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)
        return jnp.mean(nll)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(g))) for g in leaves)


@pytest.mark.parametrize("name", ASSIGNED)
def test_decode_matches_forward(name, arch_state):
    """prefill + decode_step agree with teacher-forced forward logits."""
    cfg, params = arch_state(name)
    model = get_model(cfg)
    toks, kw = _inputs(cfg)
    if cfg.n_patches:  # VLM prefix changes positions; decode covered elsewhere
        kw = {}
    cache = model.init_cache(cfg, B, 32, dtype=jnp.float32)
    lg_pre, cache = model.prefill(cfg, params, cache, toks, **kw)
    l1, cache = model.decode_step(cfg, params, cache, toks[:, :1])
    assert l1.shape == (B, 1, cfg.padded_vocab)
    full = model.forward(cfg, params, jnp.concatenate([toks, toks[:, :1]], 1), **kw)
    np.testing.assert_allclose(np.asarray(lg_pre[:, -1]), np.asarray(full[:, S - 1]),
                               atol=2e-2, rtol=2e-2)
    np.testing.assert_allclose(np.asarray(l1[:, 0]), np.asarray(full[:, S]),
                               atol=2e-2, rtol=2e-2)


def test_efficientvit_forward_and_grad():
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    imgs = jnp.asarray(np.random.default_rng(0).normal(
        0, 1, (2, cfg.img_res, cfg.img_res, 3)).astype("float32"))
    logits = model.forward(cfg, params, imgs)
    assert logits.shape == (2, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
    labels = jnp.array([1, 2])

    def loss_fn(p):
        lg = model.forward(cfg, p, imgs).astype(jnp.float32)
        return jnp.mean(-jax.nn.log_softmax(lg)[jnp.arange(2), labels])

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))


def test_efficientvit_b2_forward():
    cfg = REDUCED["efficientvit-b2-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    imgs = jnp.zeros((1, cfg.img_res, cfg.img_res, 3), jnp.float32)
    logits = model.forward(cfg, params, imgs)
    assert logits.shape == (1, cfg.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))
