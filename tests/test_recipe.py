"""The one-call quantization API: QuantRecipe -> QuantizedModel artifact.

Covers: preset equivalence with the legacy hand-wired quantize_model path
(efficientvit-b1 + one LM arch), the artifact lifecycle (quantize -> save
-> load -> HLO-identical forward, reusing the test_conv_dispatch HLO
assertions), the apot_ratio=None (Eq. 6 argmin) abstract-twin contract,
the stored-width weight_bits regression for sub-byte sweep configs, scoped
DispatchConfig resolution, and the repo-hygiene check on tracked bytecode.
"""
import dataclasses
import subprocess
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.core import (M2QPolicy, PathOverride, QM2Q, QUniform, ShapeCtx,
                        quantize_model, weight_bits)
from repro.core.calibrate import (rule_matcher, run_calibration,
                                  wrap_for_calibration)
from repro.kernels import ops
from repro.models import get_model
from repro.recipe import (PRESETS, CalibSpec, QuantizedModel, abstract_quantize,
                          quantize)

_REPO_ROOT = Path(__file__).resolve().parent.parent


def _rng(seed=0):
    return np.random.default_rng(seed)


def _evit_setup(batch=2):
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    imgs = jnp.asarray(_rng(0).normal(
        0, 1, (batch, cfg.img_res, cfg.img_res, 3)).astype(np.float32))
    return cfg, model, params, imgs


def _trees_identical(a, b):
    assert jax.tree_util.tree_structure(a) == jax.tree_util.tree_structure(b)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


# ---------------------------------------------------------------------------
# preset equivalence with the legacy hand-wired path
# ---------------------------------------------------------------------------


def test_preset_matches_legacy_wiring_efficientvit():
    """'m2q-w8a8' on efficientvit-b1 == the old wrap/calibrate/ShapeCtx/
    intensity_threshold=1.0 incantation, leaf for leaf (bitwise)."""
    cfg, model, params, imgs = _evit_setup()
    # legacy wiring (what examples/quantize_efficientvit.py used to do)
    wrapped, stats = wrap_for_calibration(params,
                                          rule_matcher(model.QUANT_RULES))
    run_calibration(lambda p, x: model.forward(cfg, p, x), wrapped, [imgs])
    ctx = ShapeCtx(tokens_per_step=imgs.shape[0] * cfg.img_res * cfg.img_res)
    legacy_qp, legacy_report = quantize_model(
        params, model.QUANT_RULES, ctx, M2QPolicy(intensity_threshold=1.0),
        act_stats=stats)
    # one-call API
    qm = quantize(cfg, params, "m2q-w8a8", calib_batches=[imgs])
    _trees_identical(qm.params, legacy_qp)
    assert [(r.path, r.decision, r.bits) for r in qm.report] == \
        [(r.path, r.decision, r.bits) for r in legacy_report]
    assert qm.provenance["calib_sites"] == len(stats)


def test_preset_matches_legacy_wiring_lm():
    """'m2q-w8a8' on a reduced LM == the old launch.serve wiring (random-
    prompt calibration + intensity_threshold=0.5 + FFN fold groups)."""
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(_rng(1).integers(0, cfg.vocab_size, (2, 32),
                                        dtype=np.int32))
    wrapped, stats = wrap_for_calibration(params,
                                          rule_matcher(model.QUANT_RULES))
    model.forward(cfg, wrapped, toks, unroll=True)
    ctx = ShapeCtx(tokens_per_step=2, moe_top_k=max(cfg.moe_top_k, 1),
                   moe_num_experts=max(cfg.moe_experts, 1))
    legacy_qp, _ = quantize_model(
        params, model.QUANT_RULES, ctx, M2QPolicy(intensity_threshold=0.5),
        act_stats=stats, ffn_groups=model.FFN_FOLD_GROUPS)
    qm = quantize(cfg, params, "m2q-w8a8", calib_batches=[toks])
    _trees_identical(qm.params, legacy_qp)
    # the perm-folded FFN groups went through the recipe resolver
    assert any(r.decision == "mixed(perm-folded)" for r in qm.report)


def test_w4_weights_only_preset():
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    qm = quantize(cfg, params, "w4-weights-only")
    assert qm.provenance["calib_batches"] == 0  # no calibration pass
    qleaves = [l for l in jax.tree.leaves(
        qm.params, is_leaf=lambda x: isinstance(x, QUniform))
        if isinstance(l, QUniform)]
    assert qleaves and all(q.bits == 4 and q.act_scale is None
                           for q in qleaves)
    assert all(r.decision == "lowbit" for r in qm.report)


def test_path_override_validates_fields():
    with pytest.raises(ValueError, match="decision"):
        PathOverride(decision="mxied")
    with pytest.raises(ValueError, match="scheme"):
        PathOverride(scheme="unifrom8")  # would diverge concrete vs abstract
    with pytest.raises(ValueError, match="bits"):
        PathOverride(bits=9)  # would wrap in the uint8 byte payload
    with pytest.raises(ValueError, match="bits"):
        PathOverride(bits=2)


def test_effective_tokens_per_step_pinned_in_artifact():
    """The deployment shape inferred from real calibration batches is baked
    into the artifact's recipe, so load()'s abstract twin re-derives the
    SAME decisions (CalibSpec.batch_size may differ from the real data)."""
    cfg, model, params, imgs = _evit_setup(batch=8)  # != CalibSpec default 2
    qm = quantize(cfg, params, "m2q-w8a8", calib_batches=[imgs])
    expect = 8 * cfg.img_res * cfg.img_res
    assert qm.provenance["tokens_per_step"] == expect
    assert qm.recipe.tokens_per_step == expect
    assert qm.recipe.resolve(cfg).shape_ctx.tokens_per_step == expect


def test_fold_group_member_override_drops_whole_group(tmp_path):
    """An override diverging ONE member of a perm-fold group (here: the
    swiglu gate w3 forced lowbit) must drop the WHOLE group to ordinary
    per-leaf quantization on both the concrete and abstract paths — and
    must NOT let the gateless fallback pattern fold w1/w2 without w3
    (misaligned elementwise product).  The saved artifact stays loadable."""
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(_rng(4).integers(0, cfg.vocab_size, (2, 16),
                                        dtype=np.int32))
    rec = PRESETS["m2q-w8a8"].replace(
        overrides=((r"layers/mlp/w3$", PathOverride(decision="lowbit")),))
    qm = quantize(cfg, params, rec, calib_batches=[toks])
    by_path = {r.path: r for r in qm.report}
    assert by_path["layers/mlp/w3"].decision == "lowbit"  # override honored
    assert by_path["layers/mlp/w1"].decision == "mixed"   # NOT perm-folded
    assert not any(r.decision == "mixed(perm-folded)" for r in qm.report)
    qm.save(tmp_path / "ov")
    qm2 = QuantizedModel.load(tmp_path / "ov")
    _trees_identical(qm.params, qm2.params)
    np.testing.assert_array_equal(np.asarray(qm.forward(toks)),
                                  np.asarray(qm2.forward(toks)))


def test_override_rejects_mixed_embedding():
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    rec = PRESETS["m2q-w8a8"].replace(
        policy=M2QPolicy(quantize_activations=False),
        overrides=((r"embed", PathOverride(decision="mixed")),))
    with pytest.raises(ValueError, match="embedding"):
        quantize(cfg, params, rec)


# ---------------------------------------------------------------------------
# artifact lifecycle: quantize -> save -> load -> HLO-identical forward
# ---------------------------------------------------------------------------


def _forward_trace(cfg, model, qp, imgs, conv_budget):
    """Compiled-forward qlint Trace (ambient dispatch scope applies: the
    callers scope ops.dispatch around this)."""
    from repro.analysis.traces import trace_fn
    return trace_fn(lambda p, x: model.forward(cfg, p, x), (qp, imgs),
                    name="evit/artifact/forward", dispatch=None,
                    meta={"conv_budget": conv_budget})


def _op_histogram(trace):
    from repro.launch.hlo_analysis import op_histogram
    return op_histogram(trace.text, include_fused=True)


def test_artifact_save_load_hlo_identical(tmp_path, monkeypatch):
    """load() rebuilds the tree through the abstract twin (no PTQ re-run);
    the restored forward compiles to the same op mix as the fresh one and
    keeps the M2Q hot-path invariants: with dispatch scoped ON the only
    convolution is the unquantized stem, and there are no gathers/concats
    from the (deleted) permutation epilogue."""
    cfg, model, params, imgs = _evit_setup()
    qm = quantize(cfg, params, "m2q-w8a8", calib_batches=[imgs])
    qm.save(tmp_path / "art")
    qm2 = QuantizedModel.load(tmp_path / "art")
    # bitwise-identical tree, same treedef (incl. n_uniform/n_apot aux)
    _trees_identical(qm.params, qm2.params)
    assert qm2.recipe == qm.recipe
    assert [r.path for r in qm2.report] == [r.path for r in qm.report]
    # numerics: fresh vs restored forward agree bitwise
    y1 = np.asarray(qm.forward(imgs))
    y2 = np.asarray(qm2.forward(imgs))
    np.testing.assert_array_equal(y1, y2)
    # HLO: identical op histograms + the qlint conv-budget invariant
    from repro.analysis import lint
    with ops.dispatch(dense=True, conv=True):
        t1 = _forward_trace(cfg, model, qm.params, imgs, conv_budget=1)
        t2 = _forward_trace(cfg, model, qm2.params, imgs, conv_budget=1)
    assert _op_histogram(t1) == _op_histogram(t2)
    assert lint(t1, "conv-budget") == []  # only the unquantized stem
    with ops.dispatch(dense=False, conv=False):
        # PWConvs STILL lower to quantized matmuls with dispatch off; only
        # the stem + the 7 weights-only depthwise fallbacks convolve
        t1 = _forward_trace(cfg, model, qm.params, imgs, conv_budget=1 + 7)
        t2 = _forward_trace(cfg, model, qm2.params, imgs, conv_budget=1 + 7)
    assert _op_histogram(t1) == _op_histogram(t2)
    assert lint(t1, "conv-budget") == [] and lint(t2, "conv-budget") == []


def test_artifact_roundtrip_lm(tmp_path):
    """Same lifecycle on a token LM: perm-folded FFN groups, stacked scan
    leaves, and the quantized embedding all survive save -> load bitwise."""
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(_rng(1).integers(0, cfg.vocab_size, (2, 16),
                                        dtype=np.int32))
    qm = quantize(cfg, params, "m2q-w8a8", calib_batches=[toks])
    qm.save(tmp_path / "lm")
    qm2 = QuantizedModel.load(tmp_path / "lm")
    _trees_identical(qm.params, qm2.params)
    np.testing.assert_array_equal(np.asarray(qm.forward(toks)),
                                  np.asarray(qm2.forward(toks)))


def test_artifact_roundtrip_moe(tmp_path):
    """MoE regression: stacked-expert (L,E,K,N) leaves carry per-layer
    act_scale broadcast over ALL trailing axes — the concrete reshape used
    to emit (L,1,1) against the abstract twin's (L,1,1,1) template, making
    every saved MoE artifact unloadable."""
    cfg = REDUCED["llama4-scout-17b-a16e"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    qm = quantize(cfg, params, "m2q-w8a8")  # synthesized calibration
    qm.save(tmp_path / "moe")
    qm2 = QuantizedModel.load(tmp_path / "moe")
    _trees_identical(qm.params, qm2.params)
    toks = jnp.asarray(_rng(2).integers(0, cfg.vocab_size, (2, 8),
                                        dtype=np.int32))
    np.testing.assert_array_equal(np.asarray(qm.forward(toks)),
                                  np.asarray(qm2.forward(toks)))


def test_artifact_serve_picks_modality(tmp_path):
    from repro.serving.engine import Engine
    from repro.serving.vision import VisionEngine
    cfg, model, params, imgs = _evit_setup()
    qm = quantize(cfg, params, "m2q-w8a8", calib_batches=[imgs])
    eng = qm.serve(max_batch=4, dispatch=ops.DispatchConfig(dense=False))
    assert isinstance(eng, VisionEngine)
    logits = eng.classify(np.asarray(imgs))
    np.testing.assert_allclose(logits, np.asarray(qm.forward(imgs)),
                               rtol=1e-5, atol=1e-5)

    lm_cfg = REDUCED["qwen1.5-0.5b"]
    lm = get_model(lm_cfg)
    lm_params = lm.init(lm_cfg, jax.random.PRNGKey(0))
    qlm = quantize(lm_cfg, lm_params, "w4-weights-only")
    teng = qlm.serve(max_batch=2, max_len=32)
    assert isinstance(teng, Engine)
    req = teng.submit(np.arange(4, dtype=np.int32), max_new_tokens=2)
    teng.run()
    assert req.done and len(req.out_tokens) == 2


# ---------------------------------------------------------------------------
# apot_ratio=None (Eq. 6 argmin): data-dependent splits carried by the
# artifact, rejected by the shape-only twin
# ---------------------------------------------------------------------------


def test_abstract_twin_rejects_ratio_none():
    rec = PRESETS["m2q-w8a8"].replace(policy=M2QPolicy(apot_ratio=None))
    with pytest.raises(ValueError, match="apot_ratio=None"):
        abstract_quantize(REDUCED["efficientvit-b1-r224"], recipe=rec,
                          tokens_per_step=64)


def test_ratio_none_artifact_roundtrip(tmp_path):
    """ratio=None quantizes data-dependently; the saved LayerReports carry
    (n_uniform, n_apot), so load() rebuilds the EXACT treedef (the old
    silent 1:1 assumption is gone)."""
    cfg, model, params, imgs = _evit_setup()
    rec = PRESETS["m2q-w8a8"].replace(policy=M2QPolicy(apot_ratio=None))
    qm = quantize(cfg, params, rec, calib_batches=[imgs])
    splits = {r.path: (r.n_uniform, r.n_apot) for r in qm.report
              if r.decision.startswith("mixed")}
    # the argmin split really is data-dependent (not always the 1:1 floor)
    assert any(nu != na and nu + na > 0 for nu, na in splits.values())
    qm.save(tmp_path / "art")
    qm2 = QuantizedModel.load(tmp_path / "art")
    _trees_identical(qm.params, qm2.params)
    np.testing.assert_array_equal(np.asarray(qm.forward(imgs)),
                                  np.asarray(qm2.forward(imgs)))


# ---------------------------------------------------------------------------
# weight_bits: stored width, not nominal width (sub-byte sweep regression)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits,expected", [(3, 8.0), (4, 4.0), (5, 8.0),
                                           (6, 8.0), (7, 8.0), (8, 8.0)])
def test_weight_bits_reports_stored_width(bits, expected):
    w = jnp.asarray(_rng(bits).normal(0, 0.05, (32, 16)).astype(np.float32))
    qt = QUniform.quantize(w, bits=bits)
    assert weight_bits(qt) == expected
    # and the payload layout really is what the report claims: one byte per
    # weight except the nibble-packed 4-bit case
    expect_cols = 16 // 2 if bits == 4 else 16
    assert qt.payload.shape == (32, expect_cols)


# ---------------------------------------------------------------------------
# scoped dispatch config
# ---------------------------------------------------------------------------


def test_dispatch_config_scoping(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_DISPATCH", raising=False)
    monkeypatch.delenv("REPRO_PALLAS_CONV_DISPATCH", raising=False)
    assert not ops.dispatch_enabled()  # CPU backend default
    with ops.dispatch(dense=True):
        assert ops.dispatch_enabled()
        assert ops.conv_dispatch_enabled()  # conv follows dense
        with ops.dispatch(conv=False):      # nested: conv off, dense kept
            assert ops.dispatch_enabled()
            assert not ops.conv_dispatch_enabled()
        assert ops.conv_dispatch_enabled()
    assert not ops.dispatch_enabled()
    # explicit kwargs layer over a config passed positionally
    with ops.dispatch(ops.DispatchConfig(dense=True, conv=True), conv=False):
        assert ops.dispatch_enabled()
        assert not ops.conv_dispatch_enabled()


def test_dispatch_scope_overrides_env(monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "1")
    monkeypatch.delenv("REPRO_PALLAS_CONV_DISPATCH", raising=False)
    assert ops.dispatch_enabled()
    with ops.dispatch(dense=False):  # programmatic scope beats process env
        assert not ops.dispatch_enabled()
        assert not ops.conv_dispatch_enabled()
    monkeypatch.setenv("REPRO_PALLAS_CONV_DISPATCH", "0")
    assert ops.dispatch_enabled() and not ops.conv_dispatch_enabled()
    with ops.dispatch(conv=True):
        assert ops.conv_dispatch_enabled()


def test_dispatch_scope_steers_real_matmul(monkeypatch):
    """The scoped config and the env var drive the SAME nn.dense routing."""
    from repro import nn
    monkeypatch.delenv("REPRO_PALLAS_DISPATCH", raising=False)
    rng = _rng(3)
    w = jnp.asarray(rng.normal(0, 0.05, (64, 32)).astype(np.float32))
    x = jnp.asarray(rng.normal(0, 1, (4, 64)).astype(np.float32))
    qt = QM2Q.quantize(w, *_select(w), act_max_abs=jnp.max(jnp.abs(x)))
    y_xla = nn.dense(x, qt)
    with ops.dispatch(dense=True):
        y_ker = nn.dense(x, qt)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_xla),
                               rtol=1e-4, atol=1e-4)


def _select(w):
    from repro.core import select_schemes
    asn = select_schemes(w, ratio=0.5)
    return asn.apot_idx, asn.uniform_idx


# ---------------------------------------------------------------------------
# repo hygiene: no tracked bytecode, ignore rules present
# ---------------------------------------------------------------------------


def test_no_tracked_bytecode_or_pycache():
    out = subprocess.run(["git", "ls-files"], capture_output=True, text=True,
                         cwd=_REPO_ROOT)
    if out.returncode != 0:  # not a git checkout (e.g. sdist)
        pytest.skip("git unavailable")
    bad = [p for p in out.stdout.splitlines()
           if "__pycache__" in p or p.endswith((".pyc", ".pyo"))]
    assert not bad, f"tracked bytecode files: {bad}"
    gi = (_REPO_ROOT / ".gitignore").read_text()
    for pattern in ("__pycache__/", "*.pyc", "autotune.json"):
        assert pattern in gi, f".gitignore missing {pattern!r}"
