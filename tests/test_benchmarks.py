"""Benchmark-level assertions: the paper's trends must reproduce, the
accelerator simulator must match the paper's published numbers, and the
EfficientViT layer inventory must match the paper's GFLOPs."""
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import accel_sim as A


def test_efficientvit_inventory_matches_paper_gflops():
    """Paper Table V: EfficientViT-B1-R224 = 0.52 GFLOPs (=0.26 GMACs)."""
    layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS["b1-r224"])
    gmacs = sum(l.macs for l in layers) / 1e9
    assert 0.26 * 0.7 <= gmacs <= 0.26 * 2.2, gmacs


def test_simulator_predicts_table3_unfit_points():
    """Fit one point (Trio B1-R224=26.06uJ); the other 7 cells of Table III
    must be predicted within 10%."""
    A.set_calibration()
    paper = {
        ("b1-r256", "trio"): 34.03, ("b1-r288", "trio"): 43.07,
        ("b2-r224", "trio"): 80.58,
        ("b1-r224", "m2q"): 17.85, ("b1-r256", "m2q"): 23.31,
        ("b1-r288", "m2q"): 29.50, ("b2-r224", "m2q"): 55.64,
    }
    for (model, method), ref in paper.items():
        layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS[model])
        sim = A.simulate(layers, method)
        assert abs(sim.energy_uj - ref) / ref < 0.10, (model, method,
                                                       sim.energy_uj, ref)


def test_simulator_reproduces_headline_claims():
    """Paper abstract: ~31.5% comp-energy saving; ~80% EDP saving."""
    A.set_calibration()
    savings = []
    for name in A.EFFICIENTVIT_CONFIGS:
        layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS[name])
        trio = A.simulate(layers, "trio")
        ours = A.simulate(layers, "m2q")
        savings.append(1 - ours.energy_uj / trio.energy_uj)
    avg = sum(savings) / len(savings)
    assert 0.25 <= avg <= 0.40, avg  # paper: 31.5%
    l224 = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS["b1-r224"])
    ours = A.simulate(l224, "m2q")
    edp_saving = 1 - ours.edp_mj_ms / 4.3  # vs paper-reported Trio EDP
    assert 0.7 <= edp_saving <= 0.95, edp_saving  # paper: 80%


def test_accel_sim_consumes_kernel_bench_conv_and_attn_rows():
    """ISSUE 4 + ISSUE 5: the committed BENCH_kernels.json conv rows AND
    msa attention rows feed the simulator's latency model — quantized
    layers whose measured fused kernel underperforms the ideal engine
    mapping take more cycles, so the calibrated EDP rows move while
    energies and baselines stay put."""
    cal = A.KernelCalibration.from_bench_json()
    assert cal.pw_speedup > 0 and cal.dw_speedup > 0 and cal.attn_speedup > 0
    A.set_calibration()
    layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS["b1-r224"])
    base = A.simulate(layers, "m2q")
    cald = A.simulate(layers, "m2q", kernel_cal=cal)
    # latency can only be derated (never credited beyond the cycle model)
    assert cald.latency_ms >= base.latency_ms
    if min(cal.pw_speedup, cal.dw_speedup, cal.attn_speedup) < 2.0:
        # some measured speedup trails the ideal 2x -> strict derate
        assert cald.latency_ms > base.latency_ms
        assert cald.edp_mj_ms > base.edp_mj_ms
    # computational energy is untouched by the latency calibration
    assert cald.energy_uj == pytest.approx(base.energy_uj)
    # non-quantized methods are not calibrated (no fused kernels involved)
    trio = A.simulate(layers, "trio")
    assert A.simulate(layers, "trio",
                      kernel_cal=cal).latency_ms == trio.latency_ms
    # derate floor: a kind whose measured speedup exceeds ideal stays 1.0
    fast = A.KernelCalibration(pw_speedup=100.0, dw_speedup=100.0,
                               attn_speedup=100.0)
    assert A.simulate(layers, "m2q",
                      kernel_cal=fast).latency_ms == base.latency_ms
    # the attention rows are consumed on their own axis: the MSA matmul
    # layers take MORE cycles when only attn_speedup trails the ideal
    slow_attn = A.KernelCalibration(pw_speedup=100.0, dw_speedup=100.0,
                                    attn_speedup=0.5)
    slow = A.simulate(layers, "m2q", kernel_cal=slow_attn)
    assert slow.latency_ms > base.latency_ms
    derated = {p.name for b, p in zip(base.per_layer, slow.per_layer)
               if p.mpma_cycles > b.mpma_cycles}
    assert derated and all(".attn_mm" in n for n in derated)


def test_kernel_bench_attn_smoke_rows():
    """ISSUE 5 satellite: the attention-row harness runs fast in interpret
    mode and produces the full fused/xla_int8/f32 contrast for both MSA
    and decode shapes."""
    from benchmarks import kernel_bench
    rows = kernel_bench.collect_attn(iters=1, smoke=True)
    bases = {n.partition("/")[0] for n in rows}
    assert any(b.startswith("msa") for b in bases)
    assert any(b.startswith("decode") for b in bases)
    for base in bases:
        for variant in ("fused", "xla_int8", "f32"):
            rec = rows[f"{base}/{variant}"]
            assert rec["wall_s"] > 0, (base, variant)
            assert rec["ops"]["total"] > 0, (base, variant)


def test_serving_bench_smoke_rows():
    """ISSUE 4 satellite: the serving benchmark's fast path produces sane
    rows for both engines at every arrival rate."""
    from benchmarks import serving_bench
    rep = serving_bench.collect(smoke=True)
    assert rep["vision"] and rep["token"]
    for row in rep["vision"]:
        assert row["imgs_per_s_wall"] > 0
        assert row["items"] == row["n"] == row["submitted"]
    for row in rep["token"]:
        assert row["tok_per_s_wall"] > 0
        # the first token of each request is sampled at prefill; the
        # decode loop emits the remaining max_new - 1
        assert row["decoded_tokens"] == row["n"] * (row["max_new"] - 1)
    for row in rep["vision"] + rep["token"]:
        assert 0.0 <= row["p50_ms"] <= row["p99_ms"]
        assert 0.0 < row["batch_occupancy"] <= 1.0
        assert sum(row["flush_reasons"].values()) == row["batches"]
    # the policy responds to load: higher arrival rate -> fuller batches
    occ = [r["batch_occupancy"] for r in rep["vision"]]
    assert occ[-1] >= occ[0]
    # ISSUE 8: wall-clock per-SLO-class daemon rows — one per class,
    # outcomes reconciled, interactive tier measurably faster than batch
    classes = {r["slo_class"]: r for r in rep["daemon"]}
    assert set(classes) == {"interactive", "batch"}
    for row in rep["daemon"]:
        assert row["engine"] == "daemon" and row["wall_s"] > 0
        assert row["completed"] == row["submitted"] > 0
        assert 0.0 < row["p50_ms"] <= row["p99_ms"]
        assert 0.0 < row["batch_occupancy"] <= 1.0
    assert (classes["interactive"]["p99_ms"]
            < classes["batch"]["p99_ms"])
    # fault-rate scenarios: faults actually fired, goodput accounts for
    # the failures, and the engines RECOVERED (every handle resolved)
    assert rep["faults"]
    for row in rep["faults"]:
        assert row["faults_fired"] > 0
        assert row["recovered"] is True
        assert row["failed"] > 0                    # the faults cost requests
        assert 0.0 <= row["goodput"] < 1.0
        assert row["goodput"] == pytest.approx(
            row["completed"] / row["submitted"], abs=1e-3)
        assert (row["completed"] + row["failed"] + row["cancelled"]
                + row["timed_out"] + row["shed"]) == row["submitted"]
    # ISSUE 10: crash-recovery rows — an uncontained crash and a hung
    # step each cost exactly one supervised restart, goodput across the
    # restart is total (zero lost handles), the journal reconciles
    # exactly, and replayed results match the uninterrupted reference
    specs = {r["fault_spec"].split("@")[0] for r in rep["recovery"]}
    assert specs == {"crash", "hang"}
    for row in rep["recovery"]:
        assert row["engine"] == "recovery"
        assert row["restarts"] >= 1 and row["replayed"] >= 1
        assert row["mttr_s"] > 0.0 and row["wall_s"] >= row["mttr_s"]
        assert row["goodput"] > 0.0 and row["lost_handles"] == 0
        assert row["journal_exact"] is True
        assert row["journal_submitted"] == row["journal_terminal"] == row["n"]
        assert row["match_reference"] is True
        assert row["restart_log"]


def test_accel_sim_consumes_serving_bench_occupancy():
    """ISSUE 8 satellite: the committed BENCH_serving.json feeds the
    simulator a measured serving calibration — occupancy from the
    highest-rate (steady-state) row derates device latency into a
    served latency, queue percentiles add the measured wait — while
    every device-level column stays put."""
    cal = A.ServingCalibration.from_bench_json()
    assert 0.0 < cal.occupancy <= 1.0
    assert 0.0 <= cal.queue_p50_ms <= cal.queue_p99_ms
    A.set_calibration()
    layers = A.efficientvit_layers(**A.EFFICIENTVIT_CONFIGS["b1-r224"])
    base = A.simulate(layers, "m2q")
    assert base.served_latency_ms is None  # opt-in column
    served = A.simulate(layers, "m2q", serving_cal=cal)
    # device columns untouched; served latency >= device latency
    assert served.latency_ms == base.latency_ms
    assert served.energy_uj == pytest.approx(base.energy_uj)
    assert served.served_latency_ms >= served.latency_ms
    assert served.served_p99_latency_ms >= served.served_latency_ms
    assert served.served_latency_ms == pytest.approx(
        base.latency_ms / cal.occupancy + cal.queue_p50_ms)
    # composes with the kernel calibration on the same call
    kcal = A.KernelCalibration.from_bench_json()
    both = A.simulate(layers, "m2q", kernel_cal=kcal, serving_cal=cal)
    assert both.served_latency_ms == pytest.approx(
        both.latency_ms / cal.occupancy + cal.queue_p50_ms)
    # a malformed occupancy fails loudly, not as a silent div-by-zero
    with pytest.raises(ValueError, match="occupancy"):
        A.ServingCalibration(occupancy=0.0, queue_p50_ms=0.0,
                             queue_p99_ms=0.0)


@pytest.mark.slow
def test_table1_table2_trends_on_proxy():
    """Needs the cached trained proxy (benchmarks/run.py trains it)."""
    from benchmarks.proxy_model import CACHE, accuracy, train_proxy, CFG
    if not CACHE.exists():
        pytest.skip("proxy not trained yet (run benchmarks.run first)")
    from repro.core import policy as pol
    from repro.core.apply import fake_quant_model
    from repro.models import get_model
    model = get_model(CFG)
    params = train_proxy()
    kinds = {pol.KIND_DENSE}
    acc = {s: accuracy(fake_quant_model(params, model.QUANT_RULES, scheme=s,
                                        bits=b, kinds=kinds))
           for s, b in [("uniform", 8), ("pot", 3), ("apot", 8), ("m2q", 8)]}
    # Table I ordering: Uniform >= mixed >= APoT >> PoT
    assert acc["uniform"] >= acc["m2q"] - 0.01
    assert acc["m2q"] >= acc["apot"] - 0.01
    assert acc["apot"] > acc["pot"]
    # Table II: 4-bit DWConv is accuracy-free vs 8-bit
    a4 = accuracy(fake_quant_model(params, model.QUANT_RULES,
                                   scheme="uniform", bits=4,
                                   kinds={pol.KIND_DWCONV}))
    a8 = accuracy(fake_quant_model(params, model.QUANT_RULES,
                                   scheme="uniform", bits=8,
                                   kinds={pol.KIND_DWCONV}))
    assert a4 >= a8 - 0.01
