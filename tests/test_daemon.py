"""Wall-clock serving daemon: streaming handles, SLO classes, preemption,
thread-safe scheduler core, and the multi-host launch dry-run.

Unit layers (FakeClock, no engine) cover the priority queue, the
per-class flush policy, and the Handle condition-variable machinery;
the wall-clock layers drive a real reduced token engine through
:class:`repro.serving.daemon.ServingDaemon` from foreign threads.
"""
import socket
import subprocess
import sys
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.models import get_model
from repro.serving.batching import ServeStats
from repro.serving.daemon import ServingDaemon
from repro.serving.errors import QueueFullError
from repro.serving.scheduler import (FLUSH_DEADLINE, FlushPolicy, Handle,
                                     OverloadPolicy, PENDING, Scheduler)
from repro.serving.slo import (BATCH, INTERACTIVE, ClassFlushPolicy,
                               SLOClass, classes_by_name)


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1000.0


@pytest.fixture(scope="module")
def lm():
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _engine(lm, **kw):
    from repro.serving.engine import Engine
    cfg, params = lm
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 64)
    return Engine(cfg, params, **kw)


# ---------------------------------------------------------------------------
# Handle: event-based waits, streaming, done-callbacks
# ---------------------------------------------------------------------------


def test_handle_result_wakeup_is_event_based_not_sleep_polled():
    """Satellite bugfix: ``result(timeout=)`` must wake on the resolver's
    notify, not on a sleep-poll tick — no ``time.sleep`` in the wait path
    and wakeup latency far below the old 0.5 ms poll interval x jitter."""
    h = Handle(uid=0, payload=None, submitted_at=0.0)
    resolved_at = []
    go = threading.Event()

    def resolver():
        go.wait(5.0)
        resolved_at.append(time.monotonic())
        h.set_result([42])

    slept = []
    real_sleep = time.sleep
    time.sleep = lambda s: (slept.append(s), real_sleep(s))
    try:
        t = threading.Thread(target=resolver)
        t.start()
        go.set()
        out = h.result(timeout=5.0)
        woke_at = time.monotonic()
        t.join()
    finally:
        time.sleep = real_sleep
    assert out == [42]
    assert not slept, f"result() wait still sleep-polls: {slept}"
    assert woke_at - resolved_at[0] < 0.2  # event wakeup, not a poll tick
    # and the timeout path still raises
    h2 = Handle(uid=1, payload=None, submitted_at=0.0)
    with pytest.raises(TimeoutError):
        h2.result(timeout=0.01)


def test_handle_streaming_iterator_and_callbacks():
    h = Handle(uid=7, payload=None, submitted_at=0.0)
    via_cb = []
    h._on_token = via_cb.append
    assert h.push_token(1) and h.push_token(2)
    assert h.streamed == 2

    got = []

    def consumer():
        got.extend(h.tokens(timeout=5.0))

    t = threading.Thread(target=consumer)
    t.start()
    h.push_token(3)
    h.set_result([1, 2, 3])
    t.join(5.0)
    assert got == [1, 2, 3] and via_cb == [1, 2, 3]
    assert not h.push_token(9)  # dropped after terminal
    assert h.streamed == 3
    # a fresh iterator over a DONE handle drains the buffer then ends
    assert list(h.tokens(timeout=1.0)) == [1, 2, 3]


def test_handle_streaming_failure_truncates_stream():
    h = Handle(uid=8, payload=None, submitted_at=0.0)
    h.push_token(5)
    h.set_exception(RuntimeError("poisoned"))
    it = h.tokens(timeout=1.0)
    assert next(it) == 5  # already-delivered tokens stand
    with pytest.raises(RuntimeError, match="poisoned"):
        next(it)
    # iterator timeout raises rather than hanging when nothing resolves
    h2 = Handle(uid=9, payload=None, submitted_at=0.0)
    with pytest.raises(TimeoutError):
        next(h2.tokens(timeout=0.01))


def test_handle_done_callbacks_fire_once_and_swallow_errors():
    h = Handle(uid=3, payload=None, submitted_at=0.0)
    calls = []
    h.add_done_callback(lambda hh: calls.append(hh.state))
    h.add_done_callback(lambda hh: 1 / 0)  # must not break the resolver
    assert h.set_result([1])
    assert not h.set_result([2])  # terminal is sticky, no second fire
    assert calls == ["DONE"]
    # late registration on a terminal handle fires immediately
    h.add_done_callback(lambda hh: calls.append("late"))
    assert calls == ["DONE", "late"]
    # on_token exceptions are swallowed too
    h2 = Handle(uid=4, payload=None, submitted_at=0.0)
    h2._on_token = lambda tok: 1 / 0
    assert h2.push_token(1)


# ---------------------------------------------------------------------------
# Scheduler: priorities, requeue, thread-safety
# ---------------------------------------------------------------------------


def test_priority_insertion_fifo_within_class():
    clk = FakeClock()
    s = Scheduler(policy=FlushPolicy(max_batch=16, max_delay_ms=0.0),
                  clock=clk)
    a = s.submit("a")                      # prio 0
    b = s.submit("b", priority=5)
    c = s.submit("c", priority=5)          # FIFO behind b within prio 5
    d = s.submit("d", priority=1)
    assert [h.payload for h in s.peek(10)] == ["b", "c", "d", "a"]
    assert [h.priority for h in (a, b, c, d)] == [0, 5, 5, 1]


def test_shed_oldest_picks_lowest_priority_class():
    clk = FakeClock()
    s = Scheduler(policy=FlushPolicy(max_batch=16, max_delay_ms=None),
                  overload=OverloadPolicy(max_queue=3, shed_oldest=True),
                  clock=clk)
    low1 = s.submit("low1")
    s.submit("hi", priority=9)
    low2 = s.submit("low2")
    s.submit("hi2", priority=9)  # queue full: sheds oldest LOW, not hi
    assert low1.state == "FAILED" and isinstance(low1.exception(),
                                                 QueueFullError)
    assert low2.state == PENDING
    assert s.stats.shed == 1 and s.stats.submitted == 4
    assert [h.payload for h in s.peek(10)] == ["hi", "hi2", "low2"]


def test_requeue_reenters_without_new_submit_count():
    clk = FakeClock()
    s = Scheduler(policy=FlushPolicy(max_batch=4, max_delay_ms=0.0),
                  clock=clk)
    h = s.submit("x", priority=2)
    [live] = s.pop([h], "full")
    assert s.pending == 0 and s.stats.submitted == 1
    clk.advance_ms(30)
    assert s.requeue(h)
    assert s.pending == 1 and s.stats.submitted == 1  # no double-count
    assert h.submitted_at == pytest.approx(0.030)     # wait clock reset
    h.cancel()
    assert not s.requeue(h)  # terminal handles never re-enter
    assert s.stats.submitted == s.stats.resolved == 1


def test_scheduler_thread_safety_stress():
    """Satellite: N submitter threads + one consumer loop against one
    Scheduler — uids stay unique, every handle goes terminal, and the
    reconciliation invariant holds EXACTLY under shedding, cancellation,
    deadline expiry, and concurrent pops."""
    s = Scheduler(policy=FlushPolicy(max_batch=4, max_delay_ms=0.0),
                  overload=OverloadPolicy(max_queue=64, shed_oldest=True))
    N_THREADS, PER_THREAD = 8, 60
    all_handles = []
    lock = threading.Lock()
    stop = threading.Event()

    def submitter(seed):
        rng = np.random.default_rng(seed)
        mine = []
        for i in range(PER_THREAD):
            kw = {}
            r = rng.random()
            if r < 0.15:
                kw["deadline_ms"] = 0.5  # most of these expire queued
            h = s.submit(f"{seed}/{i}", priority=int(rng.integers(0, 3)),
                         **kw)
            mine.append(h)
            if r > 0.9:
                h.cancel()
        with lock:
            all_handles.extend(mine)

    def consumer():
        while True:
            reason = s.due()
            if reason is not None:
                batch = s.pop(s.peek(4), reason)
                for h in batch:
                    h.set_result("ok")
            elif stop.is_set() and s.pending == 0:
                return

    cons = threading.Thread(target=consumer)
    cons.start()
    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(N_THREADS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(30.0)
    stop.set()
    cons.join(30.0)
    assert not cons.is_alive()
    assert len(all_handles) == N_THREADS * PER_THREAD
    uids = [h.uid for h in all_handles]
    assert len(set(uids)) == len(uids)
    assert all(h.state != PENDING for h in all_handles)
    st = s.stats
    assert st.submitted == N_THREADS * PER_THREAD
    assert (st.completed + st.failed + st.cancelled + st.timed_out
            + st.shed) == st.submitted
    # outcome counters match the handles' own terminal states
    from collections import Counter
    states = Counter(h.state for h in all_handles)
    assert st.completed == states["DONE"]
    assert st.timed_out == states["TIMED_OUT"]
    assert st.cancelled == states["CANCELLED"]
    assert st.failed + st.shed == states["FAILED"]


def test_servestats_record_outcome_is_thread_safe():
    st = ServeStats()
    N, PER = 8, 2000

    def bump():
        for _ in range(PER):
            st.record_outcome("completed")

    ts = [threading.Thread(target=bump) for _ in range(N)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert st.completed == N * PER  # read-add-set would lose counts


# ---------------------------------------------------------------------------
# SLO classes and the per-class flush policy
# ---------------------------------------------------------------------------


def test_slo_class_validation_and_registry():
    with pytest.raises(ValueError, match="max_delay_ms"):
        SLOClass(name="x", max_delay_ms=-1.0)
    with pytest.raises(ValueError, match="deadline_ms"):
        SLOClass(name="x", deadline_ms=0)
    with pytest.raises(ValueError, match="max_queued"):
        SLOClass(name="x", max_queued=0)
    with pytest.raises(ValueError, match="duplicate"):
        classes_by_name([INTERACTIVE, SLOClass(name="interactive")])
    assert INTERACTIVE.priority > BATCH.priority
    assert BATCH.preemptible and not INTERACTIVE.preemptible


def test_class_flush_policy_per_priority_deadlines():
    clk = FakeClock()
    pol = ClassFlushPolicy.from_classes([INTERACTIVE, BATCH], max_batch=8)
    s = Scheduler(policy=pol, clock=clk)
    # batch alone: due only after ITS 25ms coalescing window
    s.submit("b0", priority=BATCH.priority)
    assert s.due() is None
    nd = s.next_deadline()
    assert nd == pytest.approx(0.025)
    clk.t = nd  # sleeping EXACTLY until next_deadline() IS due
    assert s.due() == FLUSH_DEADLINE
    s.pop(s.peek(8), FLUSH_DEADLINE)
    # an interactive arrival makes the queue due immediately
    s.submit("b1", priority=BATCH.priority)
    assert s.due() is None
    s.submit("i0", priority=INTERACTIVE.priority)
    assert s.due() == FLUSH_DEADLINE
    # and peek admits the interactive request first
    assert [h.payload for h in s.peek(8)] == ["i0", "b1"]


def test_class_flush_policy_unknown_priority_admits_immediately():
    clk = FakeClock()
    pol = ClassFlushPolicy.from_classes([BATCH], max_batch=8)
    s = Scheduler(policy=pol, clock=clk)
    s.submit("stranger", priority=42)  # not a configured tier
    assert s.due() == FLUSH_DEADLINE  # fail toward latency


# ---------------------------------------------------------------------------
# engine-level: streaming decode + preemption (manual drive, no daemon)
# ---------------------------------------------------------------------------


def test_engine_streaming_tokens_match_result(lm):
    eng = _engine(lm, max_batch=2)
    via_cb = []
    r = eng.submit(np.arange(1, 9), max_new_tokens=5, stream=True,
                   on_token=via_cb.append)
    eng.run()
    assert r.handle.result() == via_cb
    assert list(r.handle.tokens(timeout=1.0)) == via_cb
    assert len(via_cb) == 5
    assert eng.stats.streamed_tokens == 5
    # non-streaming requests pay no streaming d2h and no stream buffer
    r2 = eng.submit(np.arange(1, 5), max_new_tokens=3)
    eng.run()
    assert r2.handle.streamed == 0 and len(r2.handle.result()) == 3


def test_engine_preemption_restart_from_prefix(lm):
    eng = _engine(lm, max_batch=1)
    low = eng.submit(np.arange(1, 7), max_new_tokens=8, stream=True,
                     priority=BATCH.priority, preemptible=True)
    eng.step()  # prefill + first decode
    eng.step()
    pre_preempt = list(low.handle._stream)
    assert len(pre_preempt) >= 2
    hi = eng.submit(np.arange(1, 5), max_new_tokens=3,
                    priority=INTERACTIVE.priority)
    eng.run()
    assert eng.stats.preemptions >= 1
    assert low.preemptions >= 1
    # the high-priority request took the only slot and finished
    assert len(hi.handle.result()) == 3
    # the preempted request kept every pre-eviction token and completed
    # its full budget: result = streamed tokens, prefix preserved
    out = low.handle.result()
    assert len(out) == 8
    assert out[: len(pre_preempt)] == pre_preempt
    assert out == list(low.handle.tokens(timeout=1.0))
    s = eng.stats
    assert s.submitted == s.resolved == 2  # requeue never double-counts


def test_engine_nonpreemptible_is_never_evicted(lm):
    eng = _engine(lm, max_batch=1)
    low = eng.submit(np.arange(1, 7), max_new_tokens=4,
                     priority=0, preemptible=False)
    eng.step()
    eng.submit(np.arange(1, 5), max_new_tokens=2,
               priority=INTERACTIVE.priority)
    eng.run()
    assert eng.stats.preemptions == 0
    assert len(low.handle.result()) == 4


# ---------------------------------------------------------------------------
# the daemon: wall-clock e2e
# ---------------------------------------------------------------------------


def test_daemon_rejects_virtual_clock(lm):
    eng = _engine(lm, clock=FakeClock())
    with pytest.raises(ValueError, match="real clock"):
        ServingDaemon(eng)


def test_daemon_e2e_slo_classes_streaming_and_reconciliation(lm):
    """ISSUE 8 acceptance: daemon running, interactive + batch submitted
    from a foreign thread under load, tokens stream incrementally
    through the Handle API, interactive p99 < batch p99 (per-class
    ServeStats), clean drain with every outcome reconciled."""
    eng = _engine(lm, max_batch=2)
    daemon = ServingDaemon(eng)
    results = []

    with daemon:
        # saturate the 2 slots with slow preemptible batch work first
        def submitter():
            for _ in range(6):
                results.append(daemon.submit(
                    np.arange(1, 7), slo="batch", max_new_tokens=16))

        th = threading.Thread(target=submitter)
        th.start()
        th.join()
        # interactive traffic arrives while every slot is busy
        incremental = []
        stream_req = daemon.submit(
            np.arange(1, 9), slo="interactive", max_new_tokens=4,
            stream=True,
            on_token=lambda tok: incremental.append(
                (tok, stream_req.handle.done())))
        for _ in range(2):
            results.append(daemon.submit(
                np.arange(1, 6), slo="interactive", max_new_tokens=4))
        streamed = list(stream_req.handle.tokens(timeout=120.0))
        results.append(stream_req)
        for r in results:
            r.handle.result(timeout=120.0)
    # incremental delivery: every token was pushed while still PENDING
    assert streamed == stream_req.handle.result()
    assert len(incremental) == 4
    assert all(not done for _, done in incremental)
    # per-class SLO: interactive completion latency beats batch
    inter = daemon.class_stats["interactive"]
    batch = daemon.class_stats["batch"]
    assert inter.submitted == 3 and batch.submitted == 6
    assert inter.completed == 3 and batch.completed == 6
    assert inter.p99_ms < batch.p99_ms
    # clean shutdown: loop exited, every outcome reconciled exactly
    assert not daemon.running
    s = eng.stats
    assert s.submitted == 9
    assert s.resolved == s.submitted
    assert s.completed == 9


def test_daemon_class_budget_rejects_over_outstanding(lm):
    eng = _engine(lm, max_batch=1)
    tight = (SLOClass(name="interactive", priority=10, max_delay_ms=0.0),
             SLOClass(name="batch", priority=0, max_delay_ms=5.0,
                      max_queued=1, preemptible=True))
    with ServingDaemon(eng, classes=tight) as daemon:
        first = daemon.submit(np.arange(1, 9), slo="batch",
                              max_new_tokens=12)
        with pytest.raises(QueueFullError, match="budget exhausted"):
            daemon.submit(np.arange(1, 9), slo="batch", max_new_tokens=4)
        with pytest.raises(KeyError, match="unknown SLO class"):
            daemon.submit(np.arange(1, 9), slo="nope")
        first.handle.result(timeout=120.0)
        # budget freed at completion: the class admits again
        second = daemon.submit(np.arange(1, 9), slo="batch",
                               max_new_tokens=2)
        second.handle.result(timeout=120.0)
    assert daemon.class_stats["batch"].rejected == 1
    assert daemon.class_stats["batch"].completed == 2
    s = eng.stats
    assert s.submitted == s.resolved == 2  # rejected never submitted


def test_daemon_shutdown_drain_false_cancels_outstanding(lm):
    eng = _engine(lm, max_batch=1)
    daemon = ServingDaemon(eng).start()
    reqs = [daemon.submit(np.arange(1, 7), slo="batch", max_new_tokens=40)
            for _ in range(3)]
    daemon.shutdown(drain=False)
    assert not daemon.running
    s = eng.stats
    assert all(r.handle.done() for r in reqs)
    assert s.submitted == 3 and s.resolved == 3
    assert s.cancelled >= 1  # at least the queued ones were cancelled
    with pytest.raises(RuntimeError, match="daemon is stopped"):
        daemon.submit(np.arange(1, 5))


def test_daemon_idle_sleep_wakes_on_submit(lm):
    """The serve loop sleeps (no work) and a foreign-thread submit must
    wake it promptly — the whole point of the condition-variable loop."""
    eng = _engine(lm, max_batch=2)
    with ServingDaemon(eng) as daemon:
        time.sleep(0.3)  # let the loop go idle (indefinite wait)
        t0 = time.monotonic()
        r = daemon.submit(np.arange(1, 5), slo="interactive",
                          max_new_tokens=2)
        r.handle.result(timeout=120.0)
        # generous bound: includes one jitted-step execution, but NOT an
        # unbounded poll interval — an unwoken loop would hang forever
        assert time.monotonic() - t0 < 60.0
    assert eng.stats.completed == 1


# ---------------------------------------------------------------------------
# multi-host mesh launch (subprocess dry-run idiom)
# ---------------------------------------------------------------------------


def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


@pytest.mark.slow
def test_multihost_daemon_launch_dryrun():
    """Two processes x 4 virtual CPU devices join one jax.distributed
    world; each verifies the global 2x4 mesh, spec-conformant
    cross-process placement via dist.sharding.put_global, and lowering
    of the prefill computation (execution is gated off on the CPU
    backend, which cannot run multiprocess programs)."""
    import os
    port = _free_port()
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src")
    cmd = [sys.executable, "-m", "repro.launch.daemon",
           "--arch", "qwen1.5-0.5b", "--reduced", "--no-quant",
           "--mesh", "2x4", "--coordinator", f"127.0.0.1:{port}",
           "--num-processes", "2"]
    procs = [subprocess.Popen(cmd + ["--process-id", str(i)],
                              stdout=subprocess.PIPE,
                              stderr=subprocess.STDOUT, text=True, env=env)
             for i in range(2)]
    outs = [p.communicate(timeout=420)[0] for p in procs]
    for i, (p, out) in enumerate(zip(procs, outs)):
        assert p.returncode == 0, f"process {i}:\n{out[-3000:]}"
        assert f"[daemon:{i}] placement-ok" in out, out[-2000:]
        assert f"[daemon:{i}] lowering-ok" in out, out[-2000:]
        assert "8 global / 4 local devices" in out
