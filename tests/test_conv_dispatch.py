"""Quantized convolutions as first-class citizens of the M2Q hot path.

Covers: the paper-taxonomy (kind-by-shape) regression on QUANT_RULES, real
QTensor production for conv leaves in quantize_model, PWConv/DWConv parity
(fused Pallas dispatch vs pure-XLA QTensor path vs dequantized float
reference), kernel routing counts on a full quantized EfficientViT forward,
the HLO proof that no f32 dequantized-weight convolution survives on the
quantized hot path, and the MBConv stride/residual assumptions.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.configs.registry import REDUCED
from repro.core import (M2QPolicy, QM2Q, QUniform, ShapeCtx, fake_quant_act,
                        quantize_model, select_schemes)
from repro.core import policy as pol
from repro.core.apply import match_kind
from repro.core.calibrate import (rule_matcher, run_calibration,
                                  wrap_for_calibration)
from repro.core.calibrate import path_str
from repro.kernels import ops
from repro.models import efficientvit as evit
from repro.models import get_model


def _rng(seed=0):
    return np.random.default_rng(seed)


def _qconv_m2q(w4, act_max_abs=None):
    """Quantize an HWIO conv filter the way core.apply does: flattened 2-D
    payload, original shape in aux."""
    w2 = jnp.asarray(w4).reshape(-1, w4.shape[-1])
    asn = select_schemes(w2, ratio=0.5)
    qt = QM2Q.quantize(w2, asn.apot_idx, asn.uniform_idx,
                       act_max_abs=act_max_abs)
    return dataclasses.replace(qt, shape=tuple(w4.shape))


def _qconv_u4(w4):
    w2 = jnp.asarray(w4).reshape(-1, w4.shape[-1])
    qt = QUniform.quantize(w2, bits=4)
    return dataclasses.replace(qt, shape=tuple(w4.shape))


# ---------------------------------------------------------------------------
# taxonomy: kind follows shape (paper Sec. III-A)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["efficientvit-b1-r224",
                                  "efficientvit-b2-r224"])
def test_quant_rules_kind_agrees_with_shape(arch):
    """Walk the param tree: every (kh,kw,1,C) depthwise filter must map to
    KIND_DWCONV (the 5x5 w_agg aggregation was historically mis-filed as
    KIND_DENSE), every 1x1 conv and 2-D matmul to KIND_DENSE."""
    cfg = REDUCED[arch]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    seen_agg = seen_dw = 0
    for path, leaf in leaves:
        key = path_str(path)
        kind = match_kind(model.QUANT_RULES, key)
        if kind in (None, pol.KIND_SKIP) or leaf.ndim < 2:
            continue
        if leaf.ndim == 4 and leaf.shape[2] == 1 and leaf.shape[0] > 1:
            assert kind == pol.KIND_DWCONV, (key, leaf.shape, kind)
            seen_dw += 1
            seen_agg += key.endswith("w_agg")
        elif leaf.ndim == 4 and leaf.shape[:2] == (1, 1):
            assert kind == pol.KIND_DENSE, (key, leaf.shape, kind)
        elif leaf.ndim == 2:
            assert kind == pol.KIND_DENSE, (key, leaf.shape, kind)
    assert seen_dw >= 2 and seen_agg >= 1  # both w_dw and w_agg exercised


# ---------------------------------------------------------------------------
# quantize_model produces real QTensors for conv leaves
# ---------------------------------------------------------------------------


def test_quantize_model_conv_leaves_are_qtensors():
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    ctx = ShapeCtx(tokens_per_step=32 * cfg.img_res * cfg.img_res)
    qp, report = quantize_model(params, model.QUANT_RULES, ctx,
                                M2QPolicy(intensity_threshold=1.0))
    flat = {path_str(p): l for p, l in
            jax.tree_util.tree_flatten_with_path(
                qp, is_leaf=lambda x: isinstance(x, (QM2Q, QUniform)))[0]}
    n_pw = n_dw = 0
    for key, leaf in flat.items():
        if key.endswith(("w_pw1", "w_pw2", "w_qkv", "w_proj", "w_in")):
            assert isinstance(leaf, QM2Q), (key, type(leaf))
            assert leaf.payload.ndim == 2 and len(leaf.shape) == 4, key
            # HWIO-aware reduction: one scale column per Cout filter
            assert leaf.u_scale.shape == (1, leaf.shape[-1]), key
            n_pw += 1
        elif key.endswith(("w_dw", "w_agg")):
            assert isinstance(leaf, QUniform) and leaf.bits == 4, key
            kh, kw, one, c = leaf.shape
            assert one == 1
            assert leaf.payload.shape == (kh * kw, c // 2), key
            assert leaf.scale.shape == (1, c), key
            n_dw += 1
    assert n_pw >= 8 and n_dw >= 4
    # the report covers every quantized leaf with a real decision
    assert all(r.decision in ("mixed", "lowbit") for r in report)
    # dequant reshapes back through the HWIO aux shape for the XLA fallback
    for key, leaf in flat.items():
        if isinstance(leaf, (QM2Q, QUniform)) and len(leaf.shape) == 4:
            assert leaf.dequant().reshape(leaf.shape).shape == leaf.shape


# ---------------------------------------------------------------------------
# PWConv parity: fused kernels vs XLA QTensor path vs float reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("cin,cout", [(16, 24), (32, 130)])
def test_pwconv_m2q_parity(cin, cout, monkeypatch):
    rng = _rng(cin + cout)
    w4 = rng.normal(0, 0.05, (1, 1, cin, cout)).astype(np.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 6, 7, cin)).astype(np.float32))
    amax = jnp.float32(np.abs(np.asarray(x)).max())
    qt = _qconv_m2q(w4, act_max_abs=amax)
    assert ops.kernel_supported(qt)
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "0")
    y_xla = nn.conv2d(x, qt)
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "1")
    y_ker = nn.conv2d(x, qt)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_xla),
                               rtol=1e-4, atol=1e-4)
    # float reference: dequantized weights + fake-quantized activations;
    # the error is quantization-level, not path-level
    y_ref = jax.lax.conv_general_dilated(
        fake_quant_act(x, qt.act_scale),
        qt.dequant().reshape(qt.shape), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    rel = float(jnp.linalg.norm(y_ker - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 5e-3, rel


@pytest.mark.parametrize("bits", [8, 4])
def test_pwconv_uniform_parity(bits, monkeypatch):
    rng = _rng(11 * bits)
    cin, cout = 24, 40
    w4 = rng.normal(0, 0.05, (1, 1, cin, cout)).astype(np.float32)
    x = jnp.asarray(rng.normal(0, 1, (3, 5, 5, cin)).astype(np.float32))
    w2 = jnp.asarray(w4).reshape(cin, cout)
    if bits == 8:
        qt = QUniform.quantize(w2, bits=8,
                               act_max_abs=jnp.max(jnp.abs(x)))
    else:
        qt = QUniform.quantize(w2, bits=4)
    qt = dataclasses.replace(qt, shape=tuple(w4.shape))
    assert ops.kernel_supported(qt)
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "0")
    y_xla = nn.conv2d(x, qt)
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "1")
    y_ker = nn.conv2d(x, qt)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_xla),
                               rtol=1e-4, atol=1e-4)
    y_ref = jax.lax.conv_general_dilated(
        x if bits == 4 else fake_quant_act(x, qt.act_scale),
        qt.dequant().reshape(qt.shape), (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    rel = float(jnp.linalg.norm(y_ker - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 5e-3, rel


# ---------------------------------------------------------------------------
# DWConv parity: packed-w4 kernel vs dequantized XLA conv
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kh,stride", [(3, 1), (3, 2), (5, 1), (5, 2)])
def test_dwconv_parity_vs_dequant_reference(kh, stride, monkeypatch):
    rng = _rng(kh * 10 + stride)
    C = 48
    w4 = rng.normal(0, 0.2, (kh, kh, 1, C)).astype(np.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 9, 9, C)).astype(np.float32))
    qt = _qconv_u4(w4)
    assert ops.dwconv_kernel_supported(qt, x, stride, C, "SAME")
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "0")
    y_xla = nn.dwconv2d(x, qt, stride=stride)  # dequantized XLA fallback
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "1")
    y_ker = nn.dwconv2d(x, qt, stride=stride)  # packed-w4 Pallas kernel
    assert y_ker.shape == y_xla.shape == (2, -(-9 // stride),
                                          -(-9 // stride), C)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_xla),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# full-model routing + HLO cleanliness
# ---------------------------------------------------------------------------


def _calibrated_quantized_reduced(batch=1):
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = _rng(0)
    imgs = jnp.asarray(rng.normal(
        0, 1, (batch, cfg.img_res, cfg.img_res, 3)).astype(np.float32))
    wrapped, stats = wrap_for_calibration(params,
                                          rule_matcher(model.QUANT_RULES))
    run_calibration(lambda p, x: model.forward(cfg, p, x), wrapped, [imgs])
    ctx = ShapeCtx(tokens_per_step=batch * cfg.img_res * cfg.img_res)
    qp, _ = quantize_model(params, model.QUANT_RULES, ctx,
                           M2QPolicy(intensity_threshold=1.0),
                           act_stats=stats)
    return cfg, model, qp, imgs


def test_quantized_forward_routes_convs_through_kernels(monkeypatch):
    """Acceptance: with dispatch on, EVERY stride-1 1x1 PWConv runs the
    fused m2q matmul and EVERY depthwise conv (3x3 + 5x5) runs dwconv_w4;
    the result matches the pure-XLA QTensor path.  The attn axis is pinned
    OFF via its env var: the int8 attention kernel shifts MSA numerics by
    quantization error, and this test's 2e-3 parity is about CONV routing
    (attention parity lives in test_attn_dispatch.py)."""
    cfg, model, qp, imgs = _calibrated_quantized_reduced()
    monkeypatch.setenv("REPRO_PALLAS_ATTN_DISPATCH", "0")
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "0")
    y_xla = model.forward(cfg, qp, imgs)
    calls = {"mm": 0, "dw": 0}
    orig_mm, orig_dw = ops.qtensor_matmul, ops.qtensor_dwconv

    def count_mm(*a, **k):
        calls["mm"] += 1
        return orig_mm(*a, **k)

    def count_dw(*a, **k):
        calls["dw"] += 1
        return orig_dw(*a, **k)

    monkeypatch.setattr(ops, "qtensor_matmul", count_mm)
    monkeypatch.setattr(ops, "qtensor_dwconv", count_dw)
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "1")
    y_ker = model.forward(cfg, qp, imgs)
    # REDUCED b1: 7 depthwise sites (4 MBConv 3x3 + 3 MSA 5x5 w_agg); every
    # quantized 1x1 PWConv (+ the 2-D head via nn.dense) hits the matmul
    # kernels
    assert calls["dw"] == 7, calls
    assert calls["mm"] >= 15, calls
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_xla),
                               rtol=2e-3, atol=2e-3)
    assert bool(jnp.all(jnp.isfinite(y_ker)))


def test_hlo_quantized_forward_has_no_f32_weight_conv():
    """Acceptance (qlint conv-budget rule): the compiled quantized forward
    emits NO dequantized-weight convolution for quantized conv leaves.
    Dispatch on: the only convolution left is the (unquantized) stem.
    Dispatch off: PWConvs STILL lower to quantized matmuls (no f32 conv);
    only the stem and the 7 weights-only depthwise fallbacks convolve."""
    from repro.analysis import lint
    from repro.analysis.traces import trace_fn
    cfg, model, qp, imgs = _calibrated_quantized_reduced()
    tr = trace_fn(lambda p, x: model.forward(cfg, p, x), (qp, imgs),
                  name="evit/m2q/forward", dispatch=True,
                  meta={"conv_budget": 1})
    assert lint(tr, "conv-budget") == []
    tr0 = trace_fn(lambda p, x: model.forward(cfg, p, x), (qp, imgs),
                   name="evit/m2q/forward-xla", dispatch=False,
                   meta={"conv_budget": 1 + 7})
    assert lint(tr0, "conv-budget") == []
    # seeded violation: a wrong budget must FIRE the rule (non-vacuous)
    tr0.meta["conv_budget"] = 1
    vs = lint(tr0, "conv-budget")
    assert [v.rule for v in vs] == ["conv-budget"] and "8 conv" in \
        vs[0].message


# ---------------------------------------------------------------------------
# dwconv_w4 H-tiled high-resolution path (the old whole-map guard is gone)
# ---------------------------------------------------------------------------


def test_dwconv_high_res_maps_stay_on_kernel():
    """ISSUE 9 satellite: with the H-tiled grid the VMEM bound is the
    TILE, so 256x256 and 384x384 maps take the kernel path — no more
    whole-map budget fallback — and the guard derives its answer from
    dwconv_tile_plan (rejecting only maps the tiler cannot block)."""
    rng = _rng(77)
    C = 4
    w4 = rng.normal(0, 0.2, (3, 3, 1, C)).astype(np.float32)
    qt = _qconv_u4(w4)
    for res in (224, 256, 384):
        x = jnp.zeros((1, res, res, C), jnp.float32)
        assert ops.dwconv_kernel_supported(qt, x, 1, C, "SAME"), res
        assert ops.dwconv_kernel_supported(qt, x, 2, C, "SAME"), res
    # 5x5 MSA window at high resolution too
    w5 = rng.normal(0, 0.2, (5, 5, 1, C)).astype(np.float32)
    x384 = jnp.zeros((1, 384, 384, C), jnp.float32)
    assert ops.dwconv_kernel_supported(_qconv_u4(w5), x384, 1, C, "SAME")
    # the tile plan itself fits under the budget at these resolutions...
    for res in (256, 384, 512):
        plan = ops.dwconv_tile_plan(res, res, 3, 3, 1)
        assert plan is not None and 1 <= plan[0] <= res
        assert ops._dwconv_tile_bytes(res, 3, 3, 1, *plan) <= \
            ops._DWCONV_VMEM_BYTES
    # ...and only a genuinely untileable map (a row too wide for even the
    # minimal 1-row 2-channel tile) is refused
    assert ops.dwconv_tile_plan(2, 2 ** 21, 3, 3, 1) is None
    assert not ops.dwconv_kernel_supported(
        qt, jnp.zeros((1, 2, 2 ** 21, C), jnp.float32), 1, C, "SAME")


@pytest.mark.parametrize("res,stride", [(256, 1), (256, 2),
                                        (384, 1), (384, 2)])
def test_dwconv_high_res_kernel_matches_xla_reference(res, stride,
                                                      monkeypatch):
    """ISSUE 9 acceptance: R256/R384 depthwise maps execute on the Pallas
    w4 kernel (dispatch-on nn.dwconv2d routes there, the H-tiled grid) and
    match the dequantized-weight XLA conv — triangulated over stride-1 and
    the fused-pad stride-2 downsampler path."""
    rng = _rng(res + stride)
    C = 4
    w4 = rng.normal(0, 0.2, (3, 3, 1, C)).astype(np.float32)
    qt = _qconv_u4(w4)
    x = jnp.asarray(rng.normal(0, 1, (1, res, res, C)).astype(np.float32))
    calls = {"dw": 0}
    orig = ops.qtensor_dwconv

    def spy(*a, **k):
        calls["dw"] += 1
        return orig(*a, **k)

    monkeypatch.setattr(ops, "qtensor_dwconv", spy)
    with ops.dispatch(conv=True):
        y = nn.dwconv2d(x, qt, stride=stride)
    assert calls["dw"] == 1, "high-res map did not take the kernel path"
    y_ref = jax.lax.conv_general_dilated(
        x, qt.dequant().reshape(qt.shape), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=C)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# opt-in int8 im2col stem (ISSUE 5 satellite; ROADMAP stem item)
# ---------------------------------------------------------------------------


def test_stem_im2col_int8_matmul_parity():
    """A quantized KxK stride-2 conv leaf lowers to im2col + the quantized
    matmul path (kernel and XLA variants agree), tracking the fake-quant
    f32 conv to quantization tolerance."""
    rng = _rng(88)
    w4 = rng.normal(0, 0.1, (3, 3, 3, 8)).astype(np.float32)
    x = jnp.asarray(rng.normal(0, 1, (2, 9, 9, 3)).astype(np.float32))
    w2 = jnp.asarray(w4).reshape(27, 8)
    qt = QUniform.quantize(w2, bits=8, act_max_abs=jnp.max(jnp.abs(x)))
    qt = dataclasses.replace(qt, shape=tuple(w4.shape))
    with ops.dispatch(dense=False, conv=False):
        y_xla = nn.conv2d(x, qt, stride=2)
    with ops.dispatch(dense=True, conv=True):
        y_ker = nn.conv2d(x, qt, stride=2)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_xla),
                               rtol=1e-4, atol=1e-4)
    y_ref = jax.lax.conv_general_dilated(
        fake_quant_act(x, qt.act_scale), qt.dequant().reshape(qt.shape),
        (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    rel = float(jnp.linalg.norm(y_ker - y_ref) / jnp.linalg.norm(y_ref))
    assert rel < 5e-3, rel


def test_stem_opt_in_recipe_quantizes_and_removes_last_conv():
    """The stem is f32 by DEFAULT; a recipe appending evit.STEM_RULE +
    evit.STEM_OVERRIDE quantizes it to uniform-8 W8A8, the forward stays
    close to the default artifact's, and the dispatch-on HLO drops to ZERO
    convolutions (the stem was the only one left)."""
    from repro.recipe import PRESETS, quantize
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    imgs = jnp.asarray(_rng(4).normal(
        0, 1, (2, cfg.img_res, cfg.img_res, 3)).astype(np.float32))
    qm_default = quantize(cfg, params, "m2q-w8a8", calib_batches=[imgs])
    assert isinstance(qm_default.params["stem"]["w"], jax.Array)  # f32 stem
    rec = PRESETS["m2q-w8a8"].replace(
        rules=tuple(evit.QUANT_RULES) + (evit.STEM_RULE,),
        overrides=(evit.STEM_OVERRIDE,))
    qm = quantize(cfg, params, rec, calib_batches=[imgs])
    stem = qm.params["stem"]["w"]
    assert isinstance(stem, QUniform) and stem.bits == 8
    assert stem.act_scale is not None  # calibrated -> true int8 path
    assert stem.payload.shape == (27, cfg.widths[0])
    # numerics: the int8 stem moves logits by bounded quantization error
    # (a RANDOM-INIT reduced net amplifies first-layer noise — the tight
    # per-layer guard is test_stem_im2col_int8_matmul_parity; the trained
    # proxy in examples/quantize_efficientvit loses no top-1)
    y_def = qm_default.forward(imgs)
    y_stem = qm.forward(imgs)
    assert bool(jnp.all(jnp.isfinite(y_stem)))
    rel = float(jnp.linalg.norm(y_stem - y_def) / jnp.linalg.norm(y_def))
    assert rel < 0.25, rel
    # the paper-taxonomy pins are unaffected by the extra override
    by_path = {r.path: r for r in qm.report}
    assert by_path["stem/w"].decision == "mixed"
    assert all(r.decision == qr.decision for r, qr in
               zip(qm_default.report, (by_path[r.path] for r in
                                       qm_default.report)))
    # HLO (qlint conv-budget rule): with conv dispatch on the stem's conv
    # is gone -> zero convolutions in the whole module
    def fwd(p, x):
        with ops.dispatch(dense=True, conv=True, attn=False):
            return model.forward(cfg, p, x)
    from repro.analysis import lint
    from repro.analysis.traces import trace_fn
    tr = trace_fn(fwd, (qm.params, imgs), name="evit/stem-q/forward",
                  dispatch=False, meta={"conv_budget": 0})
    assert lint(tr, "conv-budget") == []


# ---------------------------------------------------------------------------
# MBConv stride/residual assumptions (stride_block cleanup)
# ---------------------------------------------------------------------------


def test_mbconv_stride_and_residual_assumptions():
    """_init_mbconv is stride-agnostic: only w_dw sees the stride (1x1
    PWConvs never downsample) and the residual is gated on stride==1 AND
    matching channels.  Zeroed conv weights make the residual observable:
    the conv branch collapses to exactly 0."""
    key = jax.random.PRNGKey(0)
    x = jnp.asarray(_rng(1).normal(0, 1, (1, 8, 8, 16)).astype(np.float32))
    p_same = jax.tree.map(jnp.zeros_like, evit._init_mbconv(key, 16, 16))
    # stride 1, cin == cout: residual survives -> output IS the input
    np.testing.assert_array_equal(np.asarray(evit._mbconv(p_same, x)),
                                  np.asarray(x))
    # stride 2: spatial halves, residual must NOT be applied
    y2 = evit._mbconv(p_same, x, stride=2)
    assert y2.shape == (1, 4, 4, 16)
    np.testing.assert_array_equal(np.asarray(y2), np.zeros((1, 4, 4, 16)))
    # channel change at stride 1: no residual either
    p_wide = jax.tree.map(jnp.zeros_like, evit._init_mbconv(key, 16, 24))
    y3 = evit._mbconv(p_wide, x)
    assert y3.shape == (1, 8, 8, 24)
    np.testing.assert_array_equal(np.asarray(y3), np.zeros((1, 8, 8, 24)))


def test_stage_entry_blocks_downsample_in_forward():
    """Stage-entry blocks (bi==0, si>0) run stride 2: feature maps halve
    exactly once per stage after the stride-2 stem."""
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    x = jnp.zeros((1, cfg.img_res, cfg.img_res, 3), jnp.float32)
    x = nn.conv2d(x, params["stem"]["w"], stride=2)
    res = cfg.img_res // 2
    for si, blocks in enumerate(params["stages"]):
        for bi, blk in enumerate(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            x = evit._mbconv(blk["mb"], x, stride=stride)
            if stride == 2:
                res //= 2
            assert x.shape[1] == x.shape[2] == res, (si, bi, x.shape)
