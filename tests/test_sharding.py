"""Sharding rules + small-mesh end-to-end dry-runs (subprocess: the device
count must be fixed before jax initializes), including sharded serving of a
QuantizedModel through both engines."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P


class FakeMesh:
    """Shape-only mesh stand-in for spec-level tests (no devices needed)."""
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 4}


def test_spec_rules_divisibility_and_paths():
    import jax
    from repro.dist.sharding import spec_for_param

    m = FakeMesh()
    # column-parallel qkv
    assert spec_for_param("layers/attn/wq", (24, 64, 128), np.dtype("float32"),
                          m) == P(None, None, "model")
    # QTensor child path suffixes are stripped before rule matching
    assert spec_for_param("layers/attn/wq/0/0", (24, 64, 128),
                          np.dtype("int8"), m) == P(None, None, "model")
    # indivisible dim falls back to replication, not an error
    assert spec_for_param("layers/attn/wq", (24, 64, 126),
                          np.dtype("float32"), m) == P(None, None, None)
    # permutation indices always replicate
    assert spec_for_param("layers/attn/wq/2", (24, 128), np.dtype("int32"),
                          m) == P()
    # expert weights: EP on the (stacked) expert axis 1 of (L, E, D, F)
    assert spec_for_param("layers/moe/experts/w1", (8, 16, 64, 128),
                          np.dtype("float32"), m) == P(None, "model", None,
                                                       None)
    # fsdp adds a data axis on the first free divisible dim of big tensors
    s = spec_for_param("layers/mlp/w1", (24, 512, 256), np.dtype("float32"),
                       m, fsdp=True)
    assert s == P("data", None, "model") or s == P(("data",), None, "model")


def _leaves_with_specs(tree, specs):
    """[(path_str, leaf, spec)] — QTensor leaves flatten through."""
    import jax
    from repro.core.calibrate import path_str
    lp, treedef = jax.tree_util.tree_flatten_with_path(tree)
    sp = jax.tree_util.tree_leaves(specs,
                                   is_leaf=lambda x: isinstance(x, P))
    assert len(lp) == len(sp)
    return [(path_str(p), leaf, spec) for (p, leaf), spec in zip(lp, sp)]


def test_param_specs_over_quantized_model_artifact():
    """ISSUE 4 satellite: QTensor children of a QuantizedModel co-shard —
    the merged-byte QM2Q payload and its per-column scales all split on the
    filter (last) axis, act scales and any integer index leaves replicate."""
    import jax
    from repro.configs.registry import REDUCED
    from repro.dist.sharding import param_specs, spec_for_param
    from repro.models import get_model
    from repro.recipe import quantize

    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    qm = quantize(cfg, params, "m2q-w8a8")  # synthesized calibration
    specs = param_specs(qm.params, FakeMesh())

    rows = _leaves_with_specs(qm.params, specs)
    # mixed-decision attn projection: QM2Q children 0..3 (payload, u_scale,
    # u_zp, a_scale) must CO-shard on the filter axis
    wq = {path: (leaf, spec) for path, leaf, spec in rows
          if "attn/wq" in path}
    assert wq, "expected QM2Q children under layers/attn/wq"
    payload = [v for p, v in wq.items() if p.endswith("/0")]
    assert payload and payload[0][0].dtype == np.int8  # merged byte array
    co = {p: v for p, v in wq.items()
          if p.split("/")[-1] in ("0", "1", "2", "3")}
    assert len(co) == 4
    for path, (leaf, spec) in co.items():
        assert spec[-1] == "model", (path, spec)     # filter-axis co-shard
    # column-parallel consumer pairs with row-parallel wo (Megatron sandwich)
    wo = [(leaf, spec) for path, leaf, spec in rows
          if "attn/wo" in path and path.endswith("/0")]
    assert wo and wo[0][1][-2] == "model"
    # int32 index leaves would replicate (the merged layout has none left —
    # assert the rule directly, and that no index leaf survived)
    assert spec_for_param("layers/attn/wq/5", (2, 64), np.dtype("int32"),
                          FakeMesh()) == P()
    for path, leaf, spec in rows:
        if np.dtype(leaf.dtype).kind in "iu" and leaf.dtype.itemsize >= 4:
            assert spec == P(), (path, spec)


def test_cache_specs_cover_every_cache_family():
    """cache_specs on each family's init_cache: batch rows over 'data'
    wherever divisible (axis 0 for per-slot vectors, axis 1 under the
    stacked layer dim), attention heads over 'model' when asked."""
    import jax
    from repro.configs.registry import REDUCED
    from repro.dist.sharding import cache_specs
    from repro.models import get_model

    m = FakeMesh()
    for name in ("qwen1.5-0.5b", "rwkv6-3b", "recurrentgemma-9b"):
        cfg = REDUCED[name]
        model = get_model(cfg)
        cache = model.init_cache(cfg, 8, 16)
        specs = cache_specs(cache, m, shard_model=True)
        checked = 0
        for path, leaf, spec in _leaves_with_specs(cache, specs):
            nd = len(leaf.shape)
            if nd == 0:
                continue
            bdim = 0 if nd == 1 else 1
            want = "data" if leaf.shape[bdim] % 4 == 0 else None
            assert spec[bdim] == want, (name, path, leaf.shape, spec)
            if nd >= 5:  # (L, B, T, H, Dh) attention cache: heads axis
                want_h = "model" if leaf.shape[3] % 4 == 0 else None
                assert spec[3] == want_h, (name, path, leaf.shape, spec)
            checked += 1
        assert checked >= 2, name  # every family exposes >= 2 state leaves


_SMALL_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax, jax.numpy as jnp
    from repro.configs.registry import REDUCED
    from repro.dist import sharding as shd
    from repro.models import get_model
    from repro.optim.adamw import AdamW
    from repro.train.step import make_train_step, make_serve_step

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = REDUCED["qwen3-14b"].replace(dtype="bfloat16", act_sharding="data",
                                       attn_bf16_mm=True, causal_skip=True)
    model = get_model(cfg)
    with mesh:
        # train step compiles AND runs on 16 virtual devices
        params = model.init(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        opt_state = opt.init(params)
        step = make_train_step(cfg, model, opt)
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                 "labels": jnp.zeros((8, 32), jnp.int32)}
        pspec = shd.param_specs(params, mesh, fsdp=True)
        in_specs = (pspec, type(opt_state)(count=jax.sharding.PartitionSpec(),
                                           m=pspec, v=pspec),
                    shd.batch_specs(batch, mesh))
        fn = jax.jit(step, in_shardings=shd.shardings_from_specs(in_specs, mesh),
                     donate_argnums=(0, 1))
        params2, opt2, metrics = fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        # quantized decode also compiles + runs sharded
        from repro.core import M2QPolicy, ShapeCtx, quantize_model
        qp, _ = quantize_model(model.init(cfg, jax.random.PRNGKey(0)),
                               model.QUANT_RULES, ShapeCtx(tokens_per_step=8),
                               M2QPolicy(intensity_threshold=0.5))
        cache = model.init_cache(cfg, 8, 16)
        serve = make_serve_step(cfg, model)
        qspec = shd.param_specs(qp, mesh)
        sfn = jax.jit(serve, in_shardings=shd.shardings_from_specs(
            (qspec, shd.cache_specs(cache, mesh, shard_model=True),
             shd.batch_specs(jnp.zeros((8, 1), jnp.int32), mesh)), mesh),
            donate_argnums=(1,))
        logits, cache = sfn(qp, cache, jnp.zeros((8, 1), jnp.int32))
        print(json.dumps({"loss": loss,
                          "finite": bool(jnp.isfinite(loss)),
                          "logits_finite": bool(jnp.all(jnp.isfinite(
                              logits.astype(jnp.float32))))}))
""")


@pytest.mark.slow
def test_small_mesh_end_to_end():
    out = subprocess.run([sys.executable, "-c", _SMALL_DRYRUN],
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["finite"] and rec["logits_finite"]


_SERVE_SHARDED_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.registry import REDUCED
    from repro.dist import sharding as shd
    from repro.models import get_model
    from repro.recipe import quantize

    mesh = jax.make_mesh((4, 4), ("data", "model"))

    def assert_on_spec(tree, specs, what):
        leaves = jax.tree_util.tree_leaves(tree)
        specl = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        assert len(leaves) == len(specl), what
        for leaf, spec in zip(leaves, specl):
            want = NamedSharding(mesh, spec)
            # is_equivalent_to: spec-level equality modulo trailing-None
            # normalization (a decode-step sharding constraint round-trip
            # drops trailing Nones from the spec)
            assert leaf.sharding.is_equivalent_to(want, leaf.ndim), (
                what, leaf.shape, leaf.sharding, want)

    # ---- token engine: sharded decode over a QuantizedModel -------------
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    qm = quantize(cfg, params, "m2q-w8a8")
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, int(n), dtype=np.int32)
               for n in rng.integers(3, 9, 5)]

    eng = qm.serve(max_batch=8, max_len=32, mesh=mesh)
    # placements match dist.sharding specs EXACTLY (params + decode cache)
    assert_on_spec(eng.params, shd.param_specs(qm.params, mesh), "qparams")
    cspecs = shd.cache_specs(eng.cache, mesh, shard_model=True)
    assert_on_spec(eng.cache, cspecs, "cache@init")
    sharded = [eng.submit(p, max_new_tokens=4) for p in prompts]
    eng.run()
    assert all(r.done for r in sharded)
    # the decode loop kept the cache pinned to spec through every step
    assert_on_spec(eng.cache, cspecs, "cache@end")

    ref_eng = qm.serve(max_batch=8, max_len=32)  # single-placement ref
    ref = [ref_eng.submit(p, max_new_tokens=4) for p in prompts]
    ref_eng.run()
    token_match = all(a.out_tokens == b.out_tokens
                      for a, b in zip(sharded, ref))

    # ---- vision engine: data-parallel sharded batches -------------------
    vcfg = REDUCED["efficientvit-b1-r224"]
    vmodel = get_model(vcfg)
    vparams = vmodel.init(vcfg, jax.random.PRNGKey(1))
    imgs = rng.normal(0, 1, (5, vcfg.img_res, vcfg.img_res, 3)).astype(
        np.float32)
    vqm = quantize(vcfg, vparams, "m2q-w8a8", calib_batches=[imgs[:2]])
    veng = vqm.serve(max_batch=8, mesh=mesh)
    assert veng.min_bucket == 4  # bucket floor = data axis: even shards
    assert_on_spec(veng.params, shd.param_specs(vqm.params, mesh),
                   "vision qparams")
    handles = [veng.submit(im) for im in imgs]
    out = veng.flush()
    assert veng.stats.buckets_used == {8}  # 5 -> pow2 8, 2 rows/device
    ref_logits = np.asarray(vqm.forward(jnp.asarray(imgs)))
    vision_close = bool(np.allclose(out, ref_logits, rtol=1e-3, atol=1e-3))
    handle_rows = bool(np.allclose(
        np.stack([h.result() for h in handles]), out))

    print(json.dumps({"token_match": token_match,
                      "vision_close": vision_close,
                      "handle_rows": handle_rows,
                      "devices": len(jax.devices())}))
""")


@pytest.mark.slow
def test_sharded_serving_quantized_model_both_engines():
    """ISSUE 4 acceptance: a 16-virtual-device dry-run serves a
    QuantizedModel through BOTH engines with ``mesh=`` — param and cache
    placements equal the dist.sharding specs (asserted in-subprocess), the
    sharded token decode reproduces the unsharded greedy tokens, and the
    sharded vision logits match the direct quantized forward."""
    out = subprocess.run([sys.executable, "-c", _SERVE_SHARDED_DRYRUN],
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["devices"] == 16
    assert rec["token_match"] and rec["vision_close"] and rec["handle_rows"]
