"""Sharding rules + a small-mesh end-to-end dry-run (subprocess: the device
count must be fixed before jax initializes)."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P


def test_spec_rules_divisibility_and_paths():
    import jax
    from repro.dist.sharding import spec_for_param
    mesh = None

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 4}

    m = FakeMesh()
    # column-parallel qkv
    assert spec_for_param("layers/attn/wq", (24, 64, 128), np.dtype("float32"),
                          m) == P(None, None, "model")
    # QTensor child path suffixes are stripped before rule matching
    assert spec_for_param("layers/attn/wq/0/0", (24, 64, 128),
                          np.dtype("int8"), m) == P(None, None, "model")
    # indivisible dim falls back to replication, not an error
    assert spec_for_param("layers/attn/wq", (24, 64, 126),
                          np.dtype("float32"), m) == P(None, None, None)
    # permutation indices always replicate
    assert spec_for_param("layers/attn/wq/2", (24, 128), np.dtype("int32"),
                          m) == P()
    # expert weights: EP on the (stacked) expert axis 1 of (L, E, D, F)
    assert spec_for_param("layers/moe/experts/w1", (8, 16, 64, 128),
                          np.dtype("float32"), m) == P(None, "model", None,
                                                       None)
    # fsdp adds a data axis on the first free divisible dim of big tensors
    s = spec_for_param("layers/mlp/w1", (24, 512, 256), np.dtype("float32"),
                       m, fsdp=True)
    assert s == P("data", None, "model") or s == P(("data",), None, "model")


_SMALL_DRYRUN = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
    import json
    import jax, jax.numpy as jnp
    from repro.configs.registry import REDUCED
    from repro.dist import sharding as shd
    from repro.models import get_model
    from repro.optim.adamw import AdamW
    from repro.train.step import make_train_step, make_serve_step

    mesh = jax.make_mesh((4, 4), ("data", "model"))
    cfg = REDUCED["qwen3-14b"].replace(dtype="bfloat16", act_sharding="data",
                                       attn_bf16_mm=True, causal_skip=True)
    model = get_model(cfg)
    with mesh:
        # train step compiles AND runs on 16 virtual devices
        params = model.init(cfg, jax.random.PRNGKey(0))
        opt = AdamW(lr=1e-3)
        opt_state = opt.init(params)
        step = make_train_step(cfg, model, opt)
        batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
                 "labels": jnp.zeros((8, 32), jnp.int32)}
        pspec = shd.param_specs(params, mesh, fsdp=True)
        in_specs = (pspec, type(opt_state)(count=jax.sharding.PartitionSpec(),
                                           m=pspec, v=pspec),
                    shd.batch_specs(batch, mesh))
        fn = jax.jit(step, in_shardings=shd.shardings_from_specs(in_specs, mesh),
                     donate_argnums=(0, 1))
        params2, opt2, metrics = fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        # quantized decode also compiles + runs sharded
        from repro.core import M2QPolicy, ShapeCtx, quantize_model
        qp, _ = quantize_model(model.init(cfg, jax.random.PRNGKey(0)),
                               model.QUANT_RULES, ShapeCtx(tokens_per_step=8),
                               M2QPolicy(intensity_threshold=0.5))
        cache = model.init_cache(cfg, 8, 16)
        serve = make_serve_step(cfg, model)
        qspec = shd.param_specs(qp, mesh)
        sfn = jax.jit(serve, in_shardings=shd.shardings_from_specs(
            (qspec, shd.cache_specs(cache, mesh, shard_model=True),
             shd.batch_specs(jnp.zeros((8, 1), jnp.int32), mesh)), mesh),
            donate_argnums=(1,))
        logits, cache = sfn(qp, cache, jnp.zeros((8, 1), jnp.int32))
        print(json.dumps({"loss": loss,
                          "finite": bool(jnp.isfinite(loss)),
                          "logits_finite": bool(jnp.all(jnp.isfinite(
                              logits.astype(jnp.float32))))}))
""")


@pytest.mark.slow
def test_small_mesh_end_to_end():
    out = subprocess.run([sys.executable, "-c", _SMALL_DRYRUN],
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["finite"] and rec["logits_finite"]
