"""Unit + (optional) hypothesis property tests for the M2Q core invariants.

The property tests need the ``hypothesis`` package; when it is absent they
are skipped and the deterministic cases still run (the container image does
not ship hypothesis).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    from hypothesis.extra import numpy as hnp
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic tests still run
    HAVE_HYPOTHESIS = False

from repro.core import (
    QAPoT, QM2Q, QUniform, M2QPolicy, ShapeCtx,
    apot_codebook, apot_dequantize, apot_quantize,
    fake_quant_apot, fake_quant_pot, fake_quant_uniform,
    pot_dequantize, pot_quantize, quantize_act, select_schemes,
    quantize_model,
)
from repro.core.apply import abstract_quantize_model
from repro.core.packing import (apot_decode_values, apot_encode, pack_int4,
                                unpack_int4)

if HAVE_HYPOTHESIS:
    finite_f32 = st.floats(min_value=-4.0, max_value=4.0, width=32,
                           allow_nan=False, allow_infinity=False)

    def w_arrays(min_side=2, max_side=24):
        return hnp.arrays(np.float32,
                          hnp.array_shapes(min_dims=2, max_dims=2,
                                           min_side=min_side,
                                           max_side=max_side),
                          elements=finite_f32)


# ---------------------------------------------------------------------------
# uniform (Eq. 1-2)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(w=w_arrays(), bits=st.sampled_from([3, 4, 5, 6, 7, 8]))
    def test_uniform_error_bounded_by_half_step(w, bits):
        from repro.core.quant import uniform_quantize, uniform_dequantize
        u = uniform_quantize(jnp.asarray(w), bits=bits, axis=-1)
        w_hat = np.asarray(uniform_dequantize(u))
        step = np.asarray(u.scale)
        err = np.abs(w - w_hat)
        assert np.all(err <= 0.5 * step + 1e-5)


def test_uniform_monotone_in_bits_gaussian():
    """More bits -> lower MSE on generic (Gaussian) weights.  NOTE: strict
    per-tensor monotonicity is FALSE in general — the 3-bit grid (range/7
    steps) is not a subset of the 5-bit grid (range/31), so inputs lying
    exactly on the coarse grid quantize losslessly at 3 bits but not at 5
    (hypothesis found such a counterexample); the trend holds on continuous
    distributions, which is what the paper's Table II sweeps."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.05, (64, 64)).astype("float32"))
    errs = [float(jnp.mean((w - fake_quant_uniform(w, bits=b)) ** 2))
            for b in (3, 5, 8)]
    assert errs[0] > errs[1] > errs[2]


# ---------------------------------------------------------------------------
# PoT (Eq. 3) / APoT (Eq. 5)
# ---------------------------------------------------------------------------


def test_pot_paper_worked_example():
    # paper: W=-0.26, S=2 -> s=-1, p=-3 -> dequant -0.25
    t = pot_quantize(jnp.asarray([[-0.26, 1.74]]), bits=5, axis=None)
    w_hat = np.asarray(pot_dequantize(t))
    assert abs(w_hat[0, 0] - (-0.25)) < 1e-6


def test_pot_denormal_weights_do_not_overflow_int8_exponent():
    """Regression: bits=8 gives the paper clip bound -255, but p is stored
    int8 — a subnormal-tiny weight (log2 ~ -149) used to wrap to a POSITIVE
    exponent and explode dequant to >> scale.  The exponent clamp must keep
    every stored p in int8 range and the reconstruction <= the scale."""
    w = jnp.asarray([[1e-40, -3e-39, 1e-30, 0.5, -1.0]], jnp.float32)
    t = pot_quantize(w, bits=8, axis=None)
    assert int(np.asarray(t.p).min()) >= -127
    assert int(np.asarray(t.p).max()) <= 0
    w_hat = np.asarray(pot_dequantize(t))
    assert np.all(np.isfinite(w_hat))
    assert np.all(np.abs(w_hat) <= float(np.asarray(t.scale)) * (1 + 1e-6))
    # tiny magnitudes reconstruct to (essentially) zero, not garbage
    assert np.all(np.abs(w_hat[0, :3]) < 1e-6)
    # and normal magnitudes still land on their nearest PoT level (the
    # worst-case relative error of a power-of-two grid is ~1/3)
    assert abs(w_hat[0, 3] - 0.5) <= 0.5 / 3 + 1e-6
    assert abs(w_hat[0, 4] + 1.0) <= 1.0 / 3 + 1e-6


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(w=w_arrays())
    def test_apot_decode_matches_codebook(w):
        t = apot_quantize(jnp.asarray(w), axis=-1)
        vals = np.abs(np.asarray(apot_dequantize(t)) / np.asarray(t.scale))
        cb = apot_codebook()
        # every reconstructed magnitude is (numerically) a codebook entry
        d = np.min(np.abs(vals[..., None] - cb[None, None]), axis=-1)
        assert np.all(d < 1e-5)

    @settings(max_examples=30, deadline=None)
    @given(w=w_arrays())
    def test_apot_encode_decode_roundtrip(w):
        t = apot_quantize(jnp.asarray(w), axis=-1)
        codes = apot_encode(t)
        vals = np.asarray(apot_decode_values(codes)) * np.asarray(t.scale)
        np.testing.assert_allclose(vals, np.asarray(apot_dequantize(t)),
                                   rtol=1e-6, atol=1e-7)


def test_apot_roundtrip_deterministic():
    """Encode/decode round-trip on a fixed Gaussian draw (keeps coverage
    when hypothesis is unavailable)."""
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.normal(0, 0.07, (24, 18)).astype("float32"))
    t = apot_quantize(w, axis=-1)
    codes = apot_encode(t)
    vals = np.asarray(apot_decode_values(codes)) * np.asarray(t.scale)
    np.testing.assert_allclose(vals, np.asarray(apot_dequantize(t)),
                               rtol=1e-6, atol=1e-7)


def test_scheme_error_ordering_gaussian():
    """Paper Table I trend: PoT < APoT < mixed ~ uniform (accuracy), i.e.
    MSE ordering uniform <= m2q <= apot <= pot on gaussian filters."""
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.05, (256, 64)).astype("float32"))
    e_u = float(jnp.mean((w - fake_quant_uniform(w, 8)) ** 2))
    e_p = float(jnp.mean((w - fake_quant_pot(w, 3)) ** 2))
    e_a = float(jnp.mean((w - fake_quant_apot(w)) ** 2))
    asn = select_schemes(w, ratio=0.5)
    qm = QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx)
    e_m = float(jnp.mean((w - qm.dequant()) ** 2))
    assert e_u <= e_m <= e_a <= e_p


# ---------------------------------------------------------------------------
# packing
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    @settings(max_examples=40, deadline=None)
    @given(q=hnp.arrays(np.uint8,
                        hnp.array_shapes(min_dims=2, max_dims=3, min_side=2,
                                         max_side=16).map(
                            lambda s: s[:-1] + (s[-1] + s[-1] % 2,)),
                        elements=st.integers(0, 15)))
    def test_int4_pack_roundtrip(q):
        packed = pack_int4(jnp.asarray(q))
        assert packed.shape[-1] == q.shape[-1] // 2
        np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), q)


def test_int4_pack_roundtrip_deterministic():
    rng = np.random.default_rng(4)
    q = rng.integers(0, 16, (7, 12), dtype=np.uint8)
    packed = pack_int4(jnp.asarray(q))
    assert packed.shape[-1] == q.shape[-1] // 2
    np.testing.assert_array_equal(np.asarray(unpack_int4(packed)), q)


# ---------------------------------------------------------------------------
# scheme selection (Eq. 6)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(w=w_arrays(min_side=4))
    def test_select_schemes_ratio_and_partition(w):
        asn = select_schemes(jnp.asarray(w), ratio=0.5)
        n = w.shape[-1]
        assert len(asn.apot_idx) == n // 2
        both = np.concatenate([asn.apot_idx, asn.uniform_idx])
        np.testing.assert_array_equal(np.sort(both), np.arange(n))

    @settings(max_examples=15, deadline=None)
    @given(w=w_arrays(min_side=4))
    def test_unconstrained_selection_no_worse_than_uniform(w):
        """Eq. 6 argmin: per-filter min(mse_u, mse_a) <= uniform-only MSE."""
        wj = jnp.asarray(w)
        asn = select_schemes(wj, ratio=None)
        per_filter = np.minimum(asn.mse_uniform, asn.mse_apot)
        assert np.all(per_filter <= asn.mse_uniform + 1e-12)


def test_select_schemes_ratio_partition_deterministic():
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.normal(0, 0.1, (40, 11)).astype("float32"))
    asn = select_schemes(w, ratio=0.5)
    assert len(asn.apot_idx) == 11 // 2
    both = np.concatenate([asn.apot_idx, asn.uniform_idx])
    np.testing.assert_array_equal(np.sort(both), np.arange(11))


def test_m2q_merged_layout_partitions_columns():
    """Permutation-free merged layout: every column is owned by exactly one
    engine (u_scale and a_scale masks are complementary) and the split
    honors the 1:1 ratio."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(0, 0.1, (32, 10)).astype("float32"))
    asn = select_schemes(w)
    q = QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx)
    u_mask = np.asarray(q.u_scale.reshape(-1)) != 0
    a_mask = np.asarray(q.a_scale.reshape(-1)) != 0
    np.testing.assert_array_equal(u_mask, ~a_mask)
    assert u_mask.sum() == q.n_uniform == 5
    assert a_mask.sum() == q.n_apot == 5
    # columns ended up at their ORIGINAL positions
    np.testing.assert_array_equal(np.nonzero(a_mask)[0],
                                  np.sort(asn.apot_idx))
    np.testing.assert_array_equal(np.asarray(q.scheme_mask()), u_mask)


def test_m2q_merged_dequant_matches_halves():
    """The merged byte payload reconstructs exactly what per-half
    quantization (the pre-refactor layout) reconstructs, column by column."""
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(0, 0.05, (48, 14)).astype("float32"))
    asn = select_schemes(w, ratio=0.5)
    q = QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx)
    old = np.zeros(w.shape, np.float32)
    old[:, asn.uniform_idx] = np.asarray(
        fake_quant_uniform(w[:, asn.uniform_idx], bits=8))
    old[:, asn.apot_idx] = np.asarray(fake_quant_apot(w[:, asn.apot_idx]))
    np.testing.assert_allclose(np.asarray(q.dequant()), old,
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# activation quant + integer path
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(x=hnp.arrays(np.float32, (8, 16), elements=finite_f32),
           mx=st.floats(0.1, 8.0))
    def test_quantize_act_bounds(x, mx):
        s = jnp.float32(mx / 127.0)
        xq = np.asarray(quantize_act(jnp.asarray(x), s))
        assert xq.dtype == np.int8
        assert xq.min() >= -127 and xq.max() <= 127


def test_int8_path_close_to_dequant_path():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.normal(0, 0.05, (128, 64)).astype("float32"))
    x = jnp.asarray(rng.normal(0, 1, (16, 128)).astype("float32"))
    qt = QUniform.quantize(w, bits=8, act_max_abs=jnp.max(jnp.abs(x)))
    y_int = qt.matmul(x)
    qt_f = QUniform.quantize(w, bits=8)  # no act scale -> dequant path
    y_deq = qt_f.matmul(x)
    rel = float(jnp.linalg.norm(y_int - y_deq) / jnp.linalg.norm(y_deq))
    assert rel < 0.02


# ---------------------------------------------------------------------------
# abstract twin agrees with concrete quantization
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "dbrx-132b", "rwkv6-3b",
                                  "efficientvit-b1-r224"])
def test_abstract_quantize_matches_concrete(arch):
    from repro.configs.registry import REDUCED
    from repro.models import get_model
    cfg = REDUCED[arch]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    ctx = ShapeCtx(tokens_per_step=10_000_000,
                   moe_top_k=max(cfg.moe_top_k, 1),
                   moe_num_experts=max(cfg.moe_experts, 1))
    pol = M2QPolicy(intensity_threshold=1.0, quantize_activations=False)
    qp, _ = quantize_model(params, model.QUANT_RULES, ctx, pol)
    abs_params = jax.eval_shape(lambda: model.init(cfg, jax.random.PRNGKey(0)))
    qp_abs = abstract_quantize_model(abs_params, model.QUANT_RULES, ctx, pol,
                                     with_act_scales=False)
    conc = jax.tree_util.tree_flatten_with_path(qp)[0]
    abst = jax.tree_util.tree_flatten_with_path(qp_abs)[0]
    assert len(conc) == len(abst)
    for (pc, lc), (pa, la) in zip(conc, abst):
        assert jax.tree_util.keystr(pc) == jax.tree_util.keystr(pa)
        assert tuple(lc.shape) == tuple(la.shape), jax.tree_util.keystr(pc)
        assert lc.dtype == la.dtype, jax.tree_util.keystr(pc)
