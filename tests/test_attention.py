"""flash_attention (all variants) and decode_attention vs a naive softmax
reference; RWKV/RG-LRU recurrence invariants."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn


def naive_attention(q, k, v, causal=True, window=None, kv_len=None):
    B, S, Hq, D = q.shape
    T, Hkv = k.shape[1], k.shape[2]
    G = Hq // Hkv
    qh = q.reshape(B, S, Hkv, G, D).astype(np.float32)
    s = np.einsum("bshgd,bthd->bhgst", qh, np.asarray(k, np.float32))
    s /= math.sqrt(D)
    tpos = np.arange(T)
    qpos = np.arange(S)
    mask = np.ones((S, T), bool)
    if kv_len is not None:
        mask &= tpos[None, :] < kv_len
    if causal:
        mask &= tpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= (qpos[:, None] - tpos[None, :]) < window
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p /= np.maximum(p.sum(-1, keepdims=True), 1e-20)
    o = np.einsum("bhgst,bthd->bshgd", p, np.asarray(v, np.float32))
    return o.reshape(B, S, Hq, D)


def _qkv(B=2, S=48, T=48, Hq=4, Hkv=2, D=16, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(0, 1, (B, S, Hq, D)).astype("float32"))
    k = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype("float32"))
    v = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype("float32"))
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 8])
def test_flash_matches_naive(causal, window):
    q, k, v = _qkv()
    out = nn.flash_attention(q, k, v, causal=causal, window=window,
                             q_chunk=16, kv_chunk=16)
    ref = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_flash_causal_skip_matches_dense():
    q, k, v = _qkv(S=64, T=64)
    base = nn.flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    tri = nn.flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                             causal_skip=True)
    np.testing.assert_allclose(np.asarray(tri), np.asarray(base),
                               rtol=1e-5, atol=1e-5)


def test_flash_bf16_mm_close():
    q, k, v = _qkv()
    q, k, v = (t.astype(jnp.bfloat16) for t in (q, k, v))
    out = nn.flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16,
                             bf16_mm=True)
    ref = naive_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               rtol=0.08, atol=0.08)


def test_flash_ragged_seq_and_kvlen():
    q, k, v = _qkv(S=37, T=53)
    out = nn.flash_attention(q, k, v, causal=False, kv_len=jnp.int32(40),
                             q_chunk=16, kv_chunk=16)
    ref = naive_attention(q, k, v, causal=False, kv_len=40)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("window", [None, 8])
def test_decode_attention_matches_naive(window):
    B, T, Hq, Hkv, D = 3, 32, 4, 2, 16
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)).astype("float32"))
    kc = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype("float32"))
    vc = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype("float32"))
    lengths = jnp.asarray([5, 17, 32], jnp.int32)
    out = nn.decode_attention(q, kc, vc, lengths, window=window)
    for b in range(B):
        L = int(lengths[b])
        lo = max(0, L - window) if window else 0
        ref = naive_attention(q[b:b + 1], kc[b:b + 1, lo:L],
                              vc[b:b + 1, lo:L], causal=False)
        np.testing.assert_allclose(np.asarray(out[b]), ref[0], rtol=2e-4,
                                   atol=2e-4)


def test_rwkv_chunked_equals_stepwise():
    B, T, H, d = 2, 37, 3, 8
    rng = np.random.default_rng(2)
    r, k, v, w = (jnp.asarray(rng.normal(0, 1, (B, T, H, d)).astype("float32"))
                  for _ in range(4))
    w = jax.nn.sigmoid(w) * 0.5 + 0.5  # decay in (0.5, 1)
    u = jnp.asarray(rng.normal(0, 1, (H, d)).astype("float32"))
    s0 = jnp.zeros((B, H, d, d), jnp.float32)
    sA, outA = nn.rwkv6_attend(s0, r, k, v, w, u, chunk=8)
    # stepwise reference
    s = s0
    outs = []
    for t in range(T):
        s, o = nn.rwkv6_attend_step(s, r[:, t], k[:, t], v[:, t], w[:, t], u)
        outs.append(o)
    outB = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(outA), np.asarray(outB),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sA), np.asarray(s), rtol=1e-4,
                               atol=1e-4)


def test_rg_lru_scan_equals_step():
    B, T, R = 2, 19, 16
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(0, 1, (B, T, R)).astype("float32"))
    params = {
        "wa": jnp.asarray(rng.normal(0, 0.3, (R, R)).astype("float32")),
        "wx": jnp.asarray(rng.normal(0, 0.3, (R, R)).astype("float32")),
        "ba": jnp.zeros((R,)), "bx": jnp.zeros((R,)),
        "lam": jnp.linspace(0.5, 2.0, R),
    }
    h0 = jnp.asarray(rng.normal(0, 1, (B, R)).astype("float32"))
    hT, y = nn.rg_lru(x, h0, params)
    h = h0
    ys = []
    for t in range(T):
        h, yt = nn.rg_lru_step(x[:, t], h, params)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h), rtol=1e-4,
                               atol=1e-4)


def test_int8_kv_decode_close_to_bf16():
    """decode_attention_int8 (quantized cache) tracks the float path."""
    B, T, Hq, Hkv, D = 2, 24, 4, 2, 16
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)).astype("float32"))
    k = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype("float32"))
    v = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype("float32"))
    lengths = jnp.asarray([10, 24], jnp.int32)
    ref = nn.decode_attention(q, k, v, lengths)
    k8, ks = nn.quantize_kv_rows(k)
    v8, vs = nn.quantize_kv_rows(v)
    out = nn.decode_attention_int8(q, k8, v8, ks, vs, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0.05, atol=0.05)


def test_int8_cache_end_to_end_decode():
    import jax
    from repro.configs.registry import REDUCED
    from repro.models import dense_lm as M
    cfg = REDUCED["granite-3-8b"].replace(kv_cache_dtype="int8")
    params = M.init(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab_size, (2, 12), dtype=np.int32))
    cache = M.init_cache(cfg, 2, 24)
    assert cache["k"].dtype == jnp.int8 and "k_scale" in cache
    lg, cache = M.prefill(cfg, params, cache, toks)
    l1, cache = M.decode_step(cfg, params, cache, toks[:, :1])
    full = M.forward(cfg, params, jnp.concatenate([toks, toks[:, :1]], 1))
    np.testing.assert_allclose(np.asarray(l1[:, 0]), np.asarray(full[:, 12]),
                               rtol=0.15, atol=0.15)
