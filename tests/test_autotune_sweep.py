"""Offline autotune sweep: shape discovery, offline-vs-lazy equivalence,
zero-probe warmed traces, backend/version cache salting, and the CI smoke
gate's missing-shape failure mode (ISSUE 9)."""
import json

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import autotune
from repro.launch import autotune_sweep as sw


def _req(kernel, m, n, k, tunable=True, **meta):
    return autotune.ShapeRequest(
        kernel, m, n, k, tunable,
        tuple(sorted((key, int(v)) for key, v in meta.items())))


REQS = [
    _req("m2q_matmul", 130, 258, 514),
    _req("int8_matmul", 8, 16, 32),
    _req("int4_matmul", 64, 64, 64),
    _req("apot_matmul", 16, 8, 8),
    _req("dwconv_w4", 64, 4, 9, B=1, H=8, W=8, C=4, kh=3, kw=3, stride=1),
    _req("relu_attn", 8, 8, 2, B=1, N=8, H=2, D=8),
    _req("decode_attn_int8", 1, 2, 8, tunable=False, Hkv=2, T=4, window=0),
]


# ---------------------------------------------------------------------------
# offline warm == lazy choices, byte-identical through the JSON round trip
# ---------------------------------------------------------------------------


def test_offline_warm_matches_lazy_choices(tmp_path):
    """Satellite: a warmed cache holds exactly the block triples lazy
    tuning would have chosen for the same shapes on this backend — so
    committing the offline sweep's output changes WHEN tuning happens,
    never WHAT executes."""
    offline = str(tmp_path / "offline.json")
    lazy_path = str(tmp_path / "lazy.json")
    wrote, skipped = sw.warm(REQS, offline, progress=lambda *a: None)
    assert wrote == sum(r.tunable for r in REQS) and skipped == 0
    cache = autotune.AutotuneCache(offline).load()
    for r in REQS:
        if not r.tunable:
            assert cache.get(r.key()) is None
            continue
        lazy = autotune.blocks_for(r.kernel, r.M, r.N, r.K,
                                   interpret=True, cache_path=lazy_path)
        assert cache.get(r.key()) == lazy, r
    # idempotent: a re-run skips every already-cached shape
    wrote2, skipped2 = sw.warm(REQS, offline, progress=lambda *a: None)
    assert wrote2 == 0 and skipped2 == sum(r.tunable for r in REQS)


def test_committed_tuned_cache_overrides_heuristic(tmp_path):
    """Cache-FIRST lookup: a committed entry (e.g. tuned on a real
    accelerator of this backend name) serves its block choice verbatim
    even where live tuning is disabled."""
    path = str(tmp_path / "c.json")
    key = autotune.cache_key("m2q_matmul", 128, 128, 128)
    autotune.AutotuneCache(path).put(key, (8, 8, 8))
    got = autotune.blocks_for("m2q_matmul", 128, 128, 128,
                              interpret=True, cache_path=path)
    assert got == (8, 8, 8)
    assert got != autotune.heuristic_blocks(128, 128, 128)


def test_foreign_backend_entries_never_serve(tmp_path):
    """Backend salt: a cache committed for another backend misses here
    (its entries are valid-format, so they survive load — they just can
    never be looked up under this backend's keys)."""
    path = str(tmp_path / "tpu.json")
    foreign = autotune.cache_key("m2q_matmul", 128, 128, 128, backend="tpu")
    autotune.AutotuneCache(path).put(foreign, (8, 8, 8))
    assert jax.default_backend() != "tpu"
    got = autotune.blocks_for("m2q_matmul", 128, 128, 128,
                              interpret=True, cache_path=path)
    assert got == autotune.heuristic_blocks(128, 128, 128)
    assert autotune.AutotuneCache(path).load().get(foreign) == (8, 8, 8)


# ---------------------------------------------------------------------------
# zero tuning probes at trace time against a warmed cache
# ---------------------------------------------------------------------------


def test_trace_against_warmed_cache_zero_probes(tmp_path, monkeypatch):
    """Satellite: with the default cache pointed at a warmed file, an
    in-trace block request is a pure cache hit — the cached triple is
    served (not the heuristic) and the probe counter stays at zero."""
    path = str(tmp_path / "warm.json")
    key = autotune.cache_key("int8_matmul", 64, 32, 16)
    autotune.AutotuneCache(path).put(key, (32, 16, 8))
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    autotune.reset_probe_count()
    served = []

    def traced(x):
        served.append(autotune.blocks_for("int8_matmul", 64, 32, 16,
                                          interpret=True))
        return x

    jax.jit(traced).lower(jax.ShapeDtypeStruct((2,), jnp.float32))
    assert served == [(32, 16, 8)]
    assert autotune.tuning_probe_count() == 0


def test_probe_counter_counts_live_tuning(tmp_path):
    """The counter the zero-probe assertions rely on actually counts:
    cold-cache force-tuning probes once per candidate; the warmed second
    call probes zero more times and returns the identical choice."""
    path = str(tmp_path / "t.json")
    cands = [(8, 8, 8), (16, 16, 16)]
    autotune.reset_probe_count()
    first = autotune.blocks_for("fake_probe", 32, 32, 32, interpret=False,
                                bench_fn=lambda b: jnp.zeros(()),
                                cache_path=path, candidates=cands,
                                force_tune=True)
    assert autotune.tuning_probe_count() == len(cands)
    second = autotune.blocks_for("fake_probe", 32, 32, 32, interpret=False,
                                 bench_fn=lambda b: jnp.zeros(()),
                                 cache_path=path, candidates=cands)
    assert second == first
    assert autotune.tuning_probe_count() == len(cands)


# ---------------------------------------------------------------------------
# synthetic launch reconstruction (the accelerator tuning path)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("req", [r for r in REQS if r.tunable],
                         ids=lambda r: r.kernel)
def test_bench_fn_reconstructs_real_launches(req):
    """Every tunable kernel's recorded request rebuilds an executable
    launch from synthetic operands (what offline tuning times on a real
    backend) — here executed once in interpret mode for correctness."""
    fn = sw._bench_fn(req, interpret=True)
    assert fn is not None, req
    out = fn(autotune.heuristic_blocks(req.M, req.N, req.K))
    assert jax.block_until_ready(out) is not None


def test_bench_fn_skips_note_only_requests():
    assert sw._bench_fn(next(r for r in REQS if not r.tunable),
                        interpret=True) is None


# ---------------------------------------------------------------------------
# end-to-end: discover -> warm -> smoke (real model, reduced shapes)
# ---------------------------------------------------------------------------


def test_sweep_discovers_warms_and_smokes(tmp_path, monkeypatch):
    """The CI gate end to end on one reduced vision config: discovery
    finds dwconv/matmul/attention shapes, warming covers them all, the
    smoke passes — and deleting one committed entry makes it FAIL (a
    missing shape must never silently re-tune at serving time)."""
    from repro.analysis.traces import shape_requests

    path = str(tmp_path / "cpu.json")
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", path)
    cfg, rec = ["efficientvit-b1-r224"], ("m2q-w8a8",)
    reqs, per_trace = shape_requests(cfg, recipes=rec, hires=())
    assert per_trace and all(n > 0 for n in per_trace.values())
    kinds = {r.kernel for r in reqs}
    assert {"dwconv_w4", "m2q_matmul", "relu_attn"} <= kinds
    sw.warm(reqs, path, progress=lambda *a: None)
    assert sw.smoke(cfg, rec, path, hires=(),
                    progress=lambda *a: None) == 0
    # drop one tunable entry -> the gate must fail loudly
    data = json.loads(open(path).read())
    victim = next(r.key() for r in reqs if r.tunable)
    del data[victim]
    with open(path, "w") as f:
        json.dump(data, f)
    autotune._CACHES.pop(path, None)  # drop the warmed in-process view
    assert sw.smoke(cfg, rec, path, hires=(),
                    progress=lambda *a: None) == 1
