"""Fault-tolerant serving: every behavior here is proven by PROVOKED
failures — the Handle terminal-state machine, per-batch containment in the
scheduler and both engines, admission control (reject/shed), per-request
deadlines over queued AND in-flight work, graceful degradation through the
FallbackGuard, numerics containment, clock misbehavior, and the
deterministic fault-injection harness itself."""
import json
import warnings

import jax
import numpy as np
import pytest

from repro.configs.registry import REDUCED
from repro.kernels import ops as _kops
from repro.models import get_model
from repro.serving.batching import ServeStats
from repro.serving.errors import (CancelledError, InjectedFault,
                                  NumericalError, QueueFullError,
                                  RequestTimedOut)
from repro.serving.faults import FaultInjector, FaultSpec
from repro.serving.scheduler import (CANCELLED, DONE, FAILED, PENDING,
                                     TIMED_OUT, FlushPolicy, OverloadPolicy,
                                     Scheduler)


class FakeClock:
    """Virtual seconds: tests drive deadlines without sleeping."""

    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t

    def advance_ms(self, ms):
        self.t += ms / 1000.0


def _ok_executor(handles, reason):
    for h in handles:
        h.set_result(h.payload)


# ---------------------------------------------------------------------------
# Handle terminal-state machine
# ---------------------------------------------------------------------------


def test_handle_state_machine_one_shot_transitions():
    stats = ServeStats()
    sched = Scheduler(stats=stats, clock=FakeClock())
    h = sched.submit("p")
    assert h.state == PENDING and not h.done() and h.exception() is None
    with pytest.raises(RuntimeError, match="no result yet"):
        h.result()
    assert h.set_result(42) and h.state == DONE and h.done()
    assert h.result() == 42
    # terminal states are sticky: late transitions are dropped, uncounted
    assert not h.set_exception(RuntimeError("late"))
    assert not h.cancel()
    assert h.result() == 42
    assert stats.completed == 1 and stats.failed == 0 and stats.cancelled == 0

    h2 = sched.submit("q")
    assert h2.set_exception(RuntimeError("boom"))
    assert h2.state == FAILED and h2.done() and not h2.cancelled()
    with pytest.raises(RuntimeError, match="boom"):
        h2.result()
    assert not h2.set_result(1)             # too late: stays FAILED
    with pytest.raises(RuntimeError, match="boom"):
        h2.result()

    h3 = sched.submit("r")
    assert h3.cancel() and h3.cancelled() and h3.state == CANCELLED
    with pytest.raises(CancelledError):
        h3.result()
    assert stats.completed == 1 and stats.failed == 1 and stats.cancelled == 1
    assert stats.resolved == 3 == stats.submitted


def test_handle_result_timeout_blocks_then_raises():
    sched = Scheduler(clock=FakeClock())
    h = sched.submit("p")
    with pytest.raises(TimeoutError, match="still PENDING"):
        h.result(timeout=0.01)              # nothing drives the scheduler
    h.set_result("done")
    assert h.result(timeout=0.01) == "done"


# ---------------------------------------------------------------------------
# scheduler: executor containment, overload, queued deadlines
# ---------------------------------------------------------------------------


def test_executor_exception_fails_only_its_batch_and_loop_survives():
    calls = {"n": 0}

    def flaky(handles, reason):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("batch 1 exploded")
        _ok_executor(handles, reason)

    clk = FakeClock()
    sched = Scheduler(policy=FlushPolicy(max_batch=2, max_delay_ms=None),
                      executor=flaky, clock=clk)
    bad = [sched.submit(v) for v in (1, 2)]      # full batch: runs inline
    ok = [sched.submit(v) for v in (3, 4)]
    assert all(h.state == FAILED for h in bad)
    for h in bad:
        with pytest.raises(RuntimeError, match="batch 1 exploded"):
            h.result()
    assert [h.result() for h in ok] == [3, 4]    # the loop kept serving
    s = sched.stats
    assert s.failed == 2 and s.completed == 2
    assert s.resolved == s.submitted == 4


def test_overload_policy_rejects_with_queue_full_error():
    sched = Scheduler(policy=FlushPolicy(max_batch=8, max_delay_ms=None),
                      clock=FakeClock(),
                      overload=OverloadPolicy(max_queue=2))
    h1, h2 = sched.submit(1), sched.submit(2)
    with pytest.raises(QueueFullError, match="max_queue=2"):
        sched.submit(3)
    # the refused submit made no handle: counted rejected, NOT submitted
    assert sched.stats.rejected == 1 and sched.stats.submitted == 2
    assert h1.state == PENDING and h2.state == PENDING


def test_overload_policy_sheds_oldest():
    sched = Scheduler(policy=FlushPolicy(max_batch=8, max_delay_ms=None),
                      clock=FakeClock(),
                      overload=OverloadPolicy(max_queue=2, shed_oldest=True))
    h1, h2 = sched.submit(1), sched.submit(2)
    h3 = sched.submit(3)                         # sheds h1, admits h3
    assert h1.state == FAILED
    with pytest.raises(QueueFullError, match="shed"):
        h1.result()
    assert h2.state == PENDING and h3.state == PENDING
    assert sched.stats.shed == 1 and sched.stats.submitted == 3
    assert sched.pending_payloads() == [2, 3]    # freshest traffic wins


def test_queued_request_times_out_and_never_executes():
    clk = FakeClock()
    ran = []

    def exec_(handles, reason):
        ran.extend(h.payload for h in handles)
        _ok_executor(handles, reason)

    sched = Scheduler(policy=FlushPolicy(max_batch=8, max_delay_ms=100.0),
                      executor=exec_, clock=clk)
    doomed = sched.submit("doomed", deadline_ms=20.0)
    safe = sched.submit("safe")
    clk.advance_ms(50)                           # past doomed's deadline,
    sched.poll()                                 # before the admission one
    assert doomed.state == TIMED_OUT
    with pytest.raises(RequestTimedOut):
        doomed.result()
    clk.advance_ms(60)                           # admission deadline fires
    sched.poll()
    assert safe.result() == "safe"
    assert "doomed" not in ran                   # expired work never ran
    assert sched.stats.timed_out == 1 and sched.stats.completed == 1
    with pytest.raises(ValueError, match="deadline_ms"):
        sched.submit("x", deadline_ms=0.0)


def test_cancelled_queued_request_is_dropped_not_executed():
    clk = FakeClock()
    ran = []

    def exec_(handles, reason):
        ran.extend(h.payload for h in handles)
        _ok_executor(handles, reason)

    sched = Scheduler(policy=FlushPolicy(max_batch=8, max_delay_ms=5.0),
                      executor=exec_, clock=clk)
    a, b = sched.submit("a"), sched.submit("b")
    assert a.cancel()
    clk.advance_ms(10)
    sched.poll()
    assert ran == ["b"] and b.result() == "b"
    assert a.state == CANCELLED
    assert sched.stats.resolved == sched.stats.submitted == 2


# ---------------------------------------------------------------------------
# clock misbehavior: the monotonic guard
# ---------------------------------------------------------------------------


def test_backwards_clock_never_unfires_deadline_or_negates_age():
    clk = FakeClock()
    sched = Scheduler(policy=FlushPolicy(max_batch=8, max_delay_ms=50.0),
                      clock=clk)
    clk.t = 10.0
    h = sched.submit("x", deadline_ms=60.0)
    clk.t = 10.040
    assert sched.oldest_age_ms() == pytest.approx(40.0)
    clk.t = 3.0                                  # clock steps BACKWARDS
    # ages never go negative, never even shrink: the guard holds the max
    assert sched.oldest_age_ms() == pytest.approx(40.0)
    assert sched.due() is None and h.state == PENDING
    clk.t = 10.035                               # still pre-deadline: fine
    assert sched.oldest_age_ms() == pytest.approx(40.0)
    clk.t = 10.070                               # past the request deadline
    sched.due()
    assert h.state == TIMED_OUT
    clk.t = 0.0                                  # backwards AGAIN
    assert h.state == TIMED_OUT                  # fired deadlines stay fired
    assert sched.now() >= 10.070


def test_stalled_clock_freezes_ages_without_firing_deadlines():
    clk = FakeClock()
    sched = Scheduler(policy=FlushPolicy(max_batch=8, max_delay_ms=50.0),
                      clock=clk)
    sched.submit("x", deadline_ms=1000.0)
    for _ in range(5):                           # clock never advances
        assert sched.due() is None
        assert sched.oldest_age_ms() == 0.0
    assert sched.pending == 1                    # nothing expired or flushed


# ---------------------------------------------------------------------------
# fault-injection harness
# ---------------------------------------------------------------------------


def test_fault_spec_parse_grammar():
    s = FaultSpec.parse("raise@decode:3")
    assert (s.kind, s.site, s.nth, s.every_k) == ("raise", "decode", 3, None)
    assert s.matches(3) and not s.matches(2) and not s.matches(6)
    r = FaultSpec.parse("nan@vision:*/5")
    assert r.every_k == 5 and r.matches(5) and r.matches(10)
    assert not r.matches(4)
    d = FaultSpec.parse("delay@prefill:1:75")
    assert d.kind == "delay" and d.delay_ms == 75.0
    inj = FaultInjector.parse("raise@decode:2, nan@vision:1")
    assert len(inj.specs) == 2
    for bad in ("oops", "explode@x:1", "raise@:1", "raise@a:zero",
                "raise@a:*/0"):
        with pytest.raises(ValueError, match="fault"):
            FaultInjector.parse(bad)


def test_fault_injector_fires_on_exact_call_and_from_env(monkeypatch):
    inj = FaultInjector.parse("raise@decode:2")
    assert inj.on_call("decode") is None         # call 1: clean
    act = inj.on_call("decode")                  # call 2: fires
    with pytest.raises(InjectedFault, match="call 2"):
        act.fire()
    assert inj.on_call("decode") is None         # call 3: clean again
    assert inj.on_call("vision") is None         # other sites untouched
    assert inj.fired == [("decode", 2, "raise")]
    assert inj.summary()["calls"] == {"decode": 3, "vision": 1}

    from repro.serving import faults
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    assert faults.from_env() is None
    monkeypatch.setenv(faults.ENV_VAR, "nan@vision:1")
    env_inj = faults.from_env()
    assert env_inj is not None and env_inj.specs[0].kind == "nan"
    monkeypatch.setenv(faults.ENV_VAR, "garbage")
    with pytest.raises(ValueError, match="malformed fault spec"):
        faults.from_env()


# ---------------------------------------------------------------------------
# FallbackGuard: graceful degradation to the XLA path
# ---------------------------------------------------------------------------


def test_fallback_guard_retries_on_xla_with_matching_outputs():
    _kops.reset_trip_latch()
    calls = []

    def step(x, fallback=False):
        calls.append(fallback)
        if not fallback:
            raise RuntimeError("kernel exploded")
        return x * 2.0

    g = _kops.FallbackGuard(check_finite=False, axes=("attn",))
    x = np.arange(4.0)
    np.testing.assert_array_equal(g.run(step, x), x * 2.0)
    assert calls == [False, True] and g.tripped and g.trips == 1
    assert _kops.axis_tripped("attn") and not _kops.axis_tripped("dense")
    # once tripped: straight to the fallback, no repeated kernel attempts
    np.testing.assert_array_equal(g.run(step, x), x * 2.0)
    assert calls == [False, True, True]
    assert g.stats()["retries"] == 2
    _kops.reset_trip_latch()
    assert not _kops.axis_tripped("attn")


def test_fallback_guard_nonfinite_output_trips_finite_check():
    _kops.reset_trip_latch()
    try:
        def step(x, fallback=False):
            return x + (np.nan if not fallback else 0.0)

        g = _kops.FallbackGuard(check_finite=True)
        out = g.run(step, jax.numpy.ones(3))
        assert np.all(np.isfinite(out)) and g.tripped
        assert "non-finite" in g.stats()["last_error"]
    finally:
        _kops.reset_trip_latch()


def test_trip_latch_layers_under_scope_and_over_env(monkeypatch):
    _kops.reset_trip_latch()
    try:
        monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "1")
        assert _kops.dispatch_enabled()
        _kops.trip_axis("dense")
        assert not _kops.dispatch_enabled()      # latch beats the env var
        with _kops.dispatch(dense=True):
            assert _kops.dispatch_enabled()      # explicit scope beats latch
        assert _kops.trip_counts()["dense"] == 1
        with pytest.raises(ValueError, match="unknown dispatch axis"):
            _kops.trip_axis("bogus")
    finally:
        _kops.reset_trip_latch()


# ---------------------------------------------------------------------------
# token engine: containment, deadlines, cancellation, numerics
# ---------------------------------------------------------------------------


def _token_engine(max_batch=3, max_delay_ms=0.0, clock=None, **kw):
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    from repro.serving.engine import Engine
    if clock is not None:
        kw["clock"] = clock
    return cfg, Engine(cfg, params, max_batch=max_batch, max_len=64,
                       max_delay_ms=max_delay_ms, **kw)


def _prompts(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 9)),
                         dtype=np.int32) for _ in range(n)]


def test_engine_prefill_fault_fails_only_its_group():
    cfg, eng = _token_engine(max_batch=2,
                             faults=FaultInjector.parse("raise@prefill:1"))
    cfg2, ref = _token_engine(max_batch=2)
    ps = _prompts(cfg, 4)
    reqs = [eng.submit(p, max_new_tokens=3) for p in ps]
    refs = [ref.submit(p, max_new_tokens=3) for p in ps]
    eng.run()
    ref.run()
    # group 1 (first two requests) died on the injected prefill fault...
    for r in reqs[:2]:
        assert r.handle.state == FAILED
        with pytest.raises(InjectedFault):
            r.handle.result()
    # ...group 2 completed with tokens identical to a fault-free engine
    for r, rr in zip(reqs[2:], refs[2:]):
        assert r.handle.state == DONE
        assert r.out_tokens == rr.out_tokens
    s = eng.stats
    assert s.failed == 2 and s.completed == 2
    assert s.resolved == s.submitted == 4


def test_engine_decode_fault_fails_live_slots_keeps_serving_queue():
    cfg, eng = _token_engine(max_batch=2,
                             faults=FaultInjector.parse("raise@decode:1"))
    ps = _prompts(cfg, 4, seed=1)
    reqs = [eng.submit(p, max_new_tokens=3) for p in ps]
    eng.run()
    # the first decode step failed both slots live in it; the two queued
    # requests were admitted afterwards and completed
    states = [r.handle.state for r in reqs]
    assert states[:2] == [FAILED, FAILED] and states[2:] == [DONE, DONE]
    for r in reqs[2:]:
        assert len(r.out_tokens) == 3
    assert eng.stats.resolved == eng.stats.submitted == 4


def test_engine_nan_decode_fails_one_slot_batchmates_unharmed():
    spec = "nan@decode:1"
    cfg, eng = _token_engine(max_batch=3,
                             faults=FaultInjector.parse(spec))
    cfg2, ref = _token_engine(max_batch=3)
    ps = _prompts(cfg, 3, seed=2)
    reqs = [eng.submit(p, max_new_tokens=4) for p in ps]
    refs = [ref.submit(p, max_new_tokens=4) for p in ps]
    eng.run()
    ref.run()
    # slot 0's cache was NaN-poisoned: that ONE request fails with
    # NumericalError instead of delivering garbage tokens
    assert reqs[0].handle.state == FAILED
    with pytest.raises(NumericalError, match="non-finite"):
        reqs[0].handle.result()
    # its batchmates decoded on, token-for-token identical to fault-free
    for r, rr in zip(reqs[1:], refs[1:]):
        assert r.handle.state == DONE
        assert r.out_tokens == rr.out_tokens
    assert eng.stats.failed == 1 and eng.stats.completed == 2


def test_engine_cancel_in_flight_frees_slot_for_queued_work():
    cfg, eng = _token_engine(max_batch=1)
    ps = _prompts(cfg, 2, seed=3)
    r1 = eng.submit(ps[0], max_new_tokens=30)
    r2 = eng.submit(ps[1], max_new_tokens=2)
    eng.step()                                   # r1 occupies the only slot
    assert eng.slots[0] is not None
    assert r1.handle.cancel()
    eng.run()
    with pytest.raises(CancelledError):
        r1.handle.result()
    # the cancelled request's slot was reclaimed and r2 completed
    assert r2.handle.state == DONE and len(r2.out_tokens) == 2
    assert eng.stats.cancelled == 1 and eng.stats.completed == 1


def test_engine_deadline_expires_in_flight_decode_and_frees_slot():
    clk = FakeClock()
    cfg, eng = _token_engine(max_batch=1, clock=clk)
    ps = _prompts(cfg, 2, seed=4)
    slow = eng.submit(ps[0], max_new_tokens=40, deadline_ms=25.0)
    fast = eng.submit(ps[1], max_new_tokens=2)
    eng.step()                                   # slow takes the only slot
    assert eng.slots[0] is not None and slow.handle.state == PENDING
    clk.advance_ms(30)                           # mid-decode deadline fires
    eng.run()
    assert slow.handle.state == TIMED_OUT
    with pytest.raises(RequestTimedOut, match="mid-decode"):
        slow.handle.result()
    assert fast.handle.state == DONE             # slot freed, queue served
    assert eng.stats.timed_out == 1 and eng.stats.completed == 1


def test_engine_queued_deadline_expires_while_engine_full():
    clk = FakeClock()
    cfg, eng = _token_engine(max_batch=1, clock=clk)
    ps = _prompts(cfg, 2, seed=5)
    eng.submit(ps[0], max_new_tokens=8)
    doomed = eng.submit(ps[1], max_new_tokens=2, deadline_ms=10.0)
    eng.step()                                   # slot busy, doomed queued
    clk.advance_ms(20)
    eng.step()                                   # sweep expires the queue
    assert doomed.handle.state == TIMED_OUT
    assert eng.stats.timed_out == 1


def test_engine_overload_bounds_admission_queue():
    cfg, eng = _token_engine(max_batch=1,
                             overload=OverloadPolicy(max_queue=1))
    ps = _prompts(cfg, 3, seed=6)
    eng.submit(ps[0], max_new_tokens=2)
    eng.step()                                   # slot taken
    eng.submit(ps[1], max_new_tokens=2)          # fills the queue
    with pytest.raises(QueueFullError):
        eng.submit(ps[2], max_new_tokens=2)
    assert eng.stats.rejected == 1
    eng.run()
    assert eng.stats.completed == 2


def test_engine_submit_validates_payload_up_front():
    cfg, eng = _token_engine(max_batch=1)
    with pytest.raises(ValueError, match="1-D"):
        eng.submit(np.zeros((2, 3), np.int32))
    with pytest.raises(ValueError, match="integer token ids"):
        eng.submit(np.array([0.5, 1.5], np.float32))
    with pytest.raises(ValueError, match="in \\[0,"):
        eng.submit(np.array([0, cfg.vocab_size + 7], np.int64))
    with pytest.raises(ValueError, match="in \\[0,"):
        eng.submit(np.array([-1, 3], np.int64))
    assert eng.scheduler.pending == 0            # nothing half-enqueued


# ---------------------------------------------------------------------------
# vision engine: containment, numerics, guard recovery
# ---------------------------------------------------------------------------


def _vision_engine(max_batch=4, max_delay_ms=None, **kw):
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    from repro.serving.vision import VisionEngine
    return cfg, model, params, VisionEngine(
        cfg, params, max_batch=max_batch, max_delay_ms=max_delay_ms, **kw)


def _imgs(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1, (n, cfg.img_res, cfg.img_res, 3)).astype(
        np.float32)


def test_vision_executor_fault_fails_batch_flush_continues():
    cfg, model, params, eng = _vision_engine(
        max_batch=8, faults=FaultInjector.parse("raise@vision:1"))
    imgs = _imgs(cfg, 4)
    handles = [eng.submit(im) for im in imgs]
    # the drained batch hit the injected fault: flush does NOT raise — it
    # fails the batch's handles and returns None (nothing delivered)
    assert eng.flush() is None
    for h in handles:
        assert h.state == FAILED
        with pytest.raises(InjectedFault):
            h.result()
    more = _imgs(cfg, 2, seed=9)
    h2 = [eng.submit(im) for im in more]
    out = eng.flush()                            # the engine kept serving
    ref = np.asarray(model.forward(cfg, params, more))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.stack([h.result() for h in h2]), ref,
                               rtol=1e-4, atol=1e-4)
    s = eng.stats
    assert s.failed == 4 and s.completed == 2
    assert s.resolved == s.submitted == 6


def test_vision_nan_poisoned_row_fails_alone():
    cfg, model, params, eng = _vision_engine(
        max_batch=4, faults=FaultInjector.parse("nan@vision:1"))
    imgs = _imgs(cfg, 4, seed=1)
    handles = [eng.submit(im) for im in imgs]
    eng.flush()
    assert handles[0].state == FAILED
    with pytest.raises(NumericalError, match="non-finite"):
        handles[0].result()
    ref = np.asarray(model.forward(cfg, params, imgs))
    for h, r in zip(handles[1:], ref[1:]):       # batchmates delivered
        np.testing.assert_allclose(h.result(), r, rtol=1e-4, atol=1e-4)
    assert eng.stats.failed == 1 and eng.stats.completed == 3


def test_vision_kernel_fault_recovers_through_fallback_guard():
    """The acceptance-criteria path: a NaN-poisoned kernel-dispatched
    forward is re-run on the XLA path with MATCHING outputs."""
    _kops.reset_trip_latch()
    try:
        cfg, model, params, eng = _vision_engine(
            max_batch=2, faults=FaultInjector.parse("nan@vision.kernel:1"))
        imgs = _imgs(cfg, 2, seed=2)
        handles = [eng.submit(im) for im in imgs]
        eng.flush()
        # the guard tripped on the poisoned primary attempt, retried on
        # XLA, and every request still completed with correct logits
        assert eng.fallback_guard.tripped
        assert _kops.axis_tripped("dense")
        ref = np.asarray(model.forward(cfg, params, imgs))
        np.testing.assert_allclose(
            np.stack([h.result() for h in handles]), ref,
            rtol=1e-4, atol=1e-4)
        assert eng.stats.completed == 2 and eng.stats.failed == 0
    finally:
        _kops.reset_trip_latch()


def test_vision_submit_validates_payload_up_front():
    cfg, model, params, eng = _vision_engine(max_batch=2)
    ok = _imgs(cfg, 1)[0]
    with pytest.raises(ValueError, match="expected"):
        eng.submit(ok[:-1])                      # wrong shape
    with pytest.raises(ValueError, match="dtype"):
        eng.submit(np.full(ok.shape, "x", dtype=object))
    bad = ok.copy()
    bad[0, 0, 0] = np.nan
    with pytest.raises(ValueError, match="NaN/Inf"):
        eng.submit(bad)
    assert eng.scheduler.pending == 0


def test_vision_queued_deadline_times_out():
    clk = FakeClock()
    cfg, model, params, eng = _vision_engine(max_batch=8, max_delay_ms=100.0,
                                             clock=clk)
    imgs = _imgs(cfg, 2, seed=3)
    doomed = eng.submit(imgs[0], deadline_ms=10.0)
    safe = eng.submit(imgs[1])
    clk.advance_ms(50)
    eng.poll()
    assert doomed.state == TIMED_OUT
    clk.advance_ms(60)
    eng.poll()
    assert safe.state == DONE
    assert eng.stats.timed_out == 1 and eng.stats.completed == 1


# ---------------------------------------------------------------------------
# satellites: autotune corruption, calibration numerics
# ---------------------------------------------------------------------------


def test_autotune_cache_tolerates_corruption(tmp_path):
    from repro.kernels.autotune import AutotuneCache, cache_key
    path = tmp_path / "autotune.json"
    key = cache_key("kern", 8, 8, 8, backend="cpu")
    good = cache_key("kern", 16, 16, 16, backend="cpu")
    cases = [
        "{truncated",                            # invalid JSON
        json.dumps([1, 2, 3]),                   # non-dict top level
        json.dumps({"k": "not-a-triple"}),       # corrupt entry
        json.dumps({"k": [8, "x", 8]}),          # non-int member
    ]
    for text in cases:
        path.write_text(text)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            cache = AutotuneCache(str(path)).load()
            assert len(cache) == 0               # rebuilt, not crashed
            assert any(issubclass(x.category, RuntimeWarning) for x in w)
        # save() merges through the same corrupt file without raising,
        # and the rewritten file is clean JSON
        cache.put(key, (8, 8, 8))
        reread = AutotuneCache(str(path)).load()
        assert reread.get(key) == (8, 8, 8)
    # valid entries survive alongside dropped corrupt ones
    path.write_text(json.dumps({good: [16, 16, 16], "bad": [1, 2]}))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cache = AutotuneCache(str(path)).load()
    assert cache.get(good) == (16, 16, 16) and cache.get("bad") is None
    assert any("corrupt entries" in str(x.message) for x in w)


def test_calibration_rejects_nonfinite_activations():
    from repro.core.calibrate import CalibTensor
    store = {}
    t = CalibTensor(jax.numpy.ones((4, 4)), "blocks/0/qkv", store)
    t.record(np.ones((2, 4), np.float32))
    assert store["blocks/0/qkv"] == pytest.approx(1.0)
    poisoned = np.ones((2, 4), np.float32)
    poisoned[1, 2] = np.inf
    with pytest.raises(ValueError, match="blocks/0/qkv"):
        t.record(poisoned)
    assert store["blocks/0/qkv"] == pytest.approx(1.0)  # scale unpolluted


# ---------------------------------------------------------------------------
# stats reconciliation + docstring contract enforcement
# ---------------------------------------------------------------------------


def test_servestats_outcome_counters_and_reset():
    s = ServeStats()
    for kind in ("completed", "failed", "cancelled", "timed_out", "shed"):
        s.record_outcome(kind)
    s.record_outcome("rejected")
    assert s.resolved == 5                       # rejected is NOT resolved
    with pytest.raises(ValueError, match="unknown outcome"):
        s.record_outcome("vanished")
    summ = s.summary()
    assert summ["failed"] == 1 and summ["shed"] == 1 and summ["rejected"] == 1
    s.reset()
    assert s.resolved == 0 and s.rejected == 0


# every public serving entry point that can raise (or deliberately never
# raises) must SAY so in its docstring — suite-enforced so the contract
# cannot rot silently
_RAISE_DOCUMENTED = [
    ("repro.serving.scheduler", "Handle.result"),
    ("repro.serving.scheduler", "Scheduler.submit"),
    ("repro.serving.scheduler", "Scheduler.drain"),
    ("repro.serving.scheduler", "FlushPolicy"),
    ("repro.serving.scheduler", "OverloadPolicy"),
    ("repro.serving.engine", "Engine.submit"),
    ("repro.serving.vision", "VisionEngine.submit"),
    ("repro.serving.vision", "VisionEngine.poll"),
    ("repro.serving.vision", "VisionEngine.flush"),
    ("repro.serving.batching", "ServeStats.record_outcome"),
    ("repro.serving.batching", "pow2_bucket"),
    ("repro.serving.faults", "FaultSpec.parse"),
]


@pytest.mark.parametrize("mod_name,qualname", _RAISE_DOCUMENTED,
                         ids=[f"{m}:{q}" for m, q in _RAISE_DOCUMENTED])
def test_public_serving_entry_points_document_raise_behavior(mod_name,
                                                             qualname):
    import importlib
    obj = importlib.import_module(mod_name)
    for part in qualname.split("."):
        obj = getattr(obj, part)
    doc = obj.__doc__ or ""
    assert "aise" in doc, (                      # Raises/raises/re-raises
        f"{mod_name}.{qualname} is a public serving entry point but its "
        "docstring does not document raise behavior")


# ---------------------------------------------------------------------------
# debug numerics: pre-quantization NaN detection on a quantized engine
# ---------------------------------------------------------------------------


def _quantized_int8kv_model():
    """A calibrated (static act scales) int8-KV quantized artifact: the
    exact posture where activation quantization launders a cache NaN into
    finite logits (``NaN.astype(int8)`` is finite)."""
    from repro.recipe import quantize
    cfg = REDUCED["qwen1.5-0.5b"].replace(kv_cache_dtype="int8")
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return quantize(cfg, params, "m2q-w8a8")


def test_debug_numerics_catches_laundered_cache_nan():
    qm = _quantized_int8kv_model()
    kw = dict(max_batch=2, max_len=64)
    dbg = qm.serve(faults=FaultInjector.parse("nan@decode:1"),
                   debug_numerics=True, **kw)
    ref = qm.serve(faults=FaultInjector.parse("nan@decode:1"), **kw)
    ps = _prompts(qm.cfg, 2, seed=5)

    # default engine: the detection boundary — the logits-only check
    # misses the laundered NaN and delivers corrupt-but-finite tokens
    rref = [ref.submit(p, max_new_tokens=4) for p in ps]
    ref.run()
    assert rref[0].handle.state == DONE
    assert all(np.isfinite(rref[0].out_tokens))

    # debug engine: the per-step cache scan sees the NaN'd f32 scale rows
    # and fails ONLY the poisoned slot; its batchmate decodes on
    rdbg = [dbg.submit(p, max_new_tokens=4) for p in ps]
    dbg.run()
    assert rdbg[0].handle.state == FAILED
    with pytest.raises(NumericalError, match="non-finite"):
        rdbg[0].handle.result()
    assert rdbg[1].handle.state == DONE
    assert rdbg[1].out_tokens == rref[1].out_tokens


def test_debug_numerics_defaults_off_and_reads_env(monkeypatch):
    from repro.serving.engine import Engine
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    monkeypatch.delenv("REPRO_DEBUG_NUMERICS", raising=False)
    assert not Engine(cfg, params, max_batch=1, max_len=32).debug_numerics
    monkeypatch.setenv("REPRO_DEBUG_NUMERICS", "1")
    assert Engine(cfg, params, max_batch=1, max_len=32).debug_numerics
    # explicit constructor arg beats the env var
    assert not Engine(cfg, params, max_batch=1, max_len=32,
                      debug_numerics=False).debug_numerics
