"""Integration tests: data determinism, checkpoint atomicity/resume,
training-loss decrease, serving engine, gradient compression."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt
from repro.configs.registry import REDUCED
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.dist.compression import (compress_decompress,
                                    compress_with_feedback, init_residual)
from repro.models import get_model
from repro.serving.engine import Engine
from repro.train.loop import TrainConfig, train


def test_data_deterministic_and_rank_sharded():
    d = SyntheticLM(DataConfig(vocab_size=64, seq_len=16, global_batch=8))
    b1 = d.batch(3)
    b2 = d.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = d.batch(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # rank sharding: disjoint determinism per rank
    r0 = d.batch(5, rank=0, num_ranks=2)
    r1 = d.batch(5, rank=1, num_ranks=2)
    assert r0["tokens"].shape[0] == 4
    assert not np.array_equal(r0["tokens"], r1["tokens"])


def test_checkpoint_roundtrip_and_checksum(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
    ckpt.save(tmp_path, 7, tree, {"step": 7})
    assert ckpt.latest_step(tmp_path) == 7
    restored, extra = ckpt.restore(tmp_path, 7, tree)
    assert extra["step"] == 7
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))
    # corrupt -> checksum failure
    import numpy as _np
    d = Path(tmp_path) / "step_00000007"
    data = dict(_np.load(d / "arrays.npz"))
    data["leaf_00000"] = data["leaf_00000"] + 1
    _np.savez(d / "arrays.npz", **data)
    with pytest.raises(IOError):
        ckpt.restore(tmp_path, 7, tree)


def test_checkpoint_qtensor_tree(tmp_path):
    from repro.core import QM2Q, select_schemes
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.05, (32, 16)).astype("float32"))
    asn = select_schemes(w)
    qt = {"layer": QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx)}
    ckpt.save(tmp_path, 1, qt, {"step": 1})
    restored, _ = ckpt.restore(tmp_path, 1, qt)
    np.testing.assert_array_equal(np.asarray(restored["layer"].payload),
                                  np.asarray(qt["layer"].payload))
    np.testing.assert_allclose(np.asarray(restored["layer"].dequant()),
                               np.asarray(qt["layer"].dequant()))


def test_training_loss_decreases(tmp_path):
    cfg = REDUCED["qwen1.5-0.5b"].replace(vocab_size=64)
    tc = TrainConfig(steps=60, global_batch=8, seq_len=32, lr=1e-3, warmup=10,
                     ckpt_dir=None, metrics_path=str(tmp_path / "m.jsonl"))
    _, _, info = train(cfg, tc)
    first = np.mean(info["losses"][:10])
    last = np.mean(info["losses"][-10:])
    assert last < first - 0.1, (first, last)


def test_training_resume_exact(tmp_path):
    cfg = REDUCED["qwen1.5-0.5b"].replace(vocab_size=64)
    # run 1: 20 steps straight
    tc_full = TrainConfig(steps=20, global_batch=4, seq_len=16, lr=1e-3,
                          ckpt_dir=str(tmp_path / "a"), ckpt_every=100)
    p_full, _, _ = train(cfg, tc_full)
    # run 2: 10 steps, checkpoint, then resume to 20
    tc_half = TrainConfig(steps=10, global_batch=4, seq_len=16, lr=1e-3,
                          ckpt_dir=str(tmp_path / "b"), ckpt_every=100)
    train(cfg, tc_half)
    tc_rest = TrainConfig(steps=20, global_batch=4, seq_len=16, lr=1e-3,
                          ckpt_dir=str(tmp_path / "b"), ckpt_every=100)
    p_resumed, _, info = train(cfg, tc_rest)
    # resumed training consumed the same data (step-indexed) -> same params
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_resumed)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)


def test_serving_engine_continuous_batching():
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 5, dtype=np.int32),
                       max_new_tokens=4 + i) for i in range(5)]
    stats = eng.run()
    assert stats.finished == 5
    assert all(r.done and len(r.out_tokens) == 4 + i
               for i, r in enumerate(reqs))
    # continuous batching actually interleaved (more prefills than slots)
    assert stats.prefills == 5


def test_serving_ragged_batched_prefill_matches_solo_greedy():
    """Right-padded ragged prefill (one batched call for mixed prompt
    lengths) must decode the same greedy tokens as a solo run."""
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, L, dtype=np.int32)
               for L in (3, 7, 5)]
    eng = Engine(cfg, params, max_batch=3, max_len=64)
    batched = [eng.submit(p, max_new_tokens=4) for p in prompts]
    stats = eng.run()
    assert stats.finished == 3
    assert stats.prefill_batches == 1  # ONE call covered all three lengths
    for p, r in zip(prompts, batched):
        solo_eng = Engine(cfg, params, max_batch=1, max_len=64)
        solo = solo_eng.submit(p, max_new_tokens=4)
        solo_eng.run()
        assert solo.out_tokens == r.out_tokens


def test_serving_decode_no_host_transfer_per_token():
    """Regression for the device-resident decode loop: steps that do not
    complete a request perform ZERO device->host transfers (sampling is
    jitted, pending tokens and the output ring stay on device).  The jax
    transfer guard turns any stray ``int(tok)``-style sync into an error."""
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 5, dtype=np.int32),
                       max_new_tokens=12,
                       temperature=0.0 if i == 0 else 0.7)
            for i in range(2)]
    eng.step()  # admission + first decode: compile outside the guard
    with jax.transfer_guard_device_to_host("disallow"):
        for _ in range(8):  # well before any completion
            eng.step()
    eng.run()  # completions (the single allowed sync each) happen here
    assert all(r.done and len(r.out_tokens) == 12 for r in reqs)


def test_engine_rejects_invalid_submissions():
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=1, max_len=32)
    with pytest.raises(ValueError, match="empty prompt"):
        eng.submit(np.zeros((0,), np.int32))
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(np.zeros((8,), np.int32), max_new_tokens=100)


def test_engine_uid_monotonic_after_pops():
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=2, max_len=32)
    rng = np.random.default_rng(0)
    first = [eng.submit(rng.integers(0, cfg.vocab_size, 4, dtype=np.int32),
                        max_new_tokens=2) for _ in range(3)]
    eng.run()  # queue drains to empty
    later = eng.submit(rng.integers(0, cfg.vocab_size, 4, dtype=np.int32))
    uids = [r.uid for r in first] + [later.uid]
    assert uids == sorted(set(uids)), uids  # strictly increasing, no reuse


def test_grad_compression_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(0, 1e-3, (64, 64)).astype("float32"))}
    gc = compress_decompress(g)
    rel = float(jnp.linalg.norm(gc["w"] - g["w"]) / jnp.linalg.norm(g["w"]))
    assert rel < 0.02  # int8 block quantization error
    res = init_residual(g)
    comp, res2 = compress_with_feedback(g, res)
    # residual holds exactly what was lost
    np.testing.assert_allclose(np.asarray(comp["w"] + res2["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-8)


def test_admit_refills_slots_freed_within_the_same_call():
    """Regression: a slot freed by the in-loop _finish_done (max_new_tokens
    == 1 completing at prefill) must be re-admitted within the SAME _admit
    call — computing the free list once left it idle for a full step."""
    cfg = REDUCED["qwen1.5-0.5b"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = Engine(cfg, params, max_batch=1, max_len=32)
    rng = np.random.default_rng(0)
    reqs = [eng.submit(rng.integers(0, cfg.vocab_size, 4, dtype=np.int32),
                       max_new_tokens=1) for _ in range(3)]
    eng._admit()  # ONE admit call drains the whole queue through slot 0
    assert all(r.done and len(r.out_tokens) == 1 for r in reqs)
    assert eng.stats.finished == 3 and not eng.queue
    assert eng.stats.prefill_batches == 3  # one slot -> three passes


def test_vision_engine_pow2_buckets_and_parity():
    from repro.serving.vision import VisionEngine
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = VisionEngine(cfg, params, max_batch=8)
    assert [eng.bucket(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 8]
    rng = np.random.default_rng(0)
    imgs = rng.normal(0, 1, (5, cfg.img_res, cfg.img_res, 3)).astype(
        np.float32)
    out = eng.classify(imgs)
    ref = np.asarray(model.forward(cfg, params, jnp.asarray(imgs)))
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)
    assert out.shape == (5, cfg.n_classes)
    assert eng.stats.buckets_used == {8} and eng.stats.padded_images == 3
    # a multi-chunk ragged batch: 11 -> chunks of 8 + 3 (bucket 4)
    out2 = eng.classify(rng.normal(
        0, 1, (11, cfg.img_res, cfg.img_res, 3)).astype(np.float32))
    assert out2.shape == (11, cfg.n_classes)
    assert eng.stats.buckets_used == {4, 8}
    # submit/flush micro-batching agrees with classify
    for i in range(3):
        eng.submit(imgs[i])
    flushed = eng.flush()
    np.testing.assert_allclose(flushed, ref[:3], rtol=1e-4, atol=1e-4)
    assert eng.flush() is None
    with pytest.raises(ValueError, match="expected"):
        eng.submit(np.zeros((4, 4, 3), np.float32))
