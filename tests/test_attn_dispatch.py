"""The quantized attention hot path (ISSUE 5 tentpole).

Covers: the third ``attn`` dispatch axis (scope/env layering exactly like
the conv axis), the fused relu_attn kernel triangulated against the
kernels/ref.py oracle and the f32 relu_linear_attention across (B,N,H,D)
sweeps including non-multiple-of-block N, the decode_attn_int8 kernel vs
the XLA int8 einsum path, property-style round-trip error bounds for
``quantize_kv_rows``/``decode_attention_int8`` against the f32
``decode_attention``, the HLO proof that the MSA kv/num/den contractions
carry NO f32 dot with attn dispatch on, and the serving engine's int8-KV
decode loop under a pinned attn DispatchConfig.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import nn
from repro.core.quant import act_scale_from_stats
from repro.kernels import ops, ref
from repro.analysis import lint
from repro.analysis.traces import trace_fn


def _rng(seed=0):
    return np.random.default_rng(seed)


def _qkv(B, N, H, D, seed=0):
    rng = _rng(seed)
    return tuple(jnp.asarray(rng.normal(0, 1, (B, N, H, D))
                             .astype(np.float32)) for _ in range(3))


def _scales(q, k, v):
    return (act_scale_from_stats(jnp.maximum(jnp.max(q), 0.0)),
            act_scale_from_stats(jnp.maximum(jnp.max(k), 0.0)),
            act_scale_from_stats(jnp.max(jnp.abs(v))))


# ---------------------------------------------------------------------------
# the attn dispatch axis: scope/env layering, exactly like the conv axis
# ---------------------------------------------------------------------------


def test_attn_dispatch_layering(monkeypatch):
    monkeypatch.delenv("REPRO_PALLAS_DISPATCH", raising=False)
    monkeypatch.delenv("REPRO_PALLAS_ATTN_DISPATCH", raising=False)
    assert not ops.attn_dispatch_enabled()  # CPU backend default
    with ops.dispatch(dense=True):          # attn follows dense when unset
        assert ops.attn_dispatch_enabled()
        with ops.dispatch(attn=False):      # nested: attn off, dense kept
            assert ops.dispatch_enabled()
            assert not ops.attn_dispatch_enabled()
        assert ops.attn_dispatch_enabled()
    # env var is the process default; any scoped field beats it
    monkeypatch.setenv("REPRO_PALLAS_ATTN_DISPATCH", "1")
    assert ops.attn_dispatch_enabled()
    assert not ops.dispatch_enabled()       # attn env does NOT leak to dense
    with ops.dispatch(attn=False):
        assert not ops.attn_dispatch_enabled()
    monkeypatch.setenv("REPRO_PALLAS_ATTN_DISPATCH", "0")
    monkeypatch.setenv("REPRO_PALLAS_DISPATCH", "1")
    assert not ops.attn_dispatch_enabled()  # attn-specific env wins over dense
    with ops.dispatch(dense=True):          # ...but a scope wins over env
        assert ops.attn_dispatch_enabled()
    # DispatchConfig carries the third axis through layered_over
    cfg = ops.DispatchConfig(attn=True).layered_over(
        ops.DispatchConfig(dense=False, conv=True))
    assert (cfg.dense, cfg.conv, cfg.attn) == (False, True, True)


# ---------------------------------------------------------------------------
# relu_attn: kernel == ref == f32 within int8 tolerance
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,N,H,D", [
    (2, 16, 2, 8),      # REDUCED MSA shape, block-aligned
    (1, 196, 4, 16),    # B1-R224 stage-3 token count
    (2, 37, 3, 8),      # non-multiple-of-block N (bn >= 8)
    (1, 50, 5, 32),     # non-multiple N, wider head
    (3, 9, 1, 8),       # N smaller than the minimum block
])
def test_relu_attn_kernel_vs_ref_vs_f32(B, N, H, D):
    q, k, v = _qkv(B, N, H, D, seed=B * 1000 + N + H + D)
    y_ker = ops.relu_attn_op(q, k, v, interpret=True)
    sq, sk, sv = _scales(q, k, v)
    y_ref = ref.relu_attn_ref(q, k, v, sq, sk, sv)
    # kernel == oracle to float rounding (same int math, same order)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               rtol=1e-5, atol=1e-5)
    # vs the f32 path: error is int8-quantization-level, not path-level
    with ops.dispatch(attn=False):
        y_f32 = nn.relu_linear_attention(q, k, v)
    rel = float(jnp.linalg.norm(y_ker - y_f32) / jnp.linalg.norm(y_f32))
    assert rel < 0.05, rel
    assert bool(jnp.all(jnp.isfinite(y_ker)))


def test_relu_attn_autotune_blocks_and_fallback():
    """Interpret mode takes the heuristic q-row block (no benching); an
    explicit ``blocks`` triple pins it and computes the same values."""
    from repro.kernels import autotune
    q, k, v = _qkv(1, 40, 2, 8, seed=11)
    assert autotune.blocks_for("relu_attn", 40, 8, 2, interpret=True) == \
        autotune.heuristic_blocks(40, 8, 2)
    y_auto = ops.relu_attn_op(q, k, v, interpret=True)
    y_pinned = ops.relu_attn_op(q, k, v, interpret=True, blocks=(8, 8, 8))
    np.testing.assert_allclose(np.asarray(y_pinned), np.asarray(y_auto),
                               rtol=1e-6, atol=1e-6)


def test_relu_attn_zero_inputs_are_finite():
    """All-negative q/k ReLU to zero: den == eps must not NaN/Inf."""
    B, N, H, D = 1, 12, 2, 8
    q = -jnp.ones((B, N, H, D), jnp.float32)
    k = -jnp.ones((B, N, H, D), jnp.float32)
    v = jnp.ones((B, N, H, D), jnp.float32)
    y = ops.relu_attn_op(q, k, v, interpret=True)
    np.testing.assert_array_equal(np.asarray(y), np.zeros_like(y))


def test_relu_linear_attention_routes_through_kernel():
    """nn.relu_linear_attention under dispatch(attn=True) IS the fused
    kernel; with attn off it is the f32 einsum chain."""
    q, k, v = _qkv(2, 20, 2, 8, seed=3)
    y_op = ops.relu_attn_op(q, k, v, interpret=True)
    with ops.dispatch(attn=True):
        y_on = nn.relu_linear_attention(q, k, v)
    np.testing.assert_array_equal(np.asarray(y_on), np.asarray(y_op))
    with ops.dispatch(attn=False):
        y_off = nn.relu_linear_attention(q, k, v)
    assert float(jnp.max(jnp.abs(y_on - y_off))) > 0  # int8 vs f32 differ


def test_msa_block_close_under_attn_dispatch():
    """The full MSA block (qkv conv + 5x5 agg + two attention scales +
    proj) stays close to its f32-attention twin when the token mixer runs
    int8 — the model-level guard on the kernel's quantization error."""
    from repro.configs.registry import REDUCED
    from repro.models import efficientvit as evit
    from repro.models import get_model
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    blk = params["stages"][-1][0]["msa"]
    x = jnp.asarray(_rng(5).normal(0, 1, (2, 4, 4, 32)).astype(np.float32))
    with ops.dispatch(attn=False):
        y_f32 = evit._msa(blk, x, cfg.dim_per_head)
    with ops.dispatch(attn=True):
        y_int8 = evit._msa(blk, x, cfg.dim_per_head)
    rel = float(jnp.linalg.norm(y_int8 - y_f32) / jnp.linalg.norm(y_f32))
    assert rel < 0.05, rel


# ---------------------------------------------------------------------------
# decode_attn_int8: kernel == XLA int8 path, bounded error vs f32 decode
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", [None, 8])
@pytest.mark.parametrize("B,T,Hq,Hkv,D", [(3, 24, 4, 2, 16),
                                          (2, 17, 6, 6, 8),
                                          (1, 40, 8, 2, 32)])
def test_decode_attn_kernel_matches_xla_int8(B, T, Hq, Hkv, D, window):
    rng = _rng(B + T + Hq + D)
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.normal(0, 1, (B, T, Hkv, D)).astype(np.float32))
    lengths = jnp.asarray(rng.integers(1, T + 1, (B,)).astype(np.int32))
    k8, ks = nn.quantize_kv_rows(k)
    v8, vs = nn.quantize_kv_rows(v)
    with ops.dispatch(attn=False):
        y_xla = nn.decode_attention_int8(q, k8, v8, ks, vs, lengths,
                                         window=window)
    y_ker = ops.decode_attn_int8_op(q, k8, v8, ks, vs, lengths,
                                    window=window, interpret=True)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_xla),
                               rtol=1e-5, atol=1e-5)
    with ops.dispatch(attn=True):
        y_on = nn.decode_attention_int8(q, k8, v8, ks, vs, lengths,
                                        window=window)
    np.testing.assert_array_equal(np.asarray(y_on), np.asarray(y_ker))


@pytest.mark.parametrize("seed", range(5))
def test_int8_kv_roundtrip_error_bounds(seed):
    """Property-style bounds: (a) quantize_kv_rows reconstruction error is
    at most half an int8 step per row; (b) BOTH int8 decode paths track the
    f32 decode_attention within int8 tolerance on random caches/lengths."""
    rng = _rng(100 + seed)
    B, T, Hq, Hkv, D = 2, 24, 4, 2, 16
    scale_mag = float(rng.uniform(0.1, 4.0))  # vary the dynamic range
    q = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)).astype(np.float32))
    k = jnp.asarray((rng.normal(0, scale_mag, (B, T, Hkv, D)))
                    .astype(np.float32))
    v = jnp.asarray((rng.normal(0, scale_mag, (B, T, Hkv, D)))
                    .astype(np.float32))
    lengths = jnp.asarray(rng.integers(1, T + 1, (B,)).astype(np.int32))
    k8, ks = nn.quantize_kv_rows(k)
    v8, vs = nn.quantize_kv_rows(v)
    # (a) per-row reconstruction bound: |x - q*s| <= s/2 elementwise
    k_hat = k8.astype(np.float32) * np.asarray(ks)[..., None]
    bound = np.asarray(ks)[..., None] / 2 + 1e-6
    assert np.all(np.abs(np.asarray(k) - k_hat) <= bound)
    # (b) decode round-trip vs f32 attention
    ref_f32 = nn.decode_attention(q, k, v, lengths)
    with ops.dispatch(attn=False):
        y_xla = nn.decode_attention_int8(q, k8, v8, ks, vs, lengths)
    y_ker = ops.decode_attn_int8_op(q, k8, v8, ks, vs, lengths,
                                    interpret=True)
    for y in (y_xla, y_ker):
        err = float(jnp.max(jnp.abs(y - ref_f32)))
        assert err < 0.08 * max(scale_mag, 1.0), (seed, err)


# ---------------------------------------------------------------------------
# HLO: no f32 dot for the MSA kv/num/den contractions (acceptance)
# ---------------------------------------------------------------------------


def test_hlo_msa_contractions_have_no_f32_dot():
    """With attn dispatch on, the compiled ReLU linear attention carries
    ONLY integer dots (kv, ksum, num, den all accumulate in int32); the
    f32 path it replaces shows f32 dots (guards a vacuous check).  Same
    property for the decode-attention kernel."""
    q, k, v = _qkv(1, 49, 4, 16, seed=9)

    def fused(q, k, v):
        with ops.dispatch(attn=True):
            return nn.relu_linear_attention(q, k, v)

    def f32(q, k, v):
        with ops.dispatch(attn=False):
            return nn.relu_linear_attention(q, k, v)

    meta = {"expect_no_f32_dot": True, "quantized": False}
    tr = trace_fn(fused, (q, k, v), name="msa/relu-linattn/fused",
                  dispatch=False, meta=dict(meta))
    assert lint(tr, "no-f32-dot") == []  # incl. the non-vacuity sub-check
    # seeded violation: the f32 path it replaces must FIRE the rule
    tr0 = trace_fn(f32, (q, k, v), name="msa/relu-linattn/f32",
                   dispatch=False, meta=dict(meta))
    vs = lint(tr0, "no-f32-dot")
    assert [v.rule for v in vs] == ["no-f32-dot"] and "f32 dot" in \
        vs[0].message

    # decode attention: integer dots only as well
    rng = _rng(10)
    B, T, Hq, Hkv, D = 2, 32, 4, 2, 16
    qd = jnp.asarray(rng.normal(0, 1, (B, 1, Hq, D)).astype(np.float32))
    k8, ks = nn.quantize_kv_rows(jnp.asarray(
        rng.normal(0, 1, (B, T, Hkv, D)).astype(np.float32)))
    v8, vs = nn.quantize_kv_rows(jnp.asarray(
        rng.normal(0, 1, (B, T, Hkv, D)).astype(np.float32)))
    lengths = jnp.asarray([10, 32], jnp.int32)

    def dec(qd, k8, v8, ks, vs, lengths):
        with ops.dispatch(attn=True):
            return nn.decode_attention_int8(qd, k8, v8, ks, vs, lengths)

    tr = trace_fn(dec, (qd, k8, v8, ks, vs, lengths),
                  name="decode-attn/int8kv/fused", dispatch=False,
                  meta={"expect_no_f32_dot": True, "quantized": False})
    assert lint(tr, "no-f32-dot") == []


def test_quantized_msa_forward_hlo_no_f32_attention_dots(monkeypatch):
    """Model-level acceptance: the jitted MSA block of the QUANTIZED
    EfficientViT emits no f32 dot at all with dense+conv+attn dispatch on —
    PWConv/dwconv run the integer conv kernels and the token mixer the
    int8 attention kernel, so every remaining dot is integer.  (The m2q
    mixed-scheme kernel keeps an f32 SAT-engine dot by design, so this
    pins the MSA path on a uniform8 recipe where the property is total.)"""
    from repro.configs.registry import REDUCED
    from repro.models import efficientvit as evit
    from repro.models import get_model
    from repro.recipe import quantize
    cfg = REDUCED["efficientvit-b1-r224"]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    imgs = jnp.asarray(_rng(2).normal(
        0, 1, (1, cfg.img_res, cfg.img_res, 3)).astype(np.float32))
    qm = quantize(cfg, params, "uniform8", calib_batches=[imgs])
    blk = qm.params["stages"][-1][0]["msa"]
    x = jnp.asarray(_rng(3).normal(0, 1, (1, 4, 4, 32)).astype(np.float32))

    def msa_fused(blk, x):
        with ops.dispatch(dense=True, conv=True, attn=True):
            return evit._msa(blk, x, cfg.dim_per_head)

    tr = trace_fn(msa_fused, (blk, x), name="evit/u8/msa-block",
                  dispatch=False, meta={"expect_no_f32_dot": True})
    assert lint(tr, "no-f32-dot") == []

    def msa_f32_attn(blk, x):
        with ops.dispatch(dense=True, conv=True, attn=False):
            return evit._msa(blk, x, cfg.dim_per_head)

    # seeded violation: attention back on the f32 einsums fires the rule
    tr0 = trace_fn(msa_f32_attn, (blk, x), name="evit/u8/msa-block-f32attn",
                   dispatch=False, meta={"expect_no_f32_dot": True})
    assert any(v.rule == "no-f32-dot" and "f32 dot" in v.message
               for v in lint(tr0, "no-f32-dot"))


# ---------------------------------------------------------------------------
# serving: the int8-KV decode loop under a pinned attn DispatchConfig
# ---------------------------------------------------------------------------


def test_engine_decode_with_attn_kernel():
    """End-to-end: an Engine over an int8 KV cache with
    DispatchConfig(attn=True) decodes through the Pallas kernel and
    produces the same tokens as the XLA int8 path (greedy sampling)."""
    from repro.configs.registry import REDUCED
    from repro.models import get_model
    from repro.serving.engine import Engine
    cfg = REDUCED["granite-3-8b"].replace(kv_cache_dtype="int8")
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    prompts = [np.arange(3, dtype=np.int32) + i for i in range(2)]

    def run(dispatch):
        eng = Engine(cfg, params, max_batch=2, max_len=16, dispatch=dispatch)
        reqs = [eng.submit(p, max_new_tokens=3) for p in prompts]
        eng.run()
        return [r.out_tokens for r in reqs]

    toks_xla = run(ops.DispatchConfig(attn=False))
    toks_ker = run(ops.DispatchConfig(dense=False, conv=False, attn=True))
    assert toks_xla == toks_ker
