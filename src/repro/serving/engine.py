"""Continuous-batching serving engine over M2Q-quantized weights.

Slot-based: a fixed decode batch of B slots, each holding one request's KV
cache rows.  New requests prefill into a free slot (the per-slot cache
columns are written via the batched prefill path with left-padding masked
out by per-slot lengths); every engine step decodes one token for all live
slots; finished requests free their slot immediately (continuous batching —
no head-of-line blocking on the longest request).

This is the serving analogue of the paper's deployment: weights are the
QTensor tree from core.quantize_model, executing the int8/APoT/packed-4bit
paths.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import get_model
from ..models.config import ArchConfig


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    out_tokens: Optional[List[int]] = None
    done: bool = False


@dataclasses.dataclass
class EngineStats:
    steps: int = 0
    decoded_tokens: int = 0
    prefills: int = 0
    finished: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params, max_batch: int = 4,
                 max_len: int = 256, seed: int = 0):
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.B = max_batch
        self.T = max_len
        self.key = jax.random.PRNGKey(seed)
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.stats = EngineStats()
        self._decode = jax.jit(partial(self.model.decode_step, cfg))
        # per-slot single-row prefill (batch=1 keeps ragged prompts simple;
        # batched ragged prefill is a recorded optimization)
        self._prefill1 = jax.jit(
            lambda p, c, t: self.model.prefill(cfg, p, c, t))
        self.cache = self.model.init_cache(cfg, max_batch, max_len,
                                           dtype=jnp.float32)
        self._slot_cache_t = jax.eval_shape(
            lambda: self.model.init_cache(cfg, 1, max_len, dtype=jnp.float32))

    # -- request API --------------------------------------------------------
    def submit(self, prompt, max_new_tokens: int = 16,
               temperature: float = 0.0) -> Request:
        req = Request(uid=len(self.queue), prompt=np.asarray(prompt, np.int32),
                      max_new_tokens=max_new_tokens, temperature=temperature,
                      out_tokens=[])
        self.queue.append(req)
        return req

    # -- internals -----------------------------------------------------------
    def _write_slot(self, slot: int, slot_cache):
        """Copy a (1, ...) cache into slot row of the engine cache."""
        def put(dst, src):
            if dst.ndim == 1:  # lengths (B,)
                return dst.at[slot].set(src[0])
            return dst.at[:, slot].set(src[:, 0])

        self.cache = jax.tree.map(put, self.cache, slot_cache)

    def _admit(self):
        for slot in range(self.B):
            if self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                sc = self.model.init_cache(self.cfg, 1, self.T,
                                           dtype=jnp.float32)
                logits, sc = self._prefill1(
                    self.params, sc, jnp.asarray(req.prompt[None]))
                self._write_slot(slot, sc)
                tok = self._sample(logits[0, -1], req)
                req.out_tokens.append(int(tok))
                self.slots[slot] = req
                self._pending_token = getattr(self, "_pending_token",
                                              np.zeros(self.B, np.int32))
                self._pending_token[slot] = int(tok)
                self.stats.prefills += 1

    def _sample(self, logits, req: Request):
        logits = np.asarray(logits[: self.cfg.vocab_size], np.float32)
        if req.temperature <= 0:
            return int(np.argmax(logits))
        self.key, k = jax.random.split(self.key)
        p = jax.nn.softmax(jnp.asarray(logits) / req.temperature)
        return int(jax.random.choice(k, p.shape[0], p=p))

    def step(self) -> int:
        """Admit + one decode step for all live slots. Returns #live."""
        self._admit()
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if not live:
            return 0
        toks = jnp.asarray(
            getattr(self, "_pending_token", np.zeros(self.B, np.int32))
        )[:, None]
        logits, self.cache = self._decode(self.params, self.cache, toks)
        self.stats.steps += 1
        for slot in live:
            req = self.slots[slot]
            tok = self._sample(logits[slot, 0], req)
            req.out_tokens.append(int(tok))
            self._pending_token[slot] = int(tok)
            self.stats.decoded_tokens += 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.stats.finished += 1
                self.slots[slot] = None  # slot freed -> continuous batching
        return len(live)

    def run(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if not self.queue and all(s is None for s in self.slots):
                break
            self.step()
        return self.stats
