"""Continuous-batching serving engine over M2Q-quantized weights.

Slot-based: a fixed decode batch of B slots, each holding one request's KV
cache rows.  New requests prefill into free slots; every engine step decodes
one token for all live slots; finished requests free their slot immediately
(continuous batching — no head-of-line blocking on the longest request).

Admission runs on the shared scheduler core (serving.scheduler): ``submit``
enqueues onto a deadline-aware queue, and each step admits waiting requests
when the flush policy fires — immediately whenever slots are free with the
default ``max_delay_ms=0.0`` (regression-identical to the pre-scheduler
engine), or coalesced into bigger prefill batches when a positive deadline
is configured.  Queue latency, batch occupancy, and ragged-pad fractions
land in the unified ``ServeStats`` both serving engines share.

Device-resident decode loop: sampling (greedy AND temperature) runs inside
the jitted decode step, the pending next-token vector and the per-slot
output ring live on device, and the PRNG key threads through the jit — the
host never reads a token mid-request.  With an int8 KV cache the per-step
attention runs fully integer, and under the ``attn`` dispatch axis
(``DispatchConfig(attn=True)`` / ``REPRO_PALLAS_ATTN_DISPATCH``) it
executes as the fused ``kernels.decode_attn_int8`` Pallas kernel — one
VMEM pass per (batch, kv-head) instead of unfused XLA einsums.  The only device->host transfer is
one fetch of a request's finished token row when it completes (completion
itself is decided by host-side step counting, not by reading tokens).
Prefill is batched over ragged prompts: families that support right-padded
prompts with per-row lengths (``RAGGED_PREFILL``) admit every waiting
request in one call; recurrent families are bucketed by exact prompt length
so pad tokens never pollute their state.

With ``mesh=`` the engine runs sharded: params are placed per
``repro.dist.sharding.param_specs`` (QTensor payloads and scales co-shard),
the decode cache per ``cache_specs`` (batch rows over ``data``, attention
heads over ``model`` when divisible), and the decode step re-pins the cache
sharding every step so placements stay exactly on-spec.

This is the serving analogue of the paper's deployment: weights are the
QTensor tree from core.quantize_model, executing the int8/APoT/packed-4bit
paths.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as _kops
from ..models import get_model
from ..models.config import ArchConfig
from .batching import ServeStats, pow2_bucket
from .scheduler import FlushPolicy, Handle, Scheduler


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    out_tokens: Optional[List[int]] = None
    done: bool = False
    handle: Optional[Handle] = None  # scheduler future (resolves at finish)


@dataclasses.dataclass
class EngineStats(ServeStats):
    """Unified ServeStats + the token engine's decode-loop counters."""

    steps: int = 0
    decoded_tokens: int = 0
    prefills: int = 0
    prefill_batches: int = 0
    finished: int = 0


class Engine:
    def __init__(self, cfg: ArchConfig, params, max_batch: int = 4,
                 max_len: int = 256, seed: int = 0,
                 max_delay_ms: float = 0.0,
                 dispatch: Optional[_kops.DispatchConfig] = None,
                 mesh=None,
                 clock: Callable[[], float] = time.monotonic):
        # scoped kernels.ops.DispatchConfig pinning kernel dispatch for the
        # engine's prefill/decode traces (None inherits env/backend
        # default); the attn axis steers the int8-KV decode-attention
        # kernel in every decode step
        self.dispatch = dispatch
        self.cfg = cfg
        self.model = get_model(cfg)
        self.B = max_batch
        self.T = max_len
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.stats = EngineStats()
        if max_delay_ms is None:
            # None (the vision explicit-flush mode) would leave a sub-
            # max_batch queue waiting forever: the token engine has no
            # drain() path, so admission MUST have a deadline
            raise ValueError(
                "token engine admission needs a deadline: use "
                "max_delay_ms=0.0 (admit whenever slots free) or > 0 "
                "(coalesce prefills), not None")
        # admission queue on the shared scheduler core; max_delay_ms=0.0
        # admits whenever slots are free (the classic behavior), >0
        # coalesces prefills until the batch fills or the deadline fires
        self.scheduler = Scheduler(
            policy=FlushPolicy(max_batch=max_batch,
                               max_delay_ms=max_delay_ms),
            stats=self.stats, clock=clock)
        self._ragged = bool(getattr(self.model, "RAGGED_PREFILL", False))
        self.cache = self.model.init_cache(cfg, max_batch, max_len,
                                           dtype=jnp.float32)
        self.mesh = mesh
        self._cache_shardings = None
        if mesh is not None:
            params, self.cache = self._shard(params, self.cache, mesh)
        self.params = params
        # device-resident decode state
        self.key = jax.random.PRNGKey(seed)
        self._pending = jnp.zeros((max_batch,), jnp.int32)
        self._temps = jnp.zeros((max_batch,), jnp.float32)
        self._outbuf = jnp.zeros((max_batch, max_len), jnp.int32)
        self._counts = jnp.zeros((max_batch,), jnp.int32)
        # host mirror of per-slot emitted-token counts (drives completion
        # without reading token values back)
        self._emitted = [0] * max_batch
        self._decode_step = jax.jit(self._decode_step_impl)
        self._prefill_sample = jax.jit(self._prefill_sample_impl)
        self._prefill_sample_ragged = jax.jit(self._prefill_sample_ragged_impl)

    def _shard(self, params, cache, mesh):
        """Place params/cache per dist.sharding (decode caches shard over
        the mesh; QTensor payload+scale children co-shard by spec)."""
        from ..dist import sharding as shd
        params = jax.device_put(
            params, shd.shardings_from_specs(shd.param_specs(params, mesh),
                                             mesh))
        self._cache_shardings = shd.shardings_from_specs(
            shd.cache_specs(cache, mesh, shard_model=True), mesh)
        return params, jax.device_put(cache, self._cache_shardings)

    # -- request API ---------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        """Requests waiting for admission (FIFO), via the scheduler."""
        return self.scheduler.pending_payloads()

    def submit(self, prompt, max_new_tokens: int = 16,
               temperature: float = 0.0) -> Request:
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) == 0:
            raise ValueError("empty prompt: prefill needs at least one token")
        if max_new_tokens < 1:
            # a zero/negative budget would still burn a full prefill+sample
            # (the first token IS sampled at prefill) and retire with empty
            # output — reject instead of doing work the caller threw away
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens} (every "
                "admitted request decodes at least its prefill-sampled "
                "first token)")
        if len(prompt) + max_new_tokens > self.T:
            # the KV cache and the device output ring are both max_len wide;
            # silently clamping would truncate/corrupt the decoded stream
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f" exceeds max_len ({self.T})")
        req = Request(uid=0, prompt=prompt, max_new_tokens=max_new_tokens,
                      temperature=temperature, out_tokens=[])
        req.handle = self.scheduler.submit(req)
        req.uid = req.handle.uid
        return req

    def _dispatch_scope(self):
        return (_kops.dispatch(self.dispatch) if self.dispatch is not None
                else contextlib.nullcontext())

    # -- jitted cores --------------------------------------------------------
    def _sample_tokens(self, logits, key, temps):
        """(B, V_padded) logits -> (B,) int32 tokens, fully in-graph."""
        lg = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        safe_t = jnp.maximum(temps, 1e-6)[:, None]
        keys = jax.random.split(key, lg.shape[0])
        drawn = jax.vmap(jax.random.categorical)(keys, lg / safe_t)
        return jnp.where(temps > 0, drawn.astype(jnp.int32), greedy)

    def _decode_step_impl(self, params, cache, pending, outbuf, counts,
                          temps, live, key):
        key, k_s = jax.random.split(key)
        logits, cache = self.model.decode_step(self.cfg, params, cache,
                                               pending[:, None])
        tok = self._sample_tokens(logits[:, 0], k_s, temps)
        tok = jnp.where(live, tok, pending)
        b = jnp.arange(self.B)
        outbuf = outbuf.at[b, jnp.minimum(counts, self.T - 1)].set(
            jnp.where(live, tok, outbuf[b, jnp.minimum(counts, self.T - 1)]))
        counts = counts + live.astype(jnp.int32)
        if self._cache_shardings is not None:
            # pin the cache's dist.sharding placement through the step so
            # the sharded decode loop stays exactly on-spec
            cache = jax.tree.map(jax.lax.with_sharding_constraint, cache,
                                 self._cache_shardings)
        return cache, tok, outbuf, counts, key

    def _prefill_sample_impl(self, params, slot_cache, tokens, temps, key):
        logits, slot_cache = self.model.prefill(self.cfg, params, slot_cache,
                                                tokens)
        tok = self._sample_tokens(logits[:, -1], key, temps)
        return tok, slot_cache

    def _prefill_sample_ragged_impl(self, params, slot_cache, tokens,
                                    lengths, temps, key):
        logits, slot_cache = self.model.prefill(self.cfg, params, slot_cache,
                                                tokens, lengths=lengths)
        tok = self._sample_tokens(logits[:, -1], key, temps)
        return tok, slot_cache

    # -- internals -----------------------------------------------------------
    def _write_slots(self, slots: List[int], group_cache):
        """Copy an (n, ...) batched prefill cache into the engine cache."""
        idx = jnp.asarray(slots, jnp.int32)

        def put(dst, src):
            if dst.ndim == 1:  # lengths (B,)
                return dst.at[idx].set(src)
            return dst.at[:, idx].set(src)

        self.cache = jax.tree.map(put, self.cache, group_cache)
        if self._cache_shardings is not None:
            # eager .at[].set left the placement to XLA; re-pin to spec
            self.cache = jax.device_put(self.cache, self._cache_shardings)

    def _admit(self):
        # Free slots and the due-check are recomputed on every pass: the
        # in-loop _finish_done() (max_new_tokens==1 completing at prefill)
        # frees slots that queued requests can take within the SAME admit
        # call — computing ``free`` once left them idle until the next
        # step.  With the default max_delay_ms=0.0 the scheduler is due
        # whenever anything is pending (classic admit-on-free-slot); a
        # positive deadline holds admission to coalesce prefill batches.
        while True:
            free = [i for i, r in enumerate(self.slots) if r is None]
            if not free:
                return
            reason = self.scheduler.due()
            if reason is None:
                return
            cands = self.scheduler.peek(len(free))
            if self._ragged:
                group = list(cands)
            else:  # exact-length bucket: recurrent states must not see
                # padding; one bucket per pass, the rest re-enter next pass
                by_len: Dict[int, List[Handle]] = {}
                for h in cands:
                    by_len.setdefault(len(h.payload.prompt), []).append(h)
                group = next(iter(by_len.values()))
            self.scheduler.pop(group, reason)
            self._prefill_group(free[: len(group)], group)

    def _prefill_group(self, gslots: List[int], handles: List[Handle]):
        greqs = [h.payload for h in handles]
        lens = np.asarray([len(r.prompt) for r in greqs], np.int32)
        pmax = int(lens.max())
        if self._ragged:
            # bucket the padded length to a power of two (capped at
            # max_len): bounds XLA recompiles of the prefill graph to
            # O(B * log T) shape variants instead of one per distinct
            # prompt length; lengths mask the extra pad columns
            pmax = pow2_bucket(pmax, 8, self.T)
        toks = np.zeros((len(greqs), pmax), np.int32)
        for i, r in enumerate(greqs):
            toks[i, : len(r.prompt)] = r.prompt
        sc = self.model.init_cache(self.cfg, len(greqs), self.T,
                                   dtype=jnp.float32)
        temps = jnp.asarray([r.temperature for r in greqs], jnp.float32)
        self.key, k = jax.random.split(self.key)
        with self._dispatch_scope():
            if self._ragged:
                first, sc = self._prefill_sample_ragged(
                    self.params, sc, jnp.asarray(toks), jnp.asarray(lens),
                    temps, k)
            else:
                first, sc = self._prefill_sample(self.params, sc,
                                                 jnp.asarray(toks), temps, k)
        self._write_slots(gslots, sc)
        idx = jnp.asarray(gslots, jnp.int32)
        self._pending = self._pending.at[idx].set(first)
        self._temps = self._temps.at[idx].set(temps)
        self._outbuf = self._outbuf.at[idx, 0].set(first)
        self._counts = self._counts.at[idx].set(1)
        for s, r in zip(gslots, greqs):
            self.slots[s] = r
            self._emitted[s] = 1
        self.stats.prefills += len(greqs)
        self.stats.prefill_batches += 1
        # unified queue-level accounting: real prompt tokens vs the padded
        # (n, pmax) prefill actually executed
        self.stats.record_batch(items=int(lens.sum()),
                                padded=int(len(greqs) * pmax - lens.sum()),
                                capacity=self.B * pmax)
        self._finish_done()  # max_new_tokens == 1 finishes at prefill

    def _finish_done(self):
        """Retire completed slots; the ONLY per-request device->host read."""
        for slot, req in enumerate(self.slots):
            if req is None or self._emitted[slot] < req.max_new_tokens:
                continue
            toks = np.asarray(
                jax.device_get(self._outbuf[slot, : req.max_new_tokens]))
            req.out_tokens = [int(t) for t in toks]
            req.done = True
            if req.handle is not None:
                req.handle.set_result(req.out_tokens)
            self.stats.finished += 1
            self.slots[slot] = None
            self._emitted[slot] = 0

    def step(self) -> int:
        """Admit + one decode step for all live slots. Returns #live."""
        self._admit()
        live_mask = np.asarray([r is not None for r in self.slots], bool)
        live = [i for i in range(self.B) if live_mask[i]]
        if not live:
            return 0
        with self._dispatch_scope():
            self.cache, self._pending, self._outbuf, self._counts, self.key \
                = self._decode_step(self.params, self.cache, self._pending,
                                    self._outbuf, self._counts, self._temps,
                                    jnp.asarray(live_mask), self.key)
        self.stats.steps += 1
        self.stats.decoded_tokens += len(live)
        for slot in live:
            self._emitted[slot] += 1
        self._finish_done()
        return len(live)

    def run(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if self.scheduler.pending == 0 and all(
                    s is None for s in self.slots):
                break
            if self.step() == 0 and self.scheduler.pending \
                    and self.scheduler.clock is time.monotonic:
                # nothing live and the queue not yet due (max_delay_ms > 0
                # holding admission): sleep toward the deadline instead of
                # hot-spinning the step budget away.  Only on the REAL
                # clock — sleeping cannot advance an injected virtual
                # clock, whose driver steps the engine itself
                nd = self.scheduler.next_deadline()
                if nd is not None:
                    delay = nd - self.scheduler.clock()
                    if delay > 0:
                        time.sleep(min(delay, 1e-3))
        return self.stats
