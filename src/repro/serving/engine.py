"""Continuous-batching serving engine over M2Q-quantized weights.

Slot-based: a fixed decode batch of B slots, each holding one request's KV
cache rows.  New requests prefill into free slots; every engine step decodes
one token for all live slots; finished requests free their slot immediately
(continuous batching — no head-of-line blocking on the longest request).

Admission runs on the shared scheduler core (serving.scheduler): ``submit``
enqueues onto a deadline-aware queue, and each step admits waiting requests
when the flush policy fires — immediately whenever slots are free with the
default ``max_delay_ms=0.0`` (regression-identical to the pre-scheduler
engine), or coalesced into bigger prefill batches when a positive deadline
is configured.  Queue latency, batch occupancy, and ragged-pad fractions
land in the unified ``ServeStats`` both serving engines share.

Device-resident decode loop: sampling (greedy AND temperature) runs inside
the jitted decode step, the pending next-token vector and the per-slot
output ring live on device, and the PRNG key threads through the jit — the
host never reads a token mid-request.  With an int8 KV cache the per-step
attention runs fully integer, and under the ``attn`` dispatch axis
(``DispatchConfig(attn=True)`` / ``REPRO_PALLAS_ATTN_DISPATCH``) it
executes as the fused ``kernels.decode_attn_int8`` Pallas kernel — one
VMEM pass per (batch, kv-head) instead of unfused XLA einsums.  The only device->host transfer is
one fetch of a request's finished token row when it completes (completion
itself is decided by host-side step counting, not by reading tokens).
Prefill is batched over ragged prompts: families that support right-padded
prompts with per-row lengths (``RAGGED_PREFILL``) admit every waiting
request in one call; recurrent families are bucketed by exact prompt length
so pad tokens never pollute their state.

With ``mesh=`` the engine runs sharded: params are placed per
``repro.dist.sharding.param_specs`` (QTensor payloads and scales co-shard),
the decode cache per ``cache_specs`` (batch rows over ``data``, attention
heads over ``model`` when divisible), and the decode step re-pins the cache
sharding every step so placements stay exactly on-spec.

Failure story (the fault-tolerance layer): executor exceptions are
contained PER BATCH — a failing prefill fails only its group's handles, a
failing decode step fails only the slots live in that step — and the
engine loop keeps serving everything else.  Per-request deadlines
(``submit(..., deadline_ms=)``) expire requests both queued and
mid-decode (freeing their slots), ``Handle.cancel()`` does the same on
the caller's initiative, and an ``OverloadPolicy`` bounds the admission
queue.  Kernel-dispatch failures degrade gracefully: the decode/prefill
steps run under a ``kernels.ops.FallbackGuard`` that retries a raising
Pallas step once on the XLA path (and latches the dispatch axes off).
Decode logits carry an in-graph finite check (a sticky per-slot flag,
read only at completion, preserving the one-d2h-per-completion
invariant): a NaN-poisoned request fails with ``NumericalError`` instead
of delivering garbage tokens.  A ``serving.faults.FaultInjector``
(``faults=`` or the ``REPRO_FAULT_SPEC`` env var) provokes all of the
above deterministically at the ``prefill``/``decode`` sites.

This is the serving analogue of the paper's deployment: weights are the
QTensor tree from core.quantize_model, executing the int8/APoT/packed-4bit
paths.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as _kops
from ..models import get_model
from ..models.config import ArchConfig
from . import faults as _faults
from .batching import ServeStats, pow2_bucket
from .errors import NumericalError, RequestTimedOut
from .scheduler import (FlushPolicy, Handle, OverloadPolicy, Scheduler,
                        TIMED_OUT)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (P,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0  # 0 = greedy
    out_tokens: Optional[List[int]] = None
    done: bool = False
    handle: Optional[Handle] = None  # scheduler future (resolves at finish)
    stream: bool = False             # push tokens through the handle
    preemptible: bool = False        # slot may be evicted for higher prio
    # preemption continuation state (restart-from-prefix): tokens decoded
    # by earlier incarnations — the final result is out_prefix + the
    # current incarnation's out_tokens
    out_prefix: List[int] = dataclasses.field(default_factory=list)
    preemptions: int = 0


@dataclasses.dataclass
class EngineStats(ServeStats):
    """Unified ServeStats + the token engine's decode-loop counters."""

    steps: int = 0
    decoded_tokens: int = 0
    prefills: int = 0
    prefill_batches: int = 0
    finished: int = 0
    preemptions: int = 0       # slot evictions (restart-from-prefix)
    streamed_tokens: int = 0   # tokens pushed through streaming handles


class Engine:
    def __init__(self, cfg: ArchConfig, params, max_batch: int = 4,
                 max_len: int = 256, seed: int = 0,
                 max_delay_ms: float = 0.0,
                 dispatch: Optional[_kops.DispatchConfig] = None,
                 mesh=None,
                 clock: Callable[[], float] = time.monotonic,
                 overload: Optional[OverloadPolicy] = None,
                 faults: Optional[_faults.FaultInjector] = None,
                 check_numerics: bool = True,
                 debug_numerics: Optional[bool] = None):
        # scoped kernels.ops.DispatchConfig pinning kernel dispatch for the
        # engine's prefill/decode traces (None inherits env/backend
        # default); the attn axis steers the int8-KV decode-attention
        # kernel in every decode step
        self.dispatch = dispatch
        self.cfg = cfg
        self.model = get_model(cfg)
        self.B = max_batch
        self.T = max_len
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.stats = EngineStats()
        if max_delay_ms is None:
            # None (the vision explicit-flush mode) would leave a sub-
            # max_batch queue waiting forever: the token engine has no
            # drain() path, so admission MUST have a deadline
            raise ValueError(
                "token engine admission needs a deadline: use "
                "max_delay_ms=0.0 (admit whenever slots free) or > 0 "
                "(coalesce prefills), not None")
        # admission queue on the shared scheduler core; max_delay_ms=0.0
        # admits whenever slots are free (the classic behavior), >0
        # coalesces prefills until the batch fills or the deadline fires.
        # overload= bounds it (QueueFullError / shed-oldest); faults= (or
        # REPRO_FAULT_SPEC) provokes failures at the prefill/decode sites
        self.faults = faults if faults is not None else _faults.from_env()
        self.check_numerics = check_numerics
        # opt-in PRE-quantization numerics check (constructor arg, or the
        # REPRO_DEBUG_NUMERICS env var when the arg is None): every decode
        # step also scans the inexact cache leaves — on a quantized engine
        # the logits-only check can miss a cache NaN laundered through
        # activation quantization (NaN.astype(int8) is finite), but the
        # dynamic per-row KV scales (max|x|/127) stay f32 and DO carry the
        # NaN.  Costs a full cache read per step; debug posture only.
        if debug_numerics is None:
            debug_numerics = os.environ.get(
                "REPRO_DEBUG_NUMERICS", "").strip().lower() in (
                    "1", "true", "on", "yes")
        self.debug_numerics = bool(debug_numerics)
        self.scheduler = Scheduler(
            policy=FlushPolicy(max_batch=max_batch,
                               max_delay_ms=max_delay_ms),
            stats=self.stats, clock=clock, overload=overload)
        # retry-once-on-XLA guard around the kernel-dispatched steps (no
        # finite check here: that would force a device sync per decode
        # step — numerics ride the in-graph sticky flag instead)
        self.fallback_guard = _kops.FallbackGuard(check_finite=False)
        # real-clock time step() last ENTERED, regardless of the injected
        # scheduler clock: the supervision layer's liveness signal (a
        # virtual-clock engine still beats wall-clock time while stepped)
        self.heartbeat: Optional[float] = None
        self._ragged = bool(getattr(self.model, "RAGGED_PREFILL", False))
        self.cache = self.model.init_cache(cfg, max_batch, max_len,
                                           dtype=jnp.float32)
        self.mesh = mesh
        self._cache_shardings = None
        if mesh is not None:
            params, self.cache = self._shard(params, self.cache, mesh)
        self.params = params
        # device-resident decode state
        self.key = jax.random.PRNGKey(seed)
        self._pending = jnp.zeros((max_batch,), jnp.int32)
        self._temps = jnp.zeros((max_batch,), jnp.float32)
        self._outbuf = jnp.zeros((max_batch, max_len), jnp.int32)
        self._counts = jnp.zeros((max_batch,), jnp.int32)
        # sticky per-slot non-finite-logits flag, accumulated IN-GRAPH by
        # the decode/prefill steps and read back only at completion (the
        # one allowed d2h) — a poisoned request fails with NumericalError
        # instead of delivering garbage tokens
        self._nonfinite = jnp.zeros((max_batch,), bool)
        # host mirror of per-slot emitted-token counts (drives completion
        # without reading token values back)
        self._emitted = [0] * max_batch
        # ``fallback`` is STATIC: dispatch is resolved at trace time, so
        # the FallbackGuard's XLA retry needs its own trace, not a stale
        # kernel-path trace replayed under a different ambient scope
        self._decode_step = jax.jit(self._decode_step_impl,
                                    static_argnames=("fallback",))
        self._prefill_sample = jax.jit(self._prefill_sample_impl,
                                       static_argnames=("fallback",))
        self._prefill_sample_ragged = jax.jit(
            self._prefill_sample_ragged_impl, static_argnames=("fallback",))

    def _shard(self, params, cache, mesh):
        """Place params/cache per dist.sharding (decode caches shard over
        the mesh; QTensor payload+scale children co-shard by spec)."""
        from ..dist import sharding as shd
        params = jax.device_put(
            params, shd.shardings_from_specs(shd.param_specs(params, mesh),
                                             mesh))
        self._cache_shardings = shd.shardings_from_specs(
            shd.cache_specs(cache, mesh, shard_model=True), mesh)
        return params, jax.device_put(cache, self._cache_shardings)

    # -- request API ---------------------------------------------------------
    @property
    def queue(self) -> List[Request]:
        """Requests waiting for admission (FIFO), via the scheduler."""
        return self.scheduler.pending_payloads()

    def submit(self, prompt, max_new_tokens: int = 16,
               temperature: float = 0.0,
               deadline_ms: Optional[float] = None,
               priority: int = 0,
               stream: bool = False,
               on_token: Optional[Callable[[int], None]] = None,
               preemptible: bool = False) -> Request:
        """Enqueue one request; returns a :class:`Request` whose
        ``.handle`` resolves (or fails) at completion.

        ``deadline_ms``: optional per-request deadline — the request
        TIMES OUT (handle state ``TIMED_OUT``, slot freed) if it has not
        completed within that many ms of submission, queued or mid-decode.

        ``priority``: higher admits first (the scheduler's priority
        queue; FIFO within a class).  ``preemptible``: this request's
        decode slot may be EVICTED when a strictly-higher-priority
        request is due and no slot is free — it restarts from prefix
        (prompt + tokens so far) at the back of its class, keeping every
        already-decoded token.  ``stream=True`` (or passing ``on_token``,
        which implies it) delivers each decoded token incrementally
        through the handle — ``handle.tokens()`` / the callback — at the
        cost of one extra device->host read per decode step shared by
        ALL streaming slots (non-streaming requests keep the strict
        one-transfer-per-completion invariant).  Streamed tokens are
        pushed BEFORE the completion-time numerics check: the handle's
        terminal state says whether the stream is trustworthy.

        Raises ``ValueError`` on malformed payloads — validated UP FRONT
        so bad inputs fail here with a clear message, not deep inside a
        jitted prefill: non-1-D prompts, non-integer dtypes (embeddings
        or logits passed by mistake), token ids outside the vocab, empty
        prompts, ``max_new_tokens < 1``, or a request that cannot fit
        ``max_len``.  Raises ``QueueFullError`` when a bounded queue
        rejects the submit (see ``OverloadPolicy``).
        """
        arr = np.asarray(prompt)
        if arr.ndim != 1:
            raise ValueError(
                f"prompt must be a 1-D vector of token ids, got shape "
                f"{arr.shape}")
        if arr.size and not np.issubdtype(arr.dtype, np.integer):
            raise ValueError(
                f"prompt dtype must be integer token ids, got {arr.dtype} "
                "— passing embeddings/logits (or float-typed ids) would "
                "be silently truncated")
        if arr.size and (int(arr.min()) < 0
                         or int(arr.max()) >= self.cfg.vocab_size):
            raise ValueError(
                f"prompt token ids must be in [0, {self.cfg.vocab_size}), "
                f"got range [{int(arr.min())}, {int(arr.max())}]")
        prompt = arr.astype(np.int32)
        if len(prompt) == 0:
            raise ValueError("empty prompt: prefill needs at least one token")
        if max_new_tokens < 1:
            # a zero/negative budget would still burn a full prefill+sample
            # (the first token IS sampled at prefill) and retire with empty
            # output — reject instead of doing work the caller threw away
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens} (every "
                "admitted request decodes at least its prefill-sampled "
                "first token)")
        if len(prompt) + max_new_tokens > self.T:
            # the KV cache and the device output ring are both max_len wide;
            # silently clamping would truncate/corrupt the decoded stream
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f" exceeds max_len ({self.T})")
        req = Request(uid=0, prompt=prompt, max_new_tokens=max_new_tokens,
                      temperature=temperature, out_tokens=[],
                      stream=bool(stream) or on_token is not None,
                      preemptible=bool(preemptible))
        req.handle = self.scheduler.submit(req, deadline_ms=deadline_ms,
                                           priority=priority,
                                           on_token=on_token)
        req.uid = req.handle.uid
        return req

    def _dispatch_scope(self):
        return (_kops.dispatch(self.dispatch) if self.dispatch is not None
                else contextlib.nullcontext())

    # -- jitted cores --------------------------------------------------------
    def _sample_tokens(self, logits, key, temps):
        """(B, V_padded) logits -> (B,) int32 tokens, fully in-graph."""
        lg = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
        greedy = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        safe_t = jnp.maximum(temps, 1e-6)[:, None]
        keys = jax.random.split(key, lg.shape[0])
        drawn = jax.vmap(jax.random.categorical)(keys, lg / safe_t)
        return jnp.where(temps > 0, drawn.astype(jnp.int32), greedy)

    def _fallback_scope(self, fallback: bool):
        """``fallback=True`` (STATIC) pins the whole step to the XLA path
        for the FallbackGuard's retry trace — all three dispatch axes off,
        beating any ambient scope/env/latch (dispatch resolves at trace
        time, and this scope wraps the traced body)."""
        return (_kops.dispatch(dense=False, conv=False, attn=False)
                if fallback else contextlib.nullcontext())

    def _row_nonfinite(self, logits):
        """(B, V_padded) last-position logits -> (B,) bool: row holds any
        NaN/Inf inside the real vocab (in-graph; no host sync)."""
        lg = logits[:, : self.cfg.vocab_size].astype(jnp.float32)
        return ~jnp.all(jnp.isfinite(lg), axis=-1)

    def _cache_nonfinite(self, cache):
        """(B,) bool: any NaN/Inf in a slot's inexact cache rows (in-graph;
        batch axis 1 per the ``_write_slots`` convention).  Int payloads
        are skipped — after quantization they are finite by construction;
        it is the f32 leaves (float caches, per-row KV scales, recurrent
        states) that still carry a pre-quantization NaN."""
        bad = jnp.zeros((self.B,), bool)
        for leaf in jax.tree.leaves(cache):
            if leaf.ndim < 2 or not jnp.issubdtype(leaf.dtype, jnp.inexact):
                continue
            axes = tuple(a for a in range(leaf.ndim) if a != 1)
            bad = bad | ~jnp.all(jnp.isfinite(leaf), axis=axes)
        return bad

    def _decode_step_impl(self, params, cache, pending, outbuf, counts,
                          temps, live, nonfinite, key, fallback=False):
        with self._fallback_scope(fallback):
            key, k_s = jax.random.split(key)
            logits, cache = self.model.decode_step(self.cfg, params, cache,
                                                   pending[:, None])
            # sticky numerics flag: once a live slot's logits go non-finite
            # the bit stays set until the slot retires (read only at
            # completion — the d2h-per-completion invariant holds)
            nonfinite = nonfinite | (self._row_nonfinite(logits[:, 0]) & live)
            if self.debug_numerics:
                # opt-in pre-quantization check: a cache NaN that activation
                # quantization would launder into finite logits still trips
                # the sticky flag here (see REPRO_DEBUG_NUMERICS)
                nonfinite = nonfinite | (self._cache_nonfinite(cache) & live)
            tok = self._sample_tokens(logits[:, 0], k_s, temps)
            tok = jnp.where(live, tok, pending)
            b = jnp.arange(self.B)
            at = jnp.minimum(counts, self.T - 1)
            outbuf = outbuf.at[b, at].set(
                jnp.where(live, tok, outbuf[b, at]))
            counts = counts + live.astype(jnp.int32)
            if self._cache_shardings is not None:
                # pin the cache's dist.sharding placement through the step
                # so the sharded decode loop stays exactly on-spec
                cache = jax.tree.map(jax.lax.with_sharding_constraint, cache,
                                     self._cache_shardings)
            return cache, tok, outbuf, counts, nonfinite, key

    def _prefill_sample_impl(self, params, slot_cache, tokens, temps, key,
                             fallback=False):
        with self._fallback_scope(fallback):
            logits, slot_cache = self.model.prefill(self.cfg, params,
                                                    slot_cache, tokens)
            tok = self._sample_tokens(logits[:, -1], key, temps)
            return tok, slot_cache, self._row_nonfinite(logits[:, -1])

    def _prefill_sample_ragged_impl(self, params, slot_cache, tokens,
                                    lengths, temps, key, fallback=False):
        with self._fallback_scope(fallback):
            logits, slot_cache = self.model.prefill(self.cfg, params,
                                                    slot_cache, tokens,
                                                    lengths=lengths)
            tok = self._sample_tokens(logits[:, -1], key, temps)
            return tok, slot_cache, self._row_nonfinite(logits[:, -1])

    # -- internals -----------------------------------------------------------
    def _write_slots(self, slots: List[int], group_cache):
        """Copy an (n, ...) batched prefill cache into the engine cache."""
        idx = jnp.asarray(slots, jnp.int32)

        def put(dst, src):
            if dst.ndim == 1:  # lengths (B,)
                return dst.at[idx].set(src)
            return dst.at[:, idx].set(src)

        self.cache = jax.tree.map(put, self.cache, group_cache)
        if self._cache_shardings is not None:
            # eager .at[].set left the placement to XLA; re-pin to spec
            self.cache = jax.device_put(self.cache, self._cache_shardings)

    def _admit(self):
        # Free slots and the due-check are recomputed on every pass: the
        # in-loop _finish_done() (max_new_tokens==1 completing at prefill)
        # frees slots that queued requests can take within the SAME admit
        # call — computing ``free`` once left them idle until the next
        # step.  With the default max_delay_ms=0.0 the scheduler is due
        # whenever anything is pending (classic admit-on-free-slot); a
        # positive deadline holds admission to coalesce prefill batches.
        while True:
            free = [i for i, r in enumerate(self.slots) if r is None]
            if not free:
                if not self._maybe_preempt():
                    return
                continue  # the evicted slot is free for the due head
            reason = self.scheduler.due()
            if reason is None:
                return
            cands = self.scheduler.peek(len(free))
            if self._ragged:
                group = list(cands)
            else:  # exact-length bucket: recurrent states must not see
                # padding; one bucket per pass, the rest re-enter next pass
                by_len: Dict[int, List[Handle]] = {}
                for h in cands:
                    by_len.setdefault(len(h.payload.prompt), []).append(h)
                group = next(iter(by_len.values()))
            group = self.scheduler.pop(group, reason)
            if not group:
                continue  # whole group cancelled/expired while queued
            try:
                self._prefill_group(free[: len(group)], group)
            except Exception as e:  # noqa: BLE001 — per-batch containment
                # a failing prefill (executor bug, injected fault, a raise
                # surviving the guard's XLA retry) fails ONLY this group's
                # handles; no slot was written, the engine keeps serving
                for h in group:
                    h.set_exception(e)

    def _maybe_preempt(self) -> bool:
        """With every slot occupied: evict ONE preemptible lower-priority
        decode if a strictly-higher-priority request is due at the head
        of the queue.  Victim = lowest priority first, then most tokens
        emitted (the continuation with the least decoding left — it
        rejoins and retires soonest once pressure passes).
        Returns True if a slot was freed."""
        if self.scheduler.due() is None:
            return False
        head = self.scheduler.peek(1)
        if not head:
            return False
        want = head[0].priority
        victims = []
        for slot, req in enumerate(self.slots):
            if (req is None or not req.preemptible or req.handle is None
                    or req.handle.done()
                    or req.handle.priority >= want):
                continue
            victims.append((req.handle.priority, -self._emitted[slot], slot))
        if not victims:
            return False
        self._preempt_slot(min(victims)[2])
        return True

    def _preempt_slot(self, slot: int) -> None:
        """Evict one in-flight decode, restart-from-prefix: fold the
        tokens decoded so far into the request's prompt (prompt grows,
        ``max_new_tokens`` shrinks — their sum is invariant, so the
        ``<= max_len`` admission check still holds) and requeue the SAME
        handle at the back of its priority class.  One device->host read
        of the victim's token row per eviction (preemption is rare and
        off the per-step hot path).  A victim whose sticky numerics flag
        already tripped is failed instead — releasing its slot would
        clear the flag and the restart would launder poisoned tokens
        into the continuation's prompt."""
        req = self.slots[slot]
        h = req.handle
        emitted = self._emitted[slot]
        if self.check_numerics and bool(
                jax.device_get(self._nonfinite[slot])):
            h.set_exception(NumericalError(
                f"request {h.uid} produced non-finite logits during "
                "decode (caught at preemption); its tokens are not "
                "trustworthy and were not delivered"))
            self._release_slot(slot)
            return
        toks = np.asarray(jax.device_get(self._outbuf[slot, :emitted]))
        decoded = [int(t) for t in toks]
        req.out_prefix.extend(decoded)
        req.prompt = np.concatenate(
            [req.prompt, toks.astype(np.int32)])
        # emitted < max_new_tokens always holds here (a slot at its
        # budget retired in _finish_done), so the remainder stays >= 1
        req.max_new_tokens -= emitted
        req.preemptions += 1
        self.stats.preemptions += 1
        self._release_slot(slot)
        self.scheduler.requeue(h)

    def _prefill_group(self, gslots: List[int], handles: List[Handle]):
        greqs = [h.payload for h in handles]
        lens = np.asarray([len(r.prompt) for r in greqs], np.int32)
        pmax = int(lens.max())
        if self._ragged:
            # bucket the padded length to a power of two (capped at
            # max_len): bounds XLA recompiles of the prefill graph to
            # O(B * log T) shape variants instead of one per distinct
            # prompt length; lengths mask the extra pad columns
            pmax = pow2_bucket(pmax, 8, self.T)
        toks = np.zeros((len(greqs), pmax), np.int32)
        for i, r in enumerate(greqs):
            toks[i, : len(r.prompt)] = r.prompt
        sc = self.model.init_cache(self.cfg, len(greqs), self.T,
                                   dtype=jnp.float32)
        temps = jnp.asarray([r.temperature for r in greqs], jnp.float32)
        self.key, k = jax.random.split(self.key)
        act = (self.faults.on_call("prefill")
               if self.faults is not None else None)
        with self._dispatch_scope():
            if act is not None:
                act.fire()  # raises/delays land BEFORE any state mutates
            if self._ragged:
                first, sc, bad = self.fallback_guard.run(
                    self._prefill_sample_ragged, self.params, sc,
                    jnp.asarray(toks), jnp.asarray(lens), temps, k)
            else:
                first, sc, bad = self.fallback_guard.run(
                    self._prefill_sample, self.params, sc,
                    jnp.asarray(toks), temps, k)
        if act is not None and act.poison:
            # simulated silent corruption of the group's prefill logits:
            # flag row 0 — ONE request fails with NumericalError at
            # completion, its groupmates are untouched
            bad = bad.at[0].set(True)
        self._write_slots(gslots, sc)
        idx = jnp.asarray(gslots, jnp.int32)
        self._pending = self._pending.at[idx].set(first)
        self._temps = self._temps.at[idx].set(temps)
        self._outbuf = self._outbuf.at[idx, 0].set(first)
        self._counts = self._counts.at[idx].set(1)
        self._nonfinite = self._nonfinite.at[idx].set(bad)
        for s, r in zip(gslots, greqs):
            self.slots[s] = r
            self._emitted[s] = 1
        self.stats.prefills += len(greqs)
        self.stats.prefill_batches += 1
        if any(r.stream for r in greqs):
            # streamers pay one extra d2h per prefill group for their
            # prefill-sampled first token; non-streamers keep the strict
            # one-transfer-per-completion invariant
            fv = np.asarray(jax.device_get(first))
            for i, (r, h) in enumerate(zip(greqs, handles)):
                if r.stream and h.push_token(int(fv[i])):
                    self.stats.streamed_tokens += 1
        # unified queue-level accounting: real prompt tokens vs the padded
        # (n, pmax) prefill actually executed
        self.stats.record_batch(items=int(lens.sum()),
                                padded=int(len(greqs) * pmax - lens.sum()),
                                capacity=self.B * pmax)
        self._finish_done()  # max_new_tokens == 1 finishes at prefill

    def _release_slot(self, slot: int) -> None:
        """Free a slot mid-flight or at retirement: drop the host request
        and clear the slot's sticky numerics flag so the next occupant
        starts clean (its cache rows are overwritten at prefill)."""
        self.slots[slot] = None
        self._emitted[slot] = 0
        self._nonfinite = self._nonfinite.at[slot].set(False)

    def _sweep_slots(self) -> None:
        """Retire in-flight requests that went terminal without a result:
        caller cancellation (``Handle.cancel()``), and per-request deadline
        expiry — deadlines fire MID-DECODE too, not only while queued, so
        a stuck/slow request cannot squat its slot past its budget."""
        # queued expiry first: _admit only consults due() when a slot is
        # free, so without this a full engine would leave expired queued
        # requests PENDING until something retires
        self.scheduler.expire()
        now = self.scheduler.now()
        for slot, req in enumerate(self.slots):
            if req is None or req.handle is None:
                continue
            h = req.handle
            if (not h.done() and h.deadline is not None
                    and now >= h.deadline):
                h.set_exception(
                    RequestTimedOut(
                        f"request {h.uid} timed out mid-decode after "
                        f"{self._emitted[slot]} token(s); freeing its slot"),
                    state=TIMED_OUT)
            if h.done():
                self._release_slot(slot)

    def _finish_done(self):
        """Retire completed slots; the ONLY per-request device->host reads
        (the slot's sticky numerics flag, then — when it is clean — the
        finished token row)."""
        for slot, req in enumerate(self.slots):
            if req is None or self._emitted[slot] < req.max_new_tokens:
                continue
            h = req.handle
            if self.check_numerics and bool(
                    jax.device_get(self._nonfinite[slot])):
                # the in-graph sticky flag caught NaN/Inf logits somewhere
                # in this request's decode: fail it rather than deliver
                # garbage tokens sampled from poisoned logits
                req.done = True
                if h is not None:
                    h.set_exception(NumericalError(
                        f"request {h.uid} produced non-finite logits "
                        "during decode (NaN/Inf); its tokens are not "
                        "trustworthy and were not delivered"))
                self._release_slot(slot)
                continue
            toks = np.asarray(
                jax.device_get(self._outbuf[slot, : req.max_new_tokens]))
            # out_prefix carries tokens from pre-preemption incarnations;
            # the delivered result is always the full decoded sequence
            req.out_tokens = req.out_prefix + [int(t) for t in toks]
            req.done = True
            delivered = True
            if h is not None:
                # a late result into a handle the caller already cancelled
                # (or that timed out this very step) is dropped by the
                # state machine — don't double-count it as finished
                delivered = h.set_result(req.out_tokens)
            if delivered:
                self.stats.finished += 1
            self._release_slot(slot)

    def step(self) -> int:
        """Admit + one decode step for all live slots. Returns #live.

        Failure containment: a raising decode step (executor bug or
        injected fault) fails ONLY the slots live in that step — their
        handles get the exception, their slots free — and the engine keeps
        serving the queue.  The step itself never raises.
        """
        self.heartbeat = time.monotonic()
        self._sweep_slots()  # cancellations + mid-decode deadline expiry
        self._admit()
        live_mask = np.asarray([r is not None for r in self.slots], bool)
        live = [i for i in range(self.B) if live_mask[i]]
        if not live:
            return 0
        act = (self.faults.on_call("decode")
               if self.faults is not None else None)
        try:
            if act is not None:
                act.fire()
                if act.poison:
                    self._poison_slot(live[0])
            with self._dispatch_scope():
                (self.cache, self._pending, self._outbuf, self._counts,
                 self._nonfinite, self.key) = self.fallback_guard.run(
                    self._decode_step, self.params, self.cache,
                    self._pending, self._outbuf, self._counts, self._temps,
                    jnp.asarray(live_mask), self._nonfinite, self.key)
        except Exception as e:  # noqa: BLE001 — per-batch containment
            for slot in live:
                req = self.slots[slot]
                if req is not None and req.handle is not None:
                    req.handle.set_exception(e)
                self._release_slot(slot)
            return 0
        self.stats.steps += 1
        self.stats.decoded_tokens += len(live)
        for slot in live:
            self._emitted[slot] += 1
        self._stream_live(live)
        self._finish_done()
        return len(live)

    def _stream_live(self, live: List[int]) -> None:
        """Push this step's sampled token into every live STREAMING
        slot's handle.  Costs one device->host read of the pending-token
        vector per step, shared across all streaming slots, and nothing
        at all when no live slot streams — the one-transfer-per-
        completion invariant is intact for non-streaming traffic."""
        streamers = [
            s for s in live
            if self.slots[s] is not None and self.slots[s].stream
            and self.slots[s].handle is not None]
        if not streamers:
            return
        pend = np.asarray(jax.device_get(self._pending))
        for s in streamers:
            if self.slots[s].handle.push_token(int(pend[s])):
                self.stats.streamed_tokens += 1

    def _poison_slot(self, slot: int) -> None:
        """NaN-poison ONE slot's KV-cache rows (the fault injector's
        ``nan@decode`` site): that single request's logits go non-finite,
        the sticky flag catches it, and it alone fails with
        ``NumericalError`` — its batchmates decode on unharmed."""
        def poison(leaf):
            if not jnp.issubdtype(leaf.dtype, jnp.inexact):
                return leaf
            if leaf.ndim == 1:  # per-slot lengths etc.
                return leaf
            # batch axis convention matches _write_slots: axis 1 for the
            # (layers, B, ...) stacked cache leaves
            return leaf.at[:, slot].set(jnp.nan)
        self.cache = jax.tree.map(poison, self.cache)

    def run(self, max_steps: int = 10_000) -> EngineStats:
        for _ in range(max_steps):
            if self.scheduler.pending == 0 and all(
                    s is None for s in self.slots):
                break
            if self.step() == 0 and self.scheduler.pending \
                    and self.scheduler.clock is time.monotonic:
                # nothing live and the queue not yet due (max_delay_ms > 0
                # holding admission): sleep toward the deadline instead of
                # hot-spinning the step budget away.  Only on the REAL
                # clock — sleeping cannot advance an injected virtual
                # clock, whose driver steps the engine itself
                nd = self.scheduler.next_deadline()
                if nd is not None:
                    delay = nd - self.scheduler.clock()
                    if delay > 0:
                        time.sleep(min(delay, 1e-3))
        return self.stats
