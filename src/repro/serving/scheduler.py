"""The shared async scheduler core behind both serving engines.

One queue discipline for both modalities: requests enter through
``submit()`` and get a :class:`Handle` back immediately (a future — the
result is delivered when the batch holding the request executes).  A batch
executes when the pluggable :class:`FlushPolicy` says so:

* **full**      — ``max_batch`` requests are waiting, or
* **deadline**  — the OLDEST waiting request's age exceeds
  ``max_delay_ms`` (the latency guarantee: no request waits longer than
  one deadline for admission, however quiet the traffic), or
* **drain**     — an explicit ``drain()``/``flush()`` call.

The clock is injectable (``clock=`` returns seconds, default
``time.monotonic``) so tests and ``benchmarks/serving_bench.py`` drive
deadline behavior with virtual time instead of sleeping.

Two usage modes share the same core:

* **executor mode** (VisionEngine): the scheduler owns execution — give it
  an ``executor(handles, reason)`` callable and call :meth:`poll`
  periodically; due batches run and deliver results into their handles.
  ``submit()`` polls opportunistically, so a full batch executes inline.
* **admission mode** (token Engine): the engine owns execution (slots,
  prefill grouping, the decode loop) and uses :meth:`due` / :meth:`peek` /
  :meth:`pop` to decide *when* and *which* waiting requests to admit —
  queue latency and flush accounting still land in the shared
  :class:`~repro.serving.batching.ServeStats`.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Callable, List, Optional, Sequence

from .batching import ServeStats

# flush reasons (ServeStats.flush_reasons keys)
FLUSH_FULL = "full"
FLUSH_DEADLINE = "deadline"
FLUSH_DRAIN = "drain"


@dataclasses.dataclass(frozen=True)
class FlushPolicy:
    """When does a waiting batch execute?

    ``max_delay_ms=None`` disables the deadline (only full batches and
    explicit drains flush — the old explicit-flush batcher behavior);
    ``max_delay_ms=0.0`` flushes whenever anything is pending (the token
    engine's admit-on-free-slot behavior).
    """

    max_batch: int = 64
    max_delay_ms: Optional[float] = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_delay_ms is not None and self.max_delay_ms < 0:
            raise ValueError(
                f"max_delay_ms must be >= 0 or None, got {self.max_delay_ms}")


class Handle:
    """A submitted request: resolved when its batch executes.

    ``result()`` raises until the scheduler has flushed the request —
    drive the scheduler (``poll()`` until the deadline passes, or
    ``drain()``) to force delivery.
    """

    __slots__ = ("uid", "payload", "submitted_at", "done", "_result")

    def __init__(self, uid: int, payload, submitted_at: float):
        self.uid = uid
        self.payload = payload
        self.submitted_at = submitted_at
        self.done = False
        self._result = None

    def set_result(self, result) -> None:
        self._result = result
        self.done = True

    def result(self):
        if not self.done:
            raise RuntimeError(
                f"request {self.uid} has no result yet: it is still queued "
                "or executing; poll() until its deadline passes, or drain()")
        return self._result

    def __repr__(self):
        state = "done" if self.done else "pending"
        return f"Handle(uid={self.uid}, {state})"


class Scheduler:
    """Deadline-driven FIFO request queue (see module docstring)."""

    def __init__(self, policy: FlushPolicy = FlushPolicy(),
                 executor: Optional[Callable] = None,
                 stats: Optional[ServeStats] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.policy = policy
        self.executor = executor
        self.stats = stats if stats is not None else ServeStats()
        self.clock = clock
        self._q: List[Handle] = []
        self._uids = itertools.count()  # monotonic: uids never collide

    # -- queue state ---------------------------------------------------------
    @property
    def pending(self) -> int:
        return len(self._q)

    def pending_payloads(self) -> list:
        """Payloads still queued, FIFO order (diagnostics / engine compat)."""
        return [h.payload for h in self._q]

    def oldest_age_ms(self, now: Optional[float] = None) -> float:
        if not self._q:
            return 0.0
        now = self.clock() if now is None else now
        return (now - self._q[0].submitted_at) * 1000.0

    def next_deadline(self) -> Optional[float]:
        """Absolute clock time the oldest request becomes due (None if the
        queue is empty or the policy has no deadline) — serving loops sleep
        until this instead of busy-polling."""
        if not self._q or self.policy.max_delay_ms is None:
            return None
        return self._q[0].submitted_at + self.policy.max_delay_ms / 1000.0

    def due(self, now: Optional[float] = None) -> Optional[str]:
        """The flush reason if the policy wants a batch executed now."""
        if not self._q:
            return None
        if len(self._q) >= self.policy.max_batch:
            return FLUSH_FULL
        deadline = self.next_deadline()
        if deadline is not None:
            # compare against next_deadline()'s own arithmetic so a caller
            # that slept exactly until the returned deadline IS due (an
            # age-based >= check can miss it by one float ulp and spin)
            now = self.clock() if now is None else now
            if now >= deadline:
                return FLUSH_DEADLINE
        return None

    # -- request API ---------------------------------------------------------
    def submit(self, payload) -> Handle:
        h = Handle(uid=next(self._uids), payload=payload,
                   submitted_at=self.clock())
        self._q.append(h)
        self.stats.submitted += 1
        if self.executor is not None:
            self.poll()  # a now-full batch executes inline
        return h

    # -- admission mode (the engine owns execution) --------------------------
    def peek(self, n: int) -> List[Handle]:
        """Up to ``n`` oldest handles, not removed (the token engine groups
        them by prompt length before committing to a prefill batch)."""
        return self._q[: max(0, n)]

    def pop(self, handles: Sequence[Handle], reason: str) -> List[Handle]:
        """Remove ``handles`` from the queue; stamps each one's queue
        latency and the batch's flush reason into the shared stats."""
        now = self.clock()
        taken = {id(h) for h in handles}
        self._q = [h for h in self._q if id(h) not in taken]
        for h in handles:
            self.stats.record_latency((now - h.submitted_at) * 1000.0)
        if handles:
            self.stats.record_flush(reason)
        return list(handles)

    # -- executor mode (the scheduler owns execution) ------------------------
    def poll(self, now: Optional[float] = None) -> int:
        """Execute every batch the policy says is due.  Returns the number
        of requests delivered.  No-op without an executor."""
        if self.executor is None:
            return 0
        delivered = 0
        while True:
            reason = self.due(now)
            if reason is None:
                return delivered
            handles = self.pop(self._q[: self.policy.max_batch], reason)
            self.executor(handles, reason)
            delivered += len(handles)

    def drain(self) -> List[Handle]:
        """Flush EVERYTHING pending regardless of policy (shutdown, or the
        legacy explicit-flush API).  Returns the flushed handles in submit
        order.  Requires an executor."""
        if self.executor is None:
            raise RuntimeError("drain() needs an executor; admission-mode "
                               "callers pop() and execute themselves")
        flushed: List[Handle] = []
        while self._q:
            handles = self.pop(self._q[: self.policy.max_batch], FLUSH_DRAIN)
            self.executor(handles, FLUSH_DRAIN)
            flushed.extend(handles)
        return flushed
