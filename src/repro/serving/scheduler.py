"""The shared async scheduler core behind both serving engines.

One queue discipline for both modalities: requests enter through
``submit()`` and get a :class:`Handle` back immediately (a future — the
result is delivered when the batch holding the request executes).  A batch
executes when the pluggable :class:`FlushPolicy` says so:

* **full**      — ``max_batch`` requests are waiting, or
* **deadline**  — the OLDEST waiting request's age exceeds
  ``max_delay_ms`` (the latency guarantee: no request waits longer than
  one deadline for admission, however quiet the traffic), or
* **drain**     — an explicit ``drain()``/``flush()`` call.

The clock is injectable (``clock=`` returns seconds, default
``time.monotonic``) so tests and ``benchmarks/serving_bench.py`` drive
deadline behavior with virtual time instead of sleeping.  All scheduler
arithmetic runs on a MONOTONIC GUARD over that clock (:meth:`now`): a
clock that stalls simply freezes ages, and one that steps backwards can
neither make an age negative nor un-fire a deadline that already passed.

Failure story (the fault-tolerance layer):

* Handles are a terminal-state machine — ``PENDING`` then exactly one of
  ``DONE`` / ``FAILED`` / ``CANCELLED`` / ``TIMED_OUT``.  Executor
  exceptions in :meth:`poll`/:meth:`drain` fail ONLY the handles of the
  batch that was executing (``set_exception``) and the loop keeps
  serving; they never propagate out of the scheduler.
* Admission control: an :class:`OverloadPolicy` bounds the queue —
  reject new submits with :class:`~repro.serving.errors.QueueFullError`,
  or shed the oldest waiting request to make room.
* Per-request deadlines (``submit(..., deadline_ms=)``) expire queued
  requests to ``TIMED_OUT`` (:meth:`expire`, folded into :meth:`due` /
  :meth:`poll`); engines expire their *in-flight* requests the same way.
* Every outcome lands in the shared
  :class:`~repro.serving.batching.ServeStats` counters, so
  ``submitted == completed + failed + cancelled + timed_out + shed``
  always reconciles.

Two usage modes share the same core:

* **executor mode** (VisionEngine): the scheduler owns execution — give it
  an ``executor(handles, reason)`` callable and call :meth:`poll`
  periodically; due batches run and deliver results into their handles.
  ``submit()`` polls opportunistically, so a full batch executes inline.
* **admission mode** (token Engine): the engine owns execution (slots,
  prefill grouping, the decode loop) and uses :meth:`due` / :meth:`peek` /
  :meth:`pop` to decide *when* and *which* waiting requests to admit —
  queue latency and flush accounting still land in the shared
  :class:`~repro.serving.batching.ServeStats`.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
import time
from typing import Callable, Iterator, List, Optional, Sequence

from .batching import ServeStats
from .errors import CancelledError, QueueFullError, RequestTimedOut

# flush reasons (ServeStats.flush_reasons keys)
FLUSH_FULL = "full"
FLUSH_DEADLINE = "deadline"
FLUSH_DRAIN = "drain"

# Handle states: PENDING, then exactly one terminal state
PENDING = "PENDING"
DONE = "DONE"
FAILED = "FAILED"
CANCELLED = "CANCELLED"
TIMED_OUT = "TIMED_OUT"

# terminal state -> ServeStats outcome counter it increments
_STATE_OUTCOME = {DONE: "completed", FAILED: "failed",
                  CANCELLED: "cancelled", TIMED_OUT: "timed_out"}


@dataclasses.dataclass(frozen=True)
class FlushPolicy:
    """When does a waiting batch execute?

    ``max_delay_ms=None`` disables the deadline (only full batches and
    explicit drains flush — the old explicit-flush batcher behavior);
    ``max_delay_ms=0.0`` flushes whenever anything is pending (the token
    engine's admit-on-free-slot behavior).

    Raises ``ValueError`` for a non-positive ``max_batch`` or a negative
    ``max_delay_ms``.
    """

    max_batch: int = 64
    max_delay_ms: Optional[float] = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_delay_ms is not None and self.max_delay_ms < 0:
            raise ValueError(
                f"max_delay_ms must be >= 0 or None, got {self.max_delay_ms}")

    def admission_deadline(self, queue: Sequence["Handle"]) -> Optional[float]:
        """Absolute clock time at which the waiting queue becomes due for
        a deadline flush (None: no deadline applies).  The scheduler's
        :meth:`Scheduler.due` compares ``now >= admission_deadline()`` and
        :meth:`Scheduler.next_deadline` returns this same value, so a loop
        that slept exactly until the returned deadline IS due — one shared
        arithmetic, no float-ulp miss.  Subclasses override this to
        implement richer policies (per-SLO-class delays: see
        :class:`~repro.serving.slo.ClassFlushPolicy`)."""
        if not queue or self.max_delay_ms is None:
            return None
        return (min(h.submitted_at for h in queue)
                + self.max_delay_ms / 1000.0)


@dataclasses.dataclass(frozen=True)
class OverloadPolicy:
    """Admission control: what happens when the queue is full.

    ``max_queue=None`` (default) leaves the queue unbounded — exactly the
    pre-admission-control behavior.  With a bound, a submit that finds
    ``max_queue`` requests already waiting either raises
    :class:`~repro.serving.errors.QueueFullError` (``shed_oldest=False``,
    counted in ``ServeStats.rejected``) or sheds the OLDEST waiting
    request to make room (``shed_oldest=True``: the shed handle ends
    ``FAILED`` with a ``QueueFullError`` and counts in
    ``ServeStats.shed`` — freshest-traffic-wins load shedding).

    Raises ``ValueError`` for a non-positive ``max_queue``.
    """

    max_queue: Optional[int] = None
    shed_oldest: bool = False

    def __post_init__(self):
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue must be >= 1 or None, got {self.max_queue}")


class Handle:
    """A submitted request: a future with a terminal-state machine.

    States: ``PENDING`` until the scheduler/engine delivers exactly one
    terminal transition — ``DONE`` (``set_result``), ``FAILED``
    (``set_exception``), ``CANCELLED`` (``cancel``), or ``TIMED_OUT``
    (deadline expiry).  Terminal states are sticky: late transitions (an
    executor delivering into a handle the caller already cancelled) are
    dropped, and every transition is counted once in the scheduler's
    ``ServeStats``.

    ``result()`` raises ``RuntimeError`` while the request is still
    PENDING (drive the scheduler — ``poll()`` until the deadline passes,
    or ``drain()`` — or pass ``timeout=`` to block on the real clock);
    for a failed/cancelled/timed-out request it re-raises the recorded
    exception.

    Thread-safety: all transitions and waits synchronize on one internal
    condition variable, so a daemon thread resolving the handle wakes a
    blocked ``result(timeout=)`` / ``tokens()`` caller immediately
    (event-based — no sleep-polling jitter).  Streaming: producers push
    incremental tokens with :meth:`push_token`; consumers iterate
    :meth:`tokens` (blocking) or register an ``on_token`` callback.
    ``add_done_callback`` fires once at the terminal transition (callbacks
    run outside the handle's lock, on the resolving thread; exceptions
    they raise are swallowed so they can never break engine containment).
    """

    __slots__ = ("uid", "payload", "submitted_at", "deadline", "state",
                 "priority", "_result", "_exception", "_stats", "_cond",
                 "_stream", "_on_token", "_callbacks")

    def __init__(self, uid: int, payload, submitted_at: float,
                 deadline: Optional[float] = None,
                 stats: Optional[ServeStats] = None,
                 priority: int = 0,
                 on_token: Optional[Callable[[int], None]] = None):
        self.uid = uid
        self.payload = payload
        self.submitted_at = submitted_at
        self.deadline = deadline  # absolute clock seconds, or None
        self.priority = priority  # higher admits first (SLO classes)
        self.state = PENDING
        self._result = None
        self._exception: Optional[BaseException] = None
        self._stats = stats
        self._cond = threading.Condition()
        self._stream: List[int] = []   # incrementally delivered tokens
        self._on_token = on_token
        self._callbacks: List[Callable[["Handle"], None]] = []

    # -- state machine -------------------------------------------------------
    def _finish(self, state: str, result=None,
                exc: Optional[BaseException] = None,
                count_as: Optional[str] = None) -> bool:
        """One-shot transition PENDING -> ``state``; False if already
        terminal (the transition is dropped, nothing is overwritten)."""
        with self._cond:
            if self.state != PENDING:
                return False
            self.state = state
            self._result = result
            self._exception = exc
            if self._stats is not None:
                self._stats.record_outcome(count_as or _STATE_OUTCOME[state])
            callbacks, self._callbacks = self._callbacks, []
            self._cond.notify_all()
        for cb in callbacks:  # outside the lock: a callback may inspect us
            try:
                cb(self)
            except Exception:  # noqa: BLE001 — callbacks must not break
                pass           # the resolving engine's containment
        return True

    def add_done_callback(self, fn: Callable[["Handle"], None]) -> None:
        """Run ``fn(handle)`` once the handle reaches ANY terminal state
        (immediately if it already has).  Runs on the resolving thread,
        outside the handle's lock; exceptions are swallowed."""
        with self._cond:
            if self.state == PENDING:
                self._callbacks.append(fn)
                return
        try:
            fn(self)
        except Exception:  # noqa: BLE001 — see add-time contract
            pass

    # -- streaming -----------------------------------------------------------
    def push_token(self, token: int) -> bool:
        """Deliver one incremental token (producer side: the engine's
        decode loop).  Dropped once the handle is terminal.  Wakes
        :meth:`tokens` iterators; invokes the ``on_token`` callback (set
        via ``Engine.submit(on_token=)``) outside the lock, on the
        producing thread — exceptions it raises are swallowed."""
        with self._cond:
            if self.state != PENDING:
                return False
            self._stream.append(int(token))
            cb = self._on_token
            self._cond.notify_all()
        if cb is not None:
            try:
                cb(int(token))
            except Exception:  # noqa: BLE001 — user callback cannot break
                pass           # the engine loop
        return True

    @property
    def streamed(self) -> int:
        """Tokens pushed so far (monotonic; final result may hold more —
        tokens decoded and completed in the same step arrive together)."""
        with self._cond:
            return len(self._stream)

    def tokens(self, timeout: Optional[float] = None) -> Iterator[int]:
        """Blocking iterator over streamed tokens, in decode order.

        Yields each token as the engine produces it (requires a streaming
        submit — ``Engine.submit(stream=True)`` or ``on_token=`` — and
        something concurrently driving the engine, e.g. the serving
        daemon).  Ends when the handle resolves: normally on ``DONE``
        (after draining every pushed token), re-raising the recorded
        exception on FAILED / CANCELLED / TIMED_OUT — tokens already
        yielded stand, the failure tells the consumer the stream is
        truncated.  ``timeout``: max seconds to wait for EACH next token
        (real clock); raises ``TimeoutError`` when it expires.
        """
        pos = 0
        while True:
            with self._cond:
                while pos >= len(self._stream) and self.state == PENDING:
                    if not self._cond.wait(timeout=timeout):
                        raise TimeoutError(
                            f"request {self.uid}: no token within "
                            f"{timeout}s (is anything driving the "
                            "engine?)")
                if pos < len(self._stream):
                    tok = self._stream[pos]
                    pos += 1
                else:  # terminal and fully drained
                    if self.state == DONE:
                        return
                    exc = self._exception
                    break
            yield tok
        raise exc

    def set_result(self, result) -> bool:
        """Deliver the result (-> DONE); dropped if already terminal."""
        return self._finish(DONE, result=result)

    def set_exception(self, exc: BaseException, state: str = FAILED,
                      count_as: Optional[str] = None) -> bool:
        """Fail the request (-> FAILED by default; pass ``state=TIMED_OUT``
        for deadline expiry).  ``count_as`` overrides which ServeStats
        outcome counter increments (load shedding counts as ``"shed"``
        while still ending FAILED).  Dropped if already terminal."""
        return self._finish(state, exc=exc, count_as=count_as)

    def cancel(self) -> bool:
        """Cancel a PENDING request (-> CANCELLED); returns False if it
        already reached a terminal state (too late to cancel).  A queued
        request never executes after this; an in-flight decode is swept at
        the engine's next step (its slot is freed)."""
        return self._finish(
            CANCELLED, exc=CancelledError(f"request {self.uid} cancelled"))

    # -- inspection ----------------------------------------------------------
    def done(self) -> bool:
        """True once the handle reached ANY terminal state."""
        return self.state != PENDING

    def cancelled(self) -> bool:
        return self.state == CANCELLED

    def exception(self) -> Optional[BaseException]:
        """The recorded failure (None while PENDING or when DONE)."""
        return self._exception

    def result(self, timeout: Optional[float] = None):
        """The delivered result.

        Raises ``RuntimeError`` while the request is still PENDING and no
        ``timeout`` is given (this scheduler is poll-driven: drive it, or
        use ``timeout=`` seconds to block on the REAL clock — that only
        makes sense when something else concurrently drives the engine,
        e.g. the serving daemon; raises ``TimeoutError`` if the wait
        expires).  For a FAILED / CANCELLED / TIMED_OUT request this
        re-raises the recorded exception.
        """
        if self.state == PENDING and timeout is not None:
            # event-based wait: _finish notify_all()s this condition, so
            # the waiter wakes the instant the resolving thread delivers —
            # no sleep-poll jitter added to completion latency
            with self._cond:
                self._cond.wait_for(lambda: self.state != PENDING,
                                    timeout=timeout)
            if self.state == PENDING:
                raise TimeoutError(
                    f"request {self.uid} still PENDING after waiting "
                    f"{timeout}s (is anything driving the engine?)")
        if self.state == PENDING:
            raise RuntimeError(
                f"request {self.uid} has no result yet: it is still queued "
                "or executing; poll() until its deadline passes, or drain()")
        if self.state == DONE:
            return self._result
        raise self._exception

    def __repr__(self):
        return f"Handle(uid={self.uid}, {self.state})"


class Scheduler:
    """Deadline-driven priority/FIFO request queue (see module docstring).

    Thread-safety: all queue state is guarded by one internal
    re-entrant lock, so foreign threads may ``submit()``/``cancel()``
    while a daemon thread drives ``due()``/``pop()``/``poll()`` — the
    reconciliation invariant holds exactly under concurrency (proven by
    ``tests/test_daemon.py``'s stress test).  The executor itself runs
    OUTSIDE the lock (a long batch never blocks admission); lock order
    is scheduler lock -> handle condition, never the reverse.

    Priorities: ``submit(..., priority=)`` admits higher classes first
    (FIFO within a class — everything at the default priority 0 is the
    old pure-FIFO behavior).  Queue order is maintained sorted by
    descending priority, submit order within a class.
    """

    def __init__(self, policy: FlushPolicy = FlushPolicy(),
                 executor: Optional[Callable] = None,
                 stats: Optional[ServeStats] = None,
                 clock: Callable[[], float] = time.monotonic,
                 overload: Optional[OverloadPolicy] = None,
                 faults=None):
        self.policy = policy
        self.executor = executor
        self.stats = stats if stats is not None else ServeStats()
        self.clock = clock
        self.overload = overload if overload is not None else OverloadPolicy()
        self.faults = faults  # serving.faults.FaultInjector (site "executor")
        self._q: List[Handle] = []
        self._uids = itertools.count()  # monotonic: uids never collide
        self._last_now = float("-inf")  # monotonic guard over the clock
        self._lock = threading.RLock()

    # -- clock ---------------------------------------------------------------
    def now(self, now: Optional[float] = None) -> float:
        """Monotonic-guarded clock read: the max ever observed, so ages
        never go negative and fired deadlines never un-fire when the
        underlying clock stalls or steps backwards."""
        with self._lock:
            t = self.clock() if now is None else now
            if t > self._last_now:
                self._last_now = t
            return self._last_now

    # -- queue state ---------------------------------------------------------
    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._q)

    def pending_payloads(self) -> list:
        """Payloads still queued, admission order (diagnostics / engine
        compat)."""
        with self._lock:
            return [h.payload for h in self._q]

    def oldest_age_ms(self, now: Optional[float] = None) -> float:
        with self._lock:
            if not self._q:
                return 0.0
            oldest = min(h.submitted_at for h in self._q)
            return max(0.0, (self.now(now) - oldest) * 1000.0)

    def next_deadline(self) -> Optional[float]:
        """Absolute clock time of the next event — a waiting request
        becoming due for admission (the policy's
        :meth:`FlushPolicy.admission_deadline`), or the earliest
        per-request deadline expiring (None if neither applies) — serving
        loops sleep until this instead of busy-polling."""
        with self._lock:
            cands = []
            adm = self.policy.admission_deadline(self._q)
            if adm is not None:
                cands.append(adm)
            cands.extend(h.deadline for h in self._q
                         if h.deadline is not None)
            return min(cands) if cands else None

    def expire(self, now: Optional[float] = None) -> int:
        """Sweep the queue: drop cancelled handles and transition queued
        requests past their per-request deadline to TIMED_OUT (counted in
        ``ServeStats.timed_out``).  Returns the number expired.  Folded
        into :meth:`due`, so poll loops get it for free."""
        with self._lock:
            now = self.now(now)
            keep: List[Handle] = []
            expired: List[Handle] = []
            for h in self._q:
                if h.state != PENDING:
                    continue  # cancelled (or externally finished): drop
                if h.deadline is not None and now >= h.deadline:
                    expired.append(h)
                else:
                    keep.append(h)
            self._q = keep
        for h in expired:  # transitions outside: they run done-callbacks
            h.set_exception(
                RequestTimedOut(
                    f"request {h.uid} expired in queue: deadline passed "
                    f"{(now - h.deadline) * 1000.0:.1f}ms ago"),
                state=TIMED_OUT)
        return len(expired)

    def due(self, now: Optional[float] = None) -> Optional[str]:
        """The flush reason if the policy wants a batch executed now
        (cancelled/expired requests are swept first).  The deadline check
        compares against :meth:`FlushPolicy.admission_deadline` — the
        same arithmetic :meth:`next_deadline` returns — so a caller that
        slept exactly until next_deadline() IS due (an age-based >= check
        can miss it by one float ulp and spin)."""
        with self._lock:
            now = self.now(now)
            self.expire(now)
            if not self._q:
                return None
            if len(self._q) >= self.policy.max_batch:
                return FLUSH_FULL
            deadline = self.policy.admission_deadline(self._q)
            if deadline is not None and now >= deadline:
                return FLUSH_DEADLINE
            return None

    # -- request API ---------------------------------------------------------
    def _insert(self, h: Handle) -> None:
        """Insert maintaining (descending priority, FIFO within class):
        scan back over the strictly-lower-priority tail.  All-default
        priorities degenerate to append — the pure-FIFO fast path."""
        i = len(self._q)
        while i > 0 and self._q[i - 1].priority < h.priority:
            i -= 1
        self._q.insert(i, h)

    def submit(self, payload, deadline_ms: Optional[float] = None,
               priority: int = 0,
               on_token: Optional[Callable[[int], None]] = None) -> Handle:
        """Enqueue one request; returns its :class:`Handle` immediately.

        ``deadline_ms``: optional per-request deadline (relative to now);
        the request TIMES OUT — queued or in flight — once it passes.
        ``priority``: higher admits first (FIFO within equal priority);
        the default 0 preserves pure-FIFO behavior.
        ``on_token``: optional per-token streaming callback installed on
        the handle (invoked by the producer via ``Handle.push_token``).

        Raises :class:`~repro.serving.errors.QueueFullError` when an
        :class:`OverloadPolicy` bounds the queue, it is full, and the
        policy rejects rather than sheds (with ``shed_oldest=True`` the
        oldest waiting request of the LOWEST priority class is shed —
        failed with ``QueueFullError``, counted in ``ServeStats.shed`` —
        and this submit succeeds).
        Raises ``ValueError`` for a non-positive ``deadline_ms``.
        """
        if deadline_ms is not None and deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be > 0, got {deadline_ms}")
        shed: List[Handle] = []
        with self._lock:
            now = self.now()
            self.expire(now)
            cap = self.overload.max_queue
            if cap is not None:
                while len(self._q) - len(shed) >= cap:
                    if not self.overload.shed_oldest:
                        self.stats.record_outcome("rejected")
                        raise QueueFullError(
                            f"queue full: {len(self._q)} waiting >= "
                            f"max_queue={cap} (OverloadPolicy rejects; use "
                            "shed_oldest=True to shed instead)")
                    # victim: oldest of the lowest-priority class — the
                    # sorted invariant puts that class at the tail, its
                    # oldest first within the tail
                    minp = min(h.priority for h in self._q
                               if h not in shed)
                    victim = next(h for h in self._q
                                  if h.priority == minp and h not in shed)
                    shed.append(victim)
                taken = {id(h) for h in shed}
                self._q = [h for h in self._q if id(h) not in taken]
            h = Handle(uid=next(self._uids), payload=payload,
                       submitted_at=now,
                       deadline=(None if deadline_ms is None
                                 else now + deadline_ms / 1000.0),
                       stats=self.stats, priority=priority,
                       on_token=on_token)
            self._insert(h)
            self.stats.submitted += 1
        for old in shed:  # transitions outside the lock (done-callbacks)
            old.set_exception(
                QueueFullError(
                    f"request {old.uid} shed: queue hit max_queue="
                    f"{self.overload.max_queue} and OverloadPolicy sheds "
                    "oldest"),
                count_as="shed")
        if self.executor is not None:
            self.poll(now)  # a now-full batch executes inline
        return h

    def requeue(self, handle: Handle) -> bool:
        """Re-insert a still-PENDING handle at the back of its priority
        class (preemption continuation: the engine evicted its decode
        slot and resubmits the remaining work).  Resets ``submitted_at``
        to now — queue latency then measures each admission wait, not the
        total — does NOT count a new submit (the reconciliation invariant
        stays ``submitted == sum(outcomes)``), and bypasses the overload
        bound (preemptions are engine-internal: their number is bounded
        by the slot count, not client traffic).  Returns False (no-op) if
        the handle is already terminal."""
        with self._lock:
            if handle.state != PENDING:
                return False
            handle.submitted_at = self.now()
            self._insert(handle)
            return True

    # -- admission mode (the engine owns execution) --------------------------
    def peek(self, n: int) -> List[Handle]:
        """Up to ``n`` next-admittable PENDING handles in admission order
        (priority, then FIFO), not removed (the token engine groups them
        by prompt length before committing to a prefill batch)."""
        with self._lock:
            return [h for h in self._q if h.state == PENDING][: max(0, n)]

    def pop(self, handles: Sequence[Handle], reason: str) -> List[Handle]:
        """Remove ``handles`` from the queue; stamps each one's queue
        latency and the batch's flush reason into the shared stats.
        Returns only the handles still PENDING (cancelled/expired ones
        are dropped, never executed)."""
        with self._lock:
            now = self.now()
            taken = {id(h) for h in handles}
            self._q = [h for h in self._q if id(h) not in taken]
            live = [h for h in handles if h.state == PENDING]
            for h in live:
                self.stats.record_latency((now - h.submitted_at) * 1000.0)
            if live:
                self.stats.record_flush(reason)
            return live

    # -- executor mode (the scheduler owns execution) ------------------------
    def _run_executor(self, handles: List[Handle], reason: str) -> None:
        """One executor call with per-batch failure containment: an
        exception (including an injected fault) fails ONLY this batch's
        handles; it never propagates, so the serving loop keeps running."""
        act = self.faults.on_call("executor") if self.faults else None
        try:
            if act is not None:
                act.fire()
            self.executor(handles, reason)
        except Exception as e:  # noqa: BLE001 — containment is the point
            for h in handles:
                h.set_exception(e)

    def poll(self, now: Optional[float] = None) -> int:
        """Execute every batch the policy says is due.  Returns the number
        of requests resolved (delivered OR failed — executor exceptions
        fail the batch's handles and the loop keeps serving).  No-op
        without an executor.  The executor runs OUTSIDE the queue lock:
        foreign threads keep submitting while a batch executes."""
        if self.executor is None:
            return 0
        delivered = 0
        while True:
            with self._lock:
                reason = self.due(now)
                if reason is None:
                    return delivered
                handles = self.pop(self._q[: self.policy.max_batch], reason)
            if not handles:
                continue  # batch was entirely cancelled/expired
            self._run_executor(handles, reason)
            delivered += len(handles)

    def drain(self) -> List[Handle]:
        """Flush EVERYTHING pending regardless of policy (shutdown, or the
        legacy explicit-flush API).  Returns the flushed handles in
        admission order (executor failures fail their batch's handles; the
        drain continues).  Raises ``RuntimeError`` without an executor —
        admission-mode callers pop() and execute themselves."""
        if self.executor is None:
            raise RuntimeError("drain() needs an executor; admission-mode "
                               "callers pop() and execute themselves")
        flushed: List[Handle] = []
        while True:
            with self._lock:
                if not self._q:
                    return flushed
                handles = self.pop(self._q[: self.policy.max_batch],
                                   FLUSH_DRAIN)
            if not handles:
                continue
            self._run_executor(handles, FLUSH_DRAIN)
            flushed.extend(handles)
