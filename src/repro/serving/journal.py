"""Append-only write-ahead request journal: replay-on-restart durability.

The daemon/engine layer guarantees every submitted handle resolves *while
the process lives*; this module is the durability layer above it.  Each
supervised request is journaled as two JSONL events keyed by its
CLIENT-SUPPLIED request id:

    {"e": "submit",   "rid": ..., "t": <unix>, "slo": ..., "payload": [...],
     "kw": {...}, "deadline_unix": <unix>|null}
    {"e": "terminal", "rid": ..., "t": <unix>, "state": "DONE"|...,
     "error": null|"..."}

A restart scans the journal: rids with a ``submit`` but no ``terminal``
are the requests the dead process lost mid-flight, and the supervisor
REPLAYS them idempotently through ``daemon.submit`` — deadline-aware
(``deadline_unix`` is absolute WALL-clock time, because a monotonic clock
does not survive a process restart): an entry whose deadline already
passed resolves ``TIMED_OUT`` without re-running.  The PR-6
reconciliation invariant thereby extends across restarts — journaled
submits == journaled terminals, exactly, once replay drains.

Durability knobs:

* ``fsync=`` policy — ``"always"`` (fsync every append: a crash loses at
  most the event being written), ``"batch"`` (flush to the OS on every
  append, fsync only at :meth:`rotate`/:meth:`close`; :meth:`lag` counts
  the events a power loss could lose), or ``"never"`` (benchmarks).
* Torn tails are expected, not fatal: a crash mid-append leaves a
  partial last line; on open it is truncated away (counted in
  ``torn_records``) so appends never concatenate onto garbage.
* :meth:`rotate` compacts atomically: live (non-terminal) submits are
  rewritten to a tmp file, fsync'd, then ``os.replace``d over the
  journal — a crash mid-rotate leaves either the old file or the new
  one, never a half-written hybrid.

Payloads must be JSON-serializable (the supervisor journals token
prompts as plain int lists); callbacks (``on_token``) are deliberately
NOT journaled — a callback cannot survive a process restart, but the
replayed handle still accumulates the streamed tokens.
"""
from __future__ import annotations

import json
import os
import threading
import time
import warnings
from pathlib import Path
from typing import Dict, List, Optional

_FSYNC_POLICIES = ("always", "batch", "never")


class RequestJournal:
    """One append-only JSONL journal (see module docstring).

    Opening an existing path RESUMES it: prior records are scanned (torn
    tail truncated), so :meth:`pending` immediately reflects what the
    previous process left unfinished.  All methods are thread-safe; the
    daemon's submit path and its done-callbacks append concurrently.
    """

    def __init__(self, path, fsync: str = "always"):
        if fsync not in _FSYNC_POLICIES:
            raise ValueError(
                f"unknown fsync policy {fsync!r}; one of {_FSYNC_POLICIES}")
        self.path = Path(path)
        self.fsync = fsync
        self.torn_records = 0
        self._lock = threading.Lock()
        # rid -> submit record, insertion-ordered (dict preserves order):
        # replay happens in original submit order
        self._submits: Dict[str, dict] = {}
        self._terminal: Dict[str, dict] = {}
        self._since_sync = 0
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._recover_tail()
        self._scan()
        self._f = open(self.path, "a", encoding="utf-8")

    # -- recovery ------------------------------------------------------------
    def _recover_tail(self) -> None:
        """Truncate a torn (crash-mid-append) final line so the next
        append starts on a record boundary."""
        if not self.path.exists():
            return
        with open(self.path, "r+b") as f:
            data = f.read()
            if not data or data.endswith(b"\n"):
                return
            keep = data.rfind(b"\n") + 1  # 0 when no complete line at all
            f.truncate(keep)
            self.torn_records += 1
            warnings.warn(
                f"journal {self.path}: truncated a torn tail record "
                f"({len(data) - keep} bytes) — crash mid-append",
                RuntimeWarning, stacklevel=3)

    def _scan(self) -> None:
        if not self.path.exists():
            return
        for i, line in enumerate(
                self.path.read_text(encoding="utf-8").splitlines(), 1):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
                ev, rid = rec["e"], rec["rid"]
            except (json.JSONDecodeError, KeyError, TypeError):
                self.torn_records += 1
                warnings.warn(
                    f"journal {self.path}: skipping corrupt record at "
                    f"line {i}", RuntimeWarning, stacklevel=3)
                continue
            if ev == "submit":
                self._submits[rid] = rec
                # a resubmitted rid after a prior terminal is a NEW
                # lifecycle for that id (rotation would have dropped the
                # old pair anyway)
                self._terminal.pop(rid, None)
            elif ev == "terminal":
                self._terminal[rid] = rec

    # -- appends -------------------------------------------------------------
    def _append(self, rec: dict) -> None:
        self._f.write(json.dumps(rec, sort_keys=True) + "\n")
        self._f.flush()
        if self.fsync == "always":
            os.fsync(self._f.fileno())
            self._since_sync = 0
        else:
            self._since_sync += 1

    def record_submit(self, rid: str, payload, slo: str = "interactive",
                      kw: Optional[dict] = None,
                      deadline_unix: Optional[float] = None) -> bool:
        """Journal one submit.  Returns False (no duplicate record) when
        ``rid`` is already journaled and still non-terminal — the
        idempotency that makes replay-then-resubmit safe."""
        with self._lock:
            if rid in self._submits and rid not in self._terminal:
                return False
            rec = {"e": "submit", "rid": rid, "t": time.time(), "slo": slo,
                   "payload": payload, "kw": dict(kw or {}),
                   "deadline_unix": deadline_unix}
            self._append(rec)
            self._submits[rid] = rec
            self._terminal.pop(rid, None)
            return True

    def record_terminal(self, rid: str, state: str,
                        error: Optional[str] = None) -> bool:
        """Journal one terminal transition.  Returns False when ``rid``
        is already terminal (exactly-one-terminal idempotency) or was
        never submitted here."""
        with self._lock:
            if rid not in self._submits or rid in self._terminal:
                return False
            rec = {"e": "terminal", "rid": rid, "t": time.time(),
                   "state": state, "error": error}
            self._append(rec)
            self._terminal[rid] = rec
            return True

    # -- queries -------------------------------------------------------------
    def pending(self) -> List[dict]:
        """Submit records with no terminal yet, in submit order — the
        replay worklist after a restart."""
        with self._lock:
            return [dict(rec) for rid, rec in self._submits.items()
                    if rid not in self._terminal]

    def terminal_state(self, rid: str) -> Optional[str]:
        with self._lock:
            rec = self._terminal.get(rid)
            return None if rec is None else rec["state"]

    def lag(self) -> int:
        """Events appended since the last fsync — what a power loss could
        lose under the ``batch``/``never`` policies (always 0 under
        ``always``).  A health-probe field."""
        with self._lock:
            return self._since_sync

    def reconcile(self) -> dict:
        """The cross-restart invariant snapshot: ``submitted ==
        terminals + pending`` by construction; recovery is proven when
        ``pending == 0`` (every journaled submit has exactly one
        journaled terminal — terminal dedup is enforced at append)."""
        with self._lock:
            n_sub = len(self._submits)
            n_term = sum(1 for r in self._submits if r in self._terminal)
            return {"submitted": n_sub, "terminal": n_term,
                    "pending": n_sub - n_term, "exact": n_sub == n_term,
                    "torn_records": self.torn_records}

    # -- maintenance ---------------------------------------------------------
    def rotate(self) -> int:
        """Atomic compaction: rewrite the journal keeping only the
        non-terminal submit records (terminated pairs are history, not
        recovery state).  Returns the number of records dropped."""
        with self._lock:
            live = [rec for rid, rec in self._submits.items()
                    if rid not in self._terminal]
            dropped = (len(self._submits) - len(live)
                       + len(self._terminal))
            tmp = self.path.with_suffix(self.path.suffix + ".rotate-tmp")
            with open(tmp, "w", encoding="utf-8") as f:
                for rec in live:
                    f.write(json.dumps(rec, sort_keys=True) + "\n")
                f.flush()
                os.fsync(f.fileno())
            self._f.close()
            os.replace(tmp, self.path)
            self._f = open(self.path, "a", encoding="utf-8")
            self._submits = {rec["rid"]: rec for rec in live}
            self._terminal = {}
            self._since_sync = 0
            return dropped

    def close(self) -> None:
        with self._lock:
            if self._f.closed:
                return
            self._f.flush()
            if self.fsync != "never":
                os.fsync(self._f.fileno())
            self._since_sync = 0
            self._f.close()

    def __enter__(self) -> "RequestJournal":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
