"""Failure taxonomy for the serving stack.

Every way a request can end other than DONE has one exception class, so
callers can catch precisely what they can handle:

* :class:`QueueFullError` — admission control: the bounded queue rejected
  the submit (``OverloadPolicy(shed_oldest=False)``), or the request was
  admitted and later shed to make room (``shed_oldest=True``; the shed
  handle ends ``FAILED`` with this exception and counts in
  ``ServeStats.shed``).
* :class:`CancelledError` — the caller cancelled the handle
  (``Handle.cancel()``); ``result()`` re-raises this.
* :class:`RequestTimedOut` — the request's per-request deadline
  (``deadline_ms=`` at submit) expired while it was queued or in flight;
  a ``TimeoutError`` subclass so generic timeout handling applies.
* :class:`NumericalError` — the computation produced non-finite outputs
  (NaN-poisoned quantized forward, overflowing int accumulators); raised
  by the decode-logits finite check and by
  :class:`repro.kernels.ops.FallbackGuard` (defined there, re-exported
  here, because the guard lives below the serving layer).
* :class:`InjectedFault` — raised by the
  :mod:`repro.serving.faults` harness on a provoked executor failure
  (defined there, re-exported here).

Process-level failures (the supervision layer, ``serving.supervisor``):

* :class:`HungStepError` — the engine's serve thread was inside one step
  longer than the supervisor's watchdog threshold; the supervisor fails
  the live engine-side handles with this, tears the daemon down, and
  restarts.  Supervised client handles do NOT see it — their requests
  are replayed on the fresh daemon.
* :class:`EngineCrashError` — the serve thread died on an uncontained
  exception (e.g. :class:`~repro.serving.faults.UncontainedCrash`, the
  provoked repro of an engine-loop bug); same supervisor treatment.
* :class:`CircuitOpenError` — the supervisor's circuit breaker tripped
  (too many restarts inside the window): outstanding requests fail with
  this and new submits are rejected until a fresh supervisor starts.

Executor/engine failures that are none of the above propagate the original
exception through ``Handle.result()`` with the handle in state ``FAILED``.
"""
from __future__ import annotations

from ..kernels.ops import NumericalError

__all__ = ["QueueFullError", "CancelledError", "RequestTimedOut",
           "NumericalError", "InjectedFault", "UncontainedCrash",
           "HungStepError", "EngineCrashError", "CircuitOpenError"]


class QueueFullError(RuntimeError):
    """Admission control: the bounded request queue is full."""


class CancelledError(RuntimeError):
    """The request's handle was cancelled before it produced a result."""


class RequestTimedOut(TimeoutError):
    """The request's per-request deadline expired (queued or in flight)."""


class HungStepError(RuntimeError):
    """The serve thread sat inside one engine step past the watchdog
    threshold (supervisor teardown; in-flight attempts fail with this)."""


class EngineCrashError(RuntimeError):
    """The serve thread died on an uncontained exception; the supervisor
    restarts the daemon (in-flight attempts fail with this)."""


class CircuitOpenError(RuntimeError):
    """The supervisor's restart circuit breaker is open (NOT_READY):
    too many restarts within the window — requests are rejected."""


def __getattr__(name):
    # late imports: faults.py imports this module for the re-export chain
    if name in ("InjectedFault", "UncontainedCrash"):
        from . import faults
        return getattr(faults, name)
    raise AttributeError(name)
