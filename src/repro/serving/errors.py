"""Failure taxonomy for the serving stack.

Every way a request can end other than DONE has one exception class, so
callers can catch precisely what they can handle:

* :class:`QueueFullError` — admission control: the bounded queue rejected
  the submit (``OverloadPolicy(shed_oldest=False)``), or the request was
  admitted and later shed to make room (``shed_oldest=True``; the shed
  handle ends ``FAILED`` with this exception and counts in
  ``ServeStats.shed``).
* :class:`CancelledError` — the caller cancelled the handle
  (``Handle.cancel()``); ``result()`` re-raises this.
* :class:`RequestTimedOut` — the request's per-request deadline
  (``deadline_ms=`` at submit) expired while it was queued or in flight;
  a ``TimeoutError`` subclass so generic timeout handling applies.
* :class:`NumericalError` — the computation produced non-finite outputs
  (NaN-poisoned quantized forward, overflowing int accumulators); raised
  by the decode-logits finite check and by
  :class:`repro.kernels.ops.FallbackGuard` (defined there, re-exported
  here, because the guard lives below the serving layer).
* :class:`InjectedFault` — raised by the
  :mod:`repro.serving.faults` harness on a provoked executor failure
  (defined there, re-exported here).

Executor/engine failures that are none of the above propagate the original
exception through ``Handle.result()`` with the handle in state ``FAILED``.
"""
from __future__ import annotations

from ..kernels.ops import NumericalError

__all__ = ["QueueFullError", "CancelledError", "RequestTimedOut",
           "NumericalError", "InjectedFault"]


class QueueFullError(RuntimeError):
    """Admission control: the bounded request queue is full."""


class CancelledError(RuntimeError):
    """The request's handle was cancelled before it produced a result."""


class RequestTimedOut(TimeoutError):
    """The request's per-request deadline expired (queued or in flight)."""


def _injected_fault():
    # late import: faults.py imports this module for the re-export chain
    from .faults import InjectedFault
    return InjectedFault


def __getattr__(name):
    if name == "InjectedFault":
        return _injected_fault()
    raise AttributeError(name)
