"""Shared batching math + unified serving statistics.

Both serving front-ends (the continuous-batching token ``Engine`` and the
stateless ``VisionEngine``) bound XLA recompilation the same way: batch
shapes are rounded up to a power of two before execution, so the number of
compiled graph variants is O(log2 max_batch) regardless of the traffic's
size distribution.  The rounding lives here so the two engines cannot
drift; so does :class:`ServeStats`, the one stats object the scheduler,
the engines, and ``benchmarks/serving_bench.py`` all share — queue-latency
percentiles, batch occupancy, and the padded-work fraction are defined
once, identically, for both modalities.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, List, Optional, Set


def pow2_bucket(n: int, min_bucket: int = 1, cap: Optional[int] = None) -> int:
    """Smallest power-of-two multiple of ``min_bucket`` >= ``n``.

    ``min_bucket`` floors the result (it should itself be a power of two —
    sharded engines floor at the data-axis size so every executed batch
    stays divisible); ``cap`` bounds it (the engine's ``max_batch``, i.e.
    the largest shape ever compiled).  Raises ``ValueError`` for a
    negative count.
    """
    if n < 0:
        raise ValueError(f"bucket size for negative count {n}")
    b = max(1, min_bucket)
    while b < n:
        b *= 2
    return b if cap is None else min(b, cap)


def _percentile(sorted_vals: List[float], pct: float) -> float:
    """Nearest-rank percentile on a pre-sorted list (0 when empty)."""
    if not sorted_vals:
        return 0.0
    k = max(0, min(len(sorted_vals) - 1,
                   int(round(pct / 100.0 * (len(sorted_vals) - 1)))))
    return sorted_vals[k]


@dataclasses.dataclass
class ServeStats:
    """Unified serving counters (one definition for both engines).

    * ``queue_ms`` — per-request time from ``submit()`` to the flush that
      started executing it (recorded by the scheduler, measured on the
      scheduler's clock so tests/benchmarks can drive virtual time).
    * occupancy — real items per executed batch relative to the policy's
      ``max_batch`` (``capacity_items`` accumulates per-batch capacity).
    * padded-work fraction — pad rows (pow2 bucketing) or pad tokens
      (ragged prefill) as a share of everything actually executed.
    * outcome counters — every submitted handle resolves into exactly one
      of ``completed`` / ``failed`` / ``cancelled`` / ``timed_out`` /
      ``shed`` (recorded by the Handle state machine), so
      ``submitted == resolved`` reconciles once traffic drains.
      ``rejected`` counts submits the OverloadPolicy refused — those
      never created a handle and are NOT part of ``submitted``.

    Thread-safety: the ``record_*`` mutators serialize on an internal
    lock (not a dataclass field — ``reset()``/``fields()`` never touch
    it), because under the serving daemon a foreign submitter thread and
    the engine thread resolve outcomes concurrently and the read-add-set
    increments would otherwise lose counts.  Reads (properties,
    ``summary()``) stay lock-free snapshots.
    """

    submitted: int = 0
    items: int = 0            # real items executed through batches
    batches: int = 0
    padded_items: int = 0     # pad rows/tokens added (wasted compute)
    capacity_items: int = 0   # sum of per-batch capacity (policy max_batch)
    # terminal-outcome counters (see Handle state machine)
    completed: int = 0        # handles resolved DONE
    failed: int = 0           # executor/numerical failures -> FAILED
    cancelled: int = 0        # caller cancel() -> CANCELLED
    timed_out: int = 0        # per-request deadline expiry -> TIMED_OUT
    shed: int = 0             # load shedding (FAILED w/ QueueFullError)
    rejected: int = 0         # submits refused up front (no handle made)
    queue_ms: List[float] = dataclasses.field(default_factory=list)
    flush_reasons: Dict[str, int] = dataclasses.field(default_factory=dict)
    buckets_used: Set[int] = dataclasses.field(default_factory=set)

    _OUTCOMES = ("completed", "failed", "cancelled", "timed_out", "shed",
                 "rejected")

    def __post_init__(self):
        # plain attribute, not a dataclass field: reset() iterates
        # fields() and must never swap the lock out from under a waiter
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------------
    def record_batch(self, items: int, padded: int = 0,
                     capacity: Optional[int] = None,
                     bucket: Optional[int] = None) -> None:
        with self._lock:
            self.items += items
            self.batches += 1
            self.padded_items += padded
            self.capacity_items += capacity if capacity else items + padded
            if bucket:
                self.buckets_used.add(bucket)

    def record_flush(self, reason: str) -> None:
        with self._lock:
            self.flush_reasons[reason] = self.flush_reasons.get(reason, 0) + 1

    def record_outcome(self, kind: str) -> None:
        """Count one terminal request outcome (called by the Handle state
        machine exactly once per handle).  Raises ``ValueError`` for a
        kind outside the outcome-counter set."""
        if kind not in self._OUTCOMES:
            raise ValueError(f"unknown outcome {kind!r}; one of "
                             f"{self._OUTCOMES}")
        with self._lock:
            setattr(self, kind, getattr(self, kind) + 1)

    # long-lived engines must not leak: latency samples keep a sliding
    # window (percentiles reflect recent traffic, memory stays bounded)
    _MAX_LATENCY_SAMPLES = 16384

    def record_latency(self, ms: float) -> None:
        with self._lock:
            self.queue_ms.append(ms)
            if len(self.queue_ms) > self._MAX_LATENCY_SAMPLES:
                del self.queue_ms[: self._MAX_LATENCY_SAMPLES // 2]

    def reset(self) -> None:
        """Zero every counter in place (benchmark warmup; the scheduler
        keeps its reference, so stats must reset without rebinding)."""
        with self._lock:
            for f in dataclasses.fields(self):
                setattr(self, f.name,
                        f.default_factory()
                        if f.default is dataclasses.MISSING
                        else f.default)

    # -- derived metrics -----------------------------------------------------
    def latency_ms(self, pct: float) -> float:
        return _percentile(sorted(self.queue_ms), pct)

    @property
    def p50_ms(self) -> float:
        return self.latency_ms(50.0)

    @property
    def p99_ms(self) -> float:
        return self.latency_ms(99.0)

    @property
    def batch_occupancy(self) -> float:
        return self.items / self.capacity_items if self.capacity_items else 0.0

    @property
    def padded_fraction(self) -> float:
        total = self.items + self.padded_items
        return self.padded_items / total if total else 0.0

    @property
    def resolved(self) -> int:
        """Handles that reached a terminal state; equals ``submitted``
        once all traffic has drained (the reconciliation invariant)."""
        return (self.completed + self.failed + self.cancelled
                + self.timed_out + self.shed)

    def summary(self) -> Dict[str, object]:
        """JSON-ready snapshot (serving_bench rows, CLI reporting)."""
        return {
            "submitted": self.submitted,
            "items": self.items,
            "batches": self.batches,
            "completed": self.completed,
            "failed": self.failed,
            "cancelled": self.cancelled,
            "timed_out": self.timed_out,
            "shed": self.shed,
            "rejected": self.rejected,
            "p50_ms": round(self.p50_ms, 4),
            "p99_ms": round(self.p99_ms, 4),
            "batch_occupancy": round(self.batch_occupancy, 4),
            "padded_fraction": round(self.padded_fraction, 4),
            "flush_reasons": dict(self.flush_reasons),
            "buckets_used": sorted(self.buckets_used),
        }
