"""The wall-clock serving daemon: a thread that actually drives an engine.

Everything below the daemon is poll-driven — `Engine.step()` /
`VisionEngine.poll()` advance exactly when called, which is perfect for
tests and virtual-clock benchmarks and useless for a client that just
wants to connect and submit.  :class:`ServingDaemon` closes that gap: one
background thread owns the engine and runs the serve loop; foreign
threads call :meth:`submit` (thread-safe all the way down — the scheduler
queue, the handle state machine, and ``ServeStats`` all lock internally)
and consume results through the streaming ``Handle`` API
(``handle.tokens()``, ``on_token=``, ``result(timeout=)``).

The loop does NOT poll: while decode slots are live it steps flat-out,
and when the engine goes idle it sleeps on a condition variable with a
timeout of ``scheduler.next_deadline() - now`` — a submit notifies the
condition, a deadline (admission coalescing or per-request expiry) wakes
it by timeout, and nothing else spins.  Because ``Scheduler.due`` and
``next_deadline`` share one ``FlushPolicy.admission_deadline``
arithmetic, sleeping exactly until the returned instant IS due — the
loop never wakes a float-ulp early and spins.

SLO classes (:mod:`repro.serving.slo`) are resolved here, at submit
time, into plain engine arguments: the class's priority rides the
scheduler's priority queue, its ``max_delay_ms`` rides the installed
:class:`~repro.serving.slo.ClassFlushPolicy`, its ``deadline_ms``
becomes the request deadline (unless the submit carries its own), its
``max_queued`` bounds the class's OUTSTANDING requests (rejecting
beyond it with ``QueueFullError``), and ``preemptible`` marks decodes
the engine may evict (restart-from-prefix) for higher tiers.  Per-class
:class:`~repro.serving.batching.ServeStats` record COMPLETION latency
(submit -> terminal, not just queue wait) via done-callbacks, so
``daemon.class_stats["interactive"].p99_ms < ...["batch"].p99_ms`` is a
measurable SLO, not a hope.

Shutdown: ``shutdown(drain=True)`` stops intake and serves everything
outstanding to a terminal state; ``drain=False`` (or a drain that hits
``timeout``) cancels what remains instead — either way every submitted
handle resolves and the PR-6 reconciliation invariant
``submitted == completed+failed+cancelled+timed_out+shed`` holds
exactly.  The daemon is also a context manager (clean drain on exit).
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional

from .batching import ServeStats
from .errors import QueueFullError
from .scheduler import DONE, FAILED, Handle
from .slo import DEFAULT_CLASSES, ClassFlushPolicy, classes_by_name

# daemon lifecycle states
_NEW, _RUNNING, _STOPPING, _STOPPED = "new", "running", "stopping", "stopped"
_CRASHED = "crashed"  # the serve thread died on an uncontained exception


class ServingDaemon:
    """Background serve loop over one engine (see module docstring).

    ``engine``: a token ``Engine`` (driven via ``step()``) or a
    ``VisionEngine`` (driven via ``poll()``) — detected by which method
    it has.  ``classes``: the SLO tiers submits may name (default
    interactive + batch); installs a
    :class:`~repro.serving.slo.ClassFlushPolicy` built from them onto
    the engine's scheduler, preserving its ``max_batch``.  The engine's
    clock must be the real clock (a virtual clock cannot wake a sleeping
    thread — virtual-time tests drive the engine directly instead).
    """

    def __init__(self, engine, classes=DEFAULT_CLASSES):
        self.engine = engine
        sched = engine.scheduler
        if sched.clock is not time.monotonic:
            raise ValueError(
                "ServingDaemon needs the engine on the real clock "
                "(time.monotonic): sleeping until next_deadline() cannot "
                "advance an injected virtual clock — virtual-time tests "
                "drive the engine directly")
        self._is_token = hasattr(engine, "step")
        self.classes = classes_by_name(classes)
        sched.policy = ClassFlushPolicy.from_classes(
            classes, max_batch=sched.policy.max_batch)
        self.class_stats: Dict[str, ServeStats] = {
            name: ServeStats() for name in self.classes}
        # RLock: a vision submit executes a due batch INLINE while the
        # submitter holds _wake, and the batchmates' done-callbacks
        # re-enter _wake on that same thread
        self._wake = threading.Condition(threading.RLock())
        self._state = _NEW
        self._drain = True
        self._thread: Optional[threading.Thread] = None
        # supervision surface (serving.supervisor): ``crashed`` records an
        # uncontained exception that killed the serve thread; ``heartbeat``
        # is the real-clock time the loop last COMPLETED a pass; and
        # ``step_started`` is non-None exactly while the loop is inside
        # one engine advance — a hung step is step_started staying set
        # while the clock runs on (an idle, sleeping loop never looks
        # hung because step_started is None between passes)
        self.crashed: Optional[BaseException] = None
        self.heartbeat: Optional[float] = None
        self.step_started: Optional[float] = None
        # outstanding (unresolved) handles, per class and as a set — the
        # per-class budget reads the count; non-drain shutdown cancels
        # the set.  Guarded by _wake's lock.
        self._outstanding: Dict[int, str] = {}  # handle uid -> class name
        self._handles: Dict[int, Handle] = {}

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "ServingDaemon":
        """Start the serve thread; idempotent error on reuse (a daemon
        serves one lifecycle — make a new one after shutdown)."""
        with self._wake:
            if self._state != _NEW:
                raise RuntimeError(
                    f"daemon already {self._state}: a ServingDaemon runs "
                    "one start/shutdown lifecycle")
            self._state = _RUNNING
        self._thread = threading.Thread(
            target=self._run, name="repro-serve", daemon=True)
        self._thread.start()
        return self

    def __enter__(self) -> "ServingDaemon":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    @property
    def running(self) -> bool:
        return self._state == _RUNNING

    @property
    def outstanding(self) -> int:
        """Unresolved handles registered through :meth:`submit` (queued
        plus in flight) — a health-probe input."""
        with self._wake:
            return len(self._handles)

    def abort(self):
        """Supervisor teardown of a crashed/hung daemon: mark it STOPPING
        (non-drain) WITHOUT joining the serve thread — a hung thread
        cannot be joined, and a crashed one is already gone.  Returns the
        outstanding handles so the caller can fail them with the teardown
        reason (``HungStepError`` / ``EngineCrashError``); if the stuck
        thread ever wakes it sees STOPPING+non-drain and exits.  Regular
        clients should use :meth:`shutdown`."""
        with self._wake:
            if self._state in (_RUNNING, _CRASHED):
                self._state = _STOPPING
            self._drain = False
            self._wake.notify_all()
            return list(self._handles.values())

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the daemon.  ``drain=True`` stops intake and keeps
        serving until everything outstanding reached a terminal state;
        ``drain=False`` — or a drain still busy after ``timeout``
        seconds — CANCELS the remainder instead.  Either way every
        submitted handle resolves, so the stats reconcile exactly.
        Idempotent; returns once the serve thread has exited."""
        with self._wake:
            if self._state in (_NEW, _STOPPED):
                self._state = _STOPPED
                return
            self._state = _STOPPING
            self._drain = drain
            self._wake.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():  # drain exceeded its budget
                with self._wake:
                    self._drain = False
                    self._wake.notify_all()
                self._thread.join()
        # cancel anything the loop did not serve (drain=False, or handles
        # still queued when a timed-out drain was demoted); in-flight
        # slots are dead with the loop, so cancel resolves them too
        with self._wake:
            leftovers = list(self._handles.values())
        for h in leftovers:
            h.cancel()
        with self._wake:
            self._state = _STOPPED

    # -- submit --------------------------------------------------------------
    def submit(self, payload, slo: str = "interactive", **kw):
        """Submit ``payload`` under an SLO class, from any thread.

        Token engine: ``payload`` is the prompt; ``kw`` forwards to
        ``Engine.submit`` (``max_new_tokens=``, ``stream=``,
        ``on_token=``, ``temperature=``, ``deadline_ms=``...).  Vision
        engine: ``payload`` is the image.  The class supplies priority,
        preemptibility, and — unless ``kw`` carries ``deadline_ms`` —
        its default deadline.  Returns what the engine's submit returns
        (a ``Request`` with ``.handle``, or a bare ``Handle``).

        Raises ``QueueFullError`` when the class's ``max_queued``
        outstanding-budget is exhausted (counted ``rejected`` in that
        class's stats; nothing was submitted), ``KeyError`` for an
        unknown class name, ``RuntimeError`` when the daemon is not
        running.
        """
        if slo not in self.classes:
            raise KeyError(
                f"unknown SLO class {slo!r}; one of "
                f"{sorted(self.classes)}")
        cls = self.classes[slo]
        cstats = self.class_stats[cls.name]
        # submit + registration happen under _wake so a concurrent
        # shutdown cannot slip between them (it would miss the handle in
        # its leftover sweep and leave it PENDING forever); lock order is
        # always _wake -> scheduler lock, never the reverse
        with self._wake:
            if self._state != _RUNNING:
                raise RuntimeError(
                    f"daemon is {self._state}: submit() needs a running "
                    "daemon (start() it, or it was shut down)")
            if cls.max_queued is not None:
                n_out = sum(1 for c in self._outstanding.values()
                            if c == cls.name)
                if n_out >= cls.max_queued:
                    cstats.record_outcome("rejected")
                    raise QueueFullError(
                        f"SLO class {cls.name!r} budget exhausted: "
                        f"{n_out} outstanding >= max_queued="
                        f"{cls.max_queued}")
            kw.setdefault("deadline_ms", cls.deadline_ms)
            if self._is_token:
                out = self.engine.submit(payload, priority=cls.priority,
                                         preemptible=cls.preemptible, **kw)
                handle = out.handle
            else:
                out = self.engine.submit(payload, **kw)
                handle = out
            t0 = self.engine.scheduler.now()
            cstats.submitted += 1
            self._outstanding[handle.uid] = cls.name
            self._handles[handle.uid] = handle
            self._wake.notify_all()  # new work: wake a sleeping loop

        def _on_done(h: Handle, _cstats=cstats, _t0=t0) -> None:
            # completion latency (submit -> terminal) on the scheduler's
            # monotonic-guarded clock; the per-class outcome mirrors the
            # engine's (shed keeps its distinct counter)
            _cstats.record_latency(
                (self.engine.scheduler.now() - _t0) * 1000.0)
            state = h.state
            if state == FAILED and isinstance(h.exception(),
                                              QueueFullError):
                _cstats.record_outcome("shed")
            elif state == DONE:
                _cstats.record_outcome("completed")
            else:
                _cstats.record_outcome(
                    {"FAILED": "failed", "CANCELLED": "cancelled",
                     "TIMED_OUT": "timed_out"}[state])
            with self._wake:
                self._outstanding.pop(h.uid, None)
                self._handles.pop(h.uid, None)
                self._wake.notify_all()  # budget freed / drain progress

        handle.add_done_callback(_on_done)
        return out

    # -- the serve loop ------------------------------------------------------
    def _tick(self) -> int:
        """One engine advance; returns >0 while there is work in hand."""
        if self._is_token:
            live = self.engine.step()
            # count due queue work too: step() returns 0 when everything
            # just retired but more requests already wait
            return live or (1 if self.engine.scheduler.due() else 0)
        resolved = self.engine.poll()
        return resolved or (1 if self.engine.scheduler.due() else 0)

    def _idle(self) -> bool:
        """Nothing queued and nothing in flight (drain-complete test)."""
        if self.engine.scheduler.pending:
            return False
        if self._is_token and any(s is not None for s in self.engine.slots):
            return False
        return True

    def _run(self) -> None:
        """Thread target: the serve loop under an UNCONTAINED-crash
        recorder.  Per-request failures never reach here (the engines
        contain them with ``except Exception``); what does — a
        ``BaseException`` like ``faults.UncontainedCrash``, or a genuine
        engine-loop bug escaping containment — kills the loop.  Record
        it and flip to CRASHED so ``submit()`` fails fast and a
        supervisor can detect, tear down, and restart.  Deliberately NOT
        re-contained: outstanding handles stay PENDING for the
        supervisor to fail/replay (plain ``shutdown()`` still cancels
        them for unsupervised users)."""
        try:
            self._loop()
        except BaseException as e:  # noqa: BLE001 — crash recorder
            with self._wake:
                self.crashed = e
                self.step_started = None
                if self._state == _RUNNING:
                    self._state = _CRASHED
                self._wake.notify_all()

    def _loop(self) -> None:
        sched = self.engine.scheduler
        while True:
            self.step_started = time.monotonic()
            busy = self._tick() > 0
            self.step_started = None
            self.heartbeat = time.monotonic()
            with self._wake:
                if self._state == _STOPPING:
                    if not self._drain or self._idle():
                        return
                    if not busy:  # e.g. coalescing deadline not yet due
                        self._wake.wait(timeout=0.005)
                    continue  # draining: keep serving
                if busy:
                    continue  # hot: decode slots live or queue due
                # idle: sleep until the next deadline or a submit.  The
                # re-check under the lock closes the submit race (a
                # submit between _tick and here already notified while
                # holding this lock, so pending>0 is visible now).
                if sched.pending and sched.due() is not None:
                    continue
                nd = sched.next_deadline()
                timeout = (None if nd is None
                           else max(0.0, nd - sched.clock()))
                if timeout is None or timeout > 0:
                    self._wake.wait(timeout=timeout)

    # -- reporting -----------------------------------------------------------
    def stats_summary(self) -> Dict[str, object]:
        """JSON-ready snapshot: the engine's unified stats plus the
        per-SLO-class completion-latency stats."""
        return {
            "engine": self.engine.stats.summary(),
            "classes": {name: st.summary()
                        for name, st in self.class_stats.items()},
        }
