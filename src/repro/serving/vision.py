"""Batched vision inference (images in, logits out) over M2Q backbones.

The token engine (serving.engine) is slot-structured because decode is
stateful; image classification is stateless, so its serving shape is a
*thin executor plugged into the shared scheduler core*
(serving.scheduler): ``submit()`` enqueues one image and returns a
:class:`~repro.serving.scheduler.Handle` immediately; the request executes
when the flush policy fires — the batch fills to ``max_batch``, the oldest
request's age exceeds ``max_delay_ms`` (checked by :meth:`poll`), or an
explicit :meth:`flush` drains the queue — and the handle's ``result()``
yields that image's logits row.

Each executed batch pads up to a power-of-two bucket (shared
``batching.pow2_bucket`` — the same trick the token engine applies to
ragged prefill lengths) before running ONE jitted forward.  With ``mesh=``
the engine runs data-parallel sharded execution: params are placed by
``repro.dist.sharding.param_specs``, the bucket floor rises to the data
axis size so every executed batch shards evenly over ``batch_specs``.

With QTensor params (core.quantize_model) the jitted forward executes the
quantized conv/matmul hot path end to end: stride-1 1x1 PWConvs run the
fused m2q/int8 matmul kernels, depthwise filters the packed-w4 conv kernel
(kernels.ops.conv_dispatch_enabled), with the pure-XLA QTensor paths as
fallback — no f32 dequantized-weight convolutions.

Failure story (the fault-tolerance layer): executor exceptions fail ONLY
the batch that was executing (the scheduler core contains them) and the
engine keeps serving.  The jitted forward runs under a
``kernels.ops.FallbackGuard``: a raising or NaN-producing kernel-dispatched
forward is retried once on the XLA path (and the dispatch axes latch off
process-wide).  Delivered logits are finite-checked PER ROW — a poisoned
image fails alone with ``NumericalError`` while its batchmates get their
results.  ``submit(..., deadline_ms=)`` expires queued requests,
``OverloadPolicy`` bounds the queue, and a ``serving.faults.FaultInjector``
(``faults=`` or ``REPRO_FAULT_SPEC``) provokes all of it deterministically
at the ``vision`` / ``vision.kernel`` / ``executor`` sites.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as _kops
from ..models import get_model
from ..models.config import ArchConfig
from . import faults as _faults
from .batching import ServeStats, pow2_bucket
from .errors import NumericalError
from .scheduler import DONE, FlushPolicy, Handle, OverloadPolicy, Scheduler


@dataclasses.dataclass
class VisionStats(ServeStats):
    """Unified ServeStats + the vision-historical field names."""

    @property
    def images(self) -> int:
        return self.items

    @property
    def padded_images(self) -> int:
        return self.padded_items


class VisionEngine:
    """Deadline-batched classifier: submit images, poll (or flush) for
    logits delivered through per-request handles."""

    def __init__(self, cfg: ArchConfig, params, max_batch: int = 64,
                 min_bucket: int = 1,
                 max_delay_ms: Optional[float] = None,
                 dispatch: Optional[_kops.DispatchConfig] = None,
                 mesh=None,
                 clock: Callable[[], float] = time.monotonic,
                 overload: Optional[OverloadPolicy] = None,
                 faults: Optional[_faults.FaultInjector] = None,
                 check_numerics: bool = True):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.cfg = cfg
        self.model = get_model(cfg)
        self.B = max_batch
        self.min_bucket = max(1, min_bucket)
        self.stats = VisionStats()
        self.mesh = mesh
        self._batch_spec = None
        if mesh is not None:
            params = self._shard(params, mesh)
        self.params = params
        # faults= (or REPRO_FAULT_SPEC) provokes failures at the vision /
        # vision.kernel / executor sites; overload= bounds the queue
        self.faults = faults if faults is not None else _faults.from_env()
        self.check_numerics = check_numerics
        self.scheduler = Scheduler(
            policy=FlushPolicy(max_batch=max_batch,
                               max_delay_ms=max_delay_ms),
            executor=self._execute, stats=self.stats, clock=clock,
            overload=overload, faults=self.faults)
        # retry-once-on-XLA guard around the kernel-dispatched forward; the
        # finite check here is cheap (the vision path syncs per batch
        # anyway) so a NaN-producing kernel also degrades to XLA
        self.fallback_guard = _kops.FallbackGuard(
            check_finite=True, faults=self.faults, site="vision.kernel")
        # real-clock time poll() last entered (supervision liveness signal,
        # independent of any injected virtual scheduler clock)
        self.heartbeat: Optional[float] = None
        # ``fallback`` is STATIC: the guard's XLA retry needs its own
        # trace, not the kernel-path trace replayed under another scope
        self._fwd = jax.jit(self._fwd_impl, static_argnames=("fallback",))
        # pin kernel dispatch for every trace this engine owns (scoped
        # kernels.ops.DispatchConfig; None inherits env/backend defaults)
        self.dispatch = dispatch

    def _shard(self, params, mesh):
        """Place params per dist.sharding and raise the bucket floor to the
        data-axis size so every pow2 batch shards evenly."""
        from ..dist import sharding as shd
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = dict(mesh.shape)
        data = int(axes.get("data", 1))
        if data > 1:
            if data & (data - 1):
                raise ValueError(
                    f"data axis size {data} is not a power of two; pow2 "
                    "batch buckets cannot shard evenly over it")
            if self.B % data:
                raise ValueError(
                    f"max_batch ({self.B}) must be divisible by the data "
                    f"axis size ({data}) for sharded execution")
            self.min_bucket = max(self.min_bucket, data)
            self._batch_spec = NamedSharding(mesh, P("data", None, None, None))
        return jax.device_put(
            params, shd.shardings_from_specs(shd.param_specs(params, mesh),
                                             mesh))

    def _dispatch_scope(self):
        return (_kops.dispatch(self.dispatch) if self.dispatch is not None
                else contextlib.nullcontext())

    def _fwd_impl(self, params, images, fallback=False):
        # fallback=True (static) pins the retry trace to the XLA path —
        # all dispatch axes off, beating any ambient scope/env/latch
        scope = (_kops.dispatch(dense=False, conv=False, attn=False)
                 if fallback else contextlib.nullcontext())
        with scope:
            return self.model.forward(self.cfg, params, images)

    def bucket(self, n: int) -> int:
        """Smallest power-of-two >= n (floored at min_bucket, capped at
        max_batch) — the batch shape actually compiled and executed."""
        return pow2_bucket(n, self.min_bucket, self.B)

    # -- execution core ------------------------------------------------------
    def _run_batch(self, images: np.ndarray, bucket: int) -> np.ndarray:
        """Pad ``images`` (n <= bucket) up to ``bucket`` rows, run one
        jitted forward, record batch stats, return the n real rows."""
        n = images.shape[0]
        pad = bucket - n
        if pad:
            images = np.concatenate(
                [images, np.zeros((pad,) + images.shape[1:], np.float32)])
        x = jnp.asarray(images)
        if self._batch_spec is not None:
            x = jax.device_put(x, self._batch_spec)
        with self._dispatch_scope():
            logits = self.fallback_guard.run(self._fwd, self.params, x)
        self.stats.record_batch(items=n, padded=pad, capacity=self.B,
                                bucket=bucket)
        return np.asarray(logits)[:n]

    def _execute(self, handles: List[Handle], reason: str) -> None:
        """Scheduler executor: one flushed batch -> per-handle logits.

        Per-ROW numerics containment: rows of the executed batch holding
        NaN/Inf fail their handle alone with ``NumericalError``; the rest
        of the batch delivers normally.  An exception out of here (an
        injected ``vision``-site fault, an OOM, a raise surviving the
        guard's XLA retry) is contained by the scheduler core: it fails
        this batch's handles and the serving loop keeps running.
        """
        act = (self.faults.on_call("vision")
               if self.faults is not None else None)
        if act is not None:
            act.fire()  # raises/delays before any work runs
        imgs = np.stack([h.payload for h in handles]).astype(np.float32)
        out = self._run_batch(imgs, self.bucket(len(handles)))
        if act is not None and act.poison:
            # simulated silent corruption of the batch's outputs: poison
            # ONE row — that request fails alone, batchmates deliver
            out = out.copy()
            out[0] = np.nan
        for i, (h, row) in enumerate(zip(handles, out)):
            if self.check_numerics and not np.all(np.isfinite(row)):
                h.set_exception(NumericalError(
                    f"request {h.uid}: non-finite logits from the vision "
                    f"forward (row {i} of the executed batch); its result "
                    "was not delivered"))
            else:
                h.set_result(row)

    # -- request API ---------------------------------------------------------
    def submit(self, image: np.ndarray,
               deadline_ms: Optional[float] = None) -> Handle:
        """Queue one (H, W, 3) image; returns a handle whose ``result()``
        (this image's (n_classes,) logits) is delivered at flush — when the
        batch fills, the deadline fires, or ``flush()`` drains.

        ``deadline_ms``: optional per-request deadline — a queued request
        that is not executed within that many ms ends ``TIMED_OUT``.

        Raises ``ValueError`` on malformed payloads, validated UP FRONT so
        bad inputs fail here with a clear message, not as a poisoned batch
        later: wrong shape, non-numeric dtypes, or NaN/Inf pixels (which
        would corrupt the whole executed batch's numerics, not just this
        row's).  Raises ``QueueFullError`` when a bounded queue rejects
        the submit (see ``OverloadPolicy``).
        """
        img = np.asarray(image)
        if img.shape != (self.cfg.img_res, self.cfg.img_res, 3):
            raise ValueError(
                f"expected ({self.cfg.img_res}, {self.cfg.img_res}, 3), "
                f"got {img.shape}")
        if not np.issubdtype(img.dtype, np.number) \
                or np.issubdtype(img.dtype, np.complexfloating):
            raise ValueError(
                f"image dtype must be real-numeric pixels, got {img.dtype}")
        if np.issubdtype(img.dtype, np.floating) \
                and not np.all(np.isfinite(img)):
            raise ValueError(
                "image holds NaN/Inf pixels; refusing to enqueue a payload "
                "that would poison its whole executed batch")
        return self.scheduler.submit(img, deadline_ms=deadline_ms)

    def poll(self) -> int:
        """Execute whatever the flush policy says is due (a full batch, or
        pending requests older than ``max_delay_ms``).  Returns the number
        of requests RESOLVED — delivered or failed: executor exceptions
        fail only their batch's handles (each handle's ``result()``
        re-raises), never this call, so serving loops keep polling.
        ``scheduler.next_deadline()`` says how long they may sleep first."""
        self.heartbeat = time.monotonic()
        return self.scheduler.poll()

    def flush(self) -> Optional[np.ndarray]:
        """Drain ALL pending images regardless of policy; returns the
        delivered (n, n_classes) logits in submit order (None if idle).

        Never raises on request failures: a failed batch or a non-finite
        row fails its own handles (absent from the returned stack; their
        ``result()`` re-raises the recorded exception) and the drain
        continues through the rest of the queue."""
        flushed = self.scheduler.drain()
        ok = [h.result() for h in flushed if h.state == DONE]
        if not ok:
            return None
        return np.stack(ok)

    def classify(self, images) -> np.ndarray:
        """(N, H, W, 3) images -> (N, n_classes) logits, any N >= 1 — the
        direct batch path, bypassing the queue (offline evaluation)."""
        images = np.asarray(images, np.float32)
        n = images.shape[0]
        if n == 0:
            return np.zeros((0, self.cfg.n_classes), np.float32)
        outs = []
        for start in range(0, n, self.B):
            chunk = images[start:start + self.B]
            outs.append(self._run_batch(chunk, self.bucket(chunk.shape[0])))
            # keep sum(flush_reasons) == batches across mixed direct/queued
            # use (queued flushes record their reason in Scheduler.pop)
            self.stats.record_flush("direct")
        return np.concatenate(outs)
