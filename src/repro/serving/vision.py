"""Batched vision inference (images in, logits out) over M2Q backbones.

The token engine (serving.engine) is slot-structured because decode is
stateful; image classification is stateless, so its serving shape is a
*batcher*: requests accumulate, and each flush pads the pending batch up to
a power-of-two bucket before running ONE jitted forward.  Pow2 bucketing
bounds XLA recompilation to O(log2 max_batch) graph variants regardless of
the traffic's batch-size distribution — the same trick the token engine
applies to ragged prefill lengths.

With QTensor params (core.quantize_model) the jitted forward executes the
quantized conv/matmul hot path end to end: stride-1 1x1 PWConvs run the
fused m2q/int8 matmul kernels, depthwise filters the packed-w4 conv kernel
(kernels.ops.conv_dispatch_enabled), with the pure-XLA QTensor paths as
fallback — no f32 dequantized-weight convolutions.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import List, Optional, Set

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as _kops
from ..models import get_model
from ..models.config import ArchConfig


@dataclasses.dataclass
class VisionStats:
    images: int = 0
    batches: int = 0
    padded_images: int = 0  # pad rows added by bucketing (wasted compute)
    buckets_used: Set[int] = dataclasses.field(default_factory=set)


class VisionEngine:
    """Micro-batching classifier: submit images, flush to get logits."""

    def __init__(self, cfg: ArchConfig, params, max_batch: int = 64,
                 min_bucket: int = 1,
                 dispatch: Optional[_kops.DispatchConfig] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.cfg = cfg
        self.model = get_model(cfg)
        self.params = params
        self.B = max_batch
        self.min_bucket = max(1, min_bucket)
        self.stats = VisionStats()
        self._pending: List[np.ndarray] = []
        self._fwd = jax.jit(self._fwd_impl)
        # pin kernel dispatch for every trace this engine owns (scoped
        # kernels.ops.DispatchConfig; None inherits env/backend defaults)
        self.dispatch = dispatch

    def _dispatch_scope(self):
        return (_kops.dispatch(self.dispatch) if self.dispatch is not None
                else contextlib.nullcontext())

    def _fwd_impl(self, params, images):
        return self.model.forward(self.cfg, params, images)

    def bucket(self, n: int) -> int:
        """Smallest power-of-two >= n (floored at min_bucket, capped at
        max_batch) — the batch shape actually compiled and executed."""
        b = self.min_bucket
        while b < n:
            b *= 2
        return min(b, self.B)

    # -- request API ---------------------------------------------------------
    def submit(self, image: np.ndarray) -> int:
        """Queue one (H, W, 3) image; returns its index in the next flush."""
        img = np.asarray(image)
        if img.shape != (self.cfg.img_res, self.cfg.img_res, 3):
            raise ValueError(
                f"expected ({self.cfg.img_res}, {self.cfg.img_res}, 3), "
                f"got {img.shape}")
        self._pending.append(img)
        return len(self._pending) - 1

    def flush(self) -> Optional[np.ndarray]:
        """Run all pending images; returns (n_pending, n_classes) logits."""
        if not self._pending:
            return None
        out = self.classify(np.stack(self._pending))
        self._pending = []
        return out

    def classify(self, images) -> np.ndarray:
        """(N, H, W, 3) images -> (N, n_classes) logits, any N >= 1."""
        images = np.asarray(images, np.float32)
        n = images.shape[0]
        if n == 0:
            return np.zeros((0, self.cfg.n_classes), np.float32)
        outs = []
        for start in range(0, n, self.B):
            chunk = images[start:start + self.B]
            b = self.bucket(chunk.shape[0])
            pad = b - chunk.shape[0]
            if pad:
                chunk = np.concatenate(
                    [chunk, np.zeros((pad,) + chunk.shape[1:], np.float32)])
            with self._dispatch_scope():
                logits = self._fwd(self.params, jnp.asarray(chunk))
            outs.append(np.asarray(logits)[: b - pad])
            self.stats.batches += 1
            self.stats.padded_images += pad
            self.stats.buckets_used.add(b)
        self.stats.images += n
        return np.concatenate(outs)
