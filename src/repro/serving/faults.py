"""Deterministic fault injection for the serving stack.

Every fault-tolerance behavior in this package is tested by PROVOKED
faults, not by hoping for real ones: a :class:`FaultInjector` is handed to
the scheduler/engines (``faults=``) and fires on exactly the executor
calls a :class:`FaultSpec` names — raising, delaying, or NaN-poisoning
the Nth call at a site.

Spec grammar (one spec; join several with commas)::

    KIND@SITE:WHEN[:DELAY_MS]

    KIND   raise | delay | nan | hang | crash
    SITE   an executor call site, or * for any.  The built-in sites:
             prefill        token Engine prefill batches
             decode         token Engine decode steps
             vision         VisionEngine executed batches
             executor       Scheduler-level executor calls (vision path)
             vision.kernel  inside the VisionEngine's FallbackGuard —
                            faults the kernel-dispatched primary attempt,
                            so the guard's XLA retry is what recovers
    WHEN   N      fire on the Nth call at that site (1-based), or
           */K    fire on every Kth call (a fault *rate*)
    DELAY  milliseconds, for KIND=delay (default 25) and KIND=hang
           (max stall; default 30000 — the watchdog should fire first)

Examples::

    raise@prefill:2        second prefill batch raises InjectedFault
    nan@decode:3           3rd decode step NaN-poisons one live slot
    raise@decode:*/10      every 10th decode step raises (10% fault rate)
    delay@vision:1:50      first vision batch stalls 50ms (wall clock)
    nan@vision.kernel:1    first kernel-dispatched vision forward returns
                           NaN -> the FallbackGuard retries on XLA

The ``REPRO_FAULT_SPEC`` env var (read by :func:`from_env`, which every
engine consults when no ``faults=`` is passed) injects the same specs into
an unmodified binary — the repro hook for chasing production failures.
With the env var unset and no injector passed, nothing in this module
runs on the hot path.

What each KIND means at engine level:

* ``raise`` — the executor call raises :class:`InjectedFault`; the
  engines' containment fails ONLY the requests that call was serving
  (the prefill group / the live decode slots / the vision batch) and the
  serving loop keeps running.
* ``delay`` — the call stalls (real ``time.sleep``); deadline and
  timeout machinery sees genuinely late work.
* ``nan`` — the call's outputs are NaN-poisoned.  At ``decode`` the
  engine poisons ONE live slot's cache rows (that single request fails
  with ``NumericalError``; its batchmates decode on).  At ``vision`` the
  first request's logits row is poisoned (same per-request containment).
  At a ``*.kernel`` site the FallbackGuard sees the poison and retries
  the step on the XLA path.

* ``hang`` — the call BLOCKS (the engine thread stalls inside its step)
  until the injector's :meth:`FaultInjector.release_hangs` fires or the
  spec's DELAY_MS elapses, whichever is first.  Nothing raises: from the
  outside the step is simply not finishing — exactly what the
  supervisor's hung-step watchdog (``serving.supervisor``) must detect
  by heartbeat age.
* ``crash`` — the call raises :class:`UncontainedCrash`, a
  ``BaseException`` subclass that sails THROUGH the engines'
  per-batch ``except Exception`` containment and kills the serving
  thread: the provoked analogue of an engine-loop bug or a dying
  runtime.  Only the process-level supervisor can recover from it.

  Detection boundary: the default numerics check watches the LOGITS.
  On a fully-quantized decode path, activation quantization can launder
  a cache NaN into finite garbage before it reaches the logits
  (``NaN.astype(int8)`` is a finite value), so ``nan@decode`` against a
  quantized engine delivers corrupt-but-finite tokens undetected BY
  DEFAULT.  Opting in to the pre-quantization check
  (``debug_numerics=True`` or ``REPRO_DEBUG_NUMERICS=1``) closes the
  gap: every decode step also scans the inexact cache leaves — the
  per-row f32 KV scales carry the NaN even when the int8 payload does
  not — at the cost of a full cache read per step.
"""
from __future__ import annotations

import dataclasses
import os
import threading
import time
from typing import Dict, List, Optional, Sequence

ENV_VAR = "REPRO_FAULT_SPEC"

_KINDS = ("raise", "delay", "nan", "hang", "crash")

# a hang with no explicit DELAY_MS stalls this long before giving up on
# its own — long enough that any sanely-configured watchdog fires first
_HANG_DEFAULT_MS = 30_000.0


class InjectedFault(RuntimeError):
    """A provoked executor failure (FaultSpec kind ``raise``)."""


class UncontainedCrash(BaseException):
    """A provoked UNCONTAINED failure (FaultSpec kind ``crash``).

    Deliberately a ``BaseException`` subclass: the engines contain
    per-batch failures with ``except Exception``, so this raises straight
    through ``Engine.step()`` / ``VisionEngine.poll()`` and kills the
    daemon's serve thread — the repro for an engine-loop bug, not a
    per-request failure.  Recovery is the supervisor's job.
    """


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One deterministic fault: KIND at SITE on the Nth (or every Kth)
    call.  Build from the string grammar with :meth:`parse`."""

    kind: str             # "raise" | "delay" | "nan"
    site: str = "*"       # executor call site, "*" matches any
    nth: int = 1          # 1-based call index (ignored when every_k set)
    every_k: Optional[int] = None  # fire on every Kth call instead
    delay_ms: float = 25.0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of "
                             f"{_KINDS}")
        if self.nth < 1 or (self.every_k is not None and self.every_k < 1):
            raise ValueError(f"fault call index must be >= 1: {self}")
        if self.delay_ms < 0:
            raise ValueError(f"delay_ms must be >= 0: {self}")

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """Parse one ``KIND@SITE:WHEN[:DELAY_MS]`` spec string.  Raises
        ``ValueError`` (naming the offending text) on any malformed spec —
        a typo in ``REPRO_FAULT_SPEC`` must fail loudly at startup, not
        silently inject nothing."""
        try:
            kind, rest = text.strip().split("@", 1)
            parts = rest.split(":")
            site = parts[0].strip()
            when = parts[1].strip() if len(parts) > 1 else "1"
            kw = {}
            if len(parts) > 2:
                kw["delay_ms"] = float(parts[2])
            elif kind.strip().lower() == "hang":
                kw["delay_ms"] = _HANG_DEFAULT_MS
            if when.startswith("*/"):
                kw["every_k"] = int(when[2:])
            else:
                kw["nth"] = int(when)
            if not site:
                raise ValueError("empty site")
            return cls(kind=kind.strip().lower(), site=site, **kw)
        except ValueError as e:
            raise ValueError(
                f"malformed fault spec {text!r} (grammar: "
                f"KIND@SITE:WHEN[:DELAY_MS], e.g. 'raise@decode:3' or "
                f"'nan@vision:*/5'): {e}") from None

    def matches(self, call_index: int) -> bool:
        if self.every_k is not None:
            return call_index % self.every_k == 0
        return call_index == self.nth


@dataclasses.dataclass
class FaultAction:
    """What the matched specs of ONE call ask for (see ``fire``)."""

    site: str
    call_index: int
    do_raise: bool = False
    do_crash: bool = False
    delay_ms: float = 0.0
    hang_ms: float = 0.0
    poison: bool = False  # caller applies the NaN-poisoning (site-shaped)
    # set by the injector: release_hangs() unblocks a hanging fire()
    _hang_release: Optional[threading.Event] = None

    def fire(self) -> None:
        """Hang (until released or ``hang_ms`` elapses), then delay, then
        raise :class:`UncontainedCrash` / :class:`InjectedFault` if the
        call is spec'd to fail.  Callers check ``.poison`` themselves
        (where the NaN lands is site-specific)."""
        if self.hang_ms > 0:
            if self._hang_release is not None:
                self._hang_release.wait(timeout=self.hang_ms / 1000.0)
            else:
                time.sleep(self.hang_ms / 1000.0)
        if self.delay_ms > 0:
            time.sleep(self.delay_ms / 1000.0)
        if self.do_crash:
            raise UncontainedCrash(
                f"injected uncontained crash: call {self.call_index} at "
                f"site {self.site!r}")
        if self.do_raise:
            raise InjectedFault(
                f"injected fault: call {self.call_index} at site "
                f"{self.site!r}")


class FaultInjector:
    """Counts executor calls per site and fires the matching specs.

    Deterministic by construction: the Nth call at a site always faults,
    regardless of timing — so every containment test reproduces exactly.
    """

    def __init__(self, specs: Sequence[FaultSpec]):
        self.specs: List[FaultSpec] = list(specs)
        self.calls: Dict[str, int] = {}
        self.fired: List[tuple] = []  # (site, call_index, kind)
        # one shared release latch for every hang this injector fires: a
        # supervisor tearing down a hung engine sets it so the stuck
        # thread unblocks promptly instead of sleeping out its DELAY_MS
        self._hang_release = threading.Event()

    def release_hangs(self) -> None:
        """Unblock every in-flight (and future) ``hang`` fault from this
        injector — called by the supervisor after it has torn the hung
        daemon down, so the abandoned thread exits instead of squatting
        a core until the hang's DELAY_MS elapses."""
        self._hang_release.set()

    @classmethod
    def parse(cls, text: str) -> "FaultInjector":
        """Injector from a comma-joined spec string (see module doc)."""
        return cls([FaultSpec.parse(s) for s in text.split(",") if s.strip()])

    def on_call(self, site: str) -> Optional[FaultAction]:
        """Register one executor call at ``site``; returns the merged
        :class:`FaultAction` if any spec matches, else None."""
        n = self.calls.get(site, 0) + 1
        self.calls[site] = n
        act = None
        for spec in self.specs:
            if spec.site not in ("*", site) or not spec.matches(n):
                continue
            if act is None:
                act = FaultAction(site=site, call_index=n)
            if spec.kind == "raise":
                act.do_raise = True
            elif spec.kind == "delay":
                act.delay_ms = max(act.delay_ms, spec.delay_ms)
            elif spec.kind == "nan":
                act.poison = True
            elif spec.kind == "hang":
                act.hang_ms = max(act.hang_ms, spec.delay_ms)
                act._hang_release = self._hang_release
            elif spec.kind == "crash":
                act.do_crash = True
            self.fired.append((site, n, spec.kind))
        return act

    def summary(self) -> dict:
        """Injection accounting for bench rows / postmortems."""
        return {"specs": [dataclasses.asdict(s) for s in self.specs],
                "calls": dict(self.calls),
                "fired": [list(f) for f in self.fired]}


def from_env() -> Optional[FaultInjector]:
    """The process-default injector from ``REPRO_FAULT_SPEC`` (None when
    unset/empty).  Engines consult this when constructed without an
    explicit ``faults=`` — the zero-code-change repro hook."""
    text = os.environ.get(ENV_VAR, "").strip()
    if not text:
        return None
    return FaultInjector.parse(text)
