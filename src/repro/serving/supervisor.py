"""Process-level supervision above the serving daemon: detect, restart,
replay.

The layers below guarantee per-request failure containment (PR 6) and a
wall-clock serve loop (PR 8) — but an UNCONTAINED failure (the serve
thread dying on an engine-loop bug, a step that never returns) still
loses every in-flight request.  :class:`Supervisor` is that recovery
layer: it OWNS the daemon lifecycle instead of handing the daemon to the
client.

* **Two-level handles.**  ``Supervisor.submit`` returns a CLIENT handle
  (a plain :class:`~repro.serving.scheduler.Handle`, uid = the
  client-supplied request id) that is distinct from the per-ATTEMPT
  engine handle created by each ``daemon.submit``.  Contained outcomes
  (DONE, a ``NumericalError``, a deadline expiry) forward from the
  attempt to the client handle; an attempt killed by supervisor teardown
  (``HungStepError`` / ``EngineCrashError``) does NOT resolve the client
  handle — the request is REPLAYED on the restarted daemon, and greedy
  decode makes the replayed result identical to an uninterrupted run.
  Streaming replays dedup: tokens the client handle already received are
  skipped, so the client stream stays exactly-once and in order.

* **Detection.**  A watchdog thread polls the daemon's supervision
  surface: ``daemon.crashed`` (the serve thread died — see
  ``ServingDaemon._run``) triggers an ``EngineCrashError`` teardown;
  ``daemon.step_started`` older than ``RestartPolicy.hang_threshold_s``
  (the thread has been INSIDE one engine step that long) triggers a
  ``HungStepError`` teardown.  Teardown never joins the stuck thread:
  ``daemon.abort()`` marks it stopping, the injector's hangs are
  released, and the live attempt handles are failed with the teardown
  reason.

* **Restart discipline.**  Exponential backoff with deterministic jitter
  (seeded — reproducible schedules in tests), and a circuit breaker:
  more than ``max_restarts`` teardowns inside ``restart_window_s`` trips
  the supervisor NOT_READY (:class:`~repro.serving.errors.CircuitOpenError`
  fails everything outstanding; ``ready()`` turns false for the load
  balancer to see).

* **Durability.**  With a :class:`~repro.serving.journal.RequestJournal`
  every submit/terminal is journaled (write-ahead: the submit record
  lands BEFORE the engine sees the request), and ``start()`` replays the
  journal's non-terminal entries — idempotently, deadline-aware
  (``deadline_unix`` is wall-clock; an entry already past its deadline
  resolves TIMED_OUT without re-running) — so the reconciliation
  invariant extends across PROCESS restarts, not just daemon restarts.

* **Probes.**  ``health()`` is the JSON snapshot (queue depth, heartbeat
  age, FallbackGuard/axis trip latches, restart count, journal lag);
  ``ready()`` is the load-balancer bit.  ``launch/daemon.py
  --health-file`` writes these to disk.
"""
from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Callable, Dict, List, Optional

import numpy as np

from ..kernels import ops as _kops
from .batching import ServeStats
from .daemon import ServingDaemon
from .errors import (CancelledError, CircuitOpenError, EngineCrashError,
                     HungStepError, QueueFullError, RequestTimedOut)
from .journal import RequestJournal
from .scheduler import CANCELLED, DONE, Handle, TIMED_OUT
from .slo import DEFAULT_CLASSES

# supervisor states
_RUNNING, _NOT_READY, _STOPPED = "running", "not_ready", "stopped"


@dataclasses.dataclass(frozen=True)
class RestartPolicy:
    """Watchdog + restart knobs (docs/serving.md, "Supervision &
    recovery").

    ``hang_threshold_s``: one engine step taking longer than this is a
    hang.  Must comfortably exceed the slowest legitimate step (first-
    call jit compiles happen at engine BUILD, not inside the serve loop,
    but a cold prefill on a busy CPU can still take a while).
    ``poll_interval_s``: watchdog cadence (None: hang_threshold/5,
    clamped to [10ms, 250ms]).  Backoff before restart k (0-based) is
    ``min(backoff_max_s, backoff_base_s * 2**k)`` scaled by a
    DETERMINISTIC jitter in [1-jitter, 1+jitter] seeded by
    ``(seed, k)`` — reproducible, but a fleet of supervisors with
    different seeds still de-synchronizes its restart stampede.
    More than ``max_restarts`` teardowns within ``restart_window_s``
    trips the circuit breaker (NOT_READY).
    """

    hang_threshold_s: float = 10.0
    poll_interval_s: Optional[float] = None
    backoff_base_s: float = 0.05
    backoff_max_s: float = 2.0
    jitter: float = 0.25
    max_restarts: int = 5
    restart_window_s: float = 60.0
    seed: int = 0

    def __post_init__(self):
        if self.hang_threshold_s <= 0:
            raise ValueError("hang_threshold_s must be > 0")
        if not (0.0 <= self.jitter < 1.0):
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")
        if self.max_restarts < 1:
            raise ValueError("max_restarts must be >= 1")

    @property
    def interval(self) -> float:
        if self.poll_interval_s is not None:
            return self.poll_interval_s
        return min(0.25, max(0.01, self.hang_threshold_s / 5.0))

    def backoff(self, k: int) -> float:
        """Delay before restart ``k`` (0-based), jittered deterministically."""
        base = min(self.backoff_max_s, self.backoff_base_s * (2.0 ** k))
        u = random.Random(f"{self.seed}:{k}").uniform(-1.0, 1.0)
        return base * (1.0 + self.jitter * u)


@dataclasses.dataclass
class _Tracked:
    """One supervised request across its attempts."""

    rid: str
    payload: object
    slo: str
    kw: dict                      # engine submit kwargs (no deadline/on_token)
    handle: Handle                # the CLIENT handle (uid = rid)
    deadline_unix: Optional[float] = None
    stream: bool = False
    attempt: Optional[Handle] = None   # live engine-side handle
    attempt_tokens: int = 0            # tokens seen from the CURRENT attempt
    pushed: int = 0                    # tokens forwarded to the client
    attempts: int = 0
    from_journal: bool = False         # recovered by cold-start replay


class Supervisor:
    """Owns daemon lifecycle: watchdog, restart w/ backoff, journal replay
    (see module docstring).

    ``engine_factory``: zero-arg callable building a FRESH engine — called
    once at :meth:`start` and once per restart (engine state dies with the
    torn-down daemon; in tests the factory decides which build gets a
    ``FaultInjector``).  ``journal``: optional
    :class:`~repro.serving.journal.RequestJournal`; the supervisor takes
    ownership (closed at :meth:`shutdown`).  Journaling requires
    JSON-serializable payloads — token prompts; vision image payloads are
    served but not journaled.
    """

    def __init__(self, engine_factory: Callable[[], object],
                 classes=DEFAULT_CLASSES,
                 journal: Optional[RequestJournal] = None,
                 policy: RestartPolicy = RestartPolicy()):
        self._factory = engine_factory
        self._classes = classes
        self.journal = journal
        self.policy = policy
        self.stats = ServeStats()  # CLIENT-handle outcomes (one per request)
        self._lock = threading.RLock()
        self._state = _STOPPED
        self._daemon: Optional[ServingDaemon] = None
        self._restarting = False
        self._stop_evt = threading.Event()
        self._watchdog: Optional[threading.Thread] = None
        self._tracked: Dict[str, _Tracked] = {}  # insertion-ordered
        self._auto_rid = 0
        self.restarts = 0
        self.replayed = 0                 # attempts resubmitted after teardown
        self.restart_log: List[dict] = []
        self.last_recovery_s: Optional[float] = None
        self._restart_times: List[float] = []

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "Supervisor":
        with self._lock:
            if self._state != _STOPPED:
                raise RuntimeError(f"supervisor already {self._state}")
            self._state = _RUNNING
        self._daemon = self._build_daemon()
        if self.journal is not None:
            self._recover_from_journal()
        self._watchdog = threading.Thread(
            target=self._watch, name="repro-supervisor", daemon=True)
        self._watchdog.start()
        return self

    def __enter__(self) -> "Supervisor":
        return self.start()

    def __exit__(self, exc_type, exc, tb) -> None:
        self.shutdown(drain=exc_type is None)

    def _build_daemon(self) -> ServingDaemon:
        return ServingDaemon(self._factory(), classes=self._classes).start()

    def shutdown(self, drain: bool = True,
                 timeout: Optional[float] = None) -> None:
        """Stop the watchdog, shut the daemon down (``drain`` as in
        ``ServingDaemon.shutdown``), cancel whatever never re-attached,
        and close the journal.  Every client handle resolves."""
        self._stop_evt.set()
        if self._watchdog is not None:
            self._watchdog.join()
            self._watchdog = None
        with self._lock:
            daemon = self._daemon
            self._state = _STOPPED
        if daemon is not None:
            started = daemon.step_started
            hung = (started is not None
                    and time.monotonic() - started
                    > self.policy.hang_threshold_s)
            if daemon.crashed is not None or hung:
                # crashed/hung between the last watchdog pass and now:
                # abort (never join a hung thread) and fail the attempts
                self._teardown_daemon(daemon, EngineCrashError(
                    "daemon dead at supervisor shutdown")
                    if daemon.crashed is not None else HungStepError(
                        "daemon hung at supervisor shutdown"))
            else:
                daemon.shutdown(drain=drain, timeout=timeout)
        # anything still PENDING (parked during a restart, or teardown-
        # marked for a replay that will never come) cancels now
        for t in self._snapshot():
            if not t.handle.done():
                t.handle.set_exception(
                    CancelledError(
                        f"request {t.rid} cancelled: supervisor shutdown"),
                    state=CANCELLED)
        if self.journal is not None:
            self.journal.close()

    # -- submit --------------------------------------------------------------
    def submit(self, payload, slo: str = "interactive",
               request_id: Optional[str] = None,
               deadline_ms: Optional[float] = None,
               stream: bool = False,
               on_token: Optional[Callable[[int], None]] = None,
               **kw) -> Handle:
        """Submit under supervision; returns the CLIENT :class:`Handle`
        (uid = ``request_id``).  ``request_id`` keys the journal and makes
        resubmission idempotent: a duplicate id while the original is
        outstanding returns the SAME handle (auto-generated when omitted —
        but only client-supplied ids survive a process restart
        meaningfully).  ``kw`` forwards to the engine submit
        (``max_new_tokens=``, ``temperature=``...).

        Never raises ``QueueFullError``: an attempt rejected by the SLO
        budget fails the returned handle instead (outcome ``shed``) so
        the supervised surface is uniform — every submitted id reaches
        exactly one terminal state.  Raises ``CircuitOpenError`` when the
        breaker is open and ``RuntimeError`` when not started.
        """
        with self._lock:
            if self._state == _NOT_READY:
                self.stats.record_outcome("rejected")
                raise CircuitOpenError(
                    "supervisor NOT_READY: restart circuit breaker is open "
                    f"({self.restarts} restarts)")
            if self._state != _RUNNING:
                raise RuntimeError(
                    f"supervisor is {self._state}: submit() needs start()")
            if request_id is None:
                self._auto_rid += 1
                request_id = f"auto-{self._auto_rid:08d}"
            prior = self._tracked.get(request_id)
            if prior is not None and not prior.handle.done():
                return prior.handle  # idempotent resubmit
            deadline_unix = (None if deadline_ms is None
                             else time.time() + deadline_ms / 1000.0)
            t = _Tracked(
                rid=request_id, payload=payload, slo=slo, kw=dict(kw),
                deadline_unix=deadline_unix,
                stream=bool(stream) or on_token is not None,
                handle=Handle(uid=request_id, payload=payload,
                              submitted_at=time.monotonic(),
                              stats=self.stats, on_token=on_token))
            self._tracked[request_id] = t
            self.stats.submitted += 1
        t.handle.add_done_callback(
            lambda h, _t=t: self._on_client_done(_t, h))
        if self.journal is not None:
            self.journal.record_submit(
                t.rid, self._journal_payload(payload), slo=slo, kw=dict(kw),
                deadline_unix=deadline_unix)
        self._attach(t)
        return t.handle

    @staticmethod
    def _journal_payload(payload):
        arr = np.asarray(payload)
        if np.issubdtype(arr.dtype, np.integer) and arr.ndim == 1:
            return arr.tolist()
        return None  # non-journalable payload (vision images)

    def handles(self) -> Dict[str, Handle]:
        """rid -> client handle snapshot (all tracked, any state)."""
        with self._lock:
            return {t.rid: t.handle for t in self._tracked.values()}

    def _snapshot(self) -> List[_Tracked]:
        with self._lock:
            return list(self._tracked.values())

    # -- attempt wiring ------------------------------------------------------
    def _attach(self, t: _Tracked) -> None:
        """Submit one engine ATTEMPT for ``t`` on the current daemon (or
        leave it parked when the daemon is mid-restart — the replay pass
        picks it up).  Never raises."""
        with self._lock:
            daemon = self._daemon
            if (self._state != _RUNNING or self._restarting
                    or daemon is None or not daemon.running):
                return  # parked: _replay_pending re-attaches after restart
        if t.handle.done():
            return
        kw = dict(t.kw)
        if t.deadline_unix is not None:
            remaining_ms = (t.deadline_unix - time.time()) * 1000.0
            if remaining_ms <= 0:
                t.handle.set_exception(
                    RequestTimedOut(
                        f"request {t.rid} expired before (re)submission: "
                        "deadline passed while the daemon was down"),
                    state=TIMED_OUT)
                return
            kw["deadline_ms"] = remaining_ms
        t.attempt_tokens = 0
        if t.stream and daemon._is_token:
            kw["on_token"] = lambda tok, _t=t: self._forward_token(_t, tok)
        try:
            out = daemon.submit(np.asarray(t.payload)
                                if daemon._is_token else t.payload,
                                slo=t.slo, **kw)
        except QueueFullError as e:
            t.handle.set_exception(e, count_as="shed")
            return
        except RuntimeError:
            return  # daemon stopped under us: parked, replayed after restart
        attempt = out.handle if hasattr(out, "handle") else out
        with self._lock:
            t.attempt = attempt
            t.attempts += 1
        attempt.add_done_callback(
            lambda h, _t=t: self._on_attempt_done(_t, h))

    def _forward_token(self, t: _Tracked, tok: int) -> None:
        """Streaming bridge with replay dedup: a restarted attempt
        re-decodes from the prompt, so its first ``pushed`` tokens are
        ones the client already has (identical — greedy decode) and are
        skipped."""
        t.attempt_tokens += 1
        if t.attempt_tokens > t.pushed:
            if t.handle.push_token(tok):
                t.pushed += 1

    def _on_attempt_done(self, t: _Tracked, attempt: Handle) -> None:
        with self._lock:
            if t.attempt is attempt:
                t.attempt = None
        if t.handle.done():
            return  # client already resolved (cancelled / expired here)
        if attempt.state == DONE:
            t.handle.set_result(attempt.result())
            return
        exc = attempt.exception()
        if isinstance(exc, (HungStepError, EngineCrashError)):
            # teardown killed this attempt, not the request: leave the
            # client handle PENDING — _replay_pending resubmits it on the
            # restarted daemon
            return
        t.handle.set_exception(exc, state=attempt.state)

    def _on_client_done(self, t: _Tracked, h: Handle) -> None:
        """Terminal bookkeeping for the CLIENT handle, whichever path
        resolved it: journal the terminal (idempotent — exactly one per
        rid) and propagate a client-side cancel to the live attempt."""
        if self.journal is not None:
            exc = h.exception()
            self.journal.record_terminal(
                t.rid, h.state, error=None if exc is None else repr(exc))
        if h.state == CANCELLED:
            with self._lock:
                attempt = t.attempt
            if attempt is not None:
                attempt.cancel()

    # -- restart machinery ---------------------------------------------------
    def _watch(self) -> None:
        while not self._stop_evt.wait(self.policy.interval):
            with self._lock:
                if self._state != _RUNNING or self._restarting:
                    continue
                daemon = self._daemon
            if daemon is None:
                continue
            reason: Optional[Exception] = None
            if daemon.crashed is not None:
                reason = EngineCrashError(
                    "serve thread died on an uncontained exception: "
                    f"{daemon.crashed!r}")
            else:
                started = daemon.step_started
                if started is not None:
                    age = time.monotonic() - started
                    if age > self.policy.hang_threshold_s:
                        reason = HungStepError(
                            f"engine step in flight for {age:.2f}s > "
                            f"hang_threshold_s="
                            f"{self.policy.hang_threshold_s}")
            if reason is not None:
                self._restart(reason)

    def _teardown_daemon(self, daemon: ServingDaemon,
                         reason: Exception) -> None:
        """Abort (no join — the thread may be hung), release injected
        hangs so the abandoned thread exits promptly, and fail the live
        ATTEMPT handles with the teardown reason (their bridges mark the
        client requests for replay)."""
        leftovers = daemon.abort()
        injector = getattr(daemon.engine, "faults", None)
        if injector is not None and hasattr(injector, "release_hangs"):
            injector.release_hangs()
        for h in leftovers:
            h.set_exception(type(reason)(str(reason)))

    def _restart(self, reason: Exception) -> None:
        """One teardown -> backoff -> rebuild -> replay cycle (runs on the
        watchdog thread; submits arriving meanwhile park and are replayed
        with everything else)."""
        detected = time.monotonic()
        with self._lock:
            self._restarting = True
            old = self._daemon
        self._teardown_daemon(old, reason)
        kind = type(reason).__name__
        with self._lock:
            self.restarts += 1
            k = self.restarts - 1
            self._restart_times = [
                ts for ts in self._restart_times
                if detected - ts <= self.policy.restart_window_s]
            self._restart_times.append(detected)
            tripped = len(self._restart_times) > self.policy.max_restarts
            entry = {"reason": kind, "detail": str(reason),
                     "detected_unix": time.time(), "restart": self.restarts}
            self.restart_log.append(entry)
        if tripped:
            self._open_circuit(reason)
            return
        delay = self.policy.backoff(k)
        if self._stop_evt.wait(delay):
            with self._lock:
                self._restarting = False
            return  # shutting down: shutdown() resolves what remains
        daemon = self._build_daemon()
        recovery_s = time.monotonic() - detected
        with self._lock:
            self._daemon = daemon
            self._restarting = False
            self.last_recovery_s = recovery_s
            entry["backoff_s"] = round(delay, 4)
            entry["recovery_s"] = round(recovery_s, 4)
        self._replay_pending()

    def _replay_pending(self) -> None:
        """Re-attach every tracked request whose client handle is still
        PENDING with no live attempt (teardown-failed or parked), in
        submit order.  Idempotent: attached requests are skipped."""
        for t in self._snapshot():
            with self._lock:
                live = t.attempt is not None
            if t.handle.done() or live:
                continue
            self.replayed += 1
            self._attach(t)

    def _open_circuit(self, reason: Exception) -> None:
        with self._lock:
            self._state = _NOT_READY
            self._restarting = False
        exc = CircuitOpenError(
            f"circuit breaker open after {self.restarts} restarts within "
            f"{self.policy.restart_window_s}s (last: {reason})")
        for t in self._snapshot():
            if not t.handle.done():
                t.handle.set_exception(CircuitOpenError(str(exc)))

    # -- cold-start replay ---------------------------------------------------
    def _recover_from_journal(self) -> None:
        """Adopt the journal's non-terminal entries from the PREVIOUS
        process: expired deadlines resolve TIMED_OUT without re-running;
        the rest resubmit through ``daemon.submit`` in original order."""
        for rec in self.journal.pending():
            rid = rec["rid"]
            with self._lock:
                if rid in self._tracked:
                    continue
                if rec.get("payload") is None:
                    continue  # non-journalable payload (vision): unrecoverable
                t = _Tracked(
                    rid=rid, payload=rec["payload"],
                    slo=rec.get("slo", "interactive"),
                    kw=dict(rec.get("kw") or {}),
                    deadline_unix=rec.get("deadline_unix"),
                    stream=bool((rec.get("kw") or {}).pop("stream", False)),
                    from_journal=True,
                    handle=Handle(uid=rid, payload=rec["payload"],
                                  submitted_at=time.monotonic(),
                                  stats=self.stats))
                t.kw.pop("stream", None)
                self._tracked[rid] = t
                self.stats.submitted += 1
            t.handle.add_done_callback(
                lambda h, _t=t: self._on_client_done(_t, h))
            self.replayed += 1
            self._attach(t)

    # -- probes --------------------------------------------------------------
    def ready(self) -> dict:
        """The load-balancer bit: serving and able to accept work."""
        with self._lock:
            if self._state == _NOT_READY:
                return {"ready": False, "reason": "circuit_open"}
            if self._state != _RUNNING:
                return {"ready": False, "reason": self._state}
            if self._restarting:
                return {"ready": False, "reason": "restarting"}
            daemon = self._daemon
        if daemon is None or not daemon.running:
            return {"ready": False, "reason": "daemon_down"}
        return {"ready": True, "reason": "serving"}

    def health(self) -> dict:
        """JSON-ready probe snapshot (written by ``launch/daemon.py
        --health-file``)."""
        now = time.monotonic()
        with self._lock:
            daemon = self._daemon
            state = self._state
            outstanding = sum(1 for t in self._tracked.values()
                              if not t.handle.done())
        snap = {
            "state": state,
            "ready": self.ready(),
            "restarts": self.restarts,
            "last_recovery_s": self.last_recovery_s,
            "replayed": self.replayed,
            "supervised_outstanding": outstanding,
            "unix_time": time.time(),
            "trip_latches": {"axes": _kops.trip_counts()},
            "stats": self.stats.summary(),
        }
        if daemon is not None:
            engine = daemon.engine
            hb = daemon.heartbeat
            started = daemon.step_started
            snap.update({
                "daemon_state": daemon._state,
                "queue_depth": engine.scheduler.pending,
                "daemon_outstanding": daemon.outstanding,
                "heartbeat_age_s": (None if hb is None
                                    else round(now - hb, 4)),
                "step_in_flight_s": (0.0 if started is None
                                     else round(now - started, 4)),
                "crashed": (None if daemon.crashed is None
                            else repr(daemon.crashed)),
            })
            guard = getattr(engine, "fallback_guard", None)
            if guard is not None:
                snap["trip_latches"]["guard"] = guard.stats()
        if self.journal is not None:
            snap["journal"] = {"path": str(self.journal.path),
                               "fsync": self.journal.fsync,
                               "lag": self.journal.lag(),
                               **self.journal.reconcile()}
        return snap
