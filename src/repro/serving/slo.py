"""SLO classes: named service tiers mapped onto the scheduler's knobs.

An :class:`SLOClass` bundles the per-tier serving contract — admission
priority, how long requests may coalesce before admission
(``max_delay_ms``), an optional default per-request completion deadline,
a per-class outstanding-request budget, and whether the tier's decodes
may be PREEMPTED for higher tiers.  The daemon
(:class:`~repro.serving.daemon.ServingDaemon`) resolves a class name at
submit time into plain ``Engine.submit`` arguments, so the engines stay
SLO-agnostic: priority rides the scheduler's priority queue, deadlines
ride the existing per-request deadline machinery, and preemption rides
``Engine`` slot eviction + ``Scheduler.requeue``.

:class:`ClassFlushPolicy` is the admission half: a
:class:`~repro.serving.scheduler.FlushPolicy` whose
``admission_deadline`` is per-PRIORITY instead of queue-global, so an
interactive request (delay 0) makes the queue due immediately while
batch traffic keeps coalescing toward bigger prefill groups.  Because
``Scheduler.due`` and ``Scheduler.next_deadline`` share this one method,
the daemon's sleep-until-deadline loop stays exact under mixed tiers.

The two default tiers:

* ``interactive`` — priority 10, zero admission delay, preemption
  EXEMPT: latency-bound traffic that jumps the queue and keeps its slot.
* ``batch`` — priority 0, 25 ms admission coalescing, PREEMPTIBLE:
  throughput-bound traffic that yields slots to interactive arrivals
  (restart-from-prefix; see ``Engine._preempt_slot``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

from .scheduler import FlushPolicy


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """One service tier's contract (see module docstring).

    ``priority``: higher admits first (the scheduler's priority queue).
    ``max_delay_ms``: admission coalescing budget for this tier (0.0 =
    admit as soon as a slot frees).  ``deadline_ms``: default per-request
    completion deadline applied by the daemon when the submit does not
    carry its own (None: no deadline).  ``max_queued``: daemon-level
    budget on OUTSTANDING (unresolved) requests of this class — submits
    beyond it are rejected with ``QueueFullError`` (None: unbounded).
    ``preemptible``: this tier's in-flight decodes may be evicted
    (restart-from-prefix) when a strictly-higher-priority request is due
    and no slot is free.
    """

    name: str
    priority: int = 0
    max_delay_ms: float = 0.0
    deadline_ms: Optional[float] = None
    max_queued: Optional[int] = None
    preemptible: bool = False

    def __post_init__(self):
        if self.max_delay_ms < 0:
            raise ValueError(
                f"SLO class {self.name!r}: max_delay_ms must be >= 0, got "
                f"{self.max_delay_ms}")
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(
                f"SLO class {self.name!r}: deadline_ms must be > 0 or "
                f"None, got {self.deadline_ms}")
        if self.max_queued is not None and self.max_queued < 1:
            raise ValueError(
                f"SLO class {self.name!r}: max_queued must be >= 1 or "
                f"None, got {self.max_queued}")


INTERACTIVE = SLOClass(name="interactive", priority=10, max_delay_ms=0.0)
BATCH = SLOClass(name="batch", priority=0, max_delay_ms=25.0,
                 preemptible=True)
DEFAULT_CLASSES: Tuple[SLOClass, ...] = (INTERACTIVE, BATCH)


def classes_by_name(
        classes: Sequence[SLOClass]) -> Dict[str, SLOClass]:
    """Name -> class map; raises ``ValueError`` on duplicate names (two
    tiers silently shadowing each other is a config bug)."""
    out: Dict[str, SLOClass] = {}
    for c in classes:
        if c.name in out:
            raise ValueError(f"duplicate SLO class name {c.name!r}")
        out[c.name] = c
    return out


@dataclasses.dataclass(frozen=True)
class ClassFlushPolicy(FlushPolicy):
    """Per-priority admission delays over the shared scheduler queue.

    ``delay_ms_by_priority`` maps priority -> that tier's coalescing
    delay; priorities not listed fall back to the base
    ``max_delay_ms``.  ``admission_deadline`` is the min over EVERY
    waiting request's own per-tier deadline, so one zero-delay
    interactive arrival makes the queue due now without collapsing the
    batch tier's coalescing window when it is alone.
    """

    delay_ms_by_priority: Tuple[Tuple[int, float], ...] = ()

    def __post_init__(self):
        super().__post_init__()
        for p, d in self.delay_ms_by_priority:
            if d < 0:
                raise ValueError(
                    f"delay for priority {p} must be >= 0, got {d}")

    @classmethod
    def from_classes(cls, classes: Sequence[SLOClass],
                     max_batch: int = 64) -> "ClassFlushPolicy":
        """Build the policy from SLO classes: each class's priority gets
        its ``max_delay_ms``; unknown priorities admit immediately
        (delay 0 — fail toward latency, not starvation)."""
        return cls(
            max_batch=max_batch, max_delay_ms=0.0,
            delay_ms_by_priority=tuple(
                (c.priority, c.max_delay_ms) for c in classes))

    def delay_ms_for(self, priority: int) -> Optional[float]:
        for p, d in self.delay_ms_by_priority:
            if p == priority:
                return d
        return self.max_delay_ms

    def admission_deadline(self, queue) -> Optional[float]:
        cands = []
        for h in queue:
            d = self.delay_ms_for(h.priority)
            if d is None:
                continue
            cands.append(h.submitted_at + d / 1000.0)
        return min(cands) if cands else None
