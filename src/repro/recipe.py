"""One-call quantization API: ``QuantRecipe`` -> ``QuantizedModel`` artifact.

This module is the single public entry point for the paper's M2Q flow
(PTQ activation calibration -> Eq. 6 scheme selection -> mixed-precision /
mixed-scheme quantization -> heterogeneous-engine execution).  Consumers
declare *what* they want as a :class:`QuantRecipe` — policy, rules, FFN
fold groups, per-path overrides, and a calibration spec, with named presets
and per-arch defaults resolved from the model module + configs registry —
and call :func:`quantize` once:

    from repro.recipe import quantize

    qm = quantize("qwen1.5-0.5b", params, "m2q-w8a8")
    logits = qm.forward(tokens)
    engine = qm.serve(max_batch=8)          # token or vision engine, by modality
    qm.save("ckpts/qwen-m2q")               # persist: never re-quantizes
    qm2 = QuantizedModel.load("ckpts/qwen-m2q")   # HLO-identical forward

The artifact carries qparams + per-layer :class:`LayerReport`s + the recipe
+ activation-stats provenance, and round-trips through ``ckpt.checkpoint``
via the abstract twin: ``core.apply.abstract_quantize_model`` rebuilds the
exact serving treedef (including data-dependent Eq. 6 splits, recovered
from the saved reports), so ``load`` restores bytes into structure without
touching the float weights again.

Kernel dispatch is scoped, not global: see ``kernels.ops.DispatchConfig``.
Engines constructed via :meth:`QuantizedModel.serve` accept ``dispatch=``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from .core import apply as _apply
from .core import policy as pol
from .core.apply import LayerReport, abstract_quantize_model, quantize_model
from .core.calibrate import rule_matcher, run_calibration, wrap_for_calibration
from .core.policy import M2QPolicy, PathOverride, ShapeCtx
from .ckpt import checkpoint as ckpt
from .models import get_model
from .models.config import ArchConfig

# families whose calibration inputs quantize() can synthesize on its own
_TOKEN_FAMILIES = ("dense_lm", "moe_lm", "rwkv", "recurrentgemma")


# ---------------------------------------------------------------------------
# recipe
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CalibSpec:
    """PTQ calibration spec (paper Sec. V-A) for synthesized batches.

    Used when :func:`quantize` is not handed explicit ``calib_batches``:
    token families get ``batches`` random prompts of ``(batch_size,
    seq_len)``; the vision family gets random ``(batch_size, res, res, 3)``
    images.  ``batch_size`` also seeds the default deployment ShapeCtx.
    """

    batches: int = 4
    batch_size: int = 2
    seq_len: int = 32
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """Declarative description of one quantization run.

    ``rules`` / ``ffn_groups`` default to the model module's QUANT_RULES /
    FFN_FOLD_GROUPS; ``overrides`` are ordered ``(path regex,
    PathOverride)`` pairs consulted before arch-default overrides (first
    match wins) — the principled replacement for steering
    ``intensity_threshold`` to pin the paper taxonomy on reduced configs.
    ``tokens_per_step`` fixes the deployment ShapeCtx; None derives it from
    the calibration batches (vision: batch * res^2 pixels; LM: decode
    batch).
    """

    name: str = "m2q-w8a8"
    policy: M2QPolicy = M2QPolicy()
    rules: Optional[Tuple[_apply.Rule, ...]] = None
    ffn_groups: Optional[Tuple[tuple, ...]] = None
    overrides: Tuple[Tuple[str, PathOverride], ...] = ()
    calib: CalibSpec = CalibSpec()
    tokens_per_step: Optional[int] = None

    def replace(self, **kw) -> "QuantRecipe":
        return dataclasses.replace(self, **kw)

    def validate(self, abstract: bool = False) -> None:
        """Fail fast on configurations that cannot do what's asked.

        ``abstract=True``: the caller wants a shape-only twin (dry-run
        compile, artifact save/load template).  ``apot_ratio=None`` (the
        Eq. 6 argmin) makes the uniform/APoT split data-dependent, which a
        shape-only tree cannot represent without per-layer split hints —
        reject it here with a clear error instead of mis-building silently.
        """
        if self.policy.compute_scheme not in ("m2q", "uniform8", "apot"):
            raise ValueError(
                f"recipe {self.name!r}: unknown compute_scheme "
                f"{self.policy.compute_scheme!r}")
        if abstract and self.policy.compute_scheme == "m2q" \
                and self.policy.apot_ratio is None:
            raise ValueError(
                f"recipe {self.name!r}: apot_ratio=None (Eq. 6 argmin) has "
                "a data-dependent split and cannot produce an abstract "
                "twin; use a fixed apot_ratio, or quantize concretely and "
                "rebuild the treedef from the artifact's saved LayerReports "
                "(QuantizedModel.abstract_params does this)")

    def resolve(self, cfg: ArchConfig) -> "ResolvedRecipe":
        """Bind the recipe to one architecture: fill rules/ffn_groups from
        the model module, merge arch-default overrides, fix the ShapeCtx."""
        model = get_model(cfg)
        rules = tuple(self.rules if self.rules is not None
                      else model.QUANT_RULES)
        ffn_groups = self.ffn_groups
        if ffn_groups is None:
            ffn_groups = tuple(getattr(model, "FFN_FOLD_GROUPS", ()) or ())
        overrides = tuple(self.overrides) + _arch_overrides(cfg, model, rules)
        toks = self.tokens_per_step
        if toks is None:
            toks = _default_tokens_per_step(cfg, self.calib.batch_size)
        ctx = ShapeCtx(tokens_per_step=toks,
                       moe_top_k=max(cfg.moe_top_k, 1),
                       moe_num_experts=max(cfg.moe_experts, 1))
        return ResolvedRecipe(recipe=self, cfg=cfg, rules=rules,
                              ffn_groups=ffn_groups, overrides=overrides,
                              shape_ctx=ctx)


@dataclasses.dataclass(frozen=True)
class ResolvedRecipe:
    """A QuantRecipe bound to one ArchConfig (all defaults filled in)."""

    recipe: QuantRecipe
    cfg: ArchConfig
    rules: Tuple[_apply.Rule, ...]
    ffn_groups: Tuple[tuple, ...]
    overrides: Tuple[Tuple[str, PathOverride], ...]
    shape_ctx: ShapeCtx

    @property
    def policy(self) -> M2QPolicy:
        return self.recipe.policy


def taxonomy_overrides(rules: Sequence[_apply.Rule]
                       ) -> Tuple[Tuple[str, PathOverride], ...]:
    """decision=mixed overrides for every compute-kind rule pattern — pins
    the paper's STRUCTURAL taxonomy (PWConv/MatMul -> mixed, DWConv/embed ->
    low-bit, enforced by kind in policy.decide) regardless of how far the
    deployment shape sits below an MXU ridge point.  This is what the old
    ``intensity_threshold=1.0`` / ``0.5`` call-site hacks approximated."""
    return tuple(
        (rx, PathOverride(decision=pol.DECISION_MIXED))
        for rx, kind in rules
        if kind in (pol.KIND_DENSE, pol.KIND_HEAD, pol.KIND_EXPERT))


def _default_tokens_per_step(cfg: ArchConfig, batch: int) -> int:
    if cfg.family == "efficientvit":
        return batch * cfg.img_res * cfg.img_res  # pixels through a PWConv
    return batch  # decode deployment shape (batch tokens per step)


def _arch_overrides(cfg: ArchConfig, model, rules
                    ) -> Tuple[Tuple[str, PathOverride], ...]:
    """Per-arch default overrides: the model module's QUANT_OVERRIDES when
    declared (efficientvit pins the paper taxonomy), else demo-size
    steering for reduced LM configs whose every matmul is memory-bound at
    tiny widths — without it the mixed-scheme path would never be exercised
    in examples/tests (previously done by lowering intensity_threshold)."""
    declared = getattr(model, "QUANT_OVERRIDES", None)
    if declared is not None:
        return tuple(declared)
    if cfg.family != "efficientvit" and 0 < cfg.d_model <= 256:
        return taxonomy_overrides(rules)
    return ()


# -- named presets -----------------------------------------------------------

PRESETS: Dict[str, QuantRecipe] = {
    # the paper's two-level flow: mixed uniform8/APoT on compute-intensive
    # weights, 4-bit uniform on memory-intensive ones, W8A8 integer path
    "m2q-w8a8": QuantRecipe(name="m2q-w8a8", policy=M2QPolicy()),
    # single-scheme uniform W8A8 everywhere (the Trio-ViT baseline row)
    "uniform8": QuantRecipe(
        name="uniform8",
        policy=M2QPolicy(compute_scheme="uniform8", memory_bits=8)),
    # weights-only 4-bit (bandwidth play: no activation quantization, every
    # quantizable weight low-bit regardless of intensity)
    "w4-weights-only": QuantRecipe(
        name="w4-weights-only",
        policy=M2QPolicy(memory_bits=4, quantize_activations=False),
        overrides=((r".", PathOverride(decision=pol.DECISION_LOWBIT)),)),
}


def as_recipe(recipe: Union[str, QuantRecipe]) -> QuantRecipe:
    if isinstance(recipe, QuantRecipe):
        return recipe
    if recipe not in PRESETS:
        raise KeyError(f"unknown recipe preset {recipe!r}; "
                       f"available: {sorted(PRESETS)}")
    return PRESETS[recipe]


def _resolve_cfg(arch_or_cfg) -> ArchConfig:
    if isinstance(arch_or_cfg, ArchConfig):
        return arch_or_cfg
    from .configs.registry import ARCHS, REDUCED
    if arch_or_cfg in ARCHS:
        return ARCHS[arch_or_cfg]
    by_reduced_name = {c.name: c for c in REDUCED.values()}
    if arch_or_cfg in by_reduced_name:
        return by_reduced_name[arch_or_cfg]
    raise KeyError(f"unknown arch {arch_or_cfg!r}")


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------


def _synth_calib_batches(cfg: ArchConfig, spec: CalibSpec) -> List[np.ndarray]:
    rng = np.random.default_rng(spec.seed)
    if cfg.family == "efficientvit":
        return [rng.normal(0, 1, (spec.batch_size, cfg.img_res, cfg.img_res,
                                  3)).astype(np.float32)
                for _ in range(spec.batches)]
    if cfg.family in _TOKEN_FAMILIES:
        return [rng.integers(0, cfg.vocab_size,
                             (spec.batch_size, spec.seq_len),
                             dtype=np.int32)
                for _ in range(spec.batches)]
    raise ValueError(
        f"cannot synthesize calibration inputs for family {cfg.family!r} "
        "(its forward needs more than one input tensor); pass explicit "
        "calib_batches, or use a weights-only recipe")


def _run_calibration(cfg: ArchConfig, model, params, rules, batches):
    wrapped, store = wrap_for_calibration(params, rule_matcher(rules))
    # unjitted + unrolled: CalibTensor observers are not traceable
    run_calibration(
        lambda p, *a, **kw: model.forward(cfg, p, *a, unroll=True, **kw),
        wrapped, batches)
    return store


# ---------------------------------------------------------------------------
# the artifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class QuantizedModel:
    """The persistable result of one :func:`quantize` call.

    Carries the QTensor param tree, the per-layer reports, the (resolved)
    recipe, and the activation-stats provenance.  ``save``/``load`` go
    through ``ckpt.checkpoint``; the treedef on load comes from the
    abstract twin (plus the reports' (n_uniform, n_apot) splits), so a
    restore NEVER re-runs PTQ.
    """

    cfg: ArchConfig
    recipe: QuantRecipe
    params: object
    report: List[LayerReport]
    act_stats: Dict[str, float]
    provenance: Dict[str, object] = dataclasses.field(default_factory=dict)

    # -- execution ----------------------------------------------------------
    @property
    def model(self):
        return get_model(self.cfg)

    def forward(self, inputs, **kw):
        """One forward pass on the quantized tree (images or tokens)."""
        return self.model.forward(self.cfg, self.params, inputs, **kw)

    def serve(self, dispatch=None, mesh=None, **engine_kw):
        """A serving engine for this artifact, chosen by modality: the
        batched VisionEngine for image backbones, the continuous-batching
        token Engine otherwise.  Both run on the shared scheduler core
        (``serving.scheduler``) and accept ``max_delay_ms`` for
        deadline-based flushing.  ``dispatch``: optional
        kernels.ops.DispatchConfig pinning kernel dispatch for the engine's
        traces — the ``dense``/``conv`` axes steer the QTensor matmul/conv
        kernels and ``attn`` the int8 attention kernels (MSA ReLU linear
        attention for vision, int8-KV decode attention for token decode).  ``mesh``: optional jax Mesh enabling sharded execution —
        the artifact's qparams are placed per ``dist.sharding.param_specs``
        (vision additionally batches data-parallel, token decode caches
        shard per ``cache_specs``).

        Fault tolerance kwargs forward to the engine: ``overload`` (an
        ``OverloadPolicy`` bounding the admission queue — full queues
        raise ``QueueFullError`` or shed the oldest request), ``faults``
        (a ``FaultInjector`` for deterministic fault injection; defaults
        to ``REPRO_FAULT_SPEC`` from the env), and ``check_numerics``.
        ``submit(..., deadline_ms=)`` sets per-request deadlines.  A
        failed request never raises out of the engine loop — it resolves
        its own handle (see docs/serving.md for the failure semantics)."""
        if self.cfg.family == "efficientvit":
            from .serving.vision import VisionEngine
            return VisionEngine(self.cfg, self.params, dispatch=dispatch,
                                mesh=mesh, **engine_kw)
        from .serving.engine import Engine
        return Engine(self.cfg, self.params, dispatch=dispatch, mesh=mesh,
                      **engine_kw)

    # -- abstract twin ------------------------------------------------------
    def m2q_splits(self) -> Dict[str, Tuple[int, int]]:
        """path -> (n_uniform, n_apot) from the saved reports — lets the
        abstract twin reproduce data-dependent Eq. 6 splits exactly."""
        return {r.path: (r.n_uniform, r.n_apot) for r in self.report
                if r.n_uniform or r.n_apot}

    def abstract_params(self):
        """ShapeDtypeStruct twin of ``params`` (the load/restore template).

        Act-scale leaves exist only where calibration recorded stats, and
        the saved reports supply the (possibly data-dependent) m2q splits.
        """
        with_act = bool(self.act_stats) and \
            self.recipe.policy.quantize_activations
        return abstract_quantize(self.cfg, recipe=self.recipe,
                                 with_act_scales=with_act,
                                 m2q_splits=self.m2q_splits())

    # -- persistence --------------------------------------------------------
    def save(self, path, step: int = 0):
        """Atomic checkpoint of the QTensor tree + JSON provenance."""
        extra = {
            "kind": "quantized_model",
            "cfg": _cfg_to_json(self.cfg),
            "recipe": _recipe_to_json(self.recipe),
            "report": [_report_to_json(r) for r in self.report],
            "act_stats": {k: float(v) for k, v in self.act_stats.items()},
            "provenance": self.provenance,
        }
        return ckpt.save(path, step, self.params, extra=extra)

    @classmethod
    def load(cls, path, step: Optional[int] = None) -> "QuantizedModel":
        """Rebuild the artifact from disk WITHOUT re-quantizing: the
        abstract twin provides the treedef, the checkpoint provides the
        bytes, and the restored forward lowers to identical HLO."""
        if step is None:
            step = ckpt.latest_step(path)
            if step is None:
                raise FileNotFoundError(f"no checkpoint under {path!r}")
        probe = ckpt.read_extra(path, step)
        if probe.get("kind") != "quantized_model":
            raise ValueError(f"{path!r} is not a QuantizedModel checkpoint")
        out = cls(cfg=_cfg_from_json(probe["cfg"]),
                  recipe=_recipe_from_json(probe["recipe"]),
                  params=None,
                  report=[_report_from_json(r) for r in probe["report"]],
                  act_stats=dict(probe["act_stats"]),
                  provenance=dict(probe.get("provenance", {})))
        template = out.abstract_params()
        out.params, _ = ckpt.restore(path, step, template)
        return out


# ---------------------------------------------------------------------------
# quantize: the one-call entry point
# ---------------------------------------------------------------------------


def quantize(arch_or_cfg, params,
             recipe: Union[str, QuantRecipe] = "m2q-w8a8",
             calib_batches: Optional[Iterable] = None) -> QuantizedModel:
    """Calibrate -> scheme-select -> quantize, in one call.

    ``arch_or_cfg``: an ArchConfig or a registry name (full-size archs and
    reduced demo names both resolve).  ``params``: the float param tree.
    ``recipe``: preset name or QuantRecipe.  ``calib_batches``: iterable of
    model inputs for PTQ calibration; None synthesizes them per the
    recipe's CalibSpec (token prompts / random images — other modalities
    must pass their own).  Weights-only recipes skip calibration entirely.
    """
    cfg = _resolve_cfg(arch_or_cfg)
    rec = as_recipe(recipe)
    rec.validate()
    resolved = rec.resolve(cfg)
    model = get_model(cfg)

    act_stats: Dict[str, float] = {}
    n_calib = 0
    if rec.policy.quantize_activations:
        if calib_batches is None:
            calib_batches = _synth_calib_batches(cfg, rec.calib)
        calib_batches = list(calib_batches)
        n_calib = len(calib_batches)
        # derive the deployment ShapeCtx from the REAL calibration batch
        # size when the recipe didn't pin one
        if rec.tokens_per_step is None and calib_batches:
            first = calib_batches[0]
            if hasattr(first, "shape") and len(first.shape) >= 1:
                toks = _default_tokens_per_step(cfg, int(first.shape[0]))
                resolved = dataclasses.replace(
                    resolved, shape_ctx=dataclasses.replace(
                        resolved.shape_ctx, tokens_per_step=toks))
        act_stats = _run_calibration(cfg, model, params, resolved.rules,
                                     calib_batches)

    qparams, report = quantize_model(
        params, resolved.rules, resolved.shape_ctx, rec.policy,
        act_stats=act_stats, ffn_groups=resolved.ffn_groups or None,
        overrides=resolved.overrides)
    # pin the EFFECTIVE deployment shape into the artifact's recipe: the
    # abstract twin on load must re-derive the same mixed/lowbit decisions,
    # and a tokens_per_step inferred from the real calibration batches
    # would otherwise be lost (CalibSpec.batch_size may differ)
    rec = rec.replace(tokens_per_step=resolved.shape_ctx.tokens_per_step)
    return QuantizedModel(
        cfg=cfg, recipe=rec, params=qparams, report=report,
        act_stats=dict(act_stats),
        provenance={"calib_batches": n_calib,
                    "calib_sites": len(act_stats),
                    "tokens_per_step": resolved.shape_ctx.tokens_per_step})


def abstract_quantize(arch_or_cfg, params_abs=None,
                      recipe: Union[str, QuantRecipe] = "m2q-w8a8",
                      tokens_per_step: Optional[int] = None,
                      with_act_scales: bool = True,
                      m2q_splits: Optional[Dict[str, Tuple[int, int]]] = None):
    """Shape-only twin of :func:`quantize` (dry-run compiles, sharding
    specs, artifact load templates): returns the abstract QTensor tree for
    ``arch_or_cfg`` under ``recipe``.  ``params_abs`` defaults to
    ``jax.eval_shape`` of init; ``m2q_splits`` (path -> (n_uniform,
    n_apot), e.g. from saved LayerReports) makes data-dependent Eq. 6
    splits representable — without them apot_ratio=None is rejected."""
    cfg = _resolve_cfg(arch_or_cfg)
    rec = as_recipe(recipe)
    if tokens_per_step is not None:
        rec = rec.replace(tokens_per_step=tokens_per_step)
    rec.validate(abstract=m2q_splits is None)
    resolved = rec.resolve(cfg)
    model = get_model(cfg)
    if params_abs is None:
        params_abs = jax.eval_shape(
            lambda: model.init(cfg, jax.random.PRNGKey(0)))
    return abstract_quantize_model(
        params_abs, resolved.rules, resolved.shape_ctx, resolved.policy,
        with_act_scales=with_act_scales,
        ffn_groups=resolved.ffn_groups or None,
        overrides=resolved.overrides,
        m2q_splits=m2q_splits)


# ---------------------------------------------------------------------------
# JSON (de)serialization of the provenance payload
# ---------------------------------------------------------------------------


def _cfg_to_json(cfg: ArchConfig) -> dict:
    return dataclasses.asdict(cfg)


def _cfg_from_json(d: dict) -> ArchConfig:
    fields = {f.name: f for f in dataclasses.fields(ArchConfig)}
    kw = {}
    for k, v in d.items():
        if k not in fields:
            continue  # forward-compat: ignore unknown keys
        kw[k] = tuple(v) if isinstance(v, list) else v
    return ArchConfig(**kw)


def _recipe_to_json(rec: QuantRecipe) -> dict:
    return {
        "name": rec.name,
        "policy": dataclasses.asdict(rec.policy),
        "rules": None if rec.rules is None else [list(r) for r in rec.rules],
        "ffn_groups": None if rec.ffn_groups is None
        else [list(g) for g in rec.ffn_groups],
        "overrides": [[rx, dataclasses.asdict(ov)]
                      for rx, ov in rec.overrides],
        "calib": dataclasses.asdict(rec.calib),
        "tokens_per_step": rec.tokens_per_step,
    }


def _recipe_from_json(d: dict) -> QuantRecipe:
    return QuantRecipe(
        name=d["name"],
        policy=M2QPolicy(**d["policy"]),
        rules=None if d["rules"] is None
        else tuple(tuple(r) for r in d["rules"]),
        ffn_groups=None if d["ffn_groups"] is None
        else tuple(tuple(g) for g in d["ffn_groups"]),
        overrides=tuple((rx, PathOverride(**ov))
                        for rx, ov in d["overrides"]),
        calib=CalibSpec(**d["calib"]),
        tokens_per_step=d["tokens_per_step"])


def _report_to_json(r: LayerReport) -> dict:
    d = dataclasses.asdict(r)
    d["shape"] = list(d["shape"])
    return d


def _report_from_json(d: dict) -> LayerReport:
    d = dict(d)
    d["shape"] = tuple(d["shape"])
    return LayerReport(**d)
