"""Deterministic, resumable, rank-sharded synthetic data pipeline.

Every batch is a pure function of (seed, step, rank) via counter-based
Philox keys — resume-after-restart needs no state file and skip-ahead is
O(1); data-parallel ranks slice disjoint rows of the global batch.  The
token stream is a fixed random Markov chain (order-1 + induction copies),
so small LMs show a real, monotonically improving loss (used by the train
examples and the fault-tolerance tests).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # synthetic structure
    markov_alpha: float = 0.25  # peakiness of the transition matrix
    induction_prob: float = 0.3  # fraction of sequences with copy structure


class SyntheticLM:
    """Markov-chain + induction-head synthetic corpus."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse-ish row-stochastic transition matrix (each token prefers a
        # few successors) — learnable signal for tiny models
        prefs = rng.integers(0, v, size=(v, 4))
        self._prefs = prefs.astype(np.int64)

    def _batch_rng(self, step: int, rank: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, rank]))

    def batch(self, step: int, rank: int = 0, num_ranks: int = 1):
        """Returns {tokens, labels}: (local_batch, seq_len) int32."""
        cfg = self.cfg
        lb = cfg.global_batch // num_ranks
        rng = self._batch_rng(step, rank)
        toks = np.empty((lb, cfg.seq_len + 1), dtype=np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab_size, size=lb)
        explore = rng.random((lb, cfg.seq_len)) < cfg.markov_alpha
        choice = rng.integers(0, 4, size=(lb, cfg.seq_len))
        randtok = rng.integers(0, cfg.vocab_size, size=(lb, cfg.seq_len))
        for t in range(cfg.seq_len):
            nxt = self._prefs[toks[:, t], choice[:, t]]
            toks[:, t + 1] = np.where(explore[:, t], randtok[:, t], nxt)
        # induction copies: repeat the first half in the second half
        n_ind = int(lb * cfg.induction_prob)
        if n_ind and cfg.seq_len >= 8:
            half = cfg.seq_len // 2
            toks[:n_ind, half + 1: 2 * half + 1] = toks[:n_ind, 1: half + 1]
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, :-1]}

    def iter_batches(self, start_step: int = 0, rank: int = 0,
                     num_ranks: int = 1) -> Iterator[dict]:
        step = start_step
        while True:
            yield self.batch(step, rank, num_ranks)
            step += 1


class SyntheticVision:
    """Gaussian-blob classification task for the EfficientViT benchmarks:
    class k = a fixed random spatial template + noise. PTQ-accuracy deltas
    measured on this task reproduce the paper's Table I/II *trends*."""

    def __init__(self, n_classes: int, res: int, seed: int = 0,
                 noise: float = 0.6):
        rng = np.random.default_rng(seed)
        self.templates = rng.normal(0, 1, (n_classes, res, res, 3)).astype(
            np.float32)
        # low-pass the templates so they have spatial structure
        for _ in range(2):
            self.templates = (
                self.templates
                + np.roll(self.templates, 1, 1) + np.roll(self.templates, -1, 1)
                + np.roll(self.templates, 1, 2) + np.roll(self.templates, -1, 2)
            ) / 5.0
        self.n_classes = n_classes
        self.noise = noise

    def batch(self, step: int, batch_size: int):
        rng = np.random.default_rng(np.random.SeedSequence([7, step]))
        y = rng.integers(0, self.n_classes, size=batch_size)
        x = self.templates[y] + self.noise * rng.normal(
            0, 1, (batch_size,) + self.templates.shape[1:]).astype(np.float32)
        return x.astype(np.float32), y.astype(np.int32)
