"""AdamW + schedules + clipping, dependency-free (no optax in this image).

State is a pytree mirroring params (m, v) + a scalar count, so parameter
sharding specs apply verbatim to optimizer state (ZeRO-style when FSDP is
on).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array
    m: object  # pytree like params
    v: object


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array] | float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
        return AdamWState(count=jnp.zeros((), jnp.int32), m=zeros(params),
                          v=zeros(params))

    def _lr(self, count):
        return self.lr(count) if callable(self.lr) else jnp.float32(self.lr)

    def update(self, grads, state: AdamWState, params):
        count = state.count + 1
        if self.grad_clip is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = global_norm(grads)
        b1c = 1.0 - self.b1 ** count.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** count.astype(jnp.float32)
        lr = self._lr(count)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            m2 = self.b1 * m + (1 - self.b1) * gf
            v2 = self.b2 * v + (1 - self.b2) * jnp.square(gf)
            mhat = m2 / b1c
            vhat = v2 / b2c
            step = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decoupled decay on matrices only
                step = step + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m2, v2

        flat = jax.tree.map(upd, params, grads, state.m, state.v)
        new_params = jax.tree.map(lambda t: t[0], flat,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], flat,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, AdamWState(count, new_m, new_v), gnorm


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1):
    def lr(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup, 1)
        prog = jnp.clip((c - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(c < warmup, warm, cos)

    return lr
