"""Fused int8 ReLU linear-attention kernel (EfficientViT MSA, paper Sec. II-A).

The f32 path in ``nn.attention.relu_linear_attention`` materializes three
(B,N,H,D) einsum operands plus the (B,H,D,D) kv tensor in HBM.  This kernel
runs the whole token-mixer for one (batch, head) pair inside VMEM:

* prologue — q/k/v arrive in FLOAT with scalar max-abs act scales (the PR 1
  fused-rounding convention: the int8 payloads never exist as HBM arrays);
  ReLU is applied to q/k before rounding so the scales are computed on the
  post-ReLU range.
* body — the (D,D) kv and (D,) ksum contractions accumulate in int32 on the
  int8 operands (MPMA merged-mode analogue), then kv is requantized to int8
  in VMEM (the same trick ``decode_attention_int8`` applies to its softmax
  weights) so the per-token numerator/denominator contractions are ALSO
  integer dots — the compiled module carries no f32 dot for any MSA
  contraction.
* epilogue — the numerator/denominator normalization ``num / (den + eps)``
  runs on the f32-rescaled accumulators and writes the output tile once.

Grid: (B, H, N/bn) — kv/ksum/skv build once per (b, h) on the first
N-step (scratch persists across the sequential "arbitrary" dim, exactly
like the matmul kernels' accumulators) and every step streams one bn-row
block of q through them.  N and D are padded by the ops.py wrapper; padded
k rows quantize to zero and padded q rows emit zeros that are sliced away.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.quant import quantize_act
from .compat import CompilerParams


def _kernel(q_ref, k_ref, v_ref, sq_ref, sk_ref, sv_ref, o_ref,
            kv_ref, ksum_ref, skv_ref, *, eps: float):
    sk = sk_ref[0, 0]
    sv = sv_ref[0, 0]

    @pl.when(pl.program_id(2) == 0)
    def _build_kv():
        # prologue: ReLU + fused int8 rounding on the VMEM tiles (shared
        # quantize_act definition with the XLA/ref paths)
        k8 = quantize_act(jax.nn.relu(k_ref[0, :, 0, :].astype(jnp.float32)),
                          sk)
        v8 = quantize_act(v_ref[0, :, 0, :].astype(jnp.float32), sv)
        kv32 = jax.lax.dot_general(k8, v8, (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.int32)  # (D,D)
        ksum_ref[...] = jnp.sum(k8.astype(jnp.int32), axis=0, keepdims=True)
        # requantize kv to int8 range so the numerator dot stays integer
        # (int8 x int32_kv would overflow int32 at vision token counts)
        kv_f = kv32.astype(jnp.float32) * (sk * sv)
        skv = jnp.maximum(jnp.max(jnp.abs(kv_f)) / 127.0, 1e-8)
        skv_ref[0, 0] = skv
        kv_ref[...] = jnp.clip(jnp.round(kv_f / skv), -127, 127
                               ).astype(jnp.int32)

    sq = sq_ref[0, 0]
    q8 = quantize_act(jax.nn.relu(q_ref[0, :, 0, :].astype(jnp.float32)),
                      sq).astype(jnp.int32)
    num = jax.lax.dot_general(q8, kv_ref[...], (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)  # (bn, D)
    den = jax.lax.dot_general(q8, ksum_ref[...], (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)  # (bn, 1)
    num_f = num.astype(jnp.float32) * (sq * skv_ref[0, 0])
    den_f = den.astype(jnp.float32) * (sq * sk)
    o_ref[0, :, 0, :] = num_f / (den_f + eps)


def relu_attn(q: jax.Array, k: jax.Array, v: jax.Array,
              sq: jax.Array, sk: jax.Array, sv: jax.Array,
              *, bn: int = 128, eps: float = 1e-6,
              interpret: bool = False) -> jax.Array:
    """q/k/v (B,N,H,D) float; sq/sk/sv scalar f32 act scales -> (B,N,H,D) f32.

    N must be pre-padded to a ``bn`` multiple (ops.py does this); zero pad
    rows are inert (ReLU(0) quantizes to 0 in every contraction).
    """
    B, N, H, D = q.shape
    grid = (B, H, N // bn)
    qkv_spec = pl.BlockSpec((1, N, 1, D), lambda b, h, n: (b, 0, h, 0))
    scalar = pl.BlockSpec((1, 1), lambda b, h, n: (0, 0))
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bn, 1, D), lambda b, h, n: (b, n, h, 0)),
            qkv_spec,
            qkv_spec,
            scalar,
            scalar,
            scalar,
        ],
        out_specs=pl.BlockSpec((1, bn, 1, D), lambda b, h, n: (b, n, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, N, H, D), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((D, D), jnp.int32),   # requantized kv (int8 range)
            pltpu.VMEM((1, D), jnp.int32),   # ksum
            pltpu.VMEM((1, 1), jnp.float32),  # kv requantization scale
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, sq.reshape(1, 1), sk.reshape(1, 1), sv.reshape(1, 1))
