"""APoT-coded matmul kernel (the SAT engine, paper Sec. IV-2, on TPU).

Each weight byte is (zero<<7 | sign<<6 | e1<<3 | e2); the ASIC decodes this
with two shifters + an adder (Eq. 4).  The TPU-native equivalent performed
here: decode the byte tile *in VMEM* with exponent arithmetic
(2^-e = exp2), then feed the MXU.  Weights cross HBM as 1-byte codes and the
decoded bf16/f32 tile exists only in VMEM — the fused-dequant bandwidth win
recorded in DESIGN.md §3.

The per-filter scale stays in the epilogue (the decoded operand is the
unscaled codebook value), matching QAPoT.matmul and ref.apot_matmul_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def decode_apot_tile(codes: jax.Array) -> jax.Array:
    """code bytes (bk,bn) -> f32 values s*(2^-e1 + 2^-e2), zero-aware.

    Accepts uint8 codes OR an int8 view of the same bytes (the merged M2Q
    payload stores both engines' bytes in one int8 array): widening to int32
    and masking with 0xFF recovers the unsigned bit pattern on two's-
    complement hardware.  Bit masks are python ints (pallas kernels may not
    capture traced constants).
    """
    c = codes.astype(jnp.int32) & 0xFF
    e1 = ((c >> 3) & 0x07).astype(jnp.float32)
    e2 = (c & 0x07).astype(jnp.float32)
    mag = jnp.exp2(-e1) + jnp.exp2(-e2)
    sign = jnp.where((c & 0x40) != 0, -1.0, 1.0)
    return jnp.where((c & 0x80) != 0, 0.0, sign * mag)


def _kernel(x_ref, c_ref, scale_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    w = decode_apot_tile(c_ref[...])
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...] * scale_ref[...]


def apot_matmul(x: jax.Array, codes: jax.Array, scale: jax.Array,
                *, bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool = False) -> jax.Array:
    """x (M,K); codes (K,N) uint8; scale (N,) -> y (M,N) f32."""
    M, K = x.shape
    N = codes.shape[1]
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, codes, scale.reshape(1, -1))
