"""Fused two-level mixed-quantization matmul — the flagship M2-ViT kernel.

The paper pipelines its two engines (MPMA for the uniform filter half, SAT
for the APoT half) over the same activation stream (Sec. IV "Execution
Flow").  The TPU equivalent: ONE kernel invocation whose grid walks the
activation tile once; per (m, k) step it feeds the int8 MXU dot for the
uniform half AND the decode+dot for the APoT half from the *same* x tile in
VMEM.  The 1:1 APoT:Uniform ratio (paper Sec. V-A) is what makes the two
half-width outputs the same shape — the ratio literally aligns with the
N-tiling here, mirroring the paper's ratio<->parallelism alignment.

Inputs arrive pre-quantized (xq int8 + act_scale), since activations are
8-bit uniform everywhere in M2Q.  The inverse filter permutation is applied
by the caller (cheap gather epilogue in XLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .apot_matmul import decode_apot_tile


def _kernel(xq_ref, up_ref, uscale_ref, uzp_ref, ac_ref, ascale_s_ref,
            act_scale_ref, yu_ref, ya_ref, uacc_ref, xsum_ref, aacc_ref,
            *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        uacc_ref[...] = jnp.zeros_like(uacc_ref)
        xsum_ref[...] = jnp.zeros_like(xsum_ref)
        aacc_ref[...] = jnp.zeros_like(aacc_ref)

    xq = xq_ref[...]
    # uniform half: int8 x int8 -> int32 (MPMA merged mode; 2x MXU rate)
    uacc_ref[...] += jax.lax.dot_general(
        xq, up_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    xsum_ref[...] += jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
    # APoT half: decode codes in VMEM, f32 dot (SAT engine) — same x tile
    w = decode_apot_tile(ac_ref[...])
    aacc_ref[...] += jnp.dot(xq.astype(jnp.float32), w,
                             preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        sa = act_scale_ref[0, 0]
        u = uacc_ref[...].astype(jnp.float32)
        corr = xsum_ref[...].astype(jnp.float32) * uzp_ref[...]
        yu_ref[...] = (u - corr) * (sa * uscale_ref[...])
        # APoT half consumed xq directly -> fold act_scale into epilogue
        ya_ref[...] = aacc_ref[...] * (sa * ascale_s_ref[...])


def m2q_matmul(xq: jax.Array, act_scale: jax.Array,
               u_payload: jax.Array, u_scale: jax.Array, u_zp: jax.Array,
               a_codes: jax.Array, a_scale: jax.Array,
               *, bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = False):
    """xq (M,K) int8; uniform payload (K,Nu) int8; APoT codes (K,Na) uint8;
    Nu == Na (1:1 ratio, ops.py pads). Returns (yu (M,Nu), ya (M,Na)) f32."""
    M, K = xq.shape
    Nu = u_payload.shape[1]
    Na = a_codes.shape[1]
    assert Nu == Na, "1:1 ratio keeps both halves tile-aligned"
    nk = K // bk
    grid = (M // bm, Nu // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((M, Nu), jnp.float32),
            jax.ShapeDtypeStruct((M, Na), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xq, u_payload, u_scale.reshape(1, -1), u_zp.reshape(1, -1),
      a_codes, a_scale.reshape(1, -1), act_scale.reshape(1, 1))
