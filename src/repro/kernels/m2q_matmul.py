"""Fused two-level mixed-quantization matmul — the flagship M2-ViT kernel.

The paper pipelines its two engines (MPMA for the uniform filter half, SAT
for the APoT half) over the same activation stream (Sec. IV "Execution
Flow").  The TPU equivalent: ONE kernel invocation whose grid walks the
activation tile once; per (m, k) step it feeds the int8 MXU dot for the
uniform engine AND the decode+dot for the SAT engine from the *same* x tile
in VMEM.

Permutation-free layout (see core.qtensor): the weight arrives as a single
merged byte array in ORIGINAL filter order — each column holds either an
offset-folded int8 uniform payload or an APoT code byte, with per-column
scales zero-masked on the columns the other engine owns.  The epilogue sums
the two engine accumulators and writes ONE output tile directly in filter
order: no concatenate, no inverse-permutation gather, ever.

Fused activation quantization: x arrives in float; the max-abs scale is a
scalar operand and the int8 rounding happens in the kernel prologue on the
VMEM tile, so the quantized activation never round-trips through HBM as a
separate XLA pass.

Tradeoff (deliberate): with interleaved per-filter scheme assignment, both
engines sweep all N columns and the zero-masked scales cancel the half each
does not own — 2x the MAC count of two half-width dots.  What it buys: the
weight stays 1 byte/weight, HBM traffic is unchanged IN THIS KERNEL (the
decode lives in VMEM; the XLA fallback in core.qtensor does materialize
the decoded operand — see _merged_matmul's note), and the O(M*N) concat +
inverse-permutation gather epilogue (plus its round-trips) is gone.  The
decode/serving shapes this kernel exists for are bandwidth-bound (small
M), where bytes moved — not MACs — set the wall-clock; layers
whose consumer can absorb the reorder offline avoid even that via the
fold_perm path (apply.py FFN groups), which keeps the halves contiguous.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.quant import quantize_act
from .apot_matmul import decode_apot_tile
from .compat import CompilerParams


def _kernel(x_ref, p_ref, uscale_ref, uzp_ref, ascale_ref, act_scale_ref,
            y_ref, uacc_ref, xsum_ref, aacc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        uacc_ref[...] = jnp.zeros_like(uacc_ref)
        xsum_ref[...] = jnp.zeros_like(xsum_ref)
        aacc_ref[...] = jnp.zeros_like(aacc_ref)

    sa = act_scale_ref[0, 0]
    # fused activation quantization: float tile -> int8 in VMEM (shared
    # rounding definition with the XLA/ref paths)
    xq = quantize_act(x_ref[...].astype(jnp.float32), sa)
    p = p_ref[...]
    # uniform engine: int8 x int8 -> int32 (MPMA merged mode; 2x MXU rate)
    uacc_ref[...] += jax.lax.dot_general(
        xq, p, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
    xsum_ref[...] += jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
    # SAT engine: decode the SAME byte tile as APoT codes, f32 dot.  On
    # uniform columns the decode is garbage — cancelled by a_scale == 0.
    w = decode_apot_tile(p)
    aacc_ref[...] += jnp.dot(xq.astype(jnp.float32), w,
                             preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        u = uacc_ref[...].astype(jnp.float32)
        corr = xsum_ref[...].astype(jnp.float32) * uzp_ref[...]
        yu = (u - corr) * uscale_ref[...]
        ya = aacc_ref[...] * ascale_ref[...]
        y_ref[...] = (yu + ya) * sa


def m2q_matmul(x: jax.Array, act_scale: jax.Array, payload: jax.Array,
               u_scale: jax.Array, u_zp: jax.Array, a_scale: jax.Array,
               *, bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = False) -> jax.Array:
    """x (M,K) float; merged payload (K,N) int8; scales (N,) zero-masked.

    Returns y (M,N) f32 in original filter order (ops.py pads/unpads).
    """
    M, K = x.shape
    N = payload.shape[1]
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
            pltpu.VMEM((bm, bn), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, payload, u_scale.reshape(1, -1), u_zp.reshape(1, -1),
      a_scale.reshape(1, -1), act_scale.reshape(1, 1))
