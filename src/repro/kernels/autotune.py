"""Shape-keyed block-size autotuner for the Pallas kernels.

Replaces the fixed power-of-two ``_block()`` heuristic in ops.py: each
(kernel, M, N, K) shape gets its block triple from a persistent JSON cache,
populated either lazily (timing candidate triples at first launch on a real
accelerator backend) or — the serving posture — OFFLINE by
``repro.launch.autotune_sweep``, which enumerates a deployment's shape set
and warms the cache before the first request ever traces (first-request
compile+tune latency is a real p99 tail at serving scale).

Cache keys are salted with the KERNEL VERSION and the BACKEND:

    <kernel>@v<version>:<M>x<N>x<K>:<backend>

so a committed cache from one backend can never serve block choices on
another, and a kernel rewrite (bump :data:`KERNEL_VERSIONS`) orphans every
stale entry instead of silently reusing blocks tuned for the old grid.  The
default cache file is per-backend too (``~/.cache/repro/autotune.<backend>
.json``); ``REPRO_AUTOTUNE_CACHE`` overrides the path wholesale.  Lookup is
CACHE-FIRST on every backend — a warmed cache serves its block choice even
where tuning itself is disabled — and every candidate actually timed bumps
:func:`tuning_probe_count`, so tests can assert a warmed trace performs
ZERO probes.

Interpret-safe fallback: on CPU / interpret mode (the container has no TPU)
timing the Python interpreter is meaningless, so on a cache miss the
heuristic triple is returned immediately and nothing is benchmarked or
persisted.  Writes are atomic (tmp + rename) so concurrent processes never
observe a torn file.

A corrupt cache file NEVER takes the process down: truncated JSON, a
non-dict top level, entries that are not three ints, or keys that do not
parse as salted cache keys (foreign/legacy formats) are dropped with a
``RuntimeWarning`` and the cache rebuilds from scratch — a bad cache is a
performance bug, not a correctness one, so crashing over it is the wrong
trade.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import fcntl
import json
import os
import re
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

Blocks = Tuple[int, int, int]

_LOCK = threading.Lock()
_CACHES: Dict[str, "AutotuneCache"] = {}

# bump a kernel's version when its grid/blocking semantics change: stale
# entries (tuned for the old grid) then miss instead of mis-steering the
# rewritten kernel.  dwconv_w4 is v2: the H-tiled (B, H-tiles, C-blocks)
# grid replaced the whole-map (B, C-blocks) grid in PR 9.
KERNEL_VERSIONS: Dict[str, int] = {
    "m2q_matmul": 1,
    "int8_matmul": 1,
    "int4_matmul": 1,
    "apot_matmul": 1,
    "dwconv_w4": 2,
    "relu_attn": 1,
    "decode_attn_int8": 1,
}

# <kernel>@v<version>:<M>x<N>x<K>:<backend>
_KEY_RE = re.compile(r"^[A-Za-z0-9_.-]+@v\d+:\d+x\d+x\d+:[A-Za-z0-9_]+$")


def cache_key(kernel: str, M: int, N: int, K: int,
              backend: Optional[str] = None) -> str:
    """The salted persistent-cache key for one kernel launch shape."""
    v = KERNEL_VERSIONS.get(kernel, 1)
    b = backend or jax.default_backend()
    return f"{kernel}@v{v}:{M}x{N}x{K}:{b}"


def default_cache_path(backend: Optional[str] = None) -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    b = backend or jax.default_backend()
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        f"autotune.{b}.json")


def heuristic_block(m: int, cap: int = 128) -> int:
    """Largest power-of-two block <= cap that keeps tiny shapes legal."""
    b = 8
    while b * 2 <= min(m, cap):
        b *= 2
    return b


def heuristic_blocks(M: int, N: int, K: int, cap: int = 128) -> Blocks:
    return (heuristic_block(M, cap), heuristic_block(N, cap),
            heuristic_block(K, cap))


def candidate_blocks(M: int, N: int, K: int) -> List[Blocks]:
    """Distinct legal triples around the heuristic: the heuristic itself,
    plus smaller-M (better pipelining at small batch) and 256-wide variants
    (fewer grid steps on large shapes)."""
    base = heuristic_blocks(M, N, K)
    cands = {base}
    for bm in {8, base[0] // 2 or 8, base[0], min(256, max(8, M))}:
        for bn in {base[1], min(256, base[1] * 2)}:
            for bk in {base[2], min(256, base[2] * 2)}:
                c = (heuristic_block(M, max(bm, 8)),
                     heuristic_block(N, max(bn, 8)),
                     heuristic_block(K, max(bk, 8)))
                cands.add(c)
    return sorted(cands)


# ---------------------------------------------------------------------------
# shape-request recording (the offline sweep's discovery hook) + probe count
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeRequest:
    """One block-choice request seen by :func:`blocks_for` (or noted by a
    kernel without block parameters, ``tunable=False``).  ``meta`` carries
    enough operand geometry for the offline sweep to reconstruct a real
    launch of the same shape (synthetic-operand tuning on an accelerator)."""

    kernel: str
    M: int
    N: int
    K: int
    tunable: bool = True
    meta: Tuple[Tuple[str, int], ...] = ()

    def key(self, backend: Optional[str] = None) -> str:
        return cache_key(self.kernel, self.M, self.N, self.K, backend)


_RECORDERS: contextvars.ContextVar = contextvars.ContextVar(
    "repro_autotune_recorders", default=())


@contextlib.contextmanager
def record_requests(dest: Optional[List[ShapeRequest]] = None):
    """Collect every ShapeRequest seen inside the scope (nestable; requests
    also reach enclosing recorders).  Works under jit tracing — lowering a
    model is exactly how the offline sweep discovers a deployment's shape
    set without running it."""
    sink: List[ShapeRequest] = [] if dest is None else dest
    token = _RECORDERS.set(_RECORDERS.get() + (sink,))
    try:
        yield sink
    finally:
        _RECORDERS.reset(token)


def _record(kernel: str, M: int, N: int, K: int, tunable: bool = True,
            meta: Optional[dict] = None) -> None:
    sinks = _RECORDERS.get()
    if not sinks:
        return
    req = ShapeRequest(kernel, int(M), int(N), int(K), tunable,
                       tuple(sorted((str(k), int(v))
                                    for k, v in (meta or {}).items())))
    for sink in sinks:
        sink.append(req)


def note_shape(kernel: str, M: int, N: int, K: int,
               meta: Optional[dict] = None) -> None:
    """Record a shape for a kernel WITHOUT block parameters (decode_attn):
    the sweep lists it for coverage/bench rows but never caches blocks."""
    _record(kernel, M, N, K, tunable=False, meta=meta)


_PROBES = 0


def tuning_probe_count() -> int:
    """How many candidate timings have run in this process — the sweep's
    zero-probes-at-serving-time assertion reads this."""
    return _PROBES


def reset_probe_count() -> None:
    global _PROBES
    _PROBES = 0


# ---------------------------------------------------------------------------
# persistent cache
# ---------------------------------------------------------------------------


def _valid_entry(v) -> bool:
    """A cache entry must be exactly three positive ints (a block triple);
    anything else — strings, floats, wrong arity — is corruption."""
    return (isinstance(v, (list, tuple)) and len(v) == 3
            and all(isinstance(x, int) and not isinstance(x, bool) and x > 0
                    for x in v))


def _read_cache_file(path: str) -> Dict[str, list]:
    """Read + sanitize one cache file.  NEVER raises on corruption:
    unreadable/truncated JSON, a non-dict top level, invalid entries, or
    keys that do not parse as ``kernel@vN:MxNxK:backend`` (legacy unsalted
    caches, foreign junk) produce a ``RuntimeWarning`` naming the file and
    the salvageable subset (usually empty -> the cache rebuilds)."""
    try:
        with open(path) as f:
            raw = json.load(f)
    except OSError:
        return {}  # no cache yet: the normal first-run case, no warning
    except ValueError as e:
        warnings.warn(
            f"autotune cache {path!r} is not valid JSON ({e}); ignoring it "
            "and rebuilding from scratch", RuntimeWarning, stacklevel=3)
        return {}
    if not isinstance(raw, dict):
        warnings.warn(
            f"autotune cache {path!r} top level is {type(raw).__name__}, "
            "expected a JSON object; ignoring it and rebuilding from "
            "scratch", RuntimeWarning, stacklevel=3)
        return {}
    data = {k: list(v) for k, v in raw.items()
            if isinstance(k, str) and _KEY_RE.match(k) and _valid_entry(v)}
    if len(data) != len(raw):
        warnings.warn(
            f"autotune cache {path!r}: dropped {len(raw) - len(data)} "
            "corrupt entries (each key must be kernel@vN:MxNxK:backend and "
            "each value three positive ints); keeping "
            f"the {len(data)} valid ones", RuntimeWarning, stacklevel=3)
    return data


class AutotuneCache:
    """JSON-backed {key: [bm, bn, bk]} map with atomic persistence.

    Corruption-tolerant: see :func:`_read_cache_file` — a damaged file
    warns and rebuilds instead of raising into kernel launches."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._data: Dict[str, list] = {}
        self._loaded = False

    def load(self) -> "AutotuneCache":
        self._loaded = True
        self._data = _read_cache_file(self.path)
        return self

    def get(self, key: str) -> Optional[Blocks]:
        if not self._loaded:
            self.load()
        v = self._data.get(key)
        return tuple(int(x) for x in v) if v else None

    def put(self, key: str, blocks: Blocks, save: bool = True) -> None:
        if not self._loaded:
            self.load()
        self._data[key] = [int(b) for b in blocks]
        if save:
            self.save()

    def keys(self) -> List[str]:
        if not self._loaded:
            self.load()
        return sorted(self._data)

    def save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # merge-on-write under an exclusive file lock: concurrent tuner
        # processes (and threads) each hold a partial in-memory view, and
        # the read-merge-replace must be atomic as a unit or a slower
        # writer drops the faster one's entries
        with _LOCK, open(f"{self.path}.lock", "w") as lf:
            try:
                fcntl.flock(lf, fcntl.LOCK_EX)
            except OSError:
                pass  # exotic filesystems: fall back to atomic replace only
            merged = _read_cache_file(self.path)
            merged.update(self._data)
            self._data = merged
            tmp = f"{self.path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(self._data, f, indent=0, sort_keys=True)
            os.replace(tmp, self.path)

    def __len__(self) -> int:
        if not self._loaded:
            self.load()
        return len(self._data)


def _shared_cache(path: Optional[str]) -> AutotuneCache:
    p = path or default_cache_path()
    with _LOCK:
        if p not in _CACHES:
            _CACHES[p] = AutotuneCache(p)
        return _CACHES[p]


def shared_cache(path: Optional[str] = None) -> AutotuneCache:
    """The process-wide cache object for ``path`` (the one kernel launches
    consult) — the offline sweep warms THIS instance so a sweep and a serve
    in the same process see one view."""
    return _shared_cache(path)


def measure(fn: Callable, *args, reps: int = 3) -> float:
    """Warmup + best-of-N wall-clock of ``fn(*args)``; the one timing
    harness shared by the tuner and benchmarks/kernel_bench."""
    fn(*args)  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _time_candidate(bench_fn: Callable[[Blocks], object], blocks: Blocks,
                    reps: int = 3) -> float:
    global _PROBES
    _PROBES += 1
    try:
        return measure(bench_fn, blocks, reps=reps)
    except Exception:
        return float("inf")


def blocks_for(kernel: str, M: int, N: int, K: int, *,
               interpret: bool = False,
               bench_fn: Optional[Callable[[Blocks], object]] = None,
               cache_path: Optional[str] = None,
               candidates: Optional[Sequence[Blocks]] = None,
               force_tune: bool = False,
               meta: Optional[dict] = None) -> Blocks:
    """Resolve the block triple for one kernel launch.

    Lookup order: persistent cache (warmed offline by the sweep, or by a
    previous lazy tune on this backend) -> live tuning -> heuristic.  The
    cache is consulted FIRST on every backend — a committed cache serves
    its block choices even where tuning is disabled.  Tuning only happens
    on a real accelerator backend (or when ``force_tune`` is set, for
    tests) AND when a ``bench_fn`` is provided; every other case falls
    back to the heuristic so the interpret path stays cheap and
    deterministic.  Every call is visible to :func:`record_requests` (the
    offline sweep's shape discovery), including calls made while tracing.
    """
    _record(kernel, M, N, K, tunable=True, meta=meta)
    fallback = heuristic_blocks(M, N, K)
    key = cache_key(kernel, M, N, K)
    cache = _shared_cache(cache_path)
    if not jax.core.trace_state_clean():
        # inside a jit/vmap trace the bench closure holds tracers:
        # "timing" it measures Python tracing, not the kernel.  Use the
        # cache if warm, else the heuristic — and never persist from here.
        return cache.get(key) or fallback
    hit = cache.get(key)
    if hit is not None and not force_tune:
        return hit
    tunable = force_tune or (not interpret
                             and jax.default_backend() != "cpu")
    if not tunable or bench_fn is None:
        return fallback
    cands = list(candidates) if candidates else candidate_blocks(M, N, K)
    timed = [(_time_candidate(bench_fn, c), c) for c in cands]
    timed.sort(key=lambda t: (t[0], t[1]))
    if not timed or timed[0][0] == float("inf"):
        return fallback  # nothing ran: do not poison the persistent cache
    best = timed[0][1]
    cache.put(key, best)
    return best
