"""Shape-keyed block-size autotuner for the Pallas kernels.

Replaces the fixed power-of-two ``_block()`` heuristic in ops.py: each
(kernel, M, N, K) shape gets its block triple from a persistent JSON cache,
populated by timing candidate triples on the real accelerator backend.

Interpret-safe fallback: on CPU / interpret mode (the container has no TPU)
timing the Python interpreter is meaningless, so the heuristic triple is
returned immediately and nothing is benchmarked or persisted.  The cache
file location comes from ``REPRO_AUTOTUNE_CACHE`` (default
``~/.cache/repro/autotune.json``); writes are atomic (tmp + rename) so
concurrent processes never observe a torn file.

A corrupt cache file NEVER takes the process down: truncated JSON, a
non-dict top level, or entries that are not three ints are dropped with a
``RuntimeWarning`` and the cache rebuilds from scratch — a bad cache is a
performance bug, not a correctness one, so crashing over it is the wrong
trade.
"""
from __future__ import annotations

import fcntl
import json
import os
import threading
import time
import warnings
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax

Blocks = Tuple[int, int, int]

_LOCK = threading.Lock()
_CACHES: Dict[str, "AutotuneCache"] = {}


def default_cache_path() -> str:
    env = os.environ.get("REPRO_AUTOTUNE_CACHE")
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "autotune.json")


def heuristic_block(m: int, cap: int = 128) -> int:
    """Largest power-of-two block <= cap that keeps tiny shapes legal."""
    b = 8
    while b * 2 <= min(m, cap):
        b *= 2
    return b


def heuristic_blocks(M: int, N: int, K: int, cap: int = 128) -> Blocks:
    return (heuristic_block(M, cap), heuristic_block(N, cap),
            heuristic_block(K, cap))


def candidate_blocks(M: int, N: int, K: int) -> List[Blocks]:
    """Distinct legal triples around the heuristic: the heuristic itself,
    plus smaller-M (better pipelining at small batch) and 256-wide variants
    (fewer grid steps on large shapes)."""
    base = heuristic_blocks(M, N, K)
    cands = {base}
    for bm in {8, base[0] // 2 or 8, base[0], min(256, max(8, M))}:
        for bn in {base[1], min(256, base[1] * 2)}:
            for bk in {base[2], min(256, base[2] * 2)}:
                c = (heuristic_block(M, max(bm, 8)),
                     heuristic_block(N, max(bn, 8)),
                     heuristic_block(K, max(bk, 8)))
                cands.add(c)
    return sorted(cands)


def _valid_entry(v) -> bool:
    """A cache entry must be exactly three positive ints (a block triple);
    anything else — strings, floats, wrong arity — is corruption."""
    return (isinstance(v, (list, tuple)) and len(v) == 3
            and all(isinstance(x, int) and not isinstance(x, bool) and x > 0
                    for x in v))


def _read_cache_file(path: str) -> Dict[str, list]:
    """Read + sanitize one cache file.  NEVER raises on corruption:
    unreadable/truncated JSON, a non-dict top level, or invalid entries
    produce a ``RuntimeWarning`` naming the file and the salvageable
    subset (usually empty -> the cache rebuilds)."""
    try:
        with open(path) as f:
            raw = json.load(f)
    except OSError:
        return {}  # no cache yet: the normal first-run case, no warning
    except ValueError as e:
        warnings.warn(
            f"autotune cache {path!r} is not valid JSON ({e}); ignoring it "
            "and rebuilding from scratch", RuntimeWarning, stacklevel=3)
        return {}
    if not isinstance(raw, dict):
        warnings.warn(
            f"autotune cache {path!r} top level is {type(raw).__name__}, "
            "expected a JSON object; ignoring it and rebuilding from "
            "scratch", RuntimeWarning, stacklevel=3)
        return {}
    data = {k: list(v) for k, v in raw.items() if _valid_entry(v)}
    if len(data) != len(raw):
        warnings.warn(
            f"autotune cache {path!r}: dropped {len(raw) - len(data)} "
            "corrupt entries (each must be three positive ints); keeping "
            f"the {len(data)} valid ones", RuntimeWarning, stacklevel=3)
    return data


class AutotuneCache:
    """JSON-backed {key: [bm, bn, bk]} map with atomic persistence.

    Corruption-tolerant: see :func:`_read_cache_file` — a damaged file
    warns and rebuilds instead of raising into kernel launches."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._data: Dict[str, list] = {}
        self._loaded = False

    def load(self) -> "AutotuneCache":
        self._loaded = True
        self._data = _read_cache_file(self.path)
        return self

    def get(self, key: str) -> Optional[Blocks]:
        if not self._loaded:
            self.load()
        v = self._data.get(key)
        return tuple(int(x) for x in v) if v else None

    def put(self, key: str, blocks: Blocks, save: bool = True) -> None:
        if not self._loaded:
            self.load()
        self._data[key] = [int(b) for b in blocks]
        if save:
            self.save()

    def save(self) -> None:
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        # merge-on-write under an exclusive file lock: concurrent tuner
        # processes (and threads) each hold a partial in-memory view, and
        # the read-merge-replace must be atomic as a unit or a slower
        # writer drops the faster one's entries
        with _LOCK, open(f"{self.path}.lock", "w") as lf:
            try:
                fcntl.flock(lf, fcntl.LOCK_EX)
            except OSError:
                pass  # exotic filesystems: fall back to atomic replace only
            merged = _read_cache_file(self.path)
            merged.update(self._data)
            self._data = merged
            tmp = f"{self.path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                json.dump(self._data, f, indent=0, sort_keys=True)
            os.replace(tmp, self.path)

    def __len__(self) -> int:
        if not self._loaded:
            self.load()
        return len(self._data)


def _shared_cache(path: Optional[str]) -> AutotuneCache:
    p = path or default_cache_path()
    with _LOCK:
        if p not in _CACHES:
            _CACHES[p] = AutotuneCache(p)
        return _CACHES[p]


def measure(fn: Callable, *args, reps: int = 3) -> float:
    """Warmup + best-of-N wall-clock of ``fn(*args)``; the one timing
    harness shared by the tuner and benchmarks/kernel_bench."""
    fn(*args)  # warmup / compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def _time_candidate(bench_fn: Callable[[Blocks], object], blocks: Blocks,
                    reps: int = 3) -> float:
    try:
        return measure(bench_fn, blocks, reps=reps)
    except Exception:
        return float("inf")


def blocks_for(kernel: str, M: int, N: int, K: int, *,
               interpret: bool = False,
               bench_fn: Optional[Callable[[Blocks], object]] = None,
               cache_path: Optional[str] = None,
               candidates: Optional[Sequence[Blocks]] = None,
               force_tune: bool = False) -> Blocks:
    """Resolve the block triple for one kernel launch.

    Tuning only happens on a real accelerator backend (or when
    ``force_tune`` is set, for tests) AND when a ``bench_fn`` is provided;
    every other case falls back to the heuristic so the interpret path
    stays cheap and deterministic.
    """
    fallback = heuristic_blocks(M, N, K)
    tunable = force_tune or (not interpret
                             and jax.default_backend() != "cpu")
    if not tunable or bench_fn is None:
        return fallback
    if not jax.core.trace_state_clean():
        # inside a jit/vmap trace the bench closure holds tracers:
        # "timing" it measures Python tracing, not the kernel.  Use the
        # cache if warm, else the heuristic — and never persist from here.
        return _shared_cache(cache_path).get(
            f"{kernel}:{M}x{N}x{K}:{jax.default_backend()}") or fallback
    cache = _shared_cache(cache_path)
    key = f"{kernel}:{M}x{N}x{K}:{jax.default_backend()}"
    hit = cache.get(key)
    if hit is not None:
        return hit
    cands = list(candidates) if candidates else candidate_blocks(M, N, K)
    timed = [(_time_candidate(bench_fn, c), c) for c in cands]
    timed.sort(key=lambda t: (t[0], t[1]))
    if not timed or timed[0][0] == float("inf"):
        return fallback  # nothing ran: do not poison the persistent cache
    best = timed[0][1]
    cache.put(key, best)
    return best
