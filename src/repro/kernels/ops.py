"""jit'd dispatch wrappers for the Pallas kernels.

Handles: padding to MXU-aligned block multiples, interpret-mode fallback on
CPU (the container has no TPU; interpret=True executes the kernel body in
Python — correctness validation per the task spec), leading-batch-dim
flattening, and QTensor-level entry points mirroring core.qtensor methods.

Block sizes come from the shape-keyed autotuner (kernels.autotune): the
persistent per-backend cache is consulted FIRST (warmed offline by
``launch/autotune_sweep.py`` so serving traces are pure cache hits); on a
cache miss a real accelerator times candidates once and persists the
winner, while CPU/interpret falls back to the power-of-two heuristic.

The M2Q path is permutation-free end to end: the merged byte payload is in
original filter order, the fused kernel emits ONE output array, and the old
concatenate + ``jnp.take`` inverse-permutation epilogue is gone.  Activation
quantization is fused into the m2q/int8 kernel prologues, so these entry
points take FLOAT activations plus a scalar scale.

Dispatch control is LAYERED (see :class:`DispatchConfig`):

1. a scoped :func:`dispatch` context (programmatic, nestable — what tests
   and the serving engines use),
2. the per-axis FAULT TRIP LATCH (:func:`trip_axis` /
   :func:`axis_tripped`): once a :class:`FallbackGuard` catches a kernel
   raise or non-finite kernel output on an axis, that axis resolves to
   the XLA path process-wide until :func:`reset_trip_latch` — graceful
   degradation that an explicit scope (a test forcing kernels on) still
   overrides,
3. the ``REPRO_PALLAS_DISPATCH`` / ``REPRO_PALLAS_CONV_DISPATCH`` /
   ``REPRO_PALLAS_ATTN_DISPATCH`` env vars (process-wide defaults; this
   module is the ONLY place they are read),
4. the backend default (kernels on a real TPU, pure-XLA QTensor paths
   elsewhere — the interpret path is a correctness harness, not a fast
   path).

The ``attn`` axis steers the ACTIVATION-side int8 attention kernels
(``relu_attn`` for EfficientViT's MSA token mixer, ``decode_attn_int8``
for the serving engine's int8-KV decode step).  Unlike the dense/conv
axes — where the kernel computes the identical function as the XLA
QTensor path — turning ``attn`` on for the MSA path CHANGES numerics to
int8-quantization tolerance (the f32 einsums it replaces never quantized
activations), which is why it has its own switch.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
import os
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.qtensor import QAPoT, QExpertM2Q, QM2Q, QUniform
from ..core.quant import act_scale_from_stats
from . import autotune
from .apot_matmul import apot_matmul
from .decode_attn_int8 import decode_attn_int8
from .dwconv_w4 import dwconv_w4, same_padding
from .int4_matmul import int4_matmul
from .int8_matmul import int8_matmul
from .m2q_matmul import m2q_matmul
from .relu_attn import relu_attn


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class DispatchConfig:
    """Scoped kernel-dispatch switches; ``None`` inherits the next layer.

    ``dense`` steers QTensor matmuls (nn.dense and quantized 1x1 PWConvs),
    ``conv`` steers the conv paths specifically, and ``attn`` the int8
    attention kernels (MSA ReLU linear attention + int8-KV decode); the
    conv/attn axes follow ``dense`` when unset — the same split the
    ``REPRO_PALLAS_DISPATCH`` / ``REPRO_PALLAS_CONV_DISPATCH`` /
    ``REPRO_PALLAS_ATTN_DISPATCH`` env vars expose.  The env vars are the
    process-wide defaults consulted only when NO scope field applies: any
    scoped field beats the env vars, so a scope with ``dense=True`` also
    re-enables conv/attn paths over a ``...=0`` env var (pass
    ``conv=False`` / ``attn=False`` explicitly to keep an axis pinned).
    Enter a scope with :func:`dispatch` (a nestable context manager), or
    hand the config to a serving engine (``Engine``/``VisionEngine`` take
    ``dispatch=``) to pin its traces regardless of ambient state.

    NOTE: dispatch is consulted at TRACE time; a jit cache keyed only on
    shapes will serve a stale trace if the config flips between calls of
    the same function object (use fresh closures per scope, as the HLO
    tests do).
    """

    dense: Optional[bool] = None
    conv: Optional[bool] = None
    attn: Optional[bool] = None

    def layered_over(self, base: "DispatchConfig") -> "DispatchConfig":
        return DispatchConfig(
            dense=self.dense if self.dense is not None else base.dense,
            conv=self.conv if self.conv is not None else base.conv,
            attn=self.attn if self.attn is not None else base.attn)


_DISPATCH_SCOPE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_dispatch_scope", default=DispatchConfig())


def active_dispatch() -> DispatchConfig:
    """The currently scoped DispatchConfig (all-None outside any scope)."""
    return _DISPATCH_SCOPE.get()


@contextlib.contextmanager
def dispatch(config: Optional[DispatchConfig] = None, *,
             dense: Optional[bool] = None, conv: Optional[bool] = None,
             attn: Optional[bool] = None):
    """Scope kernel dispatch programmatically (nestable; None inherits).

        with ops.dispatch(dense=True):          # force kernels on
            ...
            with ops.dispatch(conv=False):      # ...but XLA conv paths here
                ...

    Takes an explicit :class:`DispatchConfig`, the ``dense=`` / ``conv=`` /
    ``attn=`` fields directly, or both — explicit fields layer over the
    config.  The scope overrides the env-var process defaults; unset fields
    fall through to the enclosing scope, then the env vars, then the
    backend default.
    """
    ov = DispatchConfig(dense, conv, attn)
    if config is not None:
        ov = ov.layered_over(config)
    token = _DISPATCH_SCOPE.set(ov.layered_over(_DISPATCH_SCOPE.get()))
    try:
        yield
    finally:
        _DISPATCH_SCOPE.reset(token)


def _env_flag(name: str) -> Optional[bool]:
    env = os.environ.get(name)
    if env is None:
        return None
    return env.strip().lower() not in ("", "0", "false")


# ---------------------------------------------------------------------------
# fault trip latch + FallbackGuard (graceful degradation to the XLA paths)
# ---------------------------------------------------------------------------


class NumericalError(RuntimeError):
    """A compute path produced non-finite (NaN/Inf) outputs — poisoned
    quantized forward, overflowing int accumulator, or a broken kernel.
    Raised by :class:`FallbackGuard`'s finite check and by the serving
    engines' decode-logits check (re-exported as
    ``repro.serving.errors.NumericalError``)."""


_TRIP_AXES = ("dense", "conv", "attn")
_TRIP_LATCH: dict = {ax: 0 for ax in _TRIP_AXES}


def trip_axis(axis: str) -> None:
    """Latch one dispatch axis onto the XLA fallback path (process-wide
    default; an explicit :func:`dispatch` scope still wins).  Raises
    ``ValueError`` for an unknown axis."""
    if axis not in _TRIP_LATCH:
        raise ValueError(f"unknown dispatch axis {axis!r}; one of "
                         f"{_TRIP_AXES}")
    _TRIP_LATCH[axis] += 1


def axis_tripped(axis: str) -> bool:
    return _TRIP_LATCH.get(axis, 0) > 0


def trip_counts() -> dict:
    """Per-axis trip counters (how often a FallbackGuard latched each)."""
    return dict(_TRIP_LATCH)


def reset_trip_latch() -> None:
    """Clear every axis latch (tests; or an operator re-arming kernels)."""
    for ax in _TRIP_LATCH:
        _TRIP_LATCH[ax] = 0


def _tree_nonfinite(out) -> bool:
    """True if any inexact-dtype array leaf holds a NaN/Inf (syncs)."""
    for leaf in jax.tree_util.tree_leaves(out):
        if (isinstance(leaf, jax.Array)
                and jnp.issubdtype(leaf.dtype, jnp.inexact)
                and not bool(jnp.all(jnp.isfinite(leaf)))):
            return True
    return False


def _poison_tree(out):
    """NaN-fill every inexact array leaf (the fault injector's kernel-site
    poisoning: simulates a silently-corrupting kernel)."""
    return jax.tree_util.tree_map(
        lambda x: (jnp.full_like(x, jnp.nan)
                   if isinstance(x, jax.Array)
                   and jnp.issubdtype(x.dtype, jnp.inexact) else x), out)


class FallbackGuard:
    """Retry-once-on-XLA wrapper around a kernel-dispatched step.

    ``run(fn, *args)`` calls ``fn(*args, fallback=False)``; if the call
    raises, or (with ``check_finite``) returns non-finite outputs, the
    guard records the trip, latches the configured dispatch axes onto the
    XLA path (:func:`trip_axis`), and re-runs ``fn(*args, fallback=True)``
    — the step's own XLA-path trace.  ``fn`` must take a STATIC
    ``fallback`` keyword that pins the XLA path for its trace (a scoped
    ``dispatch(dense=False, conv=False, attn=False)`` inside the traced
    body): dispatch is resolved at trace time, so retrying the *same*
    jitted trace under a different ambient scope would be a no-op.

    After the first trip the guard is latched: subsequent ``run`` calls go
    straight to the fallback path (no repeated failing-kernel attempts).
    ``faults``: optional ``serving.faults.FaultInjector`` consulted at
    ``site`` on every primary attempt — the harness provokes kernel
    raises/NaN-poisoning deterministically to prove this guard recovers.
    """

    def __init__(self, check_finite: bool = True, faults=None,
                 site: str = "kernel",
                 axes: Tuple[str, ...] = _TRIP_AXES):
        self.check_finite = check_finite
        self.faults = faults
        self.site = site
        self.axes = axes
        self.tripped = False
        self.trips = 0
        self.retries = 0
        self.last_error: Optional[str] = None

    def run(self, fn, *args):
        if self.tripped:
            self.retries += 1
            return fn(*args, fallback=True)
        act = self.faults.on_call(self.site) if self.faults is not None \
            else None
        try:
            if act is not None:
                act.fire()
            out = fn(*args, fallback=False)
            if act is not None and act.poison:
                out = _poison_tree(out)
            if self.check_finite and _tree_nonfinite(out):
                raise NumericalError(
                    f"non-finite output from kernel-dispatched step "
                    f"(site {self.site!r}); retrying on the XLA path")
            return out
        except Exception as e:  # noqa: BLE001 — any failure degrades
            self.trips += 1
            self.tripped = True
            self.last_error = repr(e)
            for ax in self.axes:
                trip_axis(ax)
            self.retries += 1
            return fn(*args, fallback=True)

    def stats(self) -> dict:
        return {"tripped": self.tripped, "trips": self.trips,
                "retries": self.retries, "last_error": self.last_error}

    def reset(self) -> None:
        """Re-arm this guard (does NOT clear the process-wide axis latch;
        see :func:`reset_trip_latch`)."""
        self.tripped = False
        self.last_error = None


def dispatch_enabled() -> bool:
    """Should nn.dense route QTensor matmuls through the Pallas kernels?

    Resolution order: active :func:`dispatch` scope -> the fault trip
    latch (:func:`axis_tripped`: a tripped axis degrades to XLA
    process-wide) -> the ``REPRO_PALLAS_DISPATCH=1/0`` env var (process
    default; tests force it on to exercise the wiring) -> backend default
    (only on a real TPU: the interpret path is a Python correctness
    harness, ~1000x slower than XLA on CPU — wiring it into serving would
    tank the engine).
    """
    scoped = _DISPATCH_SCOPE.get().dense
    if scoped is not None:
        return scoped
    if axis_tripped("dense"):
        return False
    env = _env_flag("REPRO_PALLAS_DISPATCH")
    if env is not None:
        return env
    return jax.default_backend() == "tpu"


def conv_dispatch_enabled() -> bool:
    """Should nn.conv2d route QTensor convolutions through the Pallas
    kernels (PWConv -> m2q/int8/int4 matmul, depthwise -> dwconv_w4)?

    Resolution order: active scope ``conv`` -> active scope ``dense`` ->
    the ``conv`` fault trip latch -> the
    ``REPRO_PALLAS_CONV_DISPATCH=1/0`` env var (conv-only process
    default) -> :func:`dispatch_enabled`.  Note the quantized 1x1 PWConv
    never falls back to a dequantized-weight f32 convolution: with dispatch
    off it still runs the pure-XLA QTensor *matmul* path (see
    nn.layers.conv2d).
    """
    scope = _DISPATCH_SCOPE.get()
    if scope.conv is not None:
        return scope.conv
    if scope.dense is not None:
        return scope.dense
    if axis_tripped("conv"):
        return False
    env = _env_flag("REPRO_PALLAS_CONV_DISPATCH")
    if env is not None:
        return env
    return dispatch_enabled()


def attn_dispatch_enabled() -> bool:
    """Should nn.attention route through the fused int8 attention kernels
    (relu_linear_attention -> relu_attn, decode_attention_int8 ->
    decode_attn_int8)?

    Resolution order: active scope ``attn`` -> active scope ``dense`` ->
    the ``attn`` fault trip latch -> the
    ``REPRO_PALLAS_ATTN_DISPATCH=1/0`` env var (attention-only process
    default) -> :func:`dispatch_enabled` — layered exactly like the conv
    axis.  NOTE the MSA path quantizes activations the f32 einsums do not:
    flipping this axis moves numerics by int8-quantization error, so
    strict-parity tests pin ``attn`` explicitly.
    """
    scope = _DISPATCH_SCOPE.get()
    if scope.attn is not None:
        return scope.attn
    if scope.dense is not None:
        return scope.dense
    if axis_tripped("attn"):
        return False
    env = _env_flag("REPRO_PALLAS_ATTN_DISPATCH")
    if env is not None:
        return env
    return dispatch_enabled()


def kernel_supported(qt) -> bool:
    """True when the fused kernel computes the SAME function as the XLA
    QTensor path for this leaf (2-D weight, identical activation handling
    — calibrated int paths quantize activations, weights-only paths do
    not), so dispatch cannot change serving numerics."""
    if isinstance(qt, (QM2Q, QExpertM2Q)):
        return qt.payload.ndim == 2 and qt.act_scale is not None
    if isinstance(qt, QUniform):
        if qt.payload.ndim != 2 or qt.axis != 1:
            return False
        return qt.bits == 4 or (qt.bits == 8 and qt.act_scale is not None)
    if isinstance(qt, QAPoT):
        return qt.codes.ndim == 2 and qt.act_scale is None
    return False


def _pad2(x, m0, m1, value=0):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=value)
    return x


def _pad1(x, m, value=0):
    p = (-x.shape[0]) % m
    if p:
        x = jnp.pad(x, ((0, p),), constant_values=value)
    return x


def _act_scale_or_default(x, act_scale):
    """Calibrated scalar scale, or a dynamic max-abs fallback.

    The fallback is a scalar reduce (fused by XLA into the surrounding
    graph) through the same act_scale_from_stats definition the calibrated
    path uses; the int8 payload itself never materializes in HBM — rounding
    happens inside the kernel prologue.
    """
    if act_scale is not None:
        return jnp.asarray(act_scale, jnp.float32).reshape(())
    return act_scale_from_stats(jnp.max(jnp.abs(x.astype(jnp.float32))))


# ---------------------------------------------------------------------------
# jitted cores (block sizes static) + autotuned public wrappers
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _int8_core(x, wq, act_scale, scale, zero_point, bm, bn, bk, interpret):
    M, K = x.shape
    N = wq.shape[1]
    xp = _pad2(x.astype(jnp.float32), bm, bk)
    wp = _pad2(wq, bk, bn)
    y = int8_matmul(xp, wp, act_scale, _pad1(scale, bn),
                    _pad1(zero_point, bn), bm=bm, bn=bn, bk=bk,
                    interpret=interpret)
    return y[:M, :N]


def int8_matmul_op(x, wq, act_scale, scale, zero_point,
                   interpret: Optional[bool] = None,
                   blocks: Optional[Tuple[int, int, int]] = None):
    """x (M,K) FLOAT activations; quantization is fused into the kernel."""
    interpret = _interpret_default() if interpret is None else interpret
    M, K = x.shape
    N = wq.shape[1]
    if blocks is None:
        blocks = autotune.blocks_for(
            "int8_matmul", M, N, K, interpret=interpret,
            bench_fn=lambda b: _int8_core(x, wq, act_scale, scale, zero_point,
                                          *b, interpret))
    return _int8_core(x, wq, act_scale, scale, zero_point, *blocks, interpret)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _int4_core(x, packed, scale, zero_point, bm, bn, bk, interpret):
    M, K = x.shape
    N = packed.shape[1] * 2
    xp = _pad2(x, bm, bk)
    pp = _pad2(packed, bk, bn // 2)
    y = int4_matmul(xp, pp, _pad1(scale, bn), _pad1(zero_point, bn),
                    bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:M, :N]


def int4_matmul_op(x, packed, scale, zero_point,
                   interpret: Optional[bool] = None,
                   blocks: Optional[Tuple[int, int, int]] = None):
    interpret = _interpret_default() if interpret is None else interpret
    M, K = x.shape
    N = packed.shape[1] * 2
    if blocks is None:
        blocks = autotune.blocks_for(
            "int4_matmul", M, N, K, interpret=interpret,
            bench_fn=lambda b: _int4_core(x, packed, scale, zero_point, *b,
                                          interpret))
    return _int4_core(x, packed, scale, zero_point, *blocks, interpret)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _apot_core(x, codes, scale, bm, bn, bk, interpret):
    M, K = x.shape
    N = codes.shape[1]
    xp = _pad2(x, bm, bk)
    # pad codes with the zero-flag byte so padded weights decode to 0
    cp = _pad2(codes, bk, bn, value=0x80)
    y = apot_matmul(xp, cp, _pad1(scale, bn), bm=bm, bn=bn, bk=bk,
                    interpret=interpret)
    return y[:M, :N]


def apot_matmul_op(x, codes, scale, interpret: Optional[bool] = None,
                   blocks: Optional[Tuple[int, int, int]] = None):
    interpret = _interpret_default() if interpret is None else interpret
    M, K = x.shape
    N = codes.shape[1]
    if blocks is None:
        blocks = autotune.blocks_for(
            "apot_matmul", M, N, K, interpret=interpret,
            bench_fn=lambda b: _apot_core(x, codes, scale, *b, interpret))
    return _apot_core(x, codes, scale, *blocks, interpret)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def _m2q_core(x, act_scale, payload, u_scale, u_zp, a_scale,
              bm, bn, bk, interpret):
    M, K = x.shape
    N = payload.shape[1]
    xp = _pad2(x.astype(jnp.float32), bm, bk)
    # K-pad rows of the payload multiply quantized-zero activations; N-pad
    # columns carry zero scales — both vanish, any pad byte is safe.
    pp = _pad2(payload, bk, bn)
    y = m2q_matmul(xp, act_scale, pp, _pad1(u_scale, bn), _pad1(u_zp, bn),
                   _pad1(a_scale, bn), bm=bm, bn=bn, bk=bk,
                   interpret=interpret)
    return y[:M, :N]


def m2q_matmul_op(x, act_scale, payload, u_scale, u_zp, a_scale,
                  interpret: Optional[bool] = None,
                  blocks: Optional[Tuple[int, int, int]] = None):
    """Fused permutation-free M2Q matmul.

    x (M,K) FLOAT; payload (K,N) merged int8 bytes in original filter
    order; u_scale/u_zp/a_scale (N,) zero-masked. Returns y (M,N) f32 —
    both engine halves summed in the kernel epilogue, no concat/gather.
    """
    interpret = _interpret_default() if interpret is None else interpret
    M, K = x.shape
    N = payload.shape[1]
    if blocks is None:
        blocks = autotune.blocks_for(
            "m2q_matmul", M, N, K, interpret=interpret,
            bench_fn=lambda b: _m2q_core(x, act_scale, payload, u_scale,
                                         u_zp, a_scale, *b, interpret))
    return _m2q_core(x, act_scale, payload, u_scale, u_zp, a_scale, *blocks,
                     interpret)


@partial(jax.jit, static_argnames=("kh", "kw", "stride", "bh", "bc",
                                   "fuse_pad", "interpret"))
def _dwconv_core(x, packed, scale, zero_point, kh, kw, stride, bh, bc,
                 fuse_pad, interpret):
    C = x.shape[-1]
    pc = (-C) % bc
    if pc:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pc)))
        packed = jnp.pad(packed, ((0, 0), (0, pc // 2)))
        scale = jnp.pad(scale, (0, pc))
        zero_point = jnp.pad(zero_point, (0, pc))
    y = dwconv_w4(x, packed, scale, zero_point, kh=kh, kw=kw, stride=stride,
                  bh=bh, bc=bc, fuse_pad=fuse_pad, interpret=interpret)
    return y[..., :C]


def _dwconv_bc(bn: int, C: int) -> int:
    """Channel block: capped at C and even (nibble pairs)."""
    bc = min(bn, C)
    return max(bc - (bc % 2), 2)


# Per-grid-block VMEM budget for the H-tiled dwconv kernel.  With H-tiling
# the footprint is bounded by the TILE, not the feature map: one halo'd
# input slab (bh_in x WI x bc f32), one output slab (bh x WO x bc f32), and
# the decoded weight tile.  8 MiB leaves headroom in a 16 MiB-class VMEM for
# double-buffered pipelining of the next slab.
_DWCONV_VMEM_BYTES = 8 * 1024 * 1024


def _dwconv_tile_bytes(W: int, kh: int, kw: int, stride: int,
                       bh: int, bc: int) -> int:
    """f32 VMEM bytes one (bh, bc) grid block touches at map width W."""
    pw = same_padding(W, kw, stride)
    wi = W + pw[0] + pw[1]
    wo = -(-W // stride)
    bh_in = (bh - 1) * stride + kh
    # input slab + output slab + packed nibbles + decoded f32 weights
    return (bh_in * wi + bh * wo) * bc * 4 + kh * kw * bc // 2 + kh * kw * bc * 4


def dwconv_tile_plan(H: int, W: int, kh: int, kw: int, stride: int,
                     bh: Optional[int] = None, bc: int = 128,
                     budget: int = _DWCONV_VMEM_BYTES
                     ) -> Optional[Tuple[int, int]]:
    """Fit an H-tile plan (bh output rows, bc channels) under the VMEM
    budget, shrinking the requested blocks (rows first — channel tiles keep
    lane utilization) until one block fits.  Returns None only when even
    the minimal (1, 2) tile exceeds the budget — i.e. the tiler genuinely
    cannot block the map, not merely that the whole map is large."""
    ho = -(-H // stride)
    bh = ho if bh is None else max(1, min(int(bh), ho))
    bc = max(2, bc - (bc % 2))
    while bh > 1 and _dwconv_tile_bytes(W, kh, kw, stride, bh, bc) > budget:
        bh = max(1, bh // 2)
    while bc > 2 and _dwconv_tile_bytes(W, kh, kw, stride, bh, bc) > budget:
        bc = max(2, (bc // 2) - ((bc // 2) % 2))
    if _dwconv_tile_bytes(W, kh, kw, stride, bh, bc) > budget:
        return None
    return bh, bc


def dwconv_w4_op(x, packed, scale, zero_point, kh: int = 3, kw: int = 3,
                 stride: int = 1, interpret: Optional[bool] = None,
                 blocks: Optional[Tuple[int, int, int]] = None,
                 fuse_pad: Optional[bool] = None):
    """x (B,H,W,C) float; packed (kh*kw, C/2) nibbles; SAME padding.

    The autotuner picks the (bh, bc) H-tile: candidate triples map bm -> bh
    (output rows per tile) and bn -> bc (channels per tile), each fitted
    under the VMEM budget by :func:`dwconv_tile_plan` before launch.
    ``fuse_pad`` defaults to stride > 1 — the MBConv stage-entry
    downsamplers pad inside the kernel instead of materializing a padded
    copy of the full map.
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, H, W, C = x.shape
    HO, WO = -(-H // stride), -(-W // stride)
    taps = kh * kw
    if fuse_pad is None:
        fuse_pad = stride > 1

    def _fit(b) -> Tuple[int, int]:
        plan = dwconv_tile_plan(H, W, kh, kw, stride,
                                bh=min(int(b[0]), HO),
                                bc=_dwconv_bc(int(b[1]), C))
        return plan or (1, 2)

    if blocks is None:
        # candidates are benched with the SAME fitted (bh, bc) that would
        # execute, so dedupe triples by their effective plan
        seen, cands = set(), []
        for c in autotune.candidate_blocks(HO, C, taps):
            p = _fit(c)
            if p not in seen:
                seen.add(p)
                cands.append(c)
        blocks = autotune.blocks_for(
            "dwconv_w4", B * HO * WO, C, taps,
            interpret=interpret, candidates=cands,
            meta={"B": B, "H": H, "W": W, "C": C, "kh": kh, "kw": kw,
                  "stride": stride},
            bench_fn=lambda b: _dwconv_core(x, packed, scale, zero_point,
                                            kh, kw, stride, *_fit(b),
                                            fuse_pad, interpret))
    bh, bc = _fit(blocks)
    return _dwconv_core(x, packed, scale, zero_point, kh, kw, stride, bh, bc,
                        fuse_pad, interpret)


# ---------------------------------------------------------------------------
# fused int8 attention (MSA ReLU linear attention + int8-KV decode)
# ---------------------------------------------------------------------------


def _pad_axis(x, axis: int, mult: int):
    p = (-x.shape[axis]) % mult
    if p:
        pad = [(0, 0)] * x.ndim
        pad[axis] = (0, p)
        x = jnp.pad(x, pad)
    return x


@partial(jax.jit, static_argnames=("bn", "eps", "interpret"))
def _relu_attn_core(q, k, v, bn, eps, interpret):
    B, N, H, D = q.shape
    qf = q.astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # layer-wise max-abs act scales, computed on the post-ReLU range for
    # q/k (scalar reduces fused into the graph; the int8 payloads only
    # ever exist inside the kernel prologue — the PR 1 convention)
    sq = act_scale_from_stats(jnp.maximum(jnp.max(qf), 0.0))
    sk = act_scale_from_stats(jnp.maximum(jnp.max(kf), 0.0))
    sv = act_scale_from_stats(jnp.max(jnp.abs(vf)))
    bd = autotune.heuristic_block(D)
    qp = _pad_axis(_pad_axis(qf, 1, bn), 3, bd)
    kp = _pad_axis(_pad_axis(kf, 1, bn), 3, bd)
    vp = _pad_axis(_pad_axis(vf, 1, bn), 3, bd)
    y = relu_attn(qp, kp, vp, sq, sk, sv, bn=bn, eps=eps,
                  interpret=interpret)
    return y[:, :N, :, :D]


def relu_attn_op(q, k, v, eps: float = 1e-6,
                 interpret: Optional[bool] = None,
                 blocks: Optional[Tuple[int, int, int]] = None):
    """Fused int8 ReLU linear attention; q/k/v (B,N,H,D) float.

    Padded k rows quantize to exact zeros (ReLU(0) -> 0) so padding never
    changes the unpadded outputs; padded q rows are sliced away.
    """
    interpret = _interpret_default() if interpret is None else interpret
    B, N, H, D = q.shape
    if blocks is None:
        # only the q-row block matters (k/v/kv stay whole per (b, h));
        # dedupe candidate triples by it, mirroring dwconv_w4_op
        seen, cands = set(), []
        for c in autotune.candidate_blocks(N, D, B * H):
            if c[0] not in seen:
                seen.add(c[0])
                cands.append(c)
        blocks = autotune.blocks_for(
            "relu_attn", N, D, B * H, interpret=interpret, candidates=cands,
            meta={"B": B, "N": N, "H": H, "D": D},
            bench_fn=lambda b: _relu_attn_core(q, k, v, b[0], eps, interpret))
    return _relu_attn_core(q, k, v, blocks[0], eps, interpret)


def decode_attn_int8_op(q, k_q, v_q, k_scale, v_scale, lengths,
                        window: Optional[int] = None,
                        scale: Optional[float] = None,
                        interpret: Optional[bool] = None):
    """Pallas twin of nn.attention.decode_attention_int8 (same shapes, same
    quantization definitions): q (B,1,Hq,D) float, int8 cache rows + per-row
    scales, lengths (B,).  Runs per (batch, kv-head) in one VMEM pass."""
    interpret = _interpret_default() if interpret is None else interpret
    B, _, Hq, D = q.shape
    Hkv = k_q.shape[2]
    G = Hq // Hkv
    # no block parameters to tune, but the offline sweep still wants the
    # shape listed (coverage accounting + bench rows)
    autotune.note_shape("decode_attn_int8", B, Hq, D,
                        meta={"Hkv": Hkv, "T": k_q.shape[1],
                              "window": window or 0})
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qh = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    out = decode_attn_int8(qh, k_q, v_q, k_scale, v_scale,
                           jnp.asarray(lengths, jnp.int32).reshape(B, 1),
                           scale=float(scale), window=window,
                           interpret=interpret)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# QTensor-level entry points (kernel-backed twins of core.qtensor methods)
# ---------------------------------------------------------------------------


def qtensor_matmul(x: jax.Array, qt, interpret: Optional[bool] = None):
    """Kernel-backed y = x @ W for 2-D QTensor leaves; x (..., K)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1]).astype(jnp.float32)
    if isinstance(qt, (QM2Q, QExpertM2Q)):
        sa = _act_scale_or_default(x2, qt.act_scale)
        y = m2q_matmul_op(x2, sa, qt.payload, qt.u_scale.reshape(-1),
                          qt.u_zp.reshape(-1), qt.a_scale.reshape(-1),
                          interpret=interpret)
    elif isinstance(qt, QUniform) and qt.bits == 8:
        sa = _act_scale_or_default(x2, qt.act_scale)
        y = int8_matmul_op(x2, qt.payload, sa, qt.scale.reshape(-1),
                           qt.zero_point.reshape(-1), interpret=interpret)
    elif isinstance(qt, QUniform) and qt.bits == 4:
        y = int4_matmul_op(x2, qt.payload,
                           qt.scale.reshape(-1), qt.zero_point.reshape(-1),
                           interpret=interpret)
    elif isinstance(qt, QAPoT):
        y = apot_matmul_op(x2, qt.codes, qt.scale.reshape(-1),
                           interpret=interpret)
    else:
        raise TypeError(type(qt))
    return y.reshape(*lead, y.shape[-1]).astype(x.dtype)


def dwconv_kernel_supported(qt, x, stride: int, groups: int,
                            padding: str) -> bool:
    """True when the packed-w4 depthwise kernel computes the same function
    as the dequantized-weight XLA conv for this leaf: a weights-only 4-bit
    QUniform whose HWIO shape is depthwise (cin-per-group == 1), flattened
    to a (kh*kw, C/2) payload by core.apply, under SAME padding — and
    :func:`dwconv_tile_plan` can fit an H-tile under the VMEM budget.  With
    the H-tiled grid the per-block footprint is bounded by the tile, not
    the feature map, so the plan only fails for maps so wide that even a
    single-row two-channel tile overflows VMEM — arbitrary-resolution maps
    (R256/R384/R512, detection sizes) all stay on the kernel."""
    if not isinstance(qt, QUniform) or qt.bits != 4 or qt.act_scale is not None:
        return False
    # axis must be the flattened payload's column (channel) axis, else the
    # (C,)-shaped scale/zp reshape feeds the kernel a per-row layout
    if qt.payload.ndim != 2 or qt.axis != 1:
        return False
    if len(qt.shape) != 4 or qt.shape[2] != 1:
        return False
    kh, kw, _, c = qt.shape
    if dwconv_tile_plan(x.shape[1], x.shape[2], kh, kw, max(stride, 1)) \
            is None:
        return False
    return (padding == "SAME" and stride >= 1 and groups == c
            and x.shape[-1] == c and qt.payload.shape[0] == kh * kw)


def qtensor_dwconv(x: jax.Array, qt, stride: int = 1,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Kernel-backed depthwise conv for a 4-bit QUniform conv leaf (payload
    (kh*kw, C/2) packed nibbles, shape aux = the original HWIO filter)."""
    kh, kw = int(qt.shape[0]), int(qt.shape[1])
    y = dwconv_w4_op(x.astype(jnp.float32), qt.payload,
                     qt.scale.reshape(-1), qt.zero_point.reshape(-1),
                     kh=kh, kw=kw, stride=stride, interpret=interpret)
    return y.astype(x.dtype)
