"""jit'd dispatch wrappers for the Pallas kernels.

Handles: padding to MXU-aligned block multiples, interpret-mode fallback on
CPU (the container has no TPU; interpret=True executes the kernel body in
Python — correctness validation per the task spec), leading-batch-dim
flattening, and QTensor-level entry points mirroring core.qtensor methods.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.qtensor import QAPoT, QM2Q, QUniform
from ..core.quant import quantize_act
from . import ref
from .apot_matmul import apot_matmul
from .dwconv_w4 import dwconv_w4
from .int4_matmul import int4_matmul
from .int8_matmul import int8_matmul
from .m2q_matmul import m2q_matmul


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad2(x, m0, m1, value=0):
    p0 = (-x.shape[0]) % m0
    p1 = (-x.shape[1]) % m1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)), constant_values=value)
    return x


def _pad1(x, m, value=0):
    p = (-x.shape[0]) % m
    if p:
        x = jnp.pad(x, ((0, p),), constant_values=value)
    return x


def _block(m, cap=128):
    """Largest power-of-two block <= cap that keeps tiny shapes legal."""
    b = 8
    while b * 2 <= min(m, cap):
        b *= 2
    return b


@partial(jax.jit, static_argnames=("interpret",))
def int8_matmul_op(xq, wq, act_scale, scale, zero_point,
                   interpret: Optional[bool] = None):
    interpret = _interpret_default() if interpret is None else interpret
    M, K = xq.shape
    N = wq.shape[1]
    bm, bn, bk = _block(M), _block(N), _block(K)
    xp = _pad2(xq, bm, bk)
    wp = _pad2(wq, bk, bn)
    y = int8_matmul(xp, wp, act_scale, _pad1(scale, bn), _pad1(zero_point, bn),
                    bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:M, :N]


@partial(jax.jit, static_argnames=("interpret",))
def int4_matmul_op(x, packed, scale, zero_point,
                   interpret: Optional[bool] = None):
    interpret = _interpret_default() if interpret is None else interpret
    M, K = x.shape
    N = packed.shape[1] * 2
    bm, bn, bk = _block(M), _block(N), _block(K)
    xp = _pad2(x, bm, bk)
    pp = _pad2(packed, bk, bn // 2)
    y = int4_matmul(xp, pp, _pad1(scale, bn), _pad1(zero_point, bn),
                    bm=bm, bn=bn, bk=bk, interpret=interpret)
    return y[:M, :N]


@partial(jax.jit, static_argnames=("interpret",))
def apot_matmul_op(x, codes, scale, interpret: Optional[bool] = None):
    interpret = _interpret_default() if interpret is None else interpret
    M, K = x.shape
    N = codes.shape[1]
    bm, bn, bk = _block(M), _block(N), _block(K)
    xp = _pad2(x, bm, bk)
    # pad codes with the zero-flag byte so padded weights decode to 0
    cp = _pad2(codes, bk, bn, value=0x80)
    y = apot_matmul(xp, cp, _pad1(scale, bn), bm=bm, bn=bn, bk=bk,
                    interpret=interpret)
    return y[:M, :N]


@partial(jax.jit, static_argnames=("interpret",))
def m2q_matmul_op(xq, act_scale, u_payload, u_scale, u_zp, a_codes, a_scale,
                  interpret: Optional[bool] = None):
    interpret = _interpret_default() if interpret is None else interpret
    M, K = xq.shape
    Nu, Na = u_payload.shape[1], a_codes.shape[1]
    Nh = max(Nu, Na)
    bm, bn, bk = _block(M), _block(Nh), _block(K)
    Nhp = Nh + ((-Nh) % bn)
    xp = _pad2(xq, bm, bk)
    up = _pad2(u_payload, bk, 1)
    up = jnp.pad(up, ((0, 0), (0, Nhp - Nu)))
    ap = jnp.pad(a_codes, ((0, (-K) % bk), (0, Nhp - Na)),
                 constant_values=0x80)
    us = jnp.pad(u_scale.reshape(-1), (0, Nhp - Nu))
    uz = jnp.pad(u_zp.reshape(-1), (0, Nhp - Nu))
    asc = jnp.pad(a_scale.reshape(-1), (0, Nhp - Na))
    yu, ya = m2q_matmul(xp, act_scale, up, us, uz, ap, asc,
                        bm=bm, bn=bn, bk=bk, interpret=interpret)
    return yu[:M, :Nu], ya[:M, :Na]


@partial(jax.jit, static_argnames=("interpret",))
def dwconv_w4_op(x, packed, scale, zero_point,
                 interpret: Optional[bool] = None):
    interpret = _interpret_default() if interpret is None else interpret
    C = x.shape[-1]
    bc = _block(C)
    pc = (-C) % bc
    if pc:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pc)))
        packed = jnp.pad(packed, ((0, 0), (0, pc // 2)))
        scale = jnp.pad(scale, (0, pc))
        zero_point = jnp.pad(zero_point, (0, pc))
    y = dwconv_w4(x, packed, scale, zero_point, bc=bc, interpret=interpret)
    return y[..., :C]


# ---------------------------------------------------------------------------
# QTensor-level entry points (kernel-backed twins of core.qtensor methods)
# ---------------------------------------------------------------------------


def qtensor_matmul(x: jax.Array, qt, interpret: Optional[bool] = None):
    """Kernel-backed y = x @ W for 2-D QTensor leaves; x (..., K)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if isinstance(qt, QM2Q):
        u, a = qt.uniform, qt.apot
        sa = u.act_scale if u.act_scale is not None else jnp.float32(
            jnp.max(jnp.abs(x2)) / 127.0 + 1e-9)
        xq = quantize_act(x2, sa)
        yu, ya = m2q_matmul_op(xq, sa, u.payload, u.scale.reshape(-1),
                               u.zero_point.reshape(-1), a.codes,
                               a.scale.reshape(-1), interpret=interpret)
        y = jnp.concatenate([yu, ya], axis=-1)
        y = jnp.take(y, qt.inv_perm, axis=-1)
    elif isinstance(qt, QUniform) and qt.bits == 8:
        sa = qt.act_scale if qt.act_scale is not None else jnp.float32(
            jnp.max(jnp.abs(x2)) / 127.0 + 1e-9)
        xq = quantize_act(x2, sa)
        y = int8_matmul_op(xq, qt.payload, sa, qt.scale.reshape(-1),
                           qt.zero_point.reshape(-1), interpret=interpret)
    elif isinstance(qt, QUniform) and qt.bits == 4:
        y = int4_matmul_op(x2.astype(jnp.float32), qt.payload,
                           qt.scale.reshape(-1), qt.zero_point.reshape(-1),
                           interpret=interpret)
    elif isinstance(qt, QAPoT):
        y = apot_matmul_op(x2.astype(jnp.float32), qt.codes,
                           qt.scale.reshape(-1), interpret=interpret)
    else:
        raise TypeError(type(qt))
    return y.reshape(*lead, y.shape[-1]).astype(x.dtype)
