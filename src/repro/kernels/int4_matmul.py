"""W4 (nibble-packed) weights-only matmul kernel — the MPMA *single mode*
path generalized to memory-intensive dense layers (embeddings / decode-shape
matmuls).

The 4-bit payload stays packed in HBM and through the BlockSpec pipeline;
nibbles are unpacked *in VMEM* right before the MXU dot — the HBM win the
paper's 4-bit weight buffers target (Table VI).  Activations stay bf16/f32
(weights-only quantization: the memory-intensive layers are bandwidth-, not
compute-, limited).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _unpack_nibbles(packed: jax.Array) -> jax.Array:
    """(bk, bn/2) uint8 -> (bk, bn) f32 codes in 0..15 (even idx = low)."""
    lo = (packed & 0x0F).astype(jnp.float32)
    hi = ((packed >> 4) & 0x0F).astype(jnp.float32)
    bk, half = packed.shape
    out = jnp.stack([lo, hi], axis=-1)  # (bk, bn/2, 2)
    return out.reshape(bk, 2 * half)


def _kernel(x_ref, wp_ref, wscale_ref, zp_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = _unpack_nibbles(wp_ref[...])
    w = (q - zp_ref[...]) * wscale_ref[...]
    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32), w,
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        o_ref[...] = acc_ref[...]


def int4_matmul(x: jax.Array, packed: jax.Array, scale: jax.Array,
                zero_point: jax.Array,
                *, bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool = False) -> jax.Array:
    """x (M,K) f32/bf16; packed (K,N/2) uint8; scale/zp (N,) -> (M,N) f32."""
    M, K = x.shape
    N = packed.shape[1] * 2
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn // 2), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, packed, scale.reshape(1, -1), zero_point.reshape(1, -1))
