# Pallas TPU kernels for the compute classes the paper's accelerator serves:
#   int8_matmul     — MPMA merged mode (W8A8, zero-point folded epilogue)
#   int4_matmul     — MPMA single-mode bandwidth path (nibble-packed weights)
#   apot_matmul     — SAT engine (APoT byte codes decoded in VMEM)
#   m2q_matmul      — fused MPMA+SAT (the two-level mixed layer, 1:1 split)
#   dwconv_w4       — 4-bit depthwise conv (the paper's memory-intensive case)
#   relu_attn       — fused int8 ReLU linear attention (EfficientViT MSA)
#   decode_attn_int8 — int8-KV decode attention (serving per-step hot loop)
# ops.py: jit'd wrappers (padding/dispatch); ref.py: pure-jnp oracles.
from .ops import (
    DispatchConfig,
    apot_matmul_op,
    decode_attn_int8_op,
    dispatch,
    dwconv_w4_op,
    int4_matmul_op,
    int8_matmul_op,
    m2q_matmul_op,
    qtensor_dwconv,
    qtensor_matmul,
    relu_attn_op,
)

__all__ = [
    "DispatchConfig", "apot_matmul_op", "decode_attn_int8_op", "dispatch",
    "dwconv_w4_op", "int4_matmul_op", "int8_matmul_op", "m2q_matmul_op",
    "qtensor_dwconv", "qtensor_matmul", "relu_attn_op",
]
