"""W8A8 integer matmul kernel (the MPMA *merged mode*, paper Sec. IV-1b).

Grid (M/bm, N/bn, K/bk); int32 accumulation in a VMEM scratch; the
activation row-sum (for the asymmetric-weight zero-point fold) accumulates
alongside; the float epilogue (zero-point correction + act*weight scales)
runs on the last K step so the integer tiles never round-trip to HBM.

Fused activation quantization: x arrives in FLOAT, the layer-wise max-abs
scale is a scalar operand, and the int8 rounding runs in the prologue on
the VMEM tile — the quantized activation never exists as a separate HBM
array (the XLA quantize pass this kernel used to depend on is gone).

MXU alignment: block shapes default to 128x128x128 (int8 MXU-native on
v5e); the ops.py wrapper pads inputs to block multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.quant import quantize_act
from .compat import CompilerParams


def _kernel(x_ref, w_ref, ascale_ref, wscale_ref, zp_ref, o_ref,
            acc_ref, xsum_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        xsum_ref[...] = jnp.zeros_like(xsum_ref)

    sa = ascale_ref[0, 0]
    # fused activation quantization: float tile -> int8 in VMEM (pure-jnp
    # quantize_act runs inside the kernel body, so kernel and XLA/ref paths
    # share one rounding definition)
    xq = quantize_act(x_ref[...].astype(jnp.float32), sa)
    acc_ref[...] += jax.lax.dot_general(
        xq, w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    xsum_ref[...] += jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        corr = xsum_ref[...].astype(jnp.float32) * zp_ref[...]
        o_ref[...] = (acc - corr) * (sa * wscale_ref[...])


def int8_matmul(x: jax.Array, wq: jax.Array, act_scale: jax.Array,
                scale: jax.Array, zero_point: jax.Array,
                *, bm: int = 128, bn: int = 128, bk: int = 128,
                interpret: bool = False) -> jax.Array:
    """x (M,K) float; wq (K,N) int8; scale/zp (N,) f32 -> y (M,N) f32.

    Shapes must be pre-padded to block multiples (ops.py does this).
    """
    M, K = x.shape
    N = wq.shape[1]
    nk = K // bk
    grid = (M // bm, N // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.int32),
            pltpu.VMEM((bm, 1), jnp.int32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wq, act_scale.reshape(1, 1), scale.reshape(1, -1),
      zero_point.reshape(1, -1))
