"""Small cross-version Pallas/TPU compatibility surface.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; the
container pins a version on the old name.  Kernels import from here so the
rename is absorbed in one place.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams")
