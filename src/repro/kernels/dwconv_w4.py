"""4-bit depthwise conv kernel (the MPMA *single mode*, paper Sec. IV-1a).

DWConv is the paper's memory-intensive class: one weight channel per filter,
no cross-filter input reuse — so the win is bandwidth, exactly what 4-bit
weights buy (Table II shows 4-bit is accuracy-free).  The packed nibbles
(kh*kw, C/2) stay packed across HBM; decode happens once per channel tile in
VMEM; the tap accumulation mirrors the paper's output-parallel dataflow
(partial sums accumulate across taps in registers, never leaving VMEM).

The kernel is parameterized over the kernel window (kh, kw) and stride so it
serves BOTH EfficientViT depthwise shapes: the MBConv 3x3 (stride 1 and the
stride-2 stage-entry downsamplers) and the MSA 5x5 multi-scale aggregation.
SAME padding is applied by the wrapper (XLA conventions: asymmetric for
even-sized windows under stride), so the kernel body only sees the padded
tile and accumulates kh*kw strided taps.

Grid: (B, C/bc) — channels are the parallel dim (the paper's "blocks within
a PE tile compute different channels").  H/W stay whole per block (edge
models are 224x224; H-tiling is a recorded follow-up for larger maps).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams


def same_padding(size: int, k: int, stride: int) -> Tuple[int, int]:
    """XLA SAME padding (lo, hi) for one spatial dim."""
    out = -(-size // stride)  # ceil
    total = max((out - 1) * stride + k - size, 0)
    lo = total // 2
    return lo, total - lo


def _kernel(x_ref, wp_ref, scale_ref, zp_ref, o_ref, *, KH: int, KW: int,
            HO: int, WO: int, stride: int):
    lo = (wp_ref[...] & 0x0F).astype(jnp.float32)
    hi = ((wp_ref[...] >> 4) & 0x0F).astype(jnp.float32)
    q = jnp.stack([lo, hi], axis=-1).reshape(KH * KW, -1)  # (kh*kw, bc)
    w = (q - zp_ref[...]) * scale_ref[...]  # decode once per channel tile
    x = x_ref[0].astype(jnp.float32)  # (HI, WI, bc), SAME-padded
    acc = jnp.zeros((HO, WO, x.shape[-1]), jnp.float32)
    s = stride
    for i in range(KH):
        for j in range(KW):
            tap = x[i:i + (HO - 1) * s + 1:s, j:j + (WO - 1) * s + 1:s]
            acc = acc + tap * w[KW * i + j]
    o_ref[0] = acc


def dwconv_w4(x: jax.Array, packed: jax.Array, scale: jax.Array,
              zero_point: jax.Array, *, kh: int = 3, kw: int = 3,
              stride: int = 1, bc: int = 128,
              interpret: bool = False) -> jax.Array:
    """x (B,H,W,C) (unpadded); packed (kh*kw, C/2) uint8; scale/zp (C,) f32.

    Returns (B,HO,WO,C) f32 — depthwise kh x kw, SAME padding, stride >= 1.
    """
    B, H, W, C = x.shape
    assert packed.shape[0] == kh * kw, (packed.shape, kh, kw)
    bc = min(bc, C)
    assert C % bc == 0 and bc % 2 == 0
    ph = same_padding(H, kh, stride)
    pw = same_padding(W, kw, stride)
    HO = -(-H // stride)
    WO = -(-W // stride)
    xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    HI, WI = xp.shape[1], xp.shape[2]
    grid = (B, C // bc)
    return pl.pallas_call(
        functools.partial(_kernel, KH=kh, KW=kw, HO=HO, WO=WO, stride=stride),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, HI, WI, bc), lambda b, c: (b, 0, 0, c)),
            pl.BlockSpec((kh * kw, bc // 2), lambda b, c: (0, c)),
            pl.BlockSpec((1, bc), lambda b, c: (0, c)),
            pl.BlockSpec((1, bc), lambda b, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, HO, WO, bc), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, HO, WO, C), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xp, packed, scale.reshape(1, -1), zero_point.reshape(1, -1))
