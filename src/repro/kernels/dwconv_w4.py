"""4-bit depthwise conv kernel (the MPMA *single mode*, paper Sec. IV-1a).

DWConv is the paper's memory-intensive class: one weight channel per filter,
no cross-filter input reuse — so the win is bandwidth, exactly what 4-bit
weights buy (Table II shows 4-bit is accuracy-free).  The packed nibbles
(kh*kw, C/2) stay packed across HBM; decode happens once per channel tile in
VMEM; the tap accumulation mirrors the paper's output-parallel dataflow
(partial sums accumulate across taps in registers, never leaving VMEM).

The kernel is parameterized over the kernel window (kh, kw) and stride so it
serves BOTH EfficientViT depthwise shapes: the MBConv 3x3 (stride 1 and the
stride-2 stage-entry downsamplers) and the MSA 5x5 multi-scale aggregation.

Grid: (B, H-tiles, C/bc) — channels are the parallel dim (the paper's
"blocks within a PE tile compute different channels") and the output H axis
is tiled in blocks of ``bh`` rows.  Each input block carries its halo: the
``bh`` output rows of tile ``t`` consume input rows
``[t*bh*stride, t*bh*stride + (bh-1)*stride + kh)``, so consecutive input
blocks OVERLAP by ``kh - stride`` rows.  Overlap is expressed with
``pl.Unblocked`` element-offset indexing (a blocked BlockSpec can only step
by whole blocks); the per-block VMEM footprint is bounded by the tile, not
the feature map, so arbitrary-resolution maps (R256/R384/R512, detection
sizes) run the packed-w4 kernel — the old whole-map VMEM guard is gone.

Two padding modes:

* ``fuse_pad=False`` — the wrapper materializes XLA SAME padding once
  (asymmetric for even windows under stride, matching
  ``lax.conv_general_dilated``) and the kernel body only sees padded tiles.
* ``fuse_pad=True`` — the *unpadded* map is handed to ``pallas_call`` and
  SAME padding fuses into the kernel: ``pl.Unblocked(padding=...)`` extends
  the logical index space (the DMA engine serves the halo; the pad region
  is UNINITIALIZED, not zero) and the body masks every tap against the real
  [0,H)x[0,W) bounds with iota predicates — selects, not multiplies, so
  uninitialized pad bytes (even NaN) never reach the accumulator.  This is
  the stride-2 MBConv stage-entry path: downsamplers no longer re-pad
  (an HBM round-trip of the full map) outside the kernel.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams


def same_padding(size: int, k: int, stride: int) -> Tuple[int, int]:
    """XLA SAME padding (lo, hi) for one spatial dim."""
    out = -(-size // stride)  # ceil
    total = max((out - 1) * stride + k - size, 0)
    lo = total // 2
    return lo, total - lo


def _decode_w4(wp_ref, scale_ref, zp_ref, KH: int, KW: int) -> jax.Array:
    """Unpack the (kh*kw, bc/2) nibble tile to (kh*kw, bc) f32 weights —
    once per grid step, in VMEM."""
    lo = (wp_ref[...] & 0x0F).astype(jnp.float32)
    hi = ((wp_ref[...] >> 4) & 0x0F).astype(jnp.float32)
    q = jnp.stack([lo, hi], axis=-1).reshape(KH * KW, -1)
    return (q - zp_ref[...]) * scale_ref[...]


def _kernel(x_ref, wp_ref, scale_ref, zp_ref, o_ref, *, KH: int, KW: int,
            BH: int, WO: int, stride: int):
    """Pre-padded variant: the block is SAME-padded rows, taps are pure
    strided slices."""
    w = _decode_w4(wp_ref, scale_ref, zp_ref, KH, KW)
    x = x_ref[0].astype(jnp.float32)  # (BH_in, WI, bc), SAME-padded
    acc = jnp.zeros((BH, WO, x.shape[-1]), jnp.float32)
    s = stride
    for i in range(KH):
        for j in range(KW):
            tap = x[i:i + (BH - 1) * s + 1:s, j:j + (WO - 1) * s + 1:s]
            acc = acc + tap * w[KW * i + j]
    o_ref[0] = acc


def _kernel_fused_pad(x_ref, wp_ref, scale_ref, zp_ref, o_ref, *, KH: int,
                      KW: int, BH: int, WO: int, stride: int, H: int, W: int,
                      ph_lo: int, pw_lo: int):
    """Fused-pad variant: the block indexes the logically padded map (pad
    region uninitialized) and every tap is masked against the real bounds.
    Padded-coordinate input row of output row r, tap i:  r*stride + i;
    the unpadded row is that minus ph_lo — valid iff in [0, H)."""
    t = pl.program_id(1)
    w = _decode_w4(wp_ref, scale_ref, zp_ref, KH, KW)
    x = x_ref[0].astype(jnp.float32)  # (BH_in, WI, bc), halo'd + pad garbage
    acc = jnp.zeros((BH, WO, x.shape[-1]), jnp.float32)
    s = stride
    row = jax.lax.broadcasted_iota(jnp.int32, (BH, WO), 0)  # out row in tile
    col = jax.lax.broadcasted_iota(jnp.int32, (BH, WO), 1)  # out col
    for i in range(KH):
        for j in range(KW):
            tap = x[i:i + (BH - 1) * s + 1:s, j:j + (WO - 1) * s + 1:s]
            gr = (t * BH + row) * s + i - ph_lo  # unpadded input row
            gc = col * s + j - pw_lo             # unpadded input col
            ok = (gr >= 0) & (gr < H) & (gc >= 0) & (gc < W)
            acc = acc + jnp.where(ok[..., None], tap, 0.0) * w[KW * i + j]
    o_ref[0] = acc


def dwconv_w4(x: jax.Array, packed: jax.Array, scale: jax.Array,
              zero_point: jax.Array, *, kh: int = 3, kw: int = 3,
              stride: int = 1, bh: Optional[int] = None, bc: int = 128,
              fuse_pad: bool = False, interpret: bool = False) -> jax.Array:
    """x (B,H,W,C) (unpadded); packed (kh*kw, C/2) uint8; scale/zp (C,) f32.

    Returns (B,HO,WO,C) f32 — depthwise kh x kw, SAME padding, stride >= 1.
    ``bh``: output rows per H-tile (None = whole map in one tile); ``bc``:
    channels per tile.  ``fuse_pad``: SAME-pad inside the kernel instead of
    materializing a padded copy (see module docstring).
    """
    B, H, W, C = x.shape
    assert packed.shape[0] == kh * kw, (packed.shape, kh, kw)
    bc = min(bc, C)
    assert C % bc == 0 and bc % 2 == 0
    ph = same_padding(H, kh, stride)
    pw = same_padding(W, kw, stride)
    HO = -(-H // stride)
    WO = -(-W // stride)
    bh = HO if bh is None else max(1, min(bh, HO))
    T = -(-HO // bh)                      # H-tiles
    step = bh * stride                    # input rows consumed per tile
    bh_in = (bh - 1) * stride + kh        # input rows read per tile (halo'd)
    WI = W + pw[0] + pw[1]
    # rows the LAST tile reads, in padded coordinates; pad the bottom so
    # every unblocked read stays in bounds (zero rows only ever feed output
    # rows >= HO, which are sliced away)
    hi_need = (T - 1) * step + bh_in
    grid = (B, T, C // bc)
    if fuse_pad:
        pad_bot = max(hi_need - ph[0] - H, 0)
        in_spec = pl.BlockSpec(
            (1, bh_in, WI, bc), lambda b, t, c: (b, t * step, 0, c * bc),
            indexing_mode=pl.Unblocked(
                ((0, 0), (ph[0], pad_bot), pw, (0, 0))))
        body = functools.partial(_kernel_fused_pad, KH=kh, KW=kw, BH=bh,
                                 WO=WO, stride=stride, H=H, W=W,
                                 ph_lo=ph[0], pw_lo=pw[0])
        operand = x
    else:
        xp = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
        extra = hi_need - xp.shape[1]
        if extra > 0:
            xp = jnp.pad(xp, ((0, 0), (0, extra), (0, 0), (0, 0)))
        in_spec = pl.BlockSpec(
            (1, bh_in, WI, bc), lambda b, t, c: (b, t * step, 0, c * bc),
            indexing_mode=pl.unblocked)
        body = functools.partial(_kernel, KH=kh, KW=kw, BH=bh, WO=WO,
                                 stride=stride)
        operand = xp
    y = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            in_spec,
            pl.BlockSpec((kh * kw, bc // 2), lambda b, t, c: (0, c)),
            pl.BlockSpec((1, bc), lambda b, t, c: (0, c)),
            pl.BlockSpec((1, bc), lambda b, t, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, bh, WO, bc), lambda b, t, c: (b, t, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, T * bh, WO, C), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel")),
        interpret=interpret,
    )(operand, packed, scale.reshape(1, -1), zero_point.reshape(1, -1))
    return y[:, :HO]
