"""4-bit depthwise 3x3 conv kernel (the MPMA *single mode*, paper Sec. IV-1a).

DWConv is the paper's memory-intensive class: one weight channel per filter,
no cross-filter input reuse — so the win is bandwidth, exactly what 4-bit
weights buy (Table II shows 4-bit is accuracy-free).  The packed nibbles
(9, C/2) stay packed across HBM; decode happens once per channel tile in
VMEM; the 9-tap accumulation mirrors the paper's output-parallel dataflow
(partial sums accumulate across taps in registers, never leaving VMEM).

Grid: (B, C/bc) — channels are the parallel dim (the paper's "blocks within
a PE tile compute different channels").  H/W stay whole per block (edge
models are 224x224; H-tiling is a recorded follow-up for larger maps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .compat import CompilerParams


def _kernel(x_ref, wp_ref, scale_ref, zp_ref, o_ref, *, H: int, W: int):
    lo = (wp_ref[...] & 0x0F).astype(jnp.float32)
    hi = ((wp_ref[...] >> 4) & 0x0F).astype(jnp.float32)
    q = jnp.stack([lo, hi], axis=-1).reshape(9, -1)  # (9, bc)
    w = (q - zp_ref[...]) * scale_ref[...]  # decode once per channel tile
    x = x_ref[0].astype(jnp.float32)  # (H+2, W+2, bc)
    acc = jnp.zeros((H, W, x.shape[-1]), jnp.float32)
    for i in range(3):
        for j in range(3):
            acc = acc + x[i:i + H, j:j + W] * w[3 * i + j]
    o_ref[0] = acc


def dwconv_w4(x: jax.Array, packed: jax.Array, scale: jax.Array,
              zero_point: jax.Array, *, bc: int = 128,
              interpret: bool = False) -> jax.Array:
    """x (B,H,W,C) (unpadded); packed (9, C/2) uint8; scale/zp (C,) f32.

    Returns (B,H,W,C) f32 — depthwise 3x3, stride 1, SAME.
    """
    B, H, W, C = x.shape
    bc = min(bc, C)
    assert C % bc == 0 and bc % 2 == 0
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    grid = (B, C // bc)
    return pl.pallas_call(
        functools.partial(_kernel, H=H, W=W),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H + 2, W + 2, bc), lambda b, c: (b, 0, 0, c)),
            pl.BlockSpec((9, bc // 2), lambda b, c: (0, c)),
            pl.BlockSpec((1, bc), lambda b, c: (0, c)),
            pl.BlockSpec((1, bc), lambda b, c: (0, c)),
        ],
        out_specs=pl.BlockSpec((1, H, W, bc), lambda b, c: (b, 0, 0, c)),
        out_shape=jax.ShapeDtypeStruct((B, H, W, C), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xp, packed, scale.reshape(1, -1), zero_point.reshape(1, -1))
