"""Pallas decode attention over the int8 KV cache (serving per-step hot loop).

``nn.attention.decode_attention_int8`` already runs the fully-integer math
(int8 QK^T, per-row K scales folded into the scores, softmax weights
requantized to int8 for the PV dot) but as unfused XLA einsums: the (B,T,
Hkv,D) score/probability intermediates round-trip HBM every decode step.
This kernel executes the identical computation per (batch, kv-head) pair in
one VMEM pass over that sequence's cache rows — the same quantization
definitions, in the same order, so the kernel and the XLA path agree to
float-rounding tolerance.

Grid: (B, Hkv), both parallel; T (the cache length, bounded by the engine's
``max_len``) and the G = Hq/Hkv query group stay whole per block — decode
caches are small (B, T<=max_len, D) slabs, unlike the unbounded spatial
maps that force tiling elsewhere.  The wrapper may zero-pad T; padded rows
sit at positions >= ``lengths`` and are masked exactly like unfilled cache
rows.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .compat import CompilerParams

NEG_INF = -1.0e30  # matches nn.attention's finite mask


def _kernel(q_ref, k_ref, v_ref, ks_ref, vs_ref, len_ref, o_ref, *,
            T: int, scale: float, window: Optional[int]):
    qh = q_ref[0, 0].astype(jnp.float32)                      # (G, D)
    # per-(b,h,g) on-the-fly q quantization — same expression as the XLA path
    q_s = jnp.max(jnp.abs(qh), axis=-1, keepdims=True) / 127.0 + 1e-9
    q8 = jnp.clip(jnp.round(qh / q_s), -127, 127).astype(jnp.int32)
    k8 = k_ref[0, :, 0, :].astype(jnp.int32)                  # (T, D)
    acc = jax.lax.dot_general(q8, k8, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)  # (G, T)
    s = acc.astype(jnp.float32) * q_s * scale * ks_ref[0, :, 0][None, :]
    length = len_ref[0, 0]
    pos = jax.lax.broadcasted_iota(jnp.int32, (1, T), 1)
    valid = pos < length
    if window is not None:
        valid &= pos >= (length - window)
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fold per-row V scales into p, requantize, int8 PV dot
    pv = p * vs_ref[0, :, 0][None, :]
    p_s = jnp.max(jnp.abs(pv), axis=-1, keepdims=True) / 127.0 + 1e-12
    p8 = jnp.clip(jnp.round(pv / p_s), -127, 127).astype(jnp.int32)
    v8 = v_ref[0, :, 0, :].astype(jnp.int32)                  # (T, D)
    out = jax.lax.dot_general(p8, v8, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)  # (G, D)
    o_ref[0, 0] = out.astype(jnp.float32) * p_s


def decode_attn_int8(q: jax.Array, k_q: jax.Array, v_q: jax.Array,
                     k_scale: jax.Array, v_scale: jax.Array,
                     lengths: jax.Array, *, scale: float,
                     window: Optional[int] = None,
                     interpret: bool = False) -> jax.Array:
    """q (B,Hkv,G,D) float; k_q/v_q (B,T,Hkv,D) int8; k_scale/v_scale
    (B,T,Hkv) f32 per-row; lengths (B,1) int32 -> out (B,Hkv,G,D) f32."""
    B, Hkv, G, D = q.shape
    T = k_q.shape[1]
    grid = (B, Hkv)
    cache_spec = pl.BlockSpec((1, T, 1, D), lambda b, h: (b, 0, h, 0))
    rows_spec = pl.BlockSpec((1, T, 1), lambda b, h: (b, 0, h))
    return pl.pallas_call(
        functools.partial(_kernel, T=T, scale=scale, window=window),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
            cache_spec,
            cache_spec,
            rows_spec,
            rows_spec,
            pl.BlockSpec((1, 1), lambda b, h: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), jnp.float32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(q, k_q, v_q, k_scale, v_scale, lengths)
