"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These mirror the QTensor XLA paths bit-for-bit (same zero-point folding,
same APoT decode), so kernel tests triangulate kernel == ref == QTensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import packing


def int8_matmul_ref(xq: jax.Array, wq: jax.Array, act_scale: jax.Array,
                    scale: jax.Array, zero_point: jax.Array) -> jax.Array:
    """xq (M,K) int8; wq (K,N) int8 (offset-folded); scale/zp (N,) f32.

    y = (xq @ wq - rowsum(xq) * zp) * act_scale * scale
    """
    acc = jax.lax.dot_general(xq, wq, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    xsum = jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
    y = acc.astype(jnp.float32) - xsum.astype(jnp.float32) * zero_point[None, :]
    return y * (act_scale * scale[None, :])


def int4_matmul_ref(x: jax.Array, packed: jax.Array, scale: jax.Array,
                    zero_point: jax.Array) -> jax.Array:
    """x (M,K) f32; packed (K,N/2) uint8 nibbles; scale/zp (N,) f32.

    Weights-only 4-bit: y = x @ ((unpack(packed) - zp) * scale).
    """
    q = packing.unpack_int4(packed).astype(jnp.float32)
    w = (q - zero_point[None, :]) * scale[None, :]
    return x @ w


def apot_matmul_ref(x: jax.Array, codes: jax.Array,
                    scale: jax.Array) -> jax.Array:
    """x (M,K) f32; codes (K,N) uint8 APoT bytes; scale (N,) f32.

    y = (x @ decode(codes)) * scale   (decode = s*(2^-e1 + 2^-e2), 0-aware)
    """
    vals = packing.apot_decode_values(codes, dtype=jnp.float32)
    return (x @ vals) * scale[None, :]


def m2q_merged_ref(x: jax.Array, act_scale: jax.Array, payload: jax.Array,
                   u_scale: jax.Array, u_zp: jax.Array,
                   a_scale: jax.Array) -> jax.Array:
    """Permutation-free merged-layout oracle (mirrors kernels.m2q_matmul).

    x (M,K) FLOAT — activation quantization is part of the contract (the
    kernel fuses it into its prologue); payload (K,N) int8 merged bytes;
    scales (N,) zero-masked per column.  Returns y (M,N) f32 in original
    filter order.
    """
    from ..core.quant import quantize_act
    xq = quantize_act(x, act_scale)
    acc = jax.lax.dot_general(xq, payload, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    xsum = jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
    yu = (acc.astype(jnp.float32)
          - xsum.astype(jnp.float32) * u_zp[None, :]) * u_scale[None, :]
    codes = jax.lax.bitcast_convert_type(payload, jnp.uint8)
    vals = packing.apot_decode_values(codes, dtype=jnp.float32)
    ya = (xq.astype(jnp.float32) @ vals) * a_scale[None, :]
    return (yu + ya) * act_scale


def relu_attn_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  sq: jax.Array, sk: jax.Array, sv: jax.Array,
                  eps: float = 1e-6) -> jax.Array:
    """Int8 ReLU linear attention oracle (mirrors kernels.relu_attn).

    q/k/v (B,N,H,D) FLOAT, sq/sk/sv scalar act scales — ReLU + int8
    rounding are part of the contract (the kernel fuses them into its
    prologue).  kv/ksum accumulate in int32; kv is requantized to int8
    range per (b, h) so the numerator contraction is also integer; the
    epilogue applies ``num / (den + eps)`` on the rescaled accumulators.
    """
    from ..core.quant import quantize_act
    q8 = quantize_act(jax.nn.relu(q.astype(jnp.float32)), sq).astype(jnp.int32)
    k8 = quantize_act(jax.nn.relu(k.astype(jnp.float32)), sk).astype(jnp.int32)
    v8 = quantize_act(v.astype(jnp.float32), sv).astype(jnp.int32)
    kv32 = jnp.einsum("bnhd,bnhe->bhde", k8, v8,
                      preferred_element_type=jnp.int32)
    ksum = jnp.sum(k8, axis=1)                                   # (B,H,D)
    kv_f = kv32.astype(jnp.float32) * (sk * sv)
    skv = jnp.maximum(jnp.max(jnp.abs(kv_f), axis=(-2, -1), keepdims=True)
                      / 127.0, 1e-8)                             # (B,H,1,1)
    kv8 = jnp.clip(jnp.round(kv_f / skv), -127, 127).astype(jnp.int32)
    num = jnp.einsum("bnhd,bhde->bnhe", q8, kv8,
                     preferred_element_type=jnp.int32)
    den = jnp.einsum("bnhd,bhd->bnh", q8, ksum,
                     preferred_element_type=jnp.int32)[..., None]
    num_f = num.astype(jnp.float32) * (sq * skv.transpose(0, 2, 1, 3))
    den_f = den.astype(jnp.float32) * (sq * sk)
    return num_f / (den_f + eps)


def dwconv_w4_ref(x: jax.Array, packed: jax.Array, scale: jax.Array,
                  zero_point: jax.Array, kh: int = 3, kw: int = 3,
                  stride: int = 1) -> jax.Array:
    """Depthwise kh x kw, SAME padding. x (B,H,W,C); packed (kh*kw, C/2)
    uint8; scale/zp (C,) f32 (per-filter = per-channel for DWConv)."""
    from .dwconv_w4 import same_padding
    q = packing.unpack_int4(packed.reshape(kh * kw, -1)).astype(jnp.float32)
    w = ((q - zero_point[None, :]) * scale[None, :]).reshape(kh, kw, -1)
    H, W = x.shape[1], x.shape[2]
    xp = jnp.pad(x, ((0, 0), same_padding(H, kh, stride),
                     same_padding(W, kw, stride), (0, 0)))
    HO, WO = -(-H // stride), -(-W // stride)
    out = jnp.zeros((x.shape[0], HO, WO, x.shape[-1]), jnp.float32)
    s = stride
    for i in range(kh):
        for j in range(kw):
            tap = xp[:, i:i + (HO - 1) * s + 1:s, j:j + (WO - 1) * s + 1:s]
            out = out + tap.astype(jnp.float32) * w[i, j]
    return out
