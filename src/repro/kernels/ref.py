"""Pure-jnp oracles for every Pallas kernel (the allclose targets).

These mirror the QTensor XLA paths bit-for-bit (same zero-point folding,
same APoT decode), so kernel tests triangulate kernel == ref == QTensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import packing


def int8_matmul_ref(xq: jax.Array, wq: jax.Array, act_scale: jax.Array,
                    scale: jax.Array, zero_point: jax.Array) -> jax.Array:
    """xq (M,K) int8; wq (K,N) int8 (offset-folded); scale/zp (N,) f32.

    y = (xq @ wq - rowsum(xq) * zp) * act_scale * scale
    """
    acc = jax.lax.dot_general(xq, wq, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    xsum = jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
    y = acc.astype(jnp.float32) - xsum.astype(jnp.float32) * zero_point[None, :]
    return y * (act_scale * scale[None, :])


def int4_matmul_ref(x: jax.Array, packed: jax.Array, scale: jax.Array,
                    zero_point: jax.Array) -> jax.Array:
    """x (M,K) f32; packed (K,N/2) uint8 nibbles; scale/zp (N,) f32.

    Weights-only 4-bit: y = x @ ((unpack(packed) - zp) * scale).
    """
    q = packing.unpack_int4(packed).astype(jnp.float32)
    w = (q - zero_point[None, :]) * scale[None, :]
    return x @ w


def apot_matmul_ref(x: jax.Array, codes: jax.Array,
                    scale: jax.Array) -> jax.Array:
    """x (M,K) f32; codes (K,N) uint8 APoT bytes; scale (N,) f32.

    y = (x @ decode(codes)) * scale   (decode = s*(2^-e1 + 2^-e2), 0-aware)
    """
    vals = packing.apot_decode_values(codes, dtype=jnp.float32)
    return (x @ vals) * scale[None, :]


def m2q_merged_ref(x: jax.Array, act_scale: jax.Array, payload: jax.Array,
                   u_scale: jax.Array, u_zp: jax.Array,
                   a_scale: jax.Array) -> jax.Array:
    """Permutation-free merged-layout oracle (mirrors kernels.m2q_matmul).

    x (M,K) FLOAT — activation quantization is part of the contract (the
    kernel fuses it into its prologue); payload (K,N) int8 merged bytes;
    scales (N,) zero-masked per column.  Returns y (M,N) f32 in original
    filter order.
    """
    from ..core.quant import quantize_act
    xq = quantize_act(x, act_scale)
    acc = jax.lax.dot_general(xq, payload, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    xsum = jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
    yu = (acc.astype(jnp.float32)
          - xsum.astype(jnp.float32) * u_zp[None, :]) * u_scale[None, :]
    codes = jax.lax.bitcast_convert_type(payload, jnp.uint8)
    vals = packing.apot_decode_values(codes, dtype=jnp.float32)
    ya = (xq.astype(jnp.float32) @ vals) * a_scale[None, :]
    return (yu + ya) * act_scale


def dwconv_w4_ref(x: jax.Array, packed: jax.Array, scale: jax.Array,
                  zero_point: jax.Array, kh: int = 3, kw: int = 3,
                  stride: int = 1) -> jax.Array:
    """Depthwise kh x kw, SAME padding. x (B,H,W,C); packed (kh*kw, C/2)
    uint8; scale/zp (C,) f32 (per-filter = per-channel for DWConv)."""
    from .dwconv_w4 import same_padding
    q = packing.unpack_int4(packed.reshape(kh * kw, -1)).astype(jnp.float32)
    w = ((q - zero_point[None, :]) * scale[None, :]).reshape(kh, kw, -1)
    H, W = x.shape[1], x.shape[2]
    xp = jnp.pad(x, ((0, 0), same_padding(H, kh, stride),
                     same_padding(W, kw, stride), (0, 0)))
    HO, WO = -(-H // stride), -(-W // stride)
    out = jnp.zeros((x.shape[0], HO, WO, x.shape[-1]), jnp.float32)
    s = stride
    for i in range(kh):
        for j in range(kw):
            tap = xp[:, i:i + (HO - 1) * s + 1:s, j:j + (WO - 1) * s + 1:s]
            out = out + tap.astype(jnp.float32) * w[i, j]
    return out
