"""PTQ activation calibration (paper Sec. V-A: 1024 calibration samples).

Mechanism: quantizable weight leaves are wrapped in :class:`CalibTensor`; the
model is then run *unjitted* on calibration batches.  ``nn.dense`` (and the
conv/gather helpers) recognize the wrapper, record the running max-abs of the
incoming activation under the weight's tree path, and compute the normal
float op.  No name plumbing is needed inside model code.

The collected stats feed ``core.apply.quantize_model``, which bakes per-layer
activation scales into the QTensors (8-bit symmetric, layer-wise — Eq. 1-2
applied tensor-wise as in FQ-ViT).
"""
from __future__ import annotations

from typing import Callable, Dict, Iterable, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CalibTensor:
    """Float weight + observer hook.  NOT a pytree leaf — calibration runs
    outside jit by construction (PTQ is offline)."""

    __slots__ = ("w", "key", "store")

    def __init__(self, w: jax.Array, key: str, store: Dict[str, float]):
        self.w = w
        self.key = key
        self.store = store

    # duck-typed accessors so layer code can be agnostic
    @property
    def shape(self):
        return self.w.shape

    @property
    def dtype(self):
        return self.w.dtype

    def __getitem__(self, i):
        """Slicing a stacked (per-layer) weight keeps per-slice stats keys
        ('path@i') — used by the unrolled calibration forward pass."""
        return CalibTensor(self.w[i], f"{self.key}@{i}", self.store)

    def record(self, x: jax.Array) -> None:
        if isinstance(jnp.asarray(x), jax.core.Tracer):
            raise RuntimeError(
                "Calibration must run unjitted (CalibTensor saw a tracer). "
                "Call the model apply function directly for PTQ calibration.")
        m = float(jnp.max(jnp.abs(x)))
        if not np.isfinite(m):
            # a NaN/Inf activation would silently bake a garbage scale into
            # the QTensor (NaN scales poison EVERY later inference); name
            # the offending layer so the bad calibration batch is findable
            raise ValueError(
                f"non-finite activation statistic at {self.key!r}: "
                f"max|x| = {m} over shape {tuple(jnp.shape(x))}; "
                "calibration inputs must be finite (check the calibration "
                "batch and any upstream preprocessing)")
        self.store[self.key] = max(self.store.get(self.key, 0.0), m)


def path_str(path) -> str:
    """Canonical '/'-joined string for a jax tree path."""
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def wrap_for_calibration(
    params, match: Callable[[str, jax.Array], bool]
) -> Tuple[object, Dict[str, float]]:
    """Replace every leaf with ``match(path, leaf)`` by a CalibTensor.

    Returns (wrapped_params, stats_store); the store fills in as the model is
    applied to calibration batches.
    """
    store: Dict[str, float] = {}

    def wrap(path, leaf):
        key = path_str(path)
        if isinstance(leaf, jax.Array) and match(key, leaf):
            return CalibTensor(leaf, key, store)
        return leaf

    wrapped = jax.tree_util.tree_map_with_path(wrap, params)
    return wrapped, store


def rule_matcher(rules):
    """Build a wrap_for_calibration ``match`` from a model's QUANT_RULES:
    wrap exactly the leaves quantize_model would touch."""
    from .apply import match_kind  # local import to avoid a cycle
    from . import policy as pol

    def match(key: str, leaf) -> bool:
        kind = match_kind(rules, key)
        return kind is not None and kind != pol.KIND_SKIP and leaf.ndim >= 2

    return match


def run_calibration(
    apply_fn: Callable,
    wrapped_params,
    batches: Iterable,
) -> None:
    """Drive the model over calibration batches (any extra structure in each
    batch is splatted into apply_fn)."""
    for batch in batches:
        if isinstance(batch, dict):
            apply_fn(wrapped_params, **batch)
        elif isinstance(batch, (tuple, list)):
            apply_fn(wrapped_params, *batch)
        else:
            apply_fn(wrapped_params, batch)
