"""Per-filter quantization-scheme selection (paper Eq. 6 + ratio constraint).

For each compute-intensive layer the filters (output channels) are assigned
either 8-bit uniform or APoT quantization by minimizing per-filter MSE.  The
paper additionally fixes a 1:1 APoT:Uniform ratio per layer and aligns it with
the accelerator's engine parallelism; we keep the ratio (it aligns with the
N-tile split of the fused Pallas kernel) and expose the unconstrained Eq. 6
argmin as an option.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from .quant import fake_quant_apot, fake_quant_uniform, filterwise_mse


@dataclasses.dataclass
class SchemeAssignment:
    apot_idx: np.ndarray  # filters quantized with APoT
    uniform_idx: np.ndarray  # filters quantized with 8-bit uniform
    mse_uniform: np.ndarray  # per-filter MSE under uniform
    mse_apot: np.ndarray  # per-filter MSE under APoT

    @property
    def n_filters(self) -> int:
        return len(self.apot_idx) + len(self.uniform_idx)

    @property
    def apot_fraction(self) -> float:
        return len(self.apot_idx) / max(self.n_filters, 1)


def select_schemes(
    w,
    ratio: Optional[float] = 0.5,
    bits_uniform: int = 8,
) -> SchemeAssignment:
    """Assign {APoT, Uniform} per filter of ``w`` (out channels on axis -1).

    ratio=0.5 reproduces the paper's 1:1 hardware-aligned split: the
    ``N*ratio`` filters whose APoT penalty (mse_apot - mse_uniform) is
    smallest go to APoT.  ratio=None is the unconstrained Eq. 6 argmin.
    """
    w = jnp.asarray(w, dtype=jnp.float32)
    mse_u = np.asarray(filterwise_mse(w, fake_quant_uniform(w, bits=bits_uniform), -1))
    mse_a = np.asarray(filterwise_mse(w, fake_quant_apot(w), -1))
    n = w.shape[-1]
    if ratio is None:
        apot_mask = mse_a < mse_u
        apot_idx = np.nonzero(apot_mask)[0]
        uniform_idx = np.nonzero(~apot_mask)[0]
    else:
        n_apot = int(n * ratio)  # floor: matches QM2Q's n//2 split
        # Even split keeps both kernel halves MXU-aligned; an odd remainder
        # goes to the uniform half.
        order = np.argsort(mse_a - mse_u, kind="stable")
        apot_idx = np.sort(order[:n_apot])
        uniform_idx = np.sort(order[n_apot:])
    return SchemeAssignment(
        apot_idx=apot_idx.astype(np.int32),
        uniform_idx=uniform_idx.astype(np.int32),
        mse_uniform=mse_u,
        mse_apot=mse_a,
    )
