# The paper's primary contribution: two-level mixed quantization (M2Q).
#   quant         — uniform (Eq.1-2) / PoT (Eq.3) / APoT (Eq.5) quantizers
#   scheme_select — per-filter MSE scheme assignment (Eq.6) + 1:1 ratio
#   policy        — operational-intensity layer classification
#   packing       — int4 nibble packing + APoT byte codes
#   qtensor       — quantized-weight pytree leaves + XLA execution paths
#   calibrate     — PTQ activation calibration (observer wrapping)
#   apply         — quantize_model: float params -> QTensor params
from .quant import (
    act_scale_from_stats,
    apot_codebook,
    apot_dequantize,
    apot_quantize,
    fake_quant_act,
    fake_quant_apot,
    fake_quant_pot,
    fake_quant_uniform,
    filterwise_mse,
    pot_dequantize,
    pot_quantize,
    quantize_act,
    uniform_dequantize,
    uniform_quantize,
)
from .scheme_select import SchemeAssignment, select_schemes
from .policy import (
    KIND_DENSE,
    KIND_DWCONV,
    KIND_EMBEDDING,
    KIND_EXPERT,
    KIND_HEAD,
    KIND_SKIP,
    DECISION_LOWBIT,
    DECISION_MIXED,
    DECISION_SKIP,
    M2QPolicy,
    PathOverride,
    ShapeCtx,
    decide,
    dense_intensity,
)
from .qtensor import QAPoT, QExpertM2Q, QM2Q, QUniform, is_qtensor, qmatmul, weight_bits
from .calibrate import CalibTensor, run_calibration, wrap_for_calibration
from .apply import LayerReport, fake_quant_model, quantize_model

__all__ = [
    "act_scale_from_stats", "apot_codebook", "apot_dequantize",
    "apot_quantize", "fake_quant_act", "fake_quant_apot", "fake_quant_pot",
    "fake_quant_uniform", "filterwise_mse", "pot_dequantize", "pot_quantize",
    "quantize_act", "uniform_dequantize", "uniform_quantize",
    "SchemeAssignment", "select_schemes",
    "KIND_DENSE", "KIND_DWCONV", "KIND_EMBEDDING", "KIND_EXPERT",
    "KIND_HEAD", "KIND_SKIP", "DECISION_LOWBIT", "DECISION_MIXED",
    "DECISION_SKIP", "M2QPolicy", "PathOverride", "ShapeCtx", "decide",
    "dense_intensity",
    "QAPoT", "QExpertM2Q", "QM2Q", "QUniform", "is_qtensor", "qmatmul",
    "weight_bits",
    "CalibTensor", "run_calibration", "wrap_for_calibration",
    "LayerReport", "fake_quant_model", "quantize_model",
]
