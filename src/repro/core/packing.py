"""Bit-packing for the M2Q storage formats.

* int4: two 4-bit unsigned codes per uint8 (low nibble = even index).  This is
  the storage layout of the 4-bit weight buffers in the paper's accelerator
  (Table VI: "Buffer (4bit)") and the HBM layout our Pallas kernels unpack in
  VMEM.
* APoT codes: one byte per weight — bit7 = zero flag, bit6 = sign (1 =
  negative), bits5..3 = e1, bits2..0 = e2 (e = -p, 3-bit exponents, see
  quant.APOT_EMAX).  Matches the paper's "Buffer (APoT)" 7-bit payload.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .quant import APoTQ, UniformQ

# ---------------------------------------------------------------------------
# int4 packing (packs along the LAST axis; callers reshape as needed)
# ---------------------------------------------------------------------------


def pack_int4(q: jax.Array) -> jax.Array:
    """Pack unsigned 4-bit codes (values 0..15) pairwise along the last axis.

    Last dim must be even; output last dim is halved, dtype uint8.
    """
    if q.shape[-1] % 2:
        raise ValueError(f"last dim must be even to pack int4, got {q.shape}")
    q = q.astype(jnp.uint8)
    lo = q[..., 0::2]
    hi = q[..., 1::2]
    return (lo | (hi << 4)).astype(jnp.uint8)


def unpack_int4(packed: jax.Array) -> jax.Array:
    """Inverse of :func:`pack_int4`; returns uint8 values in 0..15."""
    lo = packed & jnp.uint8(0x0F)
    hi = (packed >> 4) & jnp.uint8(0x0F)
    out = jnp.stack([lo, hi], axis=-1)
    return out.reshape(*packed.shape[:-1], packed.shape[-1] * 2)


# ---------------------------------------------------------------------------
# APoT code bytes
# ---------------------------------------------------------------------------

_ZERO_BIT = jnp.uint8(0x80)
_SIGN_BIT = jnp.uint8(0x40)


def apot_encode(t: APoTQ) -> jax.Array:
    """Encode an APoTQ into one byte per weight (see module docstring)."""
    e1 = t.e1.astype(jnp.uint8) & jnp.uint8(0x07)
    e2 = t.e2.astype(jnp.uint8) & jnp.uint8(0x07)
    neg = (t.sign < 0).astype(jnp.uint8) * _SIGN_BIT
    zero = t.is_zero.astype(jnp.uint8) * _ZERO_BIT
    return (zero | neg | (e1 << 3) | e2).astype(jnp.uint8)


def apot_decode_values(codes: jax.Array, dtype=jnp.float32) -> jax.Array:
    """Decode code bytes to *unscaled* values s*(2^-e1 + 2^-e2) (zero-aware).

    The per-channel scale is applied by the caller (it folds into the matmul
    epilogue).  This is the reference decode; the Pallas kernels perform the
    same bit arithmetic in VMEM.
    """
    e1 = ((codes >> 3) & jnp.uint8(0x07)).astype(jnp.float32)
    e2 = (codes & jnp.uint8(0x07)).astype(jnp.float32)
    mag = jnp.exp2(-e1) + jnp.exp2(-e2)
    sign = jnp.where((codes & _SIGN_BIT) != 0, -1.0, 1.0)
    val = jnp.where((codes & _ZERO_BIT) != 0, 0.0, sign * mag)
    return val.astype(dtype)


# ---------------------------------------------------------------------------
# Uniform payload storage helpers
# ---------------------------------------------------------------------------


def store_uniform(u: UniformQ) -> jax.Array:
    """Materialize the integer payload at its storage width.

    8-bit -> uint8 (one byte per weight); 4-bit -> packed uint8 (two per
    byte, last axis).  Other widths (the Table II sweep: 3..8) are stored at
    uint8 for simplicity; their *modelled* bandwidth in the accelerator
    simulator still uses the true bit width.
    """
    if u.bits == 4:
        return pack_int4(u.q)
    return u.q.astype(jnp.uint8)


def load_uniform(payload: jax.Array, bits: int) -> jax.Array:
    if bits == 4:
        return unpack_int4(payload).astype(jnp.int32)
    return payload.astype(jnp.int32)
