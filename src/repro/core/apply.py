"""quantize_model: rewrite a float param tree into M2Q QTensors.

This is the MECHANISM layer.  The public entry point for consumers is
:mod:`repro.recipe` — ``quantize(arch, params, recipe)`` resolves a
declarative :class:`~repro.recipe.QuantRecipe` (policy + rules + FFN fold
groups + per-path overrides + calibration spec, with named presets and
per-arch defaults) and drives the calibrate -> scheme-select -> quantize
pipeline below, returning a persistable ``QuantizedModel`` artifact.  Call
sites should not re-wire this module by hand.

Models declare *which* weights are quantizable and *what kind* they are via
QUANT_RULES — an ordered list of ``(regex, kind)`` matched against the
canonical tree path (first match wins; see core.policy for kinds).  The
policy + deployment ShapeCtx then decide mixed-scheme vs low-bit per weight
(optionally pinned per path by :class:`~repro.core.policy.PathOverride`
regexes), and the MSE scheme selector (Eq. 6) splits mixed layers' filters
between uniform-8bit and APoT.

Returns (qparams, report) where report is a per-layer record used by the
benchmarks, the accelerator simulator, and the artifact save/load path
(``abstract_quantize_model`` consumes the reported (n_uniform, n_apot)
splits to rebuild exact treedefs without re-quantizing).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import policy as pol
from .calibrate import path_str
from .qtensor import QAPoT, QExpertM2Q, QM2Q, QUniform, weight_bits
from .scheme_select import select_schemes
from .quant import (act_scale_from_stats, fake_quant_pot, fake_quant_apot,
                    fake_quant_uniform)

Rule = Tuple[str, str]  # (path regex, layer kind)
Override = Tuple[str, pol.PathOverride]  # (path regex, override)


def _match_override(overrides: Optional[Sequence[Override]],
                    path: str) -> Optional[pol.PathOverride]:
    for pattern, ov in overrides or ():
        if re.search(pattern, path):
            return ov
    return None


def resolve_decision(key: str, kind: str, dec_shape: tuple,
                     shape_ctx: pol.ShapeCtx, p: pol.M2QPolicy,
                     overrides: Optional[Sequence[Override]] = None):
    """(decision, effective_policy) for one leaf, honoring path overrides.

    Shared by the concrete and abstract paths so they agree by construction.
    ``scheme``/``bits`` overrides rewrite the policy for this leaf only;
    a ``decision`` override replaces the intensity classification (but an
    embedding can never be mixed — its gather path needs per-row uniform).
    """
    ov = _match_override(overrides, key)
    p_leaf = p
    if ov is not None and (ov.scheme is not None or ov.bits is not None):
        p_leaf = dataclasses.replace(
            p,
            compute_scheme=ov.scheme if ov.scheme is not None
            else p.compute_scheme,
            memory_bits=ov.bits if ov.bits is not None else p.memory_bits)
    decision = pol.decide(kind, dec_shape, shape_ctx, p_leaf)
    if ov is not None and ov.decision is not None:
        if ov.decision == pol.DECISION_MIXED and kind == pol.KIND_EMBEDDING:
            raise ValueError(
                f"override for {key!r}: an embedding cannot be mixed-scheme "
                "(nn.embed gathers integer rows, which needs per-row "
                "uniform quantization)")
        decision = ov.decision
    return decision, p_leaf


def resolve_fold_groups(flat_shapes: Dict[str, tuple],
                        ffn_groups: Optional[Sequence[tuple]],
                        shape_ctx: pol.ShapeCtx, p: pol.M2QPolicy,
                        overrides: Optional[Sequence[Override]] = None
                        ) -> List[Tuple[str, Optional[str], str]]:
    """Resolve which FFN groups WILL be perm-folded: (ku, kg|None, kd) key
    triples.  Shared by quantize_model and abstract_quantize_model so group
    membership agrees by construction — a group folds only when EVERY
    quantized member (up AND gate) resolves to (mixed, m2q) under the
    per-path overrides; a single diverging member drops the whole group
    back to ordinary per-leaf quantization on both paths.

    The FIRST group whose members all resolve to existing leaves CLAIMS
    those keys whether or not it folds: a later (fallback) pattern must
    never fold a subset of a gated group — permuting w_up's columns without
    w_gate's misaligns the elementwise product in the forward."""
    if not ffn_groups or p.compute_scheme != "m2q":
        return []

    def find(rx):
        if rx is None:
            return None
        hits = [k for k in flat_shapes if re.search(rx, k)]
        return hits[0] if len(hits) == 1 else None

    out: List[Tuple[str, Optional[str], str]] = []
    used_up, used_down = set(), set()
    for up_re, gate_re, down_re in ffn_groups:
        ku, kg, kd = find(up_re), find(gate_re), find(down_re)
        if ku is None or kd is None or (gate_re and kg is None):
            continue
        if ku in used_up or kd in used_down:
            continue  # claimed by an earlier (gated) group
        used_up.add(ku)
        if kg is not None:
            used_up.add(kg)
        used_down.add(kd)
        members_ok = True
        for k in (ku,) if kg is None else (ku, kg):
            dec, pk = resolve_decision(k, pol.KIND_DENSE,
                                       tuple(flat_shapes[k][-2:]),
                                       shape_ctx, p, overrides)
            if dec != pol.DECISION_MIXED or pk.compute_scheme != "m2q":
                members_ok = False
        if members_ok:
            out.append((ku, kg, kd))
    return out


@dataclasses.dataclass
class LayerReport:
    path: str
    kind: str
    decision: str
    shape: tuple
    bits: float  # average stored bits/weight
    n_apot: int = 0
    n_uniform: int = 0
    mse: float = 0.0


def match_kind(rules: Sequence[Rule], path: str) -> Optional[str]:
    for pattern, kind in rules:
        if re.search(pattern, path):
            return kind
    return None


def _batched_m2q(w, ratio) -> QExpertM2Q:
    """Per-slice Eq. 6 selection over the leading axis (layers or experts);
    the fixed 1:1 ratio keeps the two halves stackable."""
    apot_idx, uni_idx = [], []
    for e in range(w.shape[0]):
        asn = select_schemes(w[e], ratio=ratio if ratio is not None else 0.5)
        apot_idx.append(asn.apot_idx)
        uni_idx.append(asn.uniform_idx)
    return QExpertM2Q.quantize(w, np.stack(apot_idx), np.stack(uni_idx))


def _quantize_leaf(w, kind: str, decision: str, p: pol.M2QPolicy,
                   act_max_abs):
    """w is (K, N) dense / (V, D) embedding / (B, K, N) stacked-or-expert /
    (L, E, K, N) stacked expert / (kh, kw, 1, C) depthwise."""
    ams = None
    if p.quantize_activations and act_max_abs is not None:
        ams = jnp.asarray(act_max_abs, jnp.float32)
    batched = (kind in (pol.KIND_DENSE, pol.KIND_HEAD, pol.KIND_EXPERT)
               and w.ndim >= 3)
    if decision == pol.DECISION_LOWBIT:
        if kind == pol.KIND_EMBEDDING:
            return QUniform.quantize(w, bits=p.memory_bits, axis=0)
        ra = (w.ndim - 2,) if batched else None
        return QUniform.quantize(w, bits=p.memory_bits, axis=-1, reduce_axes=ra)
    # compute-intensive
    ra = (w.ndim - 2,) if batched else None
    if p.compute_scheme == "uniform8":
        return QUniform.quantize(w, bits=8, axis=-1, act_max_abs=ams,
                                 reduce_axes=ra)
    if p.compute_scheme == "apot":
        return QAPoT.quantize(w, act_max_abs=ams, reduce_axes=ra)
    if p.compute_scheme == "m2q":
        if w.ndim == 2:
            asn = select_schemes(w, ratio=p.apot_ratio)
            return QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx,
                                 act_max_abs=ams)
        if w.ndim == 3:
            qt = _batched_m2q(w, p.apot_ratio)
        else:  # (L, E, K, N): per-layer batched trees, stacked
            per_layer = [_batched_m2q(w[i], p.apot_ratio)
                         for i in range(w.shape[0])]
            qt = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
            # tree.map reconstructs with layer 0's aux; refresh the shape
            # so the treedef matches the abstract twin's
            qt = dataclasses.replace(qt, shape=tuple(w.shape))
        if ams is not None:
            qt.act_scale = act_scale_from_stats(ams)
        return qt
    raise ValueError(f"unknown compute scheme {p.compute_scheme}")


def _joint_group_quantize(w_up, w_gate, w_down, ratio):
    """Perm-folded mixed-scheme quantization of an FFN filter group.

    The paper's 'filter' for an FFN hidden channel spans w_up[:, f]
    (+ w_gate[:, f]) and w_down[f, :]; selecting the scheme *jointly* and
    reordering w_down's rows offline removes the runtime inverse
    permutation — which on a TP-sharded hidden axis otherwise lowers to a
    cross-shard all-gather of the full hidden activation (365 GB/step on
    qwen3-14b prefill; EXPERIMENTS §Perf).  Weights may be stacked (L,K,N).
    """
    stacked = w_up.ndim == 3
    ups, gates, downs = [], [], []
    slices = range(w_up.shape[0]) if stacked else [None]
    for i in slices:
        u = w_up[i] if stacked else w_up
        g = None if w_gate is None else (w_gate[i] if stacked else w_gate)
        d = w_down[i] if stacked else w_down
        sel_src = u if g is None else jnp.concatenate([u, g], axis=0)
        asn = select_schemes(sel_src, ratio=ratio if ratio is not None else 0.5)
        perm = np.concatenate([asn.uniform_idx, asn.apot_idx])
        # fold_perm: columns stored in [uniform | apot] order, the runtime
        # permutation folded into w_down's rows below
        ups.append(QM2Q.quantize(u, asn.apot_idx, asn.uniform_idx,
                                 fold_perm=True))
        if g is not None:
            gates.append(QM2Q.quantize(g, asn.apot_idx, asn.uniform_idx,
                                       fold_perm=True))
        downs.append(jnp.take(d, jnp.asarray(perm), axis=0))
    if not stacked:
        return ups[0], (gates[0] if gates else None), downs[0]
    q_up = dataclasses.replace(
        jax.tree.map(lambda *xs: jnp.stack(xs), *ups),
        shape=tuple(w_up.shape))
    q_gate = None
    if gates:
        q_gate = dataclasses.replace(
            jax.tree.map(lambda *xs: jnp.stack(xs), *gates),
            shape=tuple(w_gate.shape))
    return q_up, q_gate, jnp.stack(downs)


def quantize_model(
    params,
    rules: Sequence[Rule],
    shape_ctx: pol.ShapeCtx,
    m2q_policy: Optional[pol.M2QPolicy] = None,
    act_stats: Optional[Dict[str, float]] = None,
    ffn_groups: Optional[Sequence[tuple]] = None,
    overrides: Optional[Sequence[Override]] = None,
):
    """Apply M2Q to ``params``. Non-matching leaves pass through unchanged.

    ``ffn_groups``: (up_re, gate_re_or_None, down_re) path-regex triples for
    perm-folded FFN quantization (see _joint_group_quantize).
    ``overrides``: ordered (path regex, PathOverride) pairs — first match
    wins; see :func:`resolve_decision`."""
    p = m2q_policy or pol.M2QPolicy()
    act_stats = act_stats or {}
    report: List[LayerReport] = []

    # --- perm-folded FFN groups (pre-pass) ---------------------------------
    pre: Dict[str, object] = {}
    permuted_down: Dict[str, object] = {}
    if ffn_groups and p.compute_scheme == "m2q":
        flat = {path_str(path): leaf for path, leaf in
                jax.tree_util.tree_flatten_with_path(params)[0]}
        groups = resolve_fold_groups(
            {k: tuple(l.shape) for k, l in flat.items()
             if hasattr(l, "shape")},
            ffn_groups, shape_ctx, p, overrides)
        for ku, kg, kd in groups:
            q_up, q_gate, w_down = _joint_group_quantize(
                jnp.asarray(flat[ku], jnp.float32),
                None if kg is None else jnp.asarray(flat[kg], jnp.float32),
                jnp.asarray(flat[kd], jnp.float32), p.apot_ratio)
            pre[ku] = q_up
            if kg is not None:
                pre[kg] = q_gate
            permuted_down[kd] = w_down  # re-enters the normal visit below

    def visit(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)):
            return leaf
        key = path_str(path)
        if key in pre:
            qt = pre[key]
            report.append(LayerReport(path=key, kind=pol.KIND_DENSE,
                                      decision="mixed(perm-folded)",
                                      shape=tuple(leaf.shape),
                                      bits=weight_bits(qt),
                                      n_apot=qt.n_apot,
                                      n_uniform=qt.n_uniform))
            return qt
        if key in permuted_down:
            leaf = permuted_down[key]
        kind = match_kind(rules, key)
        if kind is None or kind == pol.KIND_SKIP or leaf.ndim < 2:
            return leaf
        # conv leaves (HWIO): classify on the 4-D shape (decide() reads
        # kh/kw for DWConv), but quantize the (kh*kw*cin, cout) flattening —
        # filter-wise scales land on Cout, QM2Q's merged-byte layout and the
        # matmul kernels apply unchanged, and the aux ``shape`` keeps the
        # original filter for the XLA conv fallback to reshape through.
        conv = leaf.ndim == 4 and kind in (pol.KIND_DENSE, pol.KIND_DWCONV)
        # classify on the per-unit shape (strip stacked layer / expert axes)
        if kind == pol.KIND_EXPERT and leaf.ndim >= 3:
            dec_shape = tuple(leaf.shape[-2:])
        elif kind in (pol.KIND_DENSE, pol.KIND_HEAD) and leaf.ndim == 3:
            dec_shape = tuple(leaf.shape[1:])
        else:
            dec_shape = tuple(leaf.shape)
        decision, p_leaf = resolve_decision(key, kind, dec_shape, shape_ctx,
                                            p, overrides)
        if decision == pol.DECISION_SKIP:
            return leaf
        # activation stats: plain key, or per-layer '@i' keys for stacked
        ams = act_stats.get(key)
        if ams is None and leaf.ndim >= 3 and not conv:
            per = [act_stats.get(f"{key}@{i}") for i in range(leaf.shape[0])]
            if all(v is not None for v in per):
                # per-layer scalar stats broadcast over ALL trailing axes:
                # (L,1,1) for stacked dense, (L,1,1,1) for stacked experts —
                # must mirror the abstract twin's _act_shape exactly or the
                # load-template treedef diverges on MoE artifacts
                ams = np.asarray(per, np.float32).reshape(
                    (leaf.shape[0],) + (1,) * (leaf.ndim - 1))
        w = jnp.asarray(leaf, jnp.float32)
        if conv:
            w = w.reshape(-1, w.shape[-1])
        qt = _quantize_leaf(w, kind, decision, p_leaf, ams)
        if conv:
            qt = dataclasses.replace(qt, shape=tuple(leaf.shape))
        rep = LayerReport(path=key, kind=kind, decision=decision,
                          shape=tuple(leaf.shape), bits=weight_bits(qt))
        if isinstance(qt, (QM2Q, QExpertM2Q)):
            rep.n_apot = qt.n_apot
            rep.n_uniform = qt.n_uniform
        w_hat = qt.dequant()
        rep.mse = float(jnp.mean((jnp.asarray(leaf, jnp.float32).reshape(w_hat.shape)
                                  - w_hat) ** 2))
        report.append(rep)
        return qt

    qparams = jax.tree_util.tree_map_with_path(visit, params)
    return qparams, report


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _keepdims(shape, reduce_axes):
    return tuple(1 if i in reduce_axes else d for i, d in enumerate(shape))


def abstract_quantize_model(
    params_abs,
    rules: Sequence[Rule],
    shape_ctx: pol.ShapeCtx,
    m2q_policy: Optional[pol.M2QPolicy] = None,
    with_act_scales: bool = True,
    ffn_groups: Optional[Sequence[tuple]] = None,
    overrides: Optional[Sequence[Override]] = None,
    m2q_splits: Optional[Dict[str, Tuple[int, int]]] = None,
):
    """Shape-only twin of quantize_model for the multi-pod dry-run and the
    QuantizedModel load path: takes a ShapeDtypeStruct param tree
    (jax.eval_shape of init) and returns QTensor leaves whose payloads are
    ShapeDtypeStructs — the exact serving pytree, no data, no allocation.
    Decisions depend only on shapes, so this agrees with the concrete path
    by construction (tested in test_quant.py).

    ``m2q_splits``: path -> (n_uniform, n_apot) aux counts, e.g. recovered
    from saved LayerReports.  Required for leaves whose concrete Eq. 6
    split is data-dependent (``apot_ratio=None`` on a plain 2-D or conv
    leaf) — without it those leaves raise instead of silently assuming the
    1:1 default the concrete path would not have used."""
    from .quant import _reduction_axes  # shared stats-axis resolution
    p = m2q_policy or pol.M2QPolicy()
    # fold membership comes from the SAME group resolver as the concrete
    # pre-pass (shapes suffice), so the two paths cannot disagree on which
    # members are perm-folded even under per-path overrides
    flat_shapes = {path_str(path): tuple(leaf.shape) for path, leaf in
                   jax.tree_util.tree_flatten_with_path(params_abs)[0]
                   if hasattr(leaf, "shape")}
    fold_keys = set()
    for ku, kg, _ in resolve_fold_groups(flat_shapes, ffn_groups, shape_ctx,
                                         p, overrides):
        fold_keys.add(ku)
        if kg is not None:
            fold_keys.add(kg)

    def _act_shape(shape, stacked):
        # stacked (scanned-over) leaves need a per-layer leading axis so the
        # act_scale leaf slices under lax.scan; others are scalar.
        return (shape[0],) + (1,) * (len(shape) - 1) if stacked else ()

    def q_uniform(shape, bits, axis, reduce_axes=None, act=False,
                  stacked=False):
        red = _reduction_axes(len(shape), axis, reduce_axes)
        ks = _keepdims(shape, red)
        payload_shape = list(shape)
        if bits == 4:
            payload_shape[-1] //= 2
        dtype = jnp.int8 if bits == 8 else jnp.uint8
        return QUniform(
            payload=_sds(payload_shape, dtype), scale=_sds(ks, jnp.float32),
            zero_point=_sds(ks, jnp.float32),
            act_scale=_sds(_act_shape(shape, stacked), jnp.float32) if act else None,
            bits=bits, axis=axis % len(shape), shape=tuple(shape))

    def q_apot(shape, reduce_axes=None, act=False, stacked=False):
        red = _reduction_axes(len(shape), -1, reduce_axes)
        ks = _keepdims(shape, red)
        return QAPoT(codes=_sds(shape, jnp.uint8), scale=_sds(ks, jnp.float32),
                     act_scale=_sds(_act_shape(shape, stacked), jnp.float32)
                     if act else None,
                     shape=tuple(shape))

    def _m2q_split(key, n, data_dependent):
        """(n_uniform, n_apot) aux counts mirroring select_schemes' floor
        rule — from explicit m2q_splits when given, else the policy ratio.
        ratio=None (Eq. 6 argmin) is data-dependent on plain 2-D and conv
        leaves; batched/perm-folded leaves coerce None -> 0.5 concretely
        (see _batched_m2q / _joint_group_quantize), so the twin does too."""
        if m2q_splits and key in m2q_splits:
            nu, na = int(m2q_splits[key][0]), int(m2q_splits[key][1])
            if nu + na != n:
                raise ValueError(
                    f"m2q_splits[{key!r}] = ({nu}, {na}) does not sum to "
                    f"the filter count {n}")
            return nu, na
        ratio = p.apot_ratio
        if ratio is None:
            if data_dependent:
                raise ValueError(
                    f"apot_ratio=None (Eq. 6 argmin) gives a data-dependent "
                    f"uniform/APoT split for {key!r} that the shape-only "
                    "twin cannot know; pass m2q_splits={path: (n_uniform, "
                    "n_apot)} (e.g. from the saved LayerReports of a "
                    "QuantizedModel artifact) or use a fixed apot_ratio")
            ratio = 0.5
        n_apot = int(n * ratio)
        return n - n_apot, n_apot

    def q_m2q(shape, reduce_axes=None, act=False, stacked=False, cls=None,
              *, key, data_dependent=False):
        # merged permutation-free layout: one byte payload + three
        # zero-masked per-column scale rows (see core.qtensor).  The split
        # counts live in treedef aux — resolved by _m2q_split above.
        red = _reduction_axes(len(shape), -1, reduce_axes)
        ks = _keepdims(shape, red)
        n = shape[-1]
        n_uniform, n_apot = _m2q_split(key, n, data_dependent)
        if cls is None:
            cls = QM2Q if len(shape) == 2 else QExpertM2Q
        return cls(
            payload=_sds(shape, jnp.int8), u_scale=_sds(ks, jnp.float32),
            u_zp=_sds(ks, jnp.float32), a_scale=_sds(ks, jnp.float32),
            act_scale=_sds(_act_shape(shape, stacked), jnp.float32)
            if act else None,
            shape=tuple(shape), n_uniform=n_uniform, n_apot=n_apot)

    def visit(path, leaf):
        if not hasattr(leaf, "shape"):
            return leaf
        key = path_str(path)
        kind = match_kind(rules, key)
        if kind is None or kind == pol.KIND_SKIP or len(leaf.shape) < 2:
            return leaf
        shape = tuple(leaf.shape)
        ndim = len(shape)
        if kind == pol.KIND_EXPERT and ndim >= 3:
            dec_shape = shape[-2:]
        elif kind in (pol.KIND_DENSE, pol.KIND_HEAD) and ndim == 3:
            dec_shape = shape[1:]
        else:
            dec_shape = shape
        decision, p_leaf = resolve_decision(key, kind, dec_shape, shape_ctx,
                                            p, overrides)
        if decision == pol.DECISION_SKIP:
            return leaf
        batched = (kind in (pol.KIND_DENSE, pol.KIND_HEAD, pol.KIND_EXPERT)
                   and ndim >= 3)
        act = with_act_scales and p.quantize_activations
        # conv leaves mirror the concrete path: 2-D flattened payload,
        # original HWIO shape in aux
        if ndim == 4 and kind in (pol.KIND_DENSE, pol.KIND_DWCONV):
            flat = (int(np.prod(shape[:-1])), int(shape[-1]))
            if decision == pol.DECISION_LOWBIT:
                qt = q_uniform(flat, p_leaf.memory_bits, -1)
            elif p_leaf.compute_scheme == "uniform8":
                qt = q_uniform(flat, 8, -1, act=act)
            elif p_leaf.compute_scheme == "apot":
                qt = q_apot(flat, act=act)
            else:
                qt = q_m2q(flat, None, act=act, key=key, data_dependent=True)
            return dataclasses.replace(qt, shape=shape)
        if key in fold_keys:
            # perm-folded group member: merged [uniform | apot] column order,
            # no act scale (consumer rows were permuted offline); stacked
            # groups keep the QM2Q class (3-D children via tree.map stack)
            ra2 = (ndim - 2,) if ndim >= 3 else None
            return q_m2q(shape, ra2, cls=QM2Q, key=key)
        if decision == pol.DECISION_LOWBIT:
            if kind == pol.KIND_EMBEDDING:
                return q_uniform(shape, p_leaf.memory_bits, 0)
            ra = (ndim - 2,) if batched else None
            return q_uniform(shape, p_leaf.memory_bits, -1, ra)
        # 'stacked' = carries a scanned leading layer axis (dense 3-D or
        # expert 4-D); bare 3-D experts are vmapped over E, not scanned.
        stacked = (kind in (pol.KIND_DENSE, pol.KIND_HEAD) and ndim == 3) or \
            (kind == pol.KIND_EXPERT and ndim == 4)
        ra = (ndim - 2,) if batched else None
        if p_leaf.compute_scheme == "uniform8":
            return q_uniform(shape, 8, -1, ra, act=act, stacked=stacked)
        if p_leaf.compute_scheme == "apot":
            return q_apot(shape, ra, act=act, stacked=stacked)
        # m2q: ratio-governed split of the filter axis, merged byte layout
        if ndim == 2:
            return q_m2q(shape, None, act=act, key=key, data_dependent=True)
        return q_m2q(shape, (ndim - 2,), act=act, stacked=stacked, key=key)

    return jax.tree_util.tree_map_with_path(visit, params_abs)


def fake_quant_model(params, rules: Sequence[Rule], scheme: str = "uniform8",
                     bits: int = 8, kinds: Optional[set] = None,
                     path_filter: Optional[str] = None):
    """Whole-tree fake quantization with a single scheme — used by the
    Table I / Table II benchmark sweeps (accuracy under each scheme).
    ``kinds``: restrict to these layer kinds (e.g. {KIND_DWCONV} for the
    Table II sweep); ``path_filter``: additional path regex (Table IV
    per-group ablations)."""

    def visit(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)) or leaf.ndim < 2:
            return leaf
        key = path_str(path)
        kind = match_kind(rules, key)
        if kind is None or kind == pol.KIND_SKIP:
            return leaf
        if kinds is not None and kind not in kinds:
            return leaf
        if path_filter is not None and not re.search(path_filter, key):
            return leaf
        w = jnp.asarray(leaf, jnp.float32)
        axis = 0 if kind == pol.KIND_EMBEDDING else -1
        if scheme == "uniform":
            return fake_quant_uniform(w, bits=bits, axis=axis)
        if scheme == "pot":
            return fake_quant_pot(w, bits=3, axis=axis)  # 3-bit exponent field
        if scheme == "apot":
            return fake_quant_apot(w, axis=axis)
        if scheme in ("m2q", "pot_mix"):
            # pot_mix = Auto-ViT-Acc analogue: PoT (single-shift) half
            w2 = w.reshape(-1, w.shape[-1])
            asn = select_schemes(w2, ratio=0.5)
            out = jnp.asarray(w2)
            out = out.at[:, asn.uniform_idx].set(
                fake_quant_uniform(w2[:, asn.uniform_idx], bits=8, axis=-1))
            alt = (fake_quant_apot if scheme == "m2q"
                   else lambda v, axis: fake_quant_pot(v, bits=3, axis=axis))
            out = out.at[:, asn.apot_idx].set(alt(w2[:, asn.apot_idx], axis=-1))
            return out.reshape(w.shape)
        raise ValueError(scheme)

    return jax.tree_util.tree_map_with_path(visit, params)
