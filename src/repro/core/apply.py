"""quantize_model: rewrite a float param tree into M2Q QTensors.

Models declare *which* weights are quantizable and *what kind* they are via
QUANT_RULES — an ordered list of ``(regex, kind)`` matched against the
canonical tree path (first match wins; see core.policy for kinds).  The
policy + deployment ShapeCtx then decide mixed-scheme vs low-bit per weight,
and the MSE scheme selector (Eq. 6) splits mixed layers' filters between
uniform-8bit and APoT.

Returns (qparams, report) where report is a per-layer record used by the
benchmarks and the accelerator simulator.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import policy as pol
from .calibrate import path_str
from .qtensor import QAPoT, QExpertM2Q, QM2Q, QUniform, weight_bits
from .scheme_select import select_schemes
from .quant import (act_scale_from_stats, fake_quant_pot, fake_quant_apot,
                    fake_quant_uniform)

Rule = Tuple[str, str]  # (path regex, layer kind)


@dataclasses.dataclass
class LayerReport:
    path: str
    kind: str
    decision: str
    shape: tuple
    bits: float  # average stored bits/weight
    n_apot: int = 0
    n_uniform: int = 0
    mse: float = 0.0


def match_kind(rules: Sequence[Rule], path: str) -> Optional[str]:
    for pattern, kind in rules:
        if re.search(pattern, path):
            return kind
    return None


def _batched_m2q(w, ratio) -> QExpertM2Q:
    """Per-slice Eq. 6 selection over the leading axis (layers or experts);
    the fixed 1:1 ratio keeps the two halves stackable."""
    apot_idx, uni_idx = [], []
    for e in range(w.shape[0]):
        asn = select_schemes(w[e], ratio=ratio if ratio is not None else 0.5)
        apot_idx.append(asn.apot_idx)
        uni_idx.append(asn.uniform_idx)
    return QExpertM2Q.quantize(w, np.stack(apot_idx), np.stack(uni_idx))


def _quantize_leaf(w, kind: str, decision: str, p: pol.M2QPolicy,
                   act_max_abs):
    """w is (K, N) dense / (V, D) embedding / (B, K, N) stacked-or-expert /
    (L, E, K, N) stacked expert / (kh, kw, 1, C) depthwise."""
    ams = None
    if p.quantize_activations and act_max_abs is not None:
        ams = jnp.asarray(act_max_abs, jnp.float32)
    batched = (kind in (pol.KIND_DENSE, pol.KIND_HEAD, pol.KIND_EXPERT)
               and w.ndim >= 3)
    if decision == pol.DECISION_LOWBIT:
        if kind == pol.KIND_EMBEDDING:
            return QUniform.quantize(w, bits=p.memory_bits, axis=0)
        ra = (w.ndim - 2,) if batched else None
        return QUniform.quantize(w, bits=p.memory_bits, axis=-1, reduce_axes=ra)
    # compute-intensive
    ra = (w.ndim - 2,) if batched else None
    if p.compute_scheme == "uniform8":
        return QUniform.quantize(w, bits=8, axis=-1, act_max_abs=ams,
                                 reduce_axes=ra)
    if p.compute_scheme == "apot":
        return QAPoT.quantize(w, act_max_abs=ams, reduce_axes=ra)
    if p.compute_scheme == "m2q":
        if w.ndim == 2:
            asn = select_schemes(w, ratio=p.apot_ratio)
            return QM2Q.quantize(w, asn.apot_idx, asn.uniform_idx,
                                 act_max_abs=ams)
        if w.ndim == 3:
            qt = _batched_m2q(w, p.apot_ratio)
        else:  # (L, E, K, N): per-layer batched trees, stacked
            per_layer = [_batched_m2q(w[i], p.apot_ratio)
                         for i in range(w.shape[0])]
            qt = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
            # tree.map reconstructs with layer 0's aux; refresh the shape
            # so the treedef matches the abstract twin's
            qt = dataclasses.replace(qt, shape=tuple(w.shape))
        if ams is not None:
            qt.act_scale = act_scale_from_stats(ams)
        return qt
    raise ValueError(f"unknown compute scheme {p.compute_scheme}")


def _joint_group_quantize(w_up, w_gate, w_down, ratio):
    """Perm-folded mixed-scheme quantization of an FFN filter group.

    The paper's 'filter' for an FFN hidden channel spans w_up[:, f]
    (+ w_gate[:, f]) and w_down[f, :]; selecting the scheme *jointly* and
    reordering w_down's rows offline removes the runtime inverse
    permutation — which on a TP-sharded hidden axis otherwise lowers to a
    cross-shard all-gather of the full hidden activation (365 GB/step on
    qwen3-14b prefill; EXPERIMENTS §Perf).  Weights may be stacked (L,K,N).
    """
    stacked = w_up.ndim == 3
    ups, gates, downs = [], [], []
    slices = range(w_up.shape[0]) if stacked else [None]
    for i in slices:
        u = w_up[i] if stacked else w_up
        g = None if w_gate is None else (w_gate[i] if stacked else w_gate)
        d = w_down[i] if stacked else w_down
        sel_src = u if g is None else jnp.concatenate([u, g], axis=0)
        asn = select_schemes(sel_src, ratio=ratio if ratio is not None else 0.5)
        perm = np.concatenate([asn.uniform_idx, asn.apot_idx])
        # fold_perm: columns stored in [uniform | apot] order, the runtime
        # permutation folded into w_down's rows below
        ups.append(QM2Q.quantize(u, asn.apot_idx, asn.uniform_idx,
                                 fold_perm=True))
        if g is not None:
            gates.append(QM2Q.quantize(g, asn.apot_idx, asn.uniform_idx,
                                       fold_perm=True))
        downs.append(jnp.take(d, jnp.asarray(perm), axis=0))
    if not stacked:
        return ups[0], (gates[0] if gates else None), downs[0]
    q_up = dataclasses.replace(
        jax.tree.map(lambda *xs: jnp.stack(xs), *ups),
        shape=tuple(w_up.shape))
    q_gate = None
    if gates:
        q_gate = dataclasses.replace(
            jax.tree.map(lambda *xs: jnp.stack(xs), *gates),
            shape=tuple(w_gate.shape))
    return q_up, q_gate, jnp.stack(downs)


def quantize_model(
    params,
    rules: Sequence[Rule],
    shape_ctx: pol.ShapeCtx,
    m2q_policy: Optional[pol.M2QPolicy] = None,
    act_stats: Optional[Dict[str, float]] = None,
    ffn_groups: Optional[Sequence[tuple]] = None,
):
    """Apply M2Q to ``params``. Non-matching leaves pass through unchanged.

    ``ffn_groups``: (up_re, gate_re_or_None, down_re) path-regex triples for
    perm-folded FFN quantization (see _joint_group_quantize)."""
    p = m2q_policy or pol.M2QPolicy()
    act_stats = act_stats or {}
    report: List[LayerReport] = []

    # --- perm-folded FFN groups (pre-pass) ---------------------------------
    pre: Dict[str, object] = {}
    permuted_down: Dict[str, object] = {}
    if ffn_groups and p.compute_scheme == "m2q":
        flat = {path_str(path): leaf for path, leaf in
                jax.tree_util.tree_flatten_with_path(params)[0]}

        def find(rx):
            if rx is None:
                return None
            hits = [k for k in flat if re.search(rx, k)]
            return hits[0] if len(hits) == 1 else None

        for up_re, gate_re, down_re in ffn_groups:
            ku, kg, kd = find(up_re), find(gate_re), find(down_re)
            if ku is None or kd is None or (gate_re and kg is None):
                continue
            if ku in pre or kd in permuted_down:
                continue  # already folded by an earlier (gated) group
            w_up = jnp.asarray(flat[ku], jnp.float32)
            if pol.decide(pol.KIND_DENSE, tuple(w_up.shape[-2:]), shape_ctx,
                          p) != pol.DECISION_MIXED:
                continue
            q_up, q_gate, w_down = _joint_group_quantize(
                w_up,
                None if kg is None else jnp.asarray(flat[kg], jnp.float32),
                jnp.asarray(flat[kd], jnp.float32), p.apot_ratio)
            pre[ku] = q_up
            if kg is not None:
                pre[kg] = q_gate
            permuted_down[kd] = w_down  # re-enters the normal visit below

    def visit(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)):
            return leaf
        key = path_str(path)
        if key in pre:
            qt = pre[key]
            report.append(LayerReport(path=key, kind=pol.KIND_DENSE,
                                      decision="mixed(perm-folded)",
                                      shape=tuple(leaf.shape),
                                      bits=weight_bits(qt),
                                      n_apot=qt.n_apot,
                                      n_uniform=qt.n_uniform))
            return qt
        if key in permuted_down:
            leaf = permuted_down[key]
        kind = match_kind(rules, key)
        if kind is None or kind == pol.KIND_SKIP or leaf.ndim < 2:
            return leaf
        # conv leaves (HWIO): classify on the 4-D shape (decide() reads
        # kh/kw for DWConv), but quantize the (kh*kw*cin, cout) flattening —
        # filter-wise scales land on Cout, QM2Q's merged-byte layout and the
        # matmul kernels apply unchanged, and the aux ``shape`` keeps the
        # original filter for the XLA conv fallback to reshape through.
        conv = leaf.ndim == 4 and kind in (pol.KIND_DENSE, pol.KIND_DWCONV)
        # classify on the per-unit shape (strip stacked layer / expert axes)
        if kind == pol.KIND_EXPERT and leaf.ndim >= 3:
            dec_shape = tuple(leaf.shape[-2:])
        elif kind in (pol.KIND_DENSE, pol.KIND_HEAD) and leaf.ndim == 3:
            dec_shape = tuple(leaf.shape[1:])
        else:
            dec_shape = tuple(leaf.shape)
        decision = pol.decide(kind, dec_shape, shape_ctx, p)
        if decision == pol.DECISION_SKIP:
            return leaf
        # activation stats: plain key, or per-layer '@i' keys for stacked
        ams = act_stats.get(key)
        if ams is None and leaf.ndim >= 3 and not conv:
            per = [act_stats.get(f"{key}@{i}") for i in range(leaf.shape[0])]
            if all(v is not None for v in per):
                ams = np.asarray(per, np.float32).reshape(leaf.shape[0], 1, 1)
        w = jnp.asarray(leaf, jnp.float32)
        if conv:
            w = w.reshape(-1, w.shape[-1])
        qt = _quantize_leaf(w, kind, decision, p, ams)
        if conv:
            qt = dataclasses.replace(qt, shape=tuple(leaf.shape))
        rep = LayerReport(path=key, kind=kind, decision=decision,
                          shape=tuple(leaf.shape), bits=weight_bits(qt))
        if isinstance(qt, (QM2Q, QExpertM2Q)):
            rep.n_apot = qt.n_apot
            rep.n_uniform = qt.n_uniform
        w_hat = qt.dequant()
        rep.mse = float(jnp.mean((jnp.asarray(leaf, jnp.float32).reshape(w_hat.shape)
                                  - w_hat) ** 2))
        report.append(rep)
        return qt

    qparams = jax.tree_util.tree_map_with_path(visit, params)
    return qparams, report


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _keepdims(shape, reduce_axes):
    return tuple(1 if i in reduce_axes else d for i, d in enumerate(shape))


def abstract_quantize_model(
    params_abs,
    rules: Sequence[Rule],
    shape_ctx: pol.ShapeCtx,
    m2q_policy: Optional[pol.M2QPolicy] = None,
    with_act_scales: bool = True,
    ffn_groups: Optional[Sequence[tuple]] = None,
):
    """Shape-only twin of quantize_model for the multi-pod dry-run: takes a
    ShapeDtypeStruct param tree (jax.eval_shape of init) and returns QTensor
    leaves whose payloads are ShapeDtypeStructs — the exact serving pytree,
    no data, no allocation.  Decisions depend only on shapes, so this agrees
    with the concrete path by construction (tested in test_quant.py)."""
    from .quant import _reduction_axes  # shared stats-axis resolution
    p = m2q_policy or pol.M2QPolicy()
    fold_res = []
    if ffn_groups and p.compute_scheme == "m2q":
        for up_re, gate_re, _ in ffn_groups:
            fold_res.append(up_re)
            if gate_re:
                fold_res.append(gate_re)

    def _act_shape(shape, stacked):
        # stacked (scanned-over) leaves need a per-layer leading axis so the
        # act_scale leaf slices under lax.scan; others are scalar.
        return (shape[0],) + (1,) * (len(shape) - 1) if stacked else ()

    def q_uniform(shape, bits, axis, reduce_axes=None, act=False,
                  stacked=False):
        red = _reduction_axes(len(shape), axis, reduce_axes)
        ks = _keepdims(shape, red)
        payload_shape = list(shape)
        if bits == 4:
            payload_shape[-1] //= 2
        dtype = jnp.int8 if bits == 8 else jnp.uint8
        return QUniform(
            payload=_sds(payload_shape, dtype), scale=_sds(ks, jnp.float32),
            zero_point=_sds(ks, jnp.float32),
            act_scale=_sds(_act_shape(shape, stacked), jnp.float32) if act else None,
            bits=bits, axis=axis % len(shape), shape=tuple(shape))

    def q_apot(shape, reduce_axes=None, act=False, stacked=False):
        red = _reduction_axes(len(shape), -1, reduce_axes)
        ks = _keepdims(shape, red)
        return QAPoT(codes=_sds(shape, jnp.uint8), scale=_sds(ks, jnp.float32),
                     act_scale=_sds(_act_shape(shape, stacked), jnp.float32)
                     if act else None,
                     shape=tuple(shape))

    def q_m2q(shape, reduce_axes=None, act=False, stacked=False, cls=None):
        # merged permutation-free layout: one byte payload + three
        # zero-masked per-column scale rows (see core.qtensor).  The split
        # counts live in treedef aux, so they must mirror select_schemes'
        # floor rule under the policy's ratio.  ratio=None (Eq. 6 argmin)
        # has a data-dependent split the shape-only twin cannot know; the
        # 1:1 default is assumed there.
        red = _reduction_axes(len(shape), -1, reduce_axes)
        ks = _keepdims(shape, red)
        n = shape[-1]
        ratio = p.apot_ratio if p.apot_ratio is not None else 0.5
        n_apot = int(n * ratio)
        if cls is None:
            cls = QM2Q if len(shape) == 2 else QExpertM2Q
        return cls(
            payload=_sds(shape, jnp.int8), u_scale=_sds(ks, jnp.float32),
            u_zp=_sds(ks, jnp.float32), a_scale=_sds(ks, jnp.float32),
            act_scale=_sds(_act_shape(shape, stacked), jnp.float32)
            if act else None,
            shape=tuple(shape), n_uniform=n - n_apot, n_apot=n_apot)

    def visit(path, leaf):
        if not hasattr(leaf, "shape"):
            return leaf
        key = path_str(path)
        kind = match_kind(rules, key)
        if kind is None or kind == pol.KIND_SKIP or len(leaf.shape) < 2:
            return leaf
        shape = tuple(leaf.shape)
        ndim = len(shape)
        if kind == pol.KIND_EXPERT and ndim >= 3:
            dec_shape = shape[-2:]
        elif kind in (pol.KIND_DENSE, pol.KIND_HEAD) and ndim == 3:
            dec_shape = shape[1:]
        else:
            dec_shape = shape
        decision = pol.decide(kind, dec_shape, shape_ctx, p)
        batched = (kind in (pol.KIND_DENSE, pol.KIND_HEAD, pol.KIND_EXPERT)
                   and ndim >= 3)
        act = with_act_scales and p.quantize_activations
        # conv leaves mirror the concrete path: 2-D flattened payload,
        # original HWIO shape in aux
        if ndim == 4 and kind in (pol.KIND_DENSE, pol.KIND_DWCONV):
            flat = (int(np.prod(shape[:-1])), int(shape[-1]))
            if decision == pol.DECISION_LOWBIT:
                qt = q_uniform(flat, p.memory_bits, -1)
            elif p.compute_scheme == "uniform8":
                qt = q_uniform(flat, 8, -1, act=act)
            elif p.compute_scheme == "apot":
                qt = q_apot(flat, act=act)
            else:
                qt = q_m2q(flat, None, act=act)
            return dataclasses.replace(qt, shape=shape)
        if decision == pol.DECISION_MIXED and p.compute_scheme == "m2q" and \
                any(re.search(rx, key) for rx in fold_res):
            # perm-folded group member: merged [uniform | apot] column order,
            # no act scale (consumer rows were permuted offline); stacked
            # groups keep the QM2Q class (3-D children via tree.map stack)
            ra2 = (ndim - 2,) if ndim >= 3 else None
            return q_m2q(shape, ra2, cls=QM2Q)
        if decision == pol.DECISION_LOWBIT:
            if kind == pol.KIND_EMBEDDING:
                return q_uniform(shape, p.memory_bits, 0)
            ra = (ndim - 2,) if batched else None
            return q_uniform(shape, p.memory_bits, -1, ra)
        # 'stacked' = carries a scanned leading layer axis (dense 3-D or
        # expert 4-D); bare 3-D experts are vmapped over E, not scanned.
        stacked = (kind in (pol.KIND_DENSE, pol.KIND_HEAD) and ndim == 3) or \
            (kind == pol.KIND_EXPERT and ndim == 4)
        ra = (ndim - 2,) if batched else None
        if p.compute_scheme == "uniform8":
            return q_uniform(shape, 8, -1, ra, act=act, stacked=stacked)
        if p.compute_scheme == "apot":
            return q_apot(shape, ra, act=act, stacked=stacked)
        # m2q: 1:1 split of the filter axis, merged byte layout
        if ndim == 2:
            return q_m2q(shape, None, act=act)
        return q_m2q(shape, (ndim - 2,), act=act, stacked=stacked)

    return jax.tree_util.tree_map_with_path(visit, params_abs)


def fake_quant_model(params, rules: Sequence[Rule], scheme: str = "uniform8",
                     bits: int = 8, kinds: Optional[set] = None,
                     path_filter: Optional[str] = None):
    """Whole-tree fake quantization with a single scheme — used by the
    Table I / Table II benchmark sweeps (accuracy under each scheme).
    ``kinds``: restrict to these layer kinds (e.g. {KIND_DWCONV} for the
    Table II sweep); ``path_filter``: additional path regex (Table IV
    per-group ablations)."""

    def visit(path, leaf):
        if not isinstance(leaf, (jax.Array, np.ndarray)) or leaf.ndim < 2:
            return leaf
        key = path_str(path)
        kind = match_kind(rules, key)
        if kind is None or kind == pol.KIND_SKIP:
            return leaf
        if kinds is not None and kind not in kinds:
            return leaf
        if path_filter is not None and not re.search(path_filter, key):
            return leaf
        w = jnp.asarray(leaf, jnp.float32)
        axis = 0 if kind == pol.KIND_EMBEDDING else -1
        if scheme == "uniform":
            return fake_quant_uniform(w, bits=bits, axis=axis)
        if scheme == "pot":
            return fake_quant_pot(w, bits=3, axis=axis)  # 3-bit exponent field
        if scheme == "apot":
            return fake_quant_apot(w, axis=axis)
        if scheme in ("m2q", "pot_mix"):
            # pot_mix = Auto-ViT-Acc analogue: PoT (single-shift) half
            w2 = w.reshape(-1, w.shape[-1])
            asn = select_schemes(w2, ratio=0.5)
            out = jnp.asarray(w2)
            out = out.at[:, asn.uniform_idx].set(
                fake_quant_uniform(w2[:, asn.uniform_idx], bits=8, axis=-1))
            alt = (fake_quant_apot if scheme == "m2q"
                   else lambda v, axis: fake_quant_pot(v, bits=3, axis=axis))
            out = out.at[:, asn.apot_idx].set(alt(w2[:, asn.apot_idx], axis=-1))
            return out.reshape(w.shape)
        raise ValueError(scheme)

    return jax.tree_util.tree_map_with_path(visit, params)
