"""Operational-intensity layer classification -> M2Q policy.

The paper splits EfficientViT's layers into *computation-intensive*
(PWConv/MatMul -> mixed-scheme 8-bit uniform / APoT) and *memory-intensive*
(DWConv -> 4-bit uniform), justified by operation intensity (its ref. [12] is
the roofline paper).  We make that classification explicit and shape-aware so
it generalizes to the assigned LM/MoE/SSM architectures: a layer's intensity
is computed under the *deployment shape* (train / prefill / decode tokens per
step), which reproduces the paper's assignment on EfficientViT and gives
sensible assignments elsewhere (e.g. every matmul is memory-bound at
batch-1 decode).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

# Layer kinds understood by the classifier.  Models tag their weights with
# these via their QUANT_RULES (see core.apply).
KIND_DENSE = "dense"          # generic matmul / PWConv (1x1 conv)
KIND_DWCONV = "dwconv"        # depthwise conv (paper's memory-intensive case)
KIND_EMBEDDING = "embedding"  # gather-dominated
KIND_HEAD = "head"            # vocab projection (dense, but huge N)
KIND_EXPERT = "expert"        # MoE expert matmul (reuse scaled by routing)
KIND_SKIP = "skip"            # norms, routers, gates: left unquantized

DECISION_MIXED = "mixed"    # mixed-scheme uniform8/APoT (compute-intensive)
DECISION_LOWBIT = "lowbit"  # low-bit uniform (memory-intensive)
DECISION_SKIP = "skip"


@dataclasses.dataclass(frozen=True)
class ShapeCtx:
    """Deployment shape: how many tokens flow through a weight per step."""

    tokens_per_step: int            # batch * seq (train/prefill) or batch (decode)
    moe_top_k: int = 1
    moe_num_experts: int = 1

    @property
    def tokens_per_expert(self) -> float:
        return self.tokens_per_step * self.moe_top_k / max(self.moe_num_experts, 1)


@dataclasses.dataclass(frozen=True)
class M2QPolicy:
    """The two-level mixed quantization policy (paper Sec. III-B)."""

    compute_scheme: str = "m2q"   # "m2q" | "uniform8" | "apot" | "pot"
    memory_bits: int = 4          # paper Table II -> 4-bit
    apot_ratio: Optional[float] = 0.5  # 1:1 APoT:Uniform; None = Eq.6 argmin
    act_bits: int = 8
    quantize_activations: bool = True  # enable the W8A8 integer path
    # FLOPs/byte boundary between memory- and compute-intensive.  The v5e
    # bf16 ridge is 197e12/819e9 ~= 240; layers well under it gain more from
    # bandwidth (low-bit) than from int8 MXU rate.  Default matches the
    # paper's split on EfficientViT (DWConv ~ O(10) FLOPs/byte; PWConv >>).
    intensity_threshold: float = 64.0


@dataclasses.dataclass(frozen=True)
class PathOverride:
    """Per-path quantization override (matched by regex in recipe/apply).

    Any unset field falls through to the policy + intensity classifier.
    ``decision`` pins the mixed/lowbit/skip choice for matching weights —
    this is the principled replacement for steering ``intensity_threshold``
    to force the paper's structural taxonomy onto reduced-size configs.
    ``scheme`` / ``bits`` override the policy's ``compute_scheme`` /
    ``memory_bits`` for matching leaves only.
    """

    decision: Optional[str] = None  # DECISION_MIXED | DECISION_LOWBIT | DECISION_SKIP
    scheme: Optional[str] = None    # "m2q" | "uniform8" | "apot"
    bits: Optional[int] = None      # low-bit width (3..8)

    def __post_init__(self):
        if self.decision not in (None, DECISION_MIXED, DECISION_LOWBIT,
                                 DECISION_SKIP):
            raise ValueError(f"unknown decision override {self.decision!r}")
        if self.scheme not in (None, "m2q", "uniform8", "apot"):
            # a typo here would raise at concrete quantize time but be
            # silently treated as "m2q" by the abstract twin's else-branch
            raise ValueError(f"unknown scheme override {self.scheme!r}")
        if self.bits is not None and not 3 <= self.bits <= 8:
            # >8 would wrap in the uint8 byte payload, <3 is not a sweep
            # config — both corrupt weights silently downstream
            raise ValueError(f"bits override {self.bits!r} outside 3..8")


def dense_intensity(k: int, n: int, tokens: float, weight_bits: int = 8,
                    act_bytes: int = 2) -> float:
    """FLOPs/byte of y[T,N] = x[T,K] @ w[K,N]."""
    flops = 2.0 * tokens * k * n
    bytes_moved = (weight_bits / 8.0) * k * n + act_bytes * tokens * (k + n)
    return flops / max(bytes_moved, 1.0)


def decide(kind: str, shape: tuple, ctx: ShapeCtx, policy: M2QPolicy) -> str:
    """Classify one weight -> DECISION_*."""
    if kind == KIND_SKIP:
        return DECISION_SKIP
    if kind == KIND_EMBEDDING:
        # Gather: one row touched per token; zero reuse -> memory-intensive.
        return DECISION_LOWBIT
    if kind == KIND_DWCONV:
        # Structurally memory-intensive (paper Sec. III-A): one weight
        # channel per filter means zero cross-filter reuse, so the intensity
        # is bounded by kh*kw/act_bytes (~4.5 for 3x3, ~12.5 for 5x5)
        # REGARDLESS of tokens_per_step — far below any MXU ridge point.
        # Tying this to the tunable threshold misclassified DWConvs whenever
        # the threshold was lowered to steer *dense* layers, so the paper's
        # taxonomy is honored unconditionally here.
        return DECISION_LOWBIT
    if kind in (KIND_DENSE, KIND_HEAD, KIND_EXPERT):
        k = int(math.prod(shape[:-1]))
        n = int(shape[-1])
        toks = ctx.tokens_per_expert if kind == KIND_EXPERT else ctx.tokens_per_step
        inten = dense_intensity(k, n, toks)
        return DECISION_MIXED if inten >= policy.intensity_threshold else DECISION_LOWBIT
    raise ValueError(f"unknown layer kind: {kind}")
