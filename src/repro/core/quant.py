"""M2Q quantizers: uniform (Eq. 1-2), PoT (Eq. 3), APoT (Eq. 5).

All quantizers are weight-side (the paper applies M2Q exclusively to weights;
activations use standard 8-bit uniform, layer-wise).  Weight quantization is
*filter-wise*: one scale per output channel (the paper's "filter").

Conventions
-----------
* ``axis`` is the OUTPUT-channel axis of the weight tensor.  For a dense
  weight of shape (in, out) that is axis=-1; for a conv filter (kh, kw, cin,
  cout) it is axis=-1; for depthwise (kh, kw, 1, c) also axis=-1.
* Quantizers return small dataclasses holding integer payloads + scales.
  ``dequant`` reconstructs f32.  Packing to int4 / APoT codes lives in
  :mod:`repro.core.packing`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Uniform quantization (paper Eq. 1-2): asymmetric, unsigned b-bit.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class UniformQ:
    """Asymmetric uniform-quantized tensor (pre-packing)."""

    q: jax.Array  # integer payload in [0, 2^bits - 1], stored as int32/uint8
    scale: jax.Array  # per-channel (broadcastable) f32
    zero_point: jax.Array  # per-channel (broadcastable) f32 (integer-valued)
    bits: int
    axis: int


def _reduction_axes(ndim: int, axis: Optional[int],
                    reduce_axes: Optional[tuple]) -> Optional[tuple]:
    """Resolve which axes the quantization statistics reduce over.

    ``reduce_axes`` wins if given (e.g. (1,) for per-(expert, filter) scales
    on an (E, K, N) MoE weight); otherwise all axes except ``axis`` (the
    paper's filter-wise scheme); ``axis=None`` -> tensor-wise.
    """
    if reduce_axes is not None:
        return tuple(a % ndim for a in reduce_axes)
    if axis is None:
        return None
    axis = axis % ndim
    return tuple(i for i in range(ndim) if i != axis)


def _moveaxis_stats(x: jax.Array, axis: Optional[int],
                    reduce_axes: Optional[tuple] = None):
    """Return (min, max) with keepdims over the resolved reduction axes."""
    red = _reduction_axes(x.ndim, axis, reduce_axes)
    if red is None:
        return jnp.min(x), jnp.max(x)
    return jnp.min(x, axis=red, keepdims=True), jnp.max(x, axis=red, keepdims=True)


def uniform_quantize(
    w: jax.Array, bits: int = 8, axis: Optional[int] = -1, eps: float = 1e-8,
    reduce_axes: Optional[tuple] = None,
) -> UniformQ:
    """Paper Eq. (1)-(2).

    ``axis=None`` -> tensor-wise (used for activations, layer-wise);
    otherwise filter-wise along ``axis``; ``reduce_axes`` overrides (stats
    reduce over exactly those axes).
    """
    lo, hi = _moveaxis_stats(w, axis, reduce_axes)
    lo = jnp.minimum(lo, 0.0)  # zero always representable (no zp clipping)
    hi = jnp.maximum(hi, 0.0)
    qmax = float(2**bits - 1)
    scale = jnp.maximum((hi - lo) / qmax, eps)
    zp = jnp.clip(jnp.round(-lo / scale), 0.0, qmax)
    q = jnp.clip(jnp.round(w / scale) + zp, 0.0, qmax)
    return UniformQ(q=q.astype(jnp.int32), scale=scale, zero_point=zp, bits=bits,
                    axis=(axis if axis is None else axis % w.ndim))


def uniform_dequantize(u: UniformQ) -> jax.Array:
    return (u.q.astype(jnp.float32) - u.zero_point) * u.scale


def fake_quant_uniform(w: jax.Array, bits: int = 8, axis: Optional[int] = -1) -> jax.Array:
    return uniform_dequantize(uniform_quantize(w, bits=bits, axis=axis))


# ---------------------------------------------------------------------------
# Activation quantization: 8-bit symmetric (scale-only) layer-wise.
#
# We use the symmetric signed variant for the *runtime int8 path* because it
# keeps the integer matmul zero-point-free on the activation side; the
# asymmetric weight zero-point is folded analytically (see nn.qforward).
# ---------------------------------------------------------------------------


def act_scale_from_stats(max_abs: jax.Array, bits: int = 8) -> jax.Array:
    qmax = float(2 ** (bits - 1) - 1)
    return jnp.maximum(max_abs / qmax, 1e-8)


def quantize_act(x: jax.Array, scale: jax.Array, bits: int = 8) -> jax.Array:
    qmax = float(2 ** (bits - 1) - 1)
    return jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int8)


def fake_quant_act(x: jax.Array, scale: jax.Array, bits: int = 8) -> jax.Array:
    return quantize_act(x, scale, bits).astype(x.dtype) * scale


# ---------------------------------------------------------------------------
# PoT quantization (paper Eq. 3).
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class PoTQ:
    sign: jax.Array  # {-1, +1} int8
    p: jax.Array  # exponent, integer-valued (<= 0), int8
    is_zero: jax.Array  # bool mask of exact zeros
    scale: jax.Array  # per-channel f32 (S = max - min)
    bits: int
    axis: int


def pot_quantize(w: jax.Array, bits: int = 8, axis: int = -1, eps: float = 1e-8,
                 reduce_axes=None) -> PoTQ:
    lo, hi = _moveaxis_stats(w, axis, reduce_axes)
    scale = jnp.maximum(hi - lo, eps)  # paper: S = max(W) - min(W)
    a = jnp.abs(w) / scale
    # paper clip range [-2^b + 1, 0], further clamped to what the int8
    # exponent storage can hold: for bits=8 the paper bound is -255, but
    # subnormal-tiny weights (log2(|w|/S) down to ~ -149) would wrap through
    # int8 to POSITIVE exponents and explode pot_dequantize.  -127 keeps
    # every stored p representable (and 2^-127 is already ~1e-38 * S).
    pmin = max(-(2**bits) + 1, -127)
    # log2 of 0 -> -inf; handle via is_zero mask.
    is_zero = a < 2.0 ** (pmin - 1)
    safe = jnp.where(is_zero, 1.0, a)
    p = jnp.clip(jnp.round(jnp.log2(safe)), pmin, 0)
    return PoTQ(sign=jnp.sign(w).astype(jnp.int8), p=p.astype(jnp.int8),
                is_zero=is_zero, scale=scale, bits=bits,
                axis=(axis if axis is None else axis % w.ndim))


def pot_dequantize(t: PoTQ) -> jax.Array:
    mag = jnp.exp2(t.p.astype(jnp.float32))
    val = t.sign.astype(jnp.float32) * mag * t.scale
    return jnp.where(t.is_zero, 0.0, val)


def fake_quant_pot(w: jax.Array, bits: int = 8, axis: int = -1) -> jax.Array:
    return pot_dequantize(pot_quantize(w, bits=bits, axis=axis))


# ---------------------------------------------------------------------------
# APoT quantization (paper Eq. 5): w_q = s * (2^p1 + 2^p2) * S.
#
# We use the hardware code layout of the M2-ViT SAT engine: each APoT weight
# is (sign, e1, e2) with e = -p in [0, EMAX]; EMAX=7 gives 3-bit exponents ->
# a 7-bit code (1+3+3), stored in one byte (packing.apot_encode).  The decode
# is exactly two shifts + one add on the paper's SAT; on TPU it is two
# exponent constructions + add, fused into the matmul kernel.
# ---------------------------------------------------------------------------

APOT_EMAX = 7  # 3-bit exponent field per component


def apot_codebook(emax: int = APOT_EMAX) -> np.ndarray:
    """All representable magnitudes (2^-a + 2^-b), a<=b in [0, emax]; plus 0.

    Returned sorted ascending, as float32.  Size is emax*(emax+1)/2 + emax+1
    (+1 for zero) = 37 for emax=7.
    """
    vals = {0.0}
    for a in range(emax + 1):
        for b in range(a, emax + 1):
            vals.add(2.0**-a + 2.0**-b)
    return np.array(sorted(vals), dtype=np.float32)


def _apot_code_pairs(emax: int = APOT_EMAX):
    """Parallel arrays: magnitude -> (e1, e2). Zero maps to (emax, emax) w/ flag."""
    pairs = {}
    for a in range(emax + 1):
        for b in range(a, emax + 1):
            pairs.setdefault(2.0**-a + 2.0**-b, (a, b))
    mags = sorted(pairs)
    e1 = np.array([pairs[m][0] for m in mags], dtype=np.int8)
    e2 = np.array([pairs[m][1] for m in mags], dtype=np.int8)
    return np.array(mags, dtype=np.float32), e1, e2


@dataclasses.dataclass
class APoTQ:
    sign: jax.Array  # {-1,+1} int8
    e1: jax.Array  # int8 in [0, emax]
    e2: jax.Array  # int8 in [0, emax]
    is_zero: jax.Array  # bool
    scale: jax.Array  # per-channel f32
    emax: int
    axis: int


def apot_quantize(w: jax.Array, axis: int = -1, emax: int = APOT_EMAX,
                  eps: float = 1e-8, reduce_axes=None) -> APoTQ:
    lo, hi = _moveaxis_stats(w, axis, reduce_axes)
    scale = jnp.maximum(hi - lo, eps)  # paper's S, rescales |w| into [0, ~1]
    a = jnp.abs(w) / scale
    mags, ce1, ce2 = _apot_code_pairs(emax)
    mags_j = jnp.asarray(mags)
    # nearest codebook entry (incl. zero at index 0)
    idx = jnp.argmin(jnp.abs(a[..., None] - mags_j), axis=-1)
    is_zero = idx == 0
    # shift so index 0 (zero) picks harmless exponents
    e1 = jnp.asarray(np.concatenate([[emax], np.asarray(ce1)]))[idx]
    e2 = jnp.asarray(np.concatenate([[emax], np.asarray(ce2)]))[idx]
    return APoTQ(sign=jnp.where(w < 0, -1, 1).astype(jnp.int8),
                 e1=e1.astype(jnp.int8), e2=e2.astype(jnp.int8),
                 is_zero=is_zero, scale=scale, emax=emax, axis=axis % w.ndim)


def apot_dequantize(t: APoTQ) -> jax.Array:
    mag = jnp.exp2(-t.e1.astype(jnp.float32)) + jnp.exp2(-t.e2.astype(jnp.float32))
    val = t.sign.astype(jnp.float32) * mag * t.scale
    return jnp.where(t.is_zero, 0.0, val)


def fake_quant_apot(w: jax.Array, axis: int = -1, emax: int = APOT_EMAX) -> jax.Array:
    return apot_dequantize(apot_quantize(w, axis=axis, emax=emax))


# ---------------------------------------------------------------------------
# Per-filter quantization error (drives the MSE scheme selection, Eq. 6).
# ---------------------------------------------------------------------------


def filterwise_mse(w: jax.Array, w_hat: jax.Array, axis: int = -1) -> jax.Array:
    axis = axis % w.ndim
    red = tuple(i for i in range(w.ndim) if i != axis)
    return jnp.mean((w - w_hat) ** 2, axis=red)
