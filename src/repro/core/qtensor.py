"""QTensor: quantized-weight pytree leaves + their XLA execution paths.

Three leaf kinds mirror the three operation classes the paper's accelerator
serves (Sec. IV):

* :class:`QUniform`  — b-bit uniform weights (b=8 for compute-intensive
  filters on the MPMA merged mode; b=4 for memory-intensive layers on the
  MPMA single mode; 4-bit payloads are nibble-packed).
* :class:`QAPoT`     — APoT-coded weights (the SAT engine), one byte/weight.
* :class:`QM2Q`      — a mixed-scheme layer: the filter set split 1:1 into a
  uniform half and an APoT half (paper Sec. III-B-1), stored MERGED in one
  byte-per-weight array in original filter order (the inverse permutation is
  applied to the payload offline, at quantize time).  This is the fused
  MPMA+SAT execution with no runtime concatenate/gather epilogue.

Each kind implements ``dequant()`` (reference f32 weights) and ``matmul(x)``
(the XLA serving path).  The Pallas kernels in :mod:`repro.kernels` implement
the same contracts with explicit VMEM tiling; ``repro.kernels.ops`` dispatches
on these classes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import packing
from .quant import (
    APoTQ,
    UniformQ,
    act_scale_from_stats,
    apot_quantize,
    quantize_act,
    uniform_quantize,
)

# int8 storage offset for 8-bit asymmetric payloads: q in [0,255] is stored as
# int8 (q-128) so the TPU MXU int8xint8 path applies; the zero point absorbs
# the offset.
_I8_OFFSET = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QUniform:
    """Uniform-quantized weight.

    payload: int8 (8-bit, offset by 128) or nibble-packed uint8 (4-bit,
    packed along the last axis).  scale/zero_point are stored in keepdims
    broadcast shape (e.g. (1, N) for a (K, N) dense weight with axis=-1,
    (V, 1) for a per-row-quantized (V, D) embedding with axis=0).
    ``act_scale``: optional scalar f32 enabling the W8A8 integer path.
    """

    payload: jax.Array
    scale: jax.Array
    zero_point: jax.Array  # in the *stored* domain (offset folded for 8-bit)
    act_scale: Optional[jax.Array]
    bits: int
    axis: int  # output-channel axis of the original weight
    shape: tuple  # original float weight shape

    def tree_flatten(self):
        return (self.payload, self.scale, self.zero_point, self.act_scale), (
            self.bits, self.axis, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, bits=aux[0], axis=aux[1], shape=aux[2])

    # -- construction -------------------------------------------------------
    @classmethod
    def quantize(cls, w: jax.Array, bits: int = 8, axis: int = -1,
                 act_max_abs: Optional[jax.Array] = None,
                 reduce_axes: Optional[tuple] = None) -> "QUniform":
        u: UniformQ = uniform_quantize(w, bits=bits, axis=axis,
                                       reduce_axes=reduce_axes)
        zp = u.zero_point
        if bits == 8:
            payload = (u.q - _I8_OFFSET).astype(jnp.int8)
            zp = zp - _I8_OFFSET
        elif bits == 4:
            payload = packing.pack_int4(u.q)
        else:  # 3,5,6,7-bit sweep configs: byte storage, true-width modelling
            payload = u.q.astype(jnp.uint8)
        act_scale = None if act_max_abs is None else act_scale_from_stats(act_max_abs)
        return cls(payload, u.scale, zp, act_scale, bits, axis % w.ndim,
                   tuple(w.shape))

    # -- reference dequant ---------------------------------------------------
    def _int_payload(self) -> jax.Array:
        if self.bits == 4:
            return packing.unpack_int4(self.payload).astype(jnp.int32)
        return self.payload.astype(jnp.int32)

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        # NOTE: shape falls out of the payload (unpacking restores the last
        # axis), so this also works on scan-sliced stacked leaves whose
        # leading layer axis has been stripped.
        q = self._int_payload().astype(jnp.float32)
        w = (q - self.zero_point) * self.scale
        return w.astype(dtype)

    # -- serving paths -------------------------------------------------------
    def matmul(self, x: jax.Array) -> jax.Array:
        """y = x @ W for W of shape (K, N); x (..., K); out-channels last."""
        if self.bits == 8 and self.act_scale is not None:
            # True integer path (MPMA merged mode analogue): int8 x int8 ->
            # int32, zero-point folded via the row-sum identity:
            #   x @ ((q - zp) s) = s sa (xq @ q - sum_k(xq) * zp)
            xq = quantize_act(x, self.act_scale)
            acc = jax.lax.dot_general(
                xq, self.payload, (((xq.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            xsum = jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
            y = (acc.astype(jnp.float32)
                 - xsum.astype(jnp.float32) * self.zero_point)
            return (y * (self.act_scale * self.scale)).astype(x.dtype)
        # weights-only path: dequantize; bf16 compute on the MXU.
        return x @ self.dequant(x.dtype)

    def take(self, ids: jax.Array, dtype=jnp.float32) -> jax.Array:
        """Quantized embedding gather (axis=0 per-row quantization).

        Gathers the *integer* rows (4-bit rows stay packed through the gather
        -> the HBM traffic win the paper targets for memory-intensive layers)
        and dequantizes only the gathered slice.
        """
        assert self.axis == 0, "take() path needs per-row quantization (axis=0)"
        rows = jnp.take(self.payload, ids, axis=0)
        if self.bits == 4:
            q = packing.unpack_int4(rows).astype(jnp.float32)
        else:
            q = rows.astype(jnp.float32)
        scale = jnp.take(self.scale, ids, axis=0)
        zp = jnp.take(self.zero_point, ids, axis=0)
        return ((q - zp) * scale).astype(dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QAPoT:
    """APoT-coded weight (one uint8 code per weight; see packing.apot_encode).

    Only used for compute-intensive dense weights -> axis is always -1.
    """

    codes: jax.Array
    scale: jax.Array  # (1, ..., N) f32 keepdims
    act_scale: Optional[jax.Array]
    shape: tuple

    def tree_flatten(self):
        return (self.codes, self.scale, self.act_scale), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shape=aux[0])

    @classmethod
    def quantize(cls, w: jax.Array,
                 act_max_abs: Optional[jax.Array] = None,
                 reduce_axes: Optional[tuple] = None) -> "QAPoT":
        t: APoTQ = apot_quantize(w, axis=-1, reduce_axes=reduce_axes)
        codes = packing.apot_encode(t)
        act_scale = None if act_max_abs is None else act_scale_from_stats(act_max_abs)
        return cls(codes, t.scale, act_scale, tuple(w.shape))

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        vals = packing.apot_decode_values(self.codes) * self.scale
        return vals.astype(dtype)

    def matmul(self, x: jax.Array) -> jax.Array:
        # SAT-engine analogue: decode (exponent arithmetic) + dot.  The scale
        # folds into the epilogue so the decoded operand stays unscaled (the
        # Pallas kernel keeps it in VMEM only).  Activations are 8-bit
        # uniform everywhere in M2Q -> fake-quantize when calibrated, which
        # keeps this path bit-identical to the fused m2q kernel.
        if self.act_scale is not None:
            from .quant import fake_quant_act
            x = fake_quant_act(x, self.act_scale.astype(x.dtype))
        vals = packing.apot_decode_values(self.codes, dtype=x.dtype)
        y = x @ vals
        return y * self.scale.reshape(-1).astype(x.dtype)


def _as_code_bytes(payload: jax.Array) -> jax.Array:
    """Reinterpret a merged int8 payload tile as uint8 APoT code bytes."""
    if payload.dtype == jnp.uint8:
        return payload
    return jax.lax.bitcast_convert_type(payload, jnp.uint8)


def _merged_dequant(payload, u_scale, u_zp, a_scale, dtype=jnp.float32):
    """Merged-layout dequant: each column is EITHER uniform (a_scale==0)
    or APoT (u_scale==0), so the two decodes sum without a select."""
    qi = payload.astype(jnp.int32).astype(jnp.float32)
    wu = (qi - u_zp) * u_scale
    wa = packing.apot_decode_values(_as_code_bytes(payload)) * a_scale
    return (wu + wa).astype(dtype)


def _merged_matmul(x, payload, u_scale, u_zp, a_scale, act_scale):
    """y = x @ W for the merged layout; x (..., K), payload (K, N).

    Output columns land directly in the stored (original-filter) order — no
    concatenate, no inverse-permutation gather.  Both engines stream the
    same quantized activation tile (paper Sec. IV "Execution Flow"); the
    zero-masked scales cancel each engine's contribution on the columns it
    does not own.

    NOTE: this is the pure-XLA compatibility path (works under scan/SPMD
    with no Pallas dependency), and here the full-width APoT decode DOES
    materialize a (K, N) f32 operand that the half-width legacy layout did
    not — accepted because on TPU nn.dense routes calibrated leaves to
    kernels.m2q_matmul (see kernels.ops.dispatch_enabled), where the
    decode never leaves VMEM and weight HBM traffic stays at one byte per
    weight; this fallback serves CPU runs and shapes the kernels cannot
    take.
    """
    if act_scale is None:
        return x @ _merged_dequant(payload, u_scale, u_zp, a_scale, x.dtype)
    xq = quantize_act(x, act_scale)
    acc = jax.lax.dot_general(
        xq, payload, (((xq.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)
    xsum = jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
    yu = (acc.astype(jnp.float32) - xsum.astype(jnp.float32) * u_zp) * u_scale
    vals = packing.apot_decode_values(_as_code_bytes(payload))
    ya = jnp.dot(xq.astype(jnp.float32), vals) * a_scale
    return ((yu + ya) * act_scale).astype(x.dtype)


def _merge_halves(up, uscale, uzp, codes, ascale, inv_perm=None):
    """Scatter uniform bytes + APoT code bytes into one (..., N) int8 array.

    Inputs arrive in [uniform | apot] column order; ``inv_perm`` (when given)
    restores original filter order ONCE, offline — the runtime inverse
    permutation is gone.  Scales are zero-padded on the columns the other
    engine owns, so the merged epilogue is a masked sum.
    """
    zeros_u = jnp.zeros(codes.shape[:-2] + (1, codes.shape[-1]), jnp.float32)
    zeros_a = jnp.zeros(up.shape[:-2] + (1, up.shape[-1]), jnp.float32)
    payload = jnp.concatenate(
        [up, jax.lax.bitcast_convert_type(codes, jnp.int8)], axis=-1)
    u_scale = jnp.concatenate([uscale, zeros_u], axis=-1)
    u_zp = jnp.concatenate([uzp, zeros_u], axis=-1)
    a_scale = jnp.concatenate([zeros_a, ascale], axis=-1)
    if inv_perm is not None:
        if payload.ndim == 2:
            payload = jnp.take(payload, inv_perm, axis=-1)
            u_scale = jnp.take(u_scale, inv_perm, axis=-1)
            u_zp = jnp.take(u_zp, inv_perm, axis=-1)
            a_scale = jnp.take(a_scale, inv_perm, axis=-1)
        else:  # (E, K, N) with per-expert perms (E, N)
            ip = inv_perm[..., None, :]
            payload = jnp.take_along_axis(payload, ip, axis=-1)
            u_scale = jnp.take_along_axis(u_scale, ip, axis=-1)
            u_zp = jnp.take_along_axis(u_zp, ip, axis=-1)
            a_scale = jnp.take_along_axis(a_scale, ip, axis=-1)
    return payload, u_scale, u_zp, a_scale


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QM2Q:
    """Mixed-scheme layer in the merged, permutation-free layout.

    One byte per weight, columns in ORIGINAL filter order: a column owned by
    the uniform engine stores its offset-folded int8 payload; a column owned
    by the SAT engine stores its APoT code byte.  Per-column scales are
    zero-masked (``u_scale``/``u_zp`` vanish on APoT columns, ``a_scale`` on
    uniform columns), so dequant/matmul are a sum of the two engine outputs
    with no concatenate and no inverse-permutation gather — the reordering
    happened once, offline, in :meth:`quantize`.
    """

    payload: jax.Array   # (K, N) int8 — uniform byte or APoT code per column
    u_scale: jax.Array   # (1, N) f32, 0 on APoT columns
    u_zp: jax.Array      # (1, N) f32 stored-domain zero point, 0 on APoT cols
    a_scale: jax.Array   # (1, N) f32, 0 on uniform columns
    act_scale: Optional[jax.Array]
    shape: tuple
    n_uniform: int
    n_apot: int

    def tree_flatten(self):
        return (self.payload, self.u_scale, self.u_zp, self.a_scale,
                self.act_scale), (self.shape, self.n_uniform, self.n_apot)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shape=aux[0], n_uniform=aux[1], n_apot=aux[2])

    @classmethod
    def quantize(cls, w: jax.Array, apot_idx, uniform_idx,
                 act_max_abs: Optional[jax.Array] = None,
                 fold_perm: bool = False) -> "QM2Q":
        """``fold_perm=True`` stores columns in [uniform | apot] order (the
        consumer's rows were permuted to match — see apply.py FFN groups);
        otherwise the inverse permutation is applied to the payload here,
        once, so outputs come out in original filter order."""
        w2 = w.reshape(-1, w.shape[-1])
        ui = jnp.asarray(uniform_idx, jnp.int32)
        ai = jnp.asarray(apot_idx, jnp.int32)
        u: UniformQ = uniform_quantize(w2[:, ui], bits=8, axis=-1)
        t: APoTQ = apot_quantize(w2[:, ai], axis=-1)
        inv_perm = None
        if not fold_perm:
            inv_perm = jnp.argsort(jnp.concatenate([ui, ai])).astype(jnp.int32)
        payload, u_scale, u_zp, a_scale = _merge_halves(
            (u.q - _I8_OFFSET).astype(jnp.int8), u.scale,
            u.zero_point - _I8_OFFSET, packing.apot_encode(t), t.scale,
            inv_perm)
        act_scale = None if act_max_abs is None else act_scale_from_stats(
            act_max_abs)
        # shape records the ORIGINAL weight shape (e.g. HWIO for a quantized
        # conv filter whose payload was flattened to (kh*kw*cin, cout));
        # consumers reshape dequant() output back through it.
        return cls(payload, u_scale, u_zp, a_scale, act_scale,
                   tuple(w.shape), int(ui.shape[0]), int(ai.shape[0]))

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        return _merged_dequant(self.payload, self.u_scale, self.u_zp,
                               self.a_scale, dtype)

    def matmul(self, x: jax.Array) -> jax.Array:
        return _merged_matmul(x, self.payload, self.u_scale, self.u_zp,
                              self.a_scale, self.act_scale)

    def scheme_mask(self) -> jax.Array:
        """(N,) bool — True where the column is uniform-quantized."""
        return (self.a_scale.reshape(-1) == 0.0)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QExpertM2Q:
    """Merged mixed-scheme quantization of stacked expert weights (E, K, N).

    Same permutation-free byte layout as :class:`QM2Q`, with per-(expert,
    filter) scales (reduce_axes=(1,)) and per-expert Eq. 6 splits.  Stacked
    layer trees add a leading L axis to every child (payload (L, E, K, N)).
    """

    payload: jax.Array   # (E, K, N) int8 merged bytes, original filter order
    u_scale: jax.Array   # (E, 1, N) f32, 0 on APoT columns
    u_zp: jax.Array      # (E, 1, N) f32, 0 on APoT columns
    a_scale: jax.Array   # (E, 1, N) f32, 0 on uniform columns
    act_scale: Optional[jax.Array]
    shape: tuple
    n_uniform: int
    n_apot: int

    def tree_flatten(self):
        return (self.payload, self.u_scale, self.u_zp, self.a_scale,
                self.act_scale), (self.shape, self.n_uniform, self.n_apot)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shape=aux[0], n_uniform=aux[1], n_apot=aux[2])

    @classmethod
    def quantize(cls, w: jax.Array, apot_idx: jax.Array, uniform_idx: jax.Array,
                 act_max_abs: Optional[jax.Array] = None) -> "QExpertM2Q":
        """apot_idx/uniform_idx: (E, Na) / (E, Nu) per-expert filter indices."""
        ui = jnp.asarray(uniform_idx, jnp.int32)
        ai = jnp.asarray(apot_idx, jnp.int32)
        wu = jnp.take_along_axis(w, ui[:, None, :], axis=-1)
        wa = jnp.take_along_axis(w, ai[:, None, :], axis=-1)
        u: UniformQ = uniform_quantize(wu, bits=8, axis=-1, reduce_axes=(1,))
        t: APoTQ = apot_quantize(wa, axis=-1, reduce_axes=(1,))
        inv_perm = jnp.argsort(jnp.concatenate([ui, ai], axis=-1),
                               axis=-1).astype(jnp.int32)
        payload, u_scale, u_zp, a_scale = _merge_halves(
            (u.q - _I8_OFFSET).astype(jnp.int8), u.scale,
            u.zero_point - _I8_OFFSET, packing.apot_encode(t), t.scale,
            inv_perm)
        act_scale = None if act_max_abs is None else act_scale_from_stats(
            act_max_abs)
        return cls(payload, u_scale, u_zp, a_scale, act_scale,
                   tuple(w.shape), int(ui.shape[-1]), int(ai.shape[-1]))

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        return _merged_dequant(self.payload, self.u_scale, self.u_zp,
                               self.a_scale, dtype)

    def matmul(self, x: jax.Array) -> jax.Array:
        """Dense matmul for a scan-sliced stacked leaf (payload is 2-D
        inside the layer scan); identical contract to QM2Q.matmul."""
        return _merged_matmul(x, self.payload, self.u_scale, self.u_zp,
                              self.a_scale, self.act_scale)

    def expert_matmul(self, xe: jax.Array) -> jax.Array:
        """y[E,C,N] = xe[E,C,K] @ w[E,K,N], permutation-free."""
        if self.act_scale is None:
            return jnp.einsum("eck,ekn->ecn", xe, self.dequant(xe.dtype))
        xq = quantize_act(xe, self.act_scale)
        acc = jax.lax.dot_general(
            xq, self.payload, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.int32)
        xsum = jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
        yu = (acc.astype(jnp.float32)
              - xsum.astype(jnp.float32) * self.u_zp) * self.u_scale
        vals = packing.apot_decode_values(_as_code_bytes(self.payload))
        ya = jnp.einsum("eck,ekn->ecn", xq.astype(jnp.float32),
                        vals) * self.a_scale
        return ((yu + ya) * self.act_scale).astype(xe.dtype)


QLeaf = (QUniform, QAPoT, QM2Q, QExpertM2Q)


def is_qtensor(x) -> bool:
    return isinstance(x, QLeaf)


def qmatmul(x: jax.Array, w) -> jax.Array:
    """Uniform entry point used by nn.dense."""
    return w.matmul(x)


def weight_bits(qt) -> float:
    """Average STORED bits/weight (drives bandwidth modelling + reporting).

    This is the width the serving path actually moves through HBM, not the
    nominal quantization width: only bits=4 payloads are nibble-packed, so
    the 3/5/6/7-bit Table II sweep configs occupy (and stream) one full
    byte per weight and must report 8.0 — reporting the nominal width there
    understated their bandwidth cost relative to the packed bits=4 case.
    """
    if isinstance(qt, QUniform):
        if qt.bits == 4:
            return 4.0  # nibble-packed: stored == nominal
        return 8.0 if qt.bits < 8 else float(qt.bits)  # byte-stored payloads
    if isinstance(qt, QAPoT):
        return 8.0  # one byte per code (7 useful bits)
    if isinstance(qt, (QM2Q, QExpertM2Q)):
        # merged layout: one byte per weight for both engines (8-bit uniform
        # payloads and 1-byte APoT codes interleave in a single array)
        return 8.0
    raise TypeError(type(qt))
