"""QTensor: quantized-weight pytree leaves + their XLA execution paths.

Three leaf kinds mirror the three operation classes the paper's accelerator
serves (Sec. IV):

* :class:`QUniform`  — b-bit uniform weights (b=8 for compute-intensive
  filters on the MPMA merged mode; b=4 for memory-intensive layers on the
  MPMA single mode; 4-bit payloads are nibble-packed).
* :class:`QAPoT`     — APoT-coded weights (the SAT engine), one byte/weight.
* :class:`QM2Q`      — a mixed-scheme layer: the filter set split 1:1 into a
  uniform half and an APoT half (paper Sec. III-B-1), plus the inverse
  permutation restoring filter order.  This is the fused MPMA+SAT execution.

Each kind implements ``dequant()`` (reference f32 weights) and ``matmul(x)``
(the XLA serving path).  The Pallas kernels in :mod:`repro.kernels` implement
the same contracts with explicit VMEM tiling; ``repro.kernels.ops`` dispatches
on these classes.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from . import packing
from .quant import (
    APoTQ,
    UniformQ,
    act_scale_from_stats,
    apot_quantize,
    quantize_act,
    uniform_quantize,
)

# int8 storage offset for 8-bit asymmetric payloads: q in [0,255] is stored as
# int8 (q-128) so the TPU MXU int8xint8 path applies; the zero point absorbs
# the offset.
_I8_OFFSET = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QUniform:
    """Uniform-quantized weight.

    payload: int8 (8-bit, offset by 128) or nibble-packed uint8 (4-bit,
    packed along the last axis).  scale/zero_point are stored in keepdims
    broadcast shape (e.g. (1, N) for a (K, N) dense weight with axis=-1,
    (V, 1) for a per-row-quantized (V, D) embedding with axis=0).
    ``act_scale``: optional scalar f32 enabling the W8A8 integer path.
    """

    payload: jax.Array
    scale: jax.Array
    zero_point: jax.Array  # in the *stored* domain (offset folded for 8-bit)
    act_scale: Optional[jax.Array]
    bits: int
    axis: int  # output-channel axis of the original weight
    shape: tuple  # original float weight shape

    def tree_flatten(self):
        return (self.payload, self.scale, self.zero_point, self.act_scale), (
            self.bits, self.axis, self.shape)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, bits=aux[0], axis=aux[1], shape=aux[2])

    # -- construction -------------------------------------------------------
    @classmethod
    def quantize(cls, w: jax.Array, bits: int = 8, axis: int = -1,
                 act_max_abs: Optional[jax.Array] = None,
                 reduce_axes: Optional[tuple] = None) -> "QUniform":
        u: UniformQ = uniform_quantize(w, bits=bits, axis=axis,
                                       reduce_axes=reduce_axes)
        zp = u.zero_point
        if bits == 8:
            payload = (u.q - _I8_OFFSET).astype(jnp.int8)
            zp = zp - _I8_OFFSET
        elif bits == 4:
            payload = packing.pack_int4(u.q)
        else:  # 3,5,6,7-bit sweep configs: byte storage, true-width modelling
            payload = u.q.astype(jnp.uint8)
        act_scale = None if act_max_abs is None else act_scale_from_stats(act_max_abs)
        return cls(payload, u.scale, zp, act_scale, bits, axis % w.ndim,
                   tuple(w.shape))

    # -- reference dequant ---------------------------------------------------
    def _int_payload(self) -> jax.Array:
        if self.bits == 4:
            return packing.unpack_int4(self.payload).astype(jnp.int32)
        return self.payload.astype(jnp.int32)

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        # NOTE: shape falls out of the payload (unpacking restores the last
        # axis), so this also works on scan-sliced stacked leaves whose
        # leading layer axis has been stripped.
        q = self._int_payload().astype(jnp.float32)
        w = (q - self.zero_point) * self.scale
        return w.astype(dtype)

    # -- serving paths -------------------------------------------------------
    def matmul(self, x: jax.Array) -> jax.Array:
        """y = x @ W for W of shape (K, N); x (..., K); out-channels last."""
        if self.bits == 8 and self.act_scale is not None:
            # True integer path (MPMA merged mode analogue): int8 x int8 ->
            # int32, zero-point folded via the row-sum identity:
            #   x @ ((q - zp) s) = s sa (xq @ q - sum_k(xq) * zp)
            xq = quantize_act(x, self.act_scale)
            acc = jax.lax.dot_general(
                xq, self.payload, (((xq.ndim - 1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            xsum = jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
            y = (acc.astype(jnp.float32)
                 - xsum.astype(jnp.float32) * self.zero_point)
            return (y * (self.act_scale * self.scale)).astype(x.dtype)
        # weights-only path: dequantize; bf16 compute on the MXU.
        return x @ self.dequant(x.dtype)

    def take(self, ids: jax.Array, dtype=jnp.float32) -> jax.Array:
        """Quantized embedding gather (axis=0 per-row quantization).

        Gathers the *integer* rows (4-bit rows stay packed through the gather
        -> the HBM traffic win the paper targets for memory-intensive layers)
        and dequantizes only the gathered slice.
        """
        assert self.axis == 0, "take() path needs per-row quantization (axis=0)"
        rows = jnp.take(self.payload, ids, axis=0)
        if self.bits == 4:
            q = packing.unpack_int4(rows).astype(jnp.float32)
        else:
            q = rows.astype(jnp.float32)
        scale = jnp.take(self.scale, ids, axis=0)
        zp = jnp.take(self.zero_point, ids, axis=0)
        return ((q - zp) * scale).astype(dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QAPoT:
    """APoT-coded weight (one uint8 code per weight; see packing.apot_encode).

    Only used for compute-intensive dense weights -> axis is always -1.
    """

    codes: jax.Array
    scale: jax.Array  # (1, ..., N) f32 keepdims
    act_scale: Optional[jax.Array]
    shape: tuple

    def tree_flatten(self):
        return (self.codes, self.scale, self.act_scale), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, shape=aux[0])

    @classmethod
    def quantize(cls, w: jax.Array,
                 act_max_abs: Optional[jax.Array] = None,
                 reduce_axes: Optional[tuple] = None) -> "QAPoT":
        t: APoTQ = apot_quantize(w, axis=-1, reduce_axes=reduce_axes)
        codes = packing.apot_encode(t)
        act_scale = None if act_max_abs is None else act_scale_from_stats(act_max_abs)
        return cls(codes, t.scale, act_scale, tuple(w.shape))

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        vals = packing.apot_decode_values(self.codes) * self.scale
        return vals.astype(dtype)

    def matmul(self, x: jax.Array) -> jax.Array:
        # SAT-engine analogue: decode (exponent arithmetic) + dot.  The scale
        # folds into the epilogue so the decoded operand stays unscaled (the
        # Pallas kernel keeps it in VMEM only).  Activations are 8-bit
        # uniform everywhere in M2Q -> fake-quantize when calibrated, which
        # keeps this path bit-identical to the fused m2q kernel.
        if self.act_scale is not None:
            from .quant import fake_quant_act
            x = fake_quant_act(x, self.act_scale.astype(x.dtype))
        vals = packing.apot_decode_values(self.codes, dtype=x.dtype)
        y = x @ vals
        return y * self.scale.reshape(-1).astype(x.dtype)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QM2Q:
    """Mixed-scheme layer: uniform half + APoT half + inverse filter perm."""

    uniform: QUniform
    apot: QAPoT
    inv_perm: jax.Array  # (N,) int32

    def tree_flatten(self):
        return (self.uniform, self.apot, self.inv_perm), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def quantize(cls, w: jax.Array, apot_idx, uniform_idx,
                 act_max_abs: Optional[jax.Array] = None) -> "QM2Q":
        w2 = w.reshape(-1, w.shape[-1])
        wu = w2[:, jnp.asarray(uniform_idx)]
        wa = w2[:, jnp.asarray(apot_idx)]
        perm = jnp.concatenate(
            [jnp.asarray(uniform_idx, jnp.int32), jnp.asarray(apot_idx, jnp.int32)])
        inv_perm = jnp.argsort(perm).astype(jnp.int32)
        return cls(
            uniform=QUniform.quantize(wu, bits=8, act_max_abs=act_max_abs),
            apot=QAPoT.quantize(wa, act_max_abs=act_max_abs),
            inv_perm=inv_perm)

    @property
    def shape(self):
        return (self.uniform.shape[0], self.uniform.shape[1] + self.apot.shape[1])

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        w = jnp.concatenate(
            [self.uniform.dequant(dtype), self.apot.dequant(dtype)], axis=-1)
        if self.inv_perm is None:  # perm folded into the consumer's rows
            return w
        return jnp.take(w, self.inv_perm, axis=-1)

    def matmul(self, x: jax.Array) -> jax.Array:
        # Paper Sec. IV "Execution Flow": SAT (APoT half) runs in parallel
        # with MPMA (uniform half); on TPU both halves stream the same
        # activation tile — repro.kernels.m2q_matmul fuses them in one pass.
        yu = self.uniform.matmul(x)
        ya = self.apot.matmul(x)
        y = jnp.concatenate([yu, ya], axis=-1)
        if self.inv_perm is None:
            return y
        return jnp.take(y, self.inv_perm, axis=-1)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QExpertM2Q:
    """Mixed-scheme quantization of a stacked MoE expert weight (E, K, N).

    Scales are per-(expert, filter): reduce_axes=(1,).  Each expert gets its
    own MSE scheme split (Eq. 6 applied per expert), but the 1:1 ratio makes
    the two halves stackable: uniform payload (E, K, N/2), APoT codes
    (E, K, N/2), inverse perms (E, N).
    """

    uniform: QUniform   # payload (E, K, Nu)
    apot: QAPoT         # codes (E, K, Na)
    inv_perm: jax.Array  # (E, N) int32

    def tree_flatten(self):
        return (self.uniform, self.apot, self.inv_perm), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @classmethod
    def quantize(cls, w: jax.Array, apot_idx: jax.Array, uniform_idx: jax.Array,
                 act_max_abs: Optional[jax.Array] = None) -> "QExpertM2Q":
        """apot_idx/uniform_idx: (E, Na) / (E, Nu) per-expert filter indices."""
        e = w.shape[0]
        wu = jnp.take_along_axis(w, jnp.asarray(uniform_idx)[:, None, :], axis=-1)
        wa = jnp.take_along_axis(w, jnp.asarray(apot_idx)[:, None, :], axis=-1)
        perm = jnp.concatenate([jnp.asarray(uniform_idx, jnp.int32),
                                jnp.asarray(apot_idx, jnp.int32)], axis=-1)
        inv_perm = jnp.argsort(perm, axis=-1).astype(jnp.int32)
        return cls(
            uniform=QUniform.quantize(wu, bits=8, act_max_abs=act_max_abs,
                                      reduce_axes=(1,)),
            apot=QAPoT.quantize(wa, act_max_abs=act_max_abs, reduce_axes=(1,)),
            inv_perm=inv_perm)

    @property
    def shape(self):
        e, k, nu = self.uniform.shape
        return (e, k, nu + self.apot.shape[-1])

    def dequant(self, dtype=jnp.float32) -> jax.Array:
        w = jnp.concatenate(
            [self.uniform.dequant(dtype), self.apot.dequant(dtype)], axis=-1)
        if self.inv_perm is None:
            return w
        return jnp.take_along_axis(w, self.inv_perm[..., None, :], axis=-1)

    def matmul(self, x: jax.Array) -> jax.Array:
        """Dense matmul for a scan-sliced stacked leaf (payloads are 2-D
        inside the layer scan); identical contract to QM2Q.matmul."""
        yu = self.uniform.matmul(x)
        ya = self.apot.matmul(x)
        y = jnp.concatenate([yu, ya], axis=-1)
        if self.inv_perm is None:
            return y
        return jnp.take(y, self.inv_perm, axis=-1)

    def expert_matmul(self, xe: jax.Array) -> jax.Array:
        """y[E,C,N] = xe[E,C,K] @ w[E,K,N] with the mixed-scheme halves."""
        u = self.uniform
        if u.act_scale is not None:
            xq = quantize_act(xe, u.act_scale)
            acc = jax.lax.dot_general(
                xq, u.payload, (((2,), (1,)), ((0,), (0,))),
                preferred_element_type=jnp.int32)
            xsum = jnp.sum(xq.astype(jnp.int32), axis=-1, keepdims=True)
            yu = (acc.astype(jnp.float32)
                  - xsum.astype(jnp.float32) * u.zero_point)
            yu = (yu * (u.act_scale * u.scale)).astype(xe.dtype)
        else:
            yu = jnp.einsum("eck,ekn->ecn", xe, u.dequant(xe.dtype))
        vals = packing.apot_decode_values(self.apot.codes, dtype=xe.dtype)
        ya = jnp.einsum("eck,ekn->ecn", xe, vals) * self.apot.scale.astype(xe.dtype)
        y = jnp.concatenate([yu, ya], axis=-1)
        if self.inv_perm is None:
            return y
        return jnp.take_along_axis(y, self.inv_perm[..., None, :], axis=-1)


QLeaf = (QUniform, QAPoT, QM2Q, QExpertM2Q)


def is_qtensor(x) -> bool:
    return isinstance(x, QLeaf)


def qmatmul(x: jax.Array, w) -> jax.Array:
    """Uniform entry point used by nn.dense."""
    return w.matmul(x)


def weight_bits(qt) -> float:
    """Average stored bits/weight (drives bandwidth modelling + reporting)."""
    if isinstance(qt, QUniform):
        return float(qt.bits)
    if isinstance(qt, QAPoT):
        return 8.0  # one byte per code (7 useful bits)
    if isinstance(qt, (QM2Q, QExpertM2Q)):
        n_u = qt.uniform.shape[-1]
        n_a = qt.apot.shape[-1]
        return (weight_bits(qt.uniform) * n_u + weight_bits(qt.apot) * n_a) / (n_u + n_a)
    raise TypeError(type(qt))
