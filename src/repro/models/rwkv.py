"""RWKV6 (Finch) — attention-free LM with data-dependent decay.

M2Q applicability: all projection matmuls (time-mix r/k/v/g/o, channel-mix
r/k/v) are quantizable weights; the recurrence itself is activation-side.
The decode state is O(1) in sequence length, which is why this arch runs the
``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn
from ..core import policy as pol
from .config import ArchConfig

FFN_FOLD_GROUPS = [(r"cm/cw_k$", None, r"cm/cw_v$")]

QUANT_RULES = [
    (r"embed", pol.KIND_EMBEDDING),
    (r"lm_head", pol.KIND_HEAD),
    (r"(ln|norm|gamma|mu_|w0|w_lora|u$|gn)", pol.KIND_SKIP),
    (r"tm/w[rkvgo]$", pol.KIND_DENSE),
    (r"cm/cw_[rkv]$", pol.KIND_DENSE),
]

_LORA_DIM = 64


def _init_layer(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 12)
    D, F = cfg.d_model, cfg.d_ff
    H = D // cfg.rwkv_head_dim
    mu = lambda k: jax.random.uniform(k, (D,), jnp.float32, 0.0, 1.0)
    return {
        "ln1": jnp.ones((D,), jnp.float32),
        "ln2": jnp.ones((D,), jnp.float32),
        "tm": {
            "mu_r": mu(ks[0]), "mu_k": mu(ks[1]), "mu_v": mu(ks[2]),
            "mu_g": mu(ks[3]), "mu_w": mu(ks[4]),
            "wr": nn.lecun_normal(ks[5], (D, D)),
            "wk": nn.lecun_normal(ks[6], (D, D)),
            "wv": nn.lecun_normal(ks[7], (D, D)),
            "wg": nn.lecun_normal(ks[8], (D, D)),
            "wo": nn.lecun_normal(ks[9], (D, D)),
            "w_lora_a": nn.trunc_normal(ks[10], (D, _LORA_DIM), std=0.01),
            "w_lora_b": nn.trunc_normal(ks[11], (_LORA_DIM, D), std=0.01),
            "w0": jnp.full((D,), -3.0, jnp.float32),  # slow decay init
            "u": nn.trunc_normal(ks[4], (H, cfg.rwkv_head_dim), std=0.02),
            "gn": jnp.ones((D,), jnp.float32),
        },
        "cm": {
            "mu_cr": mu(ks[0]), "mu_ck": mu(ks[1]),
            "cw_r": nn.lecun_normal(ks[2], (D, D)),
            "cw_k": nn.lecun_normal(ks[3], (D, F)),
            "cw_v": nn.lecun_normal(ks[5], (F, D)),
        },
    }


def init(cfg: ArchConfig, key) -> dict:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layers = jax.vmap(lambda k: _init_layer(cfg, k))(
        jax.random.split(k_layers, cfg.n_layers))
    return {
        "embed": nn.trunc_normal(k_emb, (cfg.padded_vocab, cfg.d_model)),
        "ln0": jnp.ones((cfg.d_model,), jnp.float32),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": nn.lecun_normal(k_head, (cfg.d_model, cfg.padded_vocab)),
    }


def _head_norm(out: jax.Array, gamma: jax.Array, n_heads: int) -> jax.Array:
    """Per-head RMS group norm on the recurrence output (dtype-preserving)."""
    dt = out.dtype
    B, T = out.shape[0], out.shape[1]
    D = gamma.shape[-1]
    x = out.reshape(B, T, n_heads, D // n_heads).astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + 1e-6)
    return (x.reshape(B, T, D) * gamma.astype(jnp.float32)).astype(dt)


def _timemix(cfg: ArchConfig, lp, x, prev, state, chunk: int = 128):
    """x: (B,T,D); prev: (B,D) last token before this segment;
    state: (B,H,d,d). Returns (y, new_prev, new_state)."""
    H = cfg.d_model // cfg.rwkv_head_dim
    xs = jnp.concatenate([prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    r, k, v, g, w = nn.rwkv6_timemix_inputs(x, xs, lp, H)
    state, out = nn.rwkv6_attend(state, r, k, v, w, lp["u"], chunk=chunk)
    B, T = x.shape[0], x.shape[1]
    out = _head_norm(out.reshape(B, T, cfg.d_model).astype(x.dtype), lp["gn"], H)
    y = nn.dense(out * g, lp["wo"])
    return y, x[:, -1], state


def _channelmix(cfg: ArchConfig, lp, x, prev):
    xs = jnp.concatenate([prev[:, None].astype(x.dtype), x[:, :-1]], axis=1)
    y = nn.rwkv6_channelmix(x, xs, lp)
    return y, x[:, -1]


def _layer(cfg, lp, x, tm_prev, cm_prev, state, chunk=128):
    h = nn.rms_norm(x, lp["ln1"])
    y, tm_prev, state = _timemix(cfg, lp["tm"], h, tm_prev, state, chunk)
    x = x + y
    h = nn.rms_norm(x, lp["ln2"])
    y, cm_prev = _channelmix(cfg, lp["cm"], h, cm_prev)
    x = x + y
    return x, tm_prev, cm_prev, state


def _zero_states(cfg: ArchConfig, batch: int):
    H = cfg.d_model // cfg.rwkv_head_dim
    d = cfg.rwkv_head_dim
    return {
        "tm_prev": jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.float32),
        "cm_prev": jnp.zeros((cfg.n_layers, batch, cfg.d_model), jnp.float32),
        "state": jnp.zeros((cfg.n_layers, batch, H, d, d), jnp.float32),
    }


def forward(cfg: ArchConfig, params, tokens, prefix_embeds=None,
            unroll: bool = False, remat: bool = True):
    dtype = jnp.dtype(cfg.dtype)
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    x = nn.rms_norm(x, params["ln0"])
    B = x.shape[0]
    st = _zero_states(cfg, B)

    def body(x, xs):
        lp, tm0, cm0, s0 = xs
        x, _, _, _ = _layer(cfg, lp, x, tm0, cm0, s0)
        return x, None

    xs = (params["layers"], st["tm_prev"], st["cm_prev"], st["state"])
    if unroll:
        for i in range(cfg.n_layers):
            sl = jax.tree.map(lambda t: t[i], xs)
            x, _ = body(x, sl)
    else:
        x, _ = jax.lax.scan(body, x, xs)
    x = nn.rms_norm(x, params["final_norm"])
    return nn.dense(x, params["lm_head"])


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    st = _zero_states(cfg, batch)
    st["lengths"] = jnp.zeros((batch,), jnp.int32)
    return st


def decode_step(cfg: ArchConfig, params, cache, tokens):
    """tokens (B,1) -> (logits (B,1,V), new cache). O(1) in history length."""
    dtype = jnp.dtype(cfg.dtype)
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    x = nn.rms_norm(x, params["ln0"])

    def body(x, xs):
        lp, tm0, cm0, s0 = xs
        x, tm1, cm1, s1 = _layer(cfg, lp, x, tm0, cm0, s0, chunk=1)
        return x, (tm1.astype(jnp.float32), cm1.astype(jnp.float32), s1)

    xs = (params["layers"], cache["tm_prev"], cache["cm_prev"], cache["state"])
    x, (tm, cm, st) = jax.lax.scan(body, x, xs)
    x = nn.rms_norm(x, params["final_norm"])
    logits = nn.dense(x, params["lm_head"])
    return logits, {"tm_prev": tm, "cm_prev": cm, "state": st,
                    "lengths": cache["lengths"] + 1}


def prefill(cfg: ArchConfig, params, cache, tokens, prefix_embeds=None):
    """Run the prompt through, carrying decode state out."""
    dtype = jnp.dtype(cfg.dtype)
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    x = nn.rms_norm(x, params["ln0"])
    B, S = tokens.shape

    def body(x, xs):
        lp, tm0, cm0, s0 = xs
        x, tm1, cm1, s1 = _layer(cfg, lp, x, tm0, cm0, s0)
        return x, (tm1.astype(jnp.float32), cm1.astype(jnp.float32), s1)

    xs = (params["layers"], cache["tm_prev"], cache["cm_prev"], cache["state"])
    x, (tm, cm, st) = jax.lax.scan(body, x, xs)
    x = nn.rms_norm(x[:, -1:], params["final_norm"])
    logits = nn.dense(x, params["lm_head"])
    return logits, {"tm_prev": tm, "cm_prev": cm, "state": st,
                    "lengths": cache["lengths"] + S}
