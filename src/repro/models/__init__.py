# Model zoo: the paper's EfficientViT + the 10 assigned architectures.
from . import dense_lm, efficientvit, recurrentgemma, rwkv, whisper
from .config import ArchConfig

# family -> model module (moe_lm shares the dense_lm implementation; the
# internvl2 VLM is dense_lm + a stub patch-embedding prefix)
FAMILIES = {
    "dense_lm": dense_lm,
    "moe_lm": dense_lm,
    "rwkv": rwkv,
    "recurrentgemma": recurrentgemma,
    "whisper": whisper,
    "efficientvit": efficientvit,
}


def get_model(cfg: ArchConfig):
    return FAMILIES[cfg.family]
