"""Whisper-large-v3 backbone (encoder-decoder).

The conv/mel frontend is a STUB per the task spec: ``input_specs()`` feeds
precomputed frame embeddings (B, n_audio_ctx, D) — i.e. the output the two
stride-2 convs would produce.  Everything after that (sinusoidal enc
positions, 32 enc + 32 dec layers, cross attention, learned decoder
positions) is implemented.  Decoder position table is extended beyond
Whisper's 448 to cover the assigned 32k decode shapes (noted in DESIGN.md).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from .. import nn
from ..core import policy as pol
from .config import ArchConfig

QUANT_RULES = [
    (r"embed", pol.KIND_EMBEDDING),
    (r"pos", pol.KIND_SKIP),
    (r"lm_head", pol.KIND_HEAD),
    (r"(ln|norm|gamma|b_|bias)", pol.KIND_SKIP),
    (r"(self|cross)/w[qkvo]$", pol.KIND_DENSE),
    (r"mlp/w\d$", pol.KIND_DENSE),
]

MAX_TARGET_POSITIONS = 32768  # extended from whisper's 448 for decode_32k


def _sinusoid(n_pos: int, d: int) -> np.ndarray:
    pos = np.arange(n_pos)[:, None]
    i = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d)
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


def _init_attn(cfg, key, cross=False):
    ks = jax.random.split(key, 4)
    D = cfg.d_model
    return {
        "wq": nn.lecun_normal(ks[0], (D, cfg.q_dim)),
        "wk": nn.lecun_normal(ks[1], (D, cfg.kv_dim)),
        "wv": nn.lecun_normal(ks[2], (D, cfg.kv_dim)),
        "wo": nn.lecun_normal(ks[3], (cfg.q_dim, D)),
        "b_q": jnp.zeros((cfg.q_dim,), jnp.float32),
        "b_v": jnp.zeros((cfg.kv_dim,), jnp.float32),
        "b_o": jnp.zeros((D,), jnp.float32),
    }


def _init_mlp(cfg, key):
    ks = jax.random.split(key, 2)
    D, F = cfg.d_model, cfg.d_ff
    return {"w1": nn.lecun_normal(ks[0], (D, F)),
            "b_1": jnp.zeros((F,), jnp.float32),
            "w2": nn.lecun_normal(ks[1], (F, D)),
            "b_2": jnp.zeros((D,), jnp.float32)}


def _init_enc_layer(cfg, key):
    ks = jax.random.split(key, 2)
    D = cfg.d_model
    return {
        "ln1_g": jnp.ones((D,), jnp.float32), "ln1_b": jnp.zeros((D,), jnp.float32),
        "ln2_g": jnp.ones((D,), jnp.float32), "ln2_b": jnp.zeros((D,), jnp.float32),
        "self": _init_attn(cfg, ks[0]),
        "mlp": _init_mlp(cfg, ks[1]),
    }


def _init_dec_layer(cfg, key):
    ks = jax.random.split(key, 3)
    D = cfg.d_model
    return {
        "ln1_g": jnp.ones((D,), jnp.float32), "ln1_b": jnp.zeros((D,), jnp.float32),
        "lnx_g": jnp.ones((D,), jnp.float32), "lnx_b": jnp.zeros((D,), jnp.float32),
        "ln2_g": jnp.ones((D,), jnp.float32), "ln2_b": jnp.zeros((D,), jnp.float32),
        "self": _init_attn(cfg, ks[0]),
        "cross": _init_attn(cfg, ks[1]),
        "mlp": _init_mlp(cfg, ks[2]),
    }


def init(cfg: ArchConfig, key) -> dict:
    k_emb, k_enc, k_dec, k_pos = jax.random.split(key, 4)
    n_enc = cfg.n_enc_layers or cfg.n_layers
    enc = jax.vmap(lambda k: _init_enc_layer(cfg, k))(
        jax.random.split(k_enc, n_enc))
    dec = jax.vmap(lambda k: _init_dec_layer(cfg, k))(
        jax.random.split(k_dec, cfg.n_layers))
    D = cfg.d_model
    return {
        "embed": nn.trunc_normal(k_emb, (cfg.padded_vocab, D)),
        "pos_dec": nn.trunc_normal(k_pos, (MAX_TARGET_POSITIONS, D), std=0.01),
        "enc_layers": enc,
        "dec_layers": dec,
        "enc_ln_g": jnp.ones((D,), jnp.float32),
        "enc_ln_b": jnp.zeros((D,), jnp.float32),
        "dec_ln_g": jnp.ones((D,), jnp.float32),
        "dec_ln_b": jnp.zeros((D,), jnp.float32),
        # whisper ties lm_head to embed; we keep it tied via reuse in forward
    }


def _mha(cfg, ap, xq, xkv, causal, kv=None):
    """Returns attention output; kv overrides (precomputed cross kv)."""
    B, S = xq.shape[0], xq.shape[1]
    q = nn.dense(xq, ap["wq"], ap["b_q"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    if kv is None:
        T = xkv.shape[1]
        k = nn.dense(xkv, ap["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.head_dim)
        v = nn.dense(xkv, ap["wv"], ap["b_v"]).reshape(B, T, cfg.n_kv_heads,
                                                       cfg.head_dim)
    else:
        k, v = kv
    o = nn.flash_attention(q, k, v, causal=causal, bf16_mm=cfg.attn_bf16_mm,
                           causal_skip=cfg.causal_skip and causal)
    return nn.dense(o.reshape(B, S, cfg.q_dim), ap["wo"], ap["b_o"])


def _mlp(lp, x):
    return nn.dense(nn.gelu(nn.dense(x, lp["w1"], lp["b_1"])), lp["w2"], lp["b_2"])


def encode(cfg: ArchConfig, params, frames):
    """frames: (B, n_audio_ctx, D) stub frontend output -> encoder memory."""
    dtype = jnp.dtype(cfg.dtype)
    x = frames.astype(dtype) + jnp.asarray(
        _sinusoid(frames.shape[1], cfg.d_model), dtype)[None]

    def body(x, lp):
        h = nn.layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        x = x + _mha(cfg, lp["self"], h, h, causal=False)
        h = nn.layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + _mlp(lp["mlp"], h)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return nn.layer_norm(x, params["enc_ln_g"], params["enc_ln_b"])


def forward(cfg: ArchConfig, params, tokens, frames=None, memory=None,
            unroll: bool = False, remat: bool = True):
    """Teacher-forced decode over the full target sequence (train shape)."""
    dtype = jnp.dtype(cfg.dtype)
    if memory is None:
        memory = encode(cfg, params, frames)
    B, S = tokens.shape
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    x = x + params["pos_dec"][:S].astype(dtype)[None]

    def body(x, lp):
        h = nn.layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        x = x + _mha(cfg, lp["self"], h, h, causal=True)
        h = nn.layer_norm(x, lp["lnx_g"], lp["lnx_b"])
        x = x + _mha(cfg, lp["cross"], h, memory, causal=False)
        h = nn.layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + _mlp(lp["mlp"], h)
        return x, None

    if unroll:
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda t: t[i], params["dec_layers"])
            x, _ = body(x, lp)
    else:
        f = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(f, x, params["dec_layers"])
    x = nn.layer_norm(x, params["dec_ln_g"], params["dec_ln_b"])
    return nn.tied_head(x, params["embed"])  # tied head


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    L = cfg.n_layers
    return {
        "k": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((L, batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
        # cross-attention kv, precomputed once at prefill
        "xk": jnp.zeros((L, batch, cfg.n_audio_ctx, cfg.n_kv_heads,
                         cfg.head_dim), dtype),
        "xv": jnp.zeros((L, batch, cfg.n_audio_ctx, cfg.n_kv_heads,
                         cfg.head_dim), dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: ArchConfig, params, cache, tokens, frames=None):
    """Encode audio, precompute cross KV, and run the target prompt."""
    dtype = jnp.dtype(cfg.dtype)
    memory = encode(cfg, params, frames)
    B, S = tokens.shape
    T_mem = memory.shape[1]
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    x = x + params["pos_dec"][:S].astype(dtype)[None]

    def body(x, xs):
        lp, kc, vc = xs
        h = nn.layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        q = nn.dense(h, lp["self"]["wq"], lp["self"]["b_q"]).reshape(
            B, S, cfg.n_heads, cfg.head_dim)
        k = nn.dense(h, lp["self"]["wk"]).reshape(B, S, cfg.n_kv_heads,
                                                  cfg.head_dim)
        v = nn.dense(h, lp["self"]["wv"], lp["self"]["b_v"]).reshape(
            B, S, cfg.n_kv_heads, cfg.head_dim)
        kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, 0, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, 0, 0, 0))
        o = nn.flash_attention(q, k, v, causal=True,
                               bf16_mm=cfg.attn_bf16_mm,
                               causal_skip=cfg.causal_skip)
        x = x + nn.dense(o.reshape(B, S, cfg.q_dim), lp["self"]["wo"],
                         lp["self"]["b_o"])
        xk = nn.dense(memory, lp["cross"]["wk"]).reshape(
            B, T_mem, cfg.n_kv_heads, cfg.head_dim)
        xv = nn.dense(memory, lp["cross"]["wv"], lp["cross"]["b_v"]).reshape(
            B, T_mem, cfg.n_kv_heads, cfg.head_dim)
        h = nn.layer_norm(x, lp["lnx_g"], lp["lnx_b"])
        x = x + _mha(cfg, lp["cross"], h, None, causal=False,
                     kv=(xk, xv))
        h = nn.layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + _mlp(lp["mlp"], h)
        return x, (kc, vc, xk.astype(kc.dtype), xv.astype(kc.dtype))

    x, (k_new, v_new, xk, xv) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"]))
    x = nn.layer_norm(x[:, -1:], params["dec_ln_g"], params["dec_ln_b"])
    logits = nn.tied_head(x, params["embed"])
    return logits, {"k": k_new, "v": v_new, "xk": xk, "xv": xv,
                    "lengths": jnp.full((B,), S, jnp.int32)}


def decode_step(cfg: ArchConfig, params, cache, tokens):
    dtype = jnp.dtype(cfg.dtype)
    lengths = cache["lengths"] + 1
    B = tokens.shape[0]
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    pos = jnp.take(params["pos_dec"], lengths - 1, axis=0).astype(dtype)
    x = x + pos[:, None, :]  # (B,1,D)

    def body(x, xs):
        lp, kc, vc, xk, xv = xs
        h = nn.layer_norm(x, lp["ln1_g"], lp["ln1_b"])
        q = nn.dense(h, lp["self"]["wq"], lp["self"]["b_q"]).reshape(
            B, 1, cfg.n_heads, cfg.head_dim)
        k = nn.dense(h, lp["self"]["wk"]).reshape(B, 1, cfg.n_kv_heads,
                                                  cfg.head_dim)
        v = nn.dense(h, lp["self"]["wv"], lp["self"]["b_v"]).reshape(
            B, 1, cfg.n_kv_heads, cfg.head_dim)
        bidx = jnp.arange(B)
        kc = kc.at[bidx, lengths - 1].set(k[:, 0].astype(kc.dtype))
        vc = vc.at[bidx, lengths - 1].set(v[:, 0].astype(vc.dtype))
        o = nn.decode_attention(q, kc, vc, lengths, bf16_mm=cfg.attn_bf16_mm)
        x = x + nn.dense(o.reshape(B, 1, cfg.q_dim), lp["self"]["wo"],
                         lp["self"]["b_o"])
        h = nn.layer_norm(x, lp["lnx_g"], lp["lnx_b"])
        qx = nn.dense(h, lp["cross"]["wq"], lp["cross"]["b_q"]).reshape(
            B, 1, cfg.n_heads, cfg.head_dim)
        T_mem = xk.shape[1]
        ox = nn.decode_attention(qx, xk, xv,
                                 jnp.full((B,), T_mem, jnp.int32),
                                 bf16_mm=cfg.attn_bf16_mm)
        x = x + nn.dense(ox.reshape(B, 1, cfg.q_dim), lp["cross"]["wo"],
                         lp["cross"]["b_o"])
        h = nn.layer_norm(x, lp["ln2_g"], lp["ln2_b"])
        x = x + _mlp(lp["mlp"], h)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = nn.layer_norm(x, params["dec_ln_g"], params["dec_ln_b"])
    logits = nn.tied_head(x, params["embed"])
    return logits, {"k": k_new, "v": v_new, "xk": cache["xk"],
                    "xv": cache["xv"], "lengths": lengths}
