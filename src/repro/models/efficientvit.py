"""EfficientViT (the paper's backbone, Fig. 1): Convolution-Transformer
hybrid with MBConvs + lightweight multi-scale ReLU linear attention (MSA).

Layer taxonomy matches the paper's Sec. III-A exactly:
  * PWConvs (1x1) and the MSA MatMuls -> computation-intensive -> mixed
    uniform8/APoT (KIND_DENSE);
  * DWConvs -> memory-intensive -> 4-bit uniform (KIND_DWCONV).

B1: widths (16,32,64,128,256), depths (1,2,3,3,4); B2: widths
(24,48,96,192,384), depths (1,3,4,4,6).  Norms are channel LayerNorms
(functional stand-in for BN; noted in DESIGN.md), activation is Hardswish->
we use SiLU (same family).  NHWC layout throughout.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .. import nn
from ..core import policy as pol
from .config import ArchConfig

QUANT_RULES = [
    (r"(ln|norm|gamma|bias|b$)", pol.KIND_SKIP),
    # every (kh,kw,1,C) depthwise filter is memory-intensive (Sec. III-A):
    # the MBConv 3x3 (w_dw) AND the MSA 5x5 aggregation (w_agg) — w_agg was
    # historically mis-filed under KIND_DENSE despite its depthwise shape
    (r"(w_dw|w_agg)", pol.KIND_DWCONV),
    (r"(w_pw\d?|w_in|w_out|w_qkv|w_proj)", pol.KIND_DENSE),
    (r"head/w", pol.KIND_DENSE),
]

# Per-arch recipe defaults (see repro.recipe): the paper's Sec. III-A split
# is STRUCTURAL — PWConv/MatMul are computation-intensive, DWConv memory-
# intensive — independent of deployment shape, so pin every dense-kind path
# to the mixed decision instead of steering intensity_threshold (the
# reduced proxy's widths sit far below any MXU ridge point and would
# otherwise classify memory-bound).  DWConv/embedding stay structurally
# low-bit in policy.decide regardless of these overrides.
QUANT_OVERRIDES = (
    (r"(w_pw\d?|w_in|w_out|w_qkv|w_proj|head/w)",
     pol.PathOverride(decision=pol.DECISION_MIXED)),
)

# Opt-in int8 stem (ROADMAP item): the 3x3 cin=3 stem stays f32 by default
# (QUANT_RULES does not match it), but a recipe may quantize it and run it
# as an im2col + int8 matmul (nn.layers routes non-1x1 quantized filters
# through patch extraction + the PWConv matmul hot path):
#
#     rec = PRESETS["m2q-w8a8"].replace(
#         rules=tuple(QUANT_RULES) + (STEM_RULE,),
#         overrides=(STEM_OVERRIDE,))
#
# The override pins uniform-8 W8A8 (the stem's 27-row filter is too small
# for the intensity classifier to place reliably, and mixed-scheme buys
# nothing at cin=3); recipe-level overrides precede QUANT_OVERRIDES, so the
# taxonomy pins above are unaffected.
STEM_RULE = (r"stem/w$", pol.KIND_DENSE)
STEM_OVERRIDE = (r"stem/w$", pol.PathOverride(decision=pol.DECISION_MIXED,
                                              scheme="uniform8"))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _conv(key, kh, kw, cin, cout):
    return nn.lecun_normal(key, (kh, kw, cin, cout))


def _init_mbconv(key, cin, cout, expand=4):
    ks = jax.random.split(key, 3)
    mid = cin * expand
    return {
        "w_pw1": _conv(ks[0], 1, 1, cin, mid),
        "w_dw": nn.lecun_normal(ks[1], (3, 3, 1, mid)),
        "w_pw2": _conv(ks[2], 1, 1, mid, cout),
        "ln1": jnp.ones((mid,), jnp.float32),
        "ln2": jnp.ones((cout,), jnp.float32),
    }


def _init_msa(key, c, dim_per_head=16):
    """Lite multi-scale attention: qkv pwconv, a 5x5 depthwise aggregation
    producing a second token scale, ReLU linear attention, output proj."""
    ks = jax.random.split(key, 4)
    d = 3 * c
    return {
        "w_qkv": _conv(ks[0], 1, 1, c, d),
        "w_agg": nn.lecun_normal(ks[1], (5, 5, 1, d)),  # depthwise multi-scale
        "w_proj": _conv(ks[2], 1, 1, 2 * c, c),
        "ln": jnp.ones((c,), jnp.float32),
    }


def init(cfg: ArchConfig, key) -> dict:
    widths, depths = cfg.widths, cfg.depths
    keys = jax.random.split(key, 64)
    ki = iter(range(64))
    params = {
        "stem": {"w": _conv(keys[next(ki)], 3, 3, 3, widths[0]),
                 "ln": jnp.ones((widths[0],), jnp.float32)},
        "stages": [],
        "head": {},
    }
    cin = widths[0]
    stages = []
    for si, (w, d) in enumerate(zip(widths, depths)):
        blocks = []
        for bi in range(d):
            # stage-entry blocks (bi==0, si>0) run their depthwise conv at
            # stride 2 — decided in forward(); _init_mbconv is stride-
            # agnostic because only w_dw sees the stride and the residual
            # is gated on stride==1 AND matching channels in _mbconv
            blk = {"mb": _init_mbconv(keys[next(ki)], cin, w)}
            if si >= len(widths) - 2:  # last two stages get MSA (transformer)
                blk["msa"] = _init_msa(keys[next(ki)], w, cfg.dim_per_head)
            blocks.append(blk)
            cin = w
        stages.append(blocks)
    params["stages"] = stages
    params["head"] = {
        "w_in": _conv(keys[next(ki)], 1, 1, cin, cin * 4),
        "ln": jnp.ones((cin * 4,), jnp.float32),
        "w": nn.lecun_normal(keys[next(ki)], (cin * 4, cfg.n_classes)),
    }
    return params


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _cln(x, g):  # channel layernorm (BN stand-in)
    return nn.rms_norm(x, g)


def _mbconv(p, x, stride=1):
    h = nn.conv2d(x, p["w_pw1"])
    h = nn.silu(_cln(h, p["ln1"]))
    h = nn.dwconv2d(h, p["w_dw"], stride=stride)
    h = nn.silu(h)
    h = nn.conv2d(h, p["w_pw2"])
    h = _cln(h, p["ln2"])
    if stride == 1 and x.shape[-1] == h.shape[-1]:
        h = h + x
    return h


def _msa(p, x, dim_per_head=16):
    B, H, W, C = x.shape
    qkv = nn.conv2d(_cln(x, p["ln"]), p["w_qkv"])  # (B,H,W,3C)
    qkv2 = nn.dwconv2d(qkv, p["w_agg"])  # second scale (5x5 aggregation)
    outs = []
    # both token scales run through nn.relu_linear_attention, which routes
    # to the fused int8 Pallas kernel under kernels.ops dispatch (the attn
    # axis) — the accelerator's low-precision engines cover the MSA
    # MatMuls, not just the conv halves
    for t in (qkv, qkv2):
        q, k, v = jnp.split(t.reshape(B, H * W, 3 * C), 3, axis=-1)
        nh = C // dim_per_head
        q = q.reshape(B, H * W, nh, dim_per_head)
        k = k.reshape(B, H * W, nh, dim_per_head)
        v = v.reshape(B, H * W, nh, dim_per_head)
        o = nn.relu_linear_attention(q, k, v)
        outs.append(o.reshape(B, H, W, C))
    o = jnp.concatenate(outs, axis=-1)  # (B,H,W,2C)
    return x + nn.conv2d(o, p["w_proj"])


def forward(cfg: ArchConfig, params, images, unroll: bool = False,
            remat: bool = False):
    """images: (B, res, res, 3) -> logits (B, n_classes)."""
    dtype = jnp.dtype(cfg.dtype)
    x = images.astype(dtype)
    x = nn.conv2d(x, params["stem"]["w"], stride=2)
    x = nn.silu(_cln(x, params["stem"]["ln"]))
    for si, blocks in enumerate(params["stages"]):
        for bi, blk in enumerate(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            x = _mbconv(blk["mb"], x, stride=stride)
            if "msa" in blk:
                x = _msa(blk["msa"], x, cfg.dim_per_head)
    x = nn.conv2d(x, params["head"]["w_in"])
    x = nn.silu(_cln(x, params["head"]["ln"]))
    x = jnp.mean(x, axis=(1, 2))  # global pool
    return nn.dense(x, params["head"]["w"])
