"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local attention
in a 1:2 (attn:recurrent) pattern — layer i is attention iff i % 3 == 2.

The recurrent layers are scanned in (rec, rec, attn) groups; leftover
recurrent layers (38 = 12*3 + 2) are unrolled at the tail.  Local attention
uses a *ring-buffer* KV cache bounded by the window (2048), and the RG-LRU
state is O(1) — together these make the ``long_500k`` decode cell run with a
constant ~window-sized memory footprint.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .. import nn
from ..core import policy as pol
from .config import ArchConfig

FFN_FOLD_GROUPS = [
    (r"rec/mlp/w1$", r"rec/mlp/w3$", r"rec/mlp/w2$"),
    (r"attn/mlp/w1$", r"attn/mlp/w3$", r"attn/mlp/w2$"),
]

QUANT_RULES = [
    (r"embed", pol.KIND_EMBEDDING),
    (r"lm_head", pol.KIND_HEAD),
    (r"(ln|norm|gamma|lam|conv_b|b_)", pol.KIND_SKIP),
    (r"conv_w", pol.KIND_SKIP),  # (4, R) temporal conv: tiny, bf16
    (r"(wa|wx|w_in1|w_in2|w_out)$", pol.KIND_DENSE),
    (r"attn/w[qkvo]$", pol.KIND_DENSE),
    (r"mlp/w\d$", pol.KIND_DENSE),
]


def n_attn_layers(cfg: ArchConfig) -> int:
    return sum(1 for i in range(cfg.n_layers) if i % 3 == 2)


def n_rec_layers(cfg: ArchConfig) -> int:
    return cfg.n_layers - n_attn_layers(cfg)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_rec(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    D, R, F = cfg.d_model, cfg.lru_width or cfg.d_model, cfg.d_ff
    return {
        "ln1": jnp.ones((D,), jnp.float32),
        "ln2": jnp.ones((D,), jnp.float32),
        "mix": {
            "w_in1": nn.lecun_normal(ks[0], (D, R)),
            "w_in2": nn.lecun_normal(ks[1], (D, R)),
            "w_out": nn.lecun_normal(ks[2], (R, D)),
            "conv_w": nn.trunc_normal(ks[3], (cfg.conv1d_width, R), std=0.1),
            "conv_b": jnp.zeros((R,), jnp.float32),
            "wa": nn.lecun_normal(ks[4], (R, R)),
            "wx": nn.lecun_normal(ks[5], (R, R)),
            "ba": jnp.zeros((R,), jnp.float32),
            "bx": jnp.zeros((R,), jnp.float32),
            # Λ init so a ~ U(0.9, 0.999) at r=1 (Griffin appendix)
            "lam": jnp.linspace(0.5, 4.0, R, dtype=jnp.float32),
        },
        "mlp": _init_mlp(cfg, ks[6]),
    }


def _init_mlp(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 3)
    D, F = cfg.d_model, cfg.d_ff
    return {
        "w1": nn.lecun_normal(ks[0], (D, F)),
        "w3": nn.lecun_normal(ks[1], (D, F)),
        "w2": nn.lecun_normal(ks[2], (F, D)),
    }


def _init_attn(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 6)
    D = cfg.d_model
    return {
        "ln1": jnp.ones((D,), jnp.float32),
        "ln2": jnp.ones((D,), jnp.float32),
        "attn": {
            "wq": nn.lecun_normal(ks[0], (D, cfg.q_dim)),
            "wk": nn.lecun_normal(ks[1], (D, cfg.kv_dim)),
            "wv": nn.lecun_normal(ks[2], (D, cfg.kv_dim)),
            "wo": nn.lecun_normal(ks[3], (cfg.q_dim, D)),
        },
        "mlp": _init_mlp(cfg, ks[4]),
    }


def init(cfg: ArchConfig, key) -> dict:
    k_emb, k_rec, k_attn, k_head = jax.random.split(key, 4)
    nr, na = n_rec_layers(cfg), n_attn_layers(cfg)
    rec = jax.vmap(lambda k: _init_rec(cfg, k))(jax.random.split(k_rec, nr))
    attn = jax.vmap(lambda k: _init_attn(cfg, k))(jax.random.split(k_attn, na))
    return {
        "embed": nn.trunc_normal(k_emb, (cfg.padded_vocab, cfg.d_model)),
        "rec": rec,
        "attn": attn,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": nn.lecun_normal(k_head, (cfg.d_model, cfg.padded_vocab)),
    }


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _rec_mix(cfg, mp, x, h0, conv0):
    """Griffin recurrent mixer. x: (B,T,D). Returns (y, h_T, conv_state)."""
    u = nn.dense(x, mp["w_in1"])
    gate = nn.gelu(nn.dense(x, mp["w_in2"]))
    u, conv_state = nn.temporal_conv1d(u, mp["conv_w"], mp["conv_b"], state=conv0)
    h_final, h = nn.rg_lru(u, h0, mp)
    y = nn.dense(h * gate, mp["w_out"])
    return y, h_final, conv_state


def _rec_layer(cfg, lp, x, h0, conv0):
    y, h, cs = _rec_mix(cfg, lp["mix"], nn.rms_norm(x, lp["ln1"]), h0, conv0)
    x = x + y
    m = lp["mlp"]
    x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"]), m["w1"], m["w3"], m["w2"])
    return x, h, cs


def _attn_layer(cfg, lp, x, positions):
    a = lp["attn"]
    h = nn.rms_norm(x, lp["ln1"])
    B, S = x.shape[0], x.shape[1]
    q = nn.dense(h, a["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = nn.dense(h, a["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = nn.dense(h, a["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    k = nn.apply_rope(k, positions, cfg.rope_theta)
    o = nn.flash_attention(q, k, v, causal=True, window=cfg.window,
                           bf16_mm=cfg.attn_bf16_mm,
                           causal_skip=cfg.causal_skip)
    x = x + nn.dense(o.reshape(B, S, cfg.q_dim), a["wo"])
    m = lp["mlp"]
    x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"]), m["w1"], m["w3"], m["w2"])
    return x


# ---------------------------------------------------------------------------
# forward (train / prefill shape)
# ---------------------------------------------------------------------------


def _group_counts(cfg) -> Tuple[int, int]:
    g = cfg.n_layers // 3
    extra = cfg.n_layers - 3 * g  # leftover recurrent layers (pattern rec,rec,attn)
    return g, extra


def forward(cfg: ArchConfig, params, tokens, prefix_embeds=None,
            unroll: bool = False, remat: bool = True):
    dtype = jnp.dtype(cfg.dtype)
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]
    R = cfg.lru_width or cfg.d_model
    G, extra = _group_counts(cfg)

    rec_groups = jax.tree.map(
        lambda t: t[: 2 * G].reshape(G, 2, *t.shape[1:]), params["rec"])

    def group_body(x, xs):
        rec2, attn1 = xs
        for j in range(2):
            lp = jax.tree.map(lambda t: t[j], rec2)
            h0 = jnp.zeros((B, R), jnp.float32)
            c0 = jnp.zeros((B, cfg.conv1d_width - 1, R), dtype)
            x, _, _ = _rec_layer(cfg, lp, x, h0, c0)
        x = _attn_layer(cfg, attn1, x, positions)
        return x, None

    if unroll:
        for g in range(G):
            sl = jax.tree.map(lambda t: t[g], (rec_groups, params["attn"]))
            x, _ = group_body(x, sl)
    else:
        body = jax.checkpoint(group_body) if remat else group_body
        x, _ = jax.lax.scan(body, x, (rec_groups, params["attn"]))
    for i in range(extra):
        lp = jax.tree.map(lambda t: t[2 * G + i], params["rec"])
        h0 = jnp.zeros((B, R), jnp.float32)
        c0 = jnp.zeros((B, cfg.conv1d_width - 1, R), dtype)
        x, _, _ = _rec_layer(cfg, lp, x, h0, c0)
    x = nn.rms_norm(x, params["final_norm"])
    return nn.dense(x, params["lm_head"])


# ---------------------------------------------------------------------------
# decode (ring-buffer local attention + carried LRU/conv state)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    R = cfg.lru_width or cfg.d_model
    W = min(cfg.window or max_len, max_len)
    nr, na = n_rec_layers(cfg), n_attn_layers(cfg)
    return {
        "h": jnp.zeros((nr, batch, R), jnp.float32),
        "conv": jnp.zeros((nr, batch, cfg.conv1d_width - 1, R), dtype),
        "k": jnp.zeros((na, batch, W, cfg.n_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((na, batch, W, cfg.n_kv_heads, cfg.head_dim), dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def _attn_decode(cfg, lp, x, kc, vc, lengths):
    """Ring-buffer windowed decode. kc/vc: (B, W, Hkv, hd)."""
    a = lp["attn"]
    B = x.shape[0]
    W = kc.shape[1]
    h = nn.rms_norm(x, lp["ln1"])
    pos = (lengths - 1)[:, None]
    q = nn.dense(h, a["wq"]).reshape(B, 1, cfg.n_heads, cfg.head_dim)
    k = nn.dense(h, a["wk"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    v = nn.dense(h, a["wv"]).reshape(B, 1, cfg.n_kv_heads, cfg.head_dim)
    q = nn.apply_rope(q, pos, cfg.rope_theta)
    k = nn.apply_rope(k, pos, cfg.rope_theta)  # rope at write time
    slot = (lengths - 1) % W
    bidx = jnp.arange(B)
    kc = kc.at[bidx, slot].set(k[:, 0].astype(kc.dtype))
    vc = vc.at[bidx, slot].set(v[:, 0].astype(vc.dtype))
    # ring semantics: valid slots = min(length, W); order irrelevant to softmax
    o = nn.decode_attention(q, kc, vc, jnp.minimum(lengths, W),
                            bf16_mm=cfg.attn_bf16_mm)
    x = x + nn.dense(o.reshape(B, 1, cfg.q_dim), a["wo"])
    m = lp["mlp"]
    x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"]), m["w1"], m["w3"], m["w2"])
    return x, kc, vc


def _rec_decode(cfg, lp, x, h0, conv0):
    mp = lp["mix"]
    hx = nn.rms_norm(x, lp["ln1"])
    u = nn.dense(hx, mp["w_in1"])
    gate = nn.gelu(nn.dense(hx, mp["w_in2"]))
    u, conv_state = nn.temporal_conv1d(u, mp["conv_w"], mp["conv_b"], state=conv0)
    h_new, y = nn.rg_lru_step(u[:, 0], h0, mp)
    y = nn.dense(y[:, None] * gate, mp["w_out"])
    x = x + y
    m = lp["mlp"]
    x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"]), m["w1"], m["w3"], m["w2"])
    return x, h_new, conv_state


def _ring_fill(kc, k, S):
    """Write the last min(S, W) of k (B,S,..) into ring slots (pos %% W)."""
    W = kc.shape[1]
    n = min(S, W)
    take = max(S - W, 0) + jnp.arange(n)
    slots = take % W
    rows = jnp.take(k, take, axis=1).astype(kc.dtype)
    return kc.at[:, slots].set(rows)


def _attn_prefill(cfg, lp, x, kc, vc, positions):
    a = lp["attn"]
    B, S = x.shape[0], x.shape[1]
    h = nn.rms_norm(x, lp["ln1"])
    q = nn.dense(h, a["wq"]).reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = nn.dense(h, a["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = nn.dense(h, a["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    k = nn.apply_rope(k, positions, cfg.rope_theta)
    kc = _ring_fill(kc, k, S)
    vc = _ring_fill(vc, v, S)
    o = nn.flash_attention(q, k, v, causal=True, window=cfg.window,
                           bf16_mm=cfg.attn_bf16_mm,
                           causal_skip=cfg.causal_skip)
    x = x + nn.dense(o.reshape(B, S, cfg.q_dim), a["wo"])
    m = lp["mlp"]
    x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"]), m["w1"], m["w3"], m["w2"])
    return x, kc, vc


def prefill(cfg: ArchConfig, params, cache, tokens, prefix_embeds=None):
    """Prompt pass carrying LRU/conv state + windowed ring KV caches out."""
    dtype = jnp.dtype(cfg.dtype)
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    B, S = tokens.shape
    positions = jnp.arange(S)[None, :]

    new_h, new_conv, new_k, new_v = [], [], [], []
    ri, ai = 0, 0
    for i in range(cfg.n_layers):
        if i % 3 == 2:
            lp = jax.tree.map(lambda t: t[ai], params["attn"])
            x, kc, vc = _attn_prefill(cfg, lp, x, cache["k"][ai],
                                      cache["v"][ai], positions)
            new_k.append(kc)
            new_v.append(vc)
            ai += 1
        else:
            lp = jax.tree.map(lambda t: t[ri], params["rec"])
            h = nn.rms_norm(x, lp["ln1"])
            y, hf, cs = _rec_mix(cfg, lp["mix"], h, cache["h"][ri],
                                 cache["conv"][ri])
            x = x + y
            m = lp["mlp"]
            x = x + nn.swiglu(nn.rms_norm(x, lp["ln2"]), m["w1"], m["w3"],
                              m["w2"])
            new_h.append(hf)
            new_conv.append(cs)
            ri += 1
    xl = nn.rms_norm(x[:, -1:], params["final_norm"])
    logits = nn.dense(xl, params["lm_head"])
    return logits, {
        "h": jnp.stack(new_h), "conv": jnp.stack(new_conv),
        "k": jnp.stack(new_k), "v": jnp.stack(new_v),
        "lengths": cache["lengths"] + S,
    }


def decode_step(cfg: ArchConfig, params, cache, tokens):
    dtype = jnp.dtype(cfg.dtype)
    lengths = cache["lengths"] + 1
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    G, extra = _group_counts(cfg)
    nr = n_rec_layers(cfg)

    h_all, conv_all = cache["h"], cache["conv"]
    k_all, v_all = cache["k"], cache["v"]
    new_h, new_conv, new_k, new_v = [], [], [], []
    ri, ai = 0, 0
    for i in range(cfg.n_layers):
        if i % 3 == 2:
            lp = jax.tree.map(lambda t: t[ai], params["attn"])
            x, kc, vc = _attn_decode(cfg, lp, x, k_all[ai], v_all[ai], lengths)
            new_k.append(kc)
            new_v.append(vc)
            ai += 1
        else:
            lp = jax.tree.map(lambda t: t[ri], params["rec"])
            x, h, cs = _rec_decode(cfg, lp, x, h_all[ri], conv_all[ri])
            new_h.append(h)
            new_conv.append(cs)
            ri += 1
    x = nn.rms_norm(x, params["final_norm"])
    logits = nn.dense(x, params["lm_head"])
    return logits, {
        "h": jnp.stack(new_h), "conv": jnp.stack(new_conv),
        "k": jnp.stack(new_k), "v": jnp.stack(new_v), "lengths": lengths,
    }
