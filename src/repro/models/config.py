"""Unified architecture config for the assigned pool + the paper's models."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense_lm | moe_lm | rwkv | recurrentgemma | whisper | efficientvit
    n_layers: int
    d_model: int
    vocab_size: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 128
    d_ff: int = 0
    qkv_bias: bool = False
    qk_norm: bool = False
    ffn: str = "swiglu"  # swiglu | relu2 | gelu (classic 2-matrix MLP)
    rope_theta: float = 10000.0
    norm: str = "rms"  # rms | layer
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    # hybrid / local attention
    window: Optional[int] = None
    block_pattern: Tuple[str, ...] = ()  # e.g. ("rec", "rec", "attn")
    lru_width: int = 0
    conv1d_width: int = 4
    # rwkv
    rwkv_head_dim: int = 64
    # encoder-decoder (whisper): n_layers = decoder layers
    n_enc_layers: int = 0
    n_audio_ctx: int = 1500
    # vlm stub frontend
    n_patches: int = 0
    # efficientvit (vision)
    widths: Tuple[int, ...] = ()
    depths: Tuple[int, ...] = ()
    img_res: int = 224
    n_classes: int = 1000
    dim_per_head: int = 16  # EfficientViT MSA head dim
    # perf knobs (EXPERIMENTS.md §Perf; defaults = recorded baseline)
    attn_bf16_mm: bool = False   # MXU-native bf16 attention dots, f32 accum
    causal_skip: bool = False    # triangular chunk scan (skip masked pairs)
    act_sharding: str = ""       # ""|"data"|"pod+data": pin activation batch
                                 # sharding at block boundaries (anti-reshard)
    remat_policy: str = "full"   # full|dots: checkpoint policy for the
                                 # layer scan (dots = keep MXU outputs)
    kv_cache_dtype: str = "bf16"  # bf16|int8: int8 = M2Q applied to the KV
                                  # cache (per-row scales, integer attention)
    # numerics
    dtype: str = "bfloat16"

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128: TP-16 shardable and even per
        shard (int4 nibble packing needs even filter counts)."""
        return round_up(self.vocab_size, 128)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)
