"""Decoder-only transformer LM family.

Covers qwen1.5-0.5b (QKV bias, MHA), qwen3-14b (qk_norm, GQA),
granite-3-8b (GQA), minitron-4b (GQA, squared-ReLU FFN), internvl2-2b
(InternLM2 backbone + stub patch-embedding prefix), and the MoE variants
(llama4-scout, dbrx) via ``cfg.moe_experts > 0``.

Layer parameters are *stacked* along a leading L axis and executed with
``lax.scan`` (compact HLO — essential for compiling 40-layer full-size
configs in the dry-run).  ``unroll=True`` runs a python loop instead, which
is what PTQ calibration uses (CalibTensor observers are not traceable).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec

from .. import nn
from ..core import policy as pol
from .config import ArchConfig


def _csc(x, cfg: ArchConfig):
    """Pin the batch axis of an activation to the data axes (replicated on
    model) — prevents XLA SPMD from replicating batch / sharding attention
    contractions inside the chunk loops (EXPERIMENTS.md §Perf iter 1)."""
    if not cfg.act_sharding:
        return x
    axes = tuple(cfg.act_sharding.split("+"))
    spec = PartitionSpec(axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)

# perm-foldable FFN filter groups: (up, gate|None, down) path regexes
FFN_FOLD_GROUPS = [
    (r"layers/mlp/w1$", r"layers/mlp/w3$", r"layers/mlp/w2$"),   # swiglu
    (r"layers/mlp/w1$", None, r"layers/mlp/w2$"),                # relu2
    (r"layers/shared/w1$", r"layers/shared/w3$", r"layers/shared/w2$"),
]

# prefill() accepts per-row lengths with right-padded prompts (attention
# caches mask positions >= length; recurrent families must not see pad
# tokens, so they leave this unset and the engine buckets by exact length)
RAGGED_PREFILL = True

# quantization rules: path regex -> layer kind (first match wins)
QUANT_RULES = [
    (r"embed", pol.KIND_EMBEDDING),
    (r"lm_head", pol.KIND_HEAD),
    (r"experts/", pol.KIND_EXPERT),
    (r"router", pol.KIND_SKIP),
    (r"(ln|norm|gamma|scale|bias|b_)", pol.KIND_SKIP),
    (r"attn/w[qkvo]$", pol.KIND_DENSE),
    (r"(mlp|shared)/w\d$", pol.KIND_DENSE),
]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_layer(cfg: ArchConfig, key) -> dict:
    ks = jax.random.split(key, 12)
    D, F = cfg.d_model, cfg.d_ff
    p = {
        "ln1": jnp.ones((D,), jnp.float32),
        "ln2": jnp.ones((D,), jnp.float32),
        "attn": {
            "wq": nn.lecun_normal(ks[0], (D, cfg.q_dim)),
            "wk": nn.lecun_normal(ks[1], (D, cfg.kv_dim)),
            "wv": nn.lecun_normal(ks[2], (D, cfg.kv_dim)),
            "wo": nn.lecun_normal(ks[3], (cfg.q_dim, D)),
        },
    }
    if cfg.qkv_bias:
        p["attn"]["bq"] = jnp.zeros((cfg.q_dim,), jnp.float32)
        p["attn"]["bk"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
        p["attn"]["bv"] = jnp.zeros((cfg.kv_dim,), jnp.float32)
    if cfg.qk_norm:
        p["attn"]["q_gamma"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["attn"]["k_gamma"] = jnp.ones((cfg.head_dim,), jnp.float32)
    if cfg.moe_experts:
        E, Fm = cfg.moe_experts, cfg.moe_d_ff or cfg.d_ff
        p["moe"] = {
            "router": nn.lecun_normal(ks[4], (D, E)),
            "experts": {
                "w1": nn.lecun_normal(ks[5], (E, D, Fm)),
                "w3": nn.lecun_normal(ks[6], (E, D, Fm)),
                "w2": nn.lecun_normal(ks[7], (E, Fm, D)),
            },
        }
        if cfg.moe_shared_expert:
            p["shared"] = {
                "w1": nn.lecun_normal(ks[8], (D, Fm)),
                "w3": nn.lecun_normal(ks[9], (D, Fm)),
                "w2": nn.lecun_normal(ks[10], (Fm, D)),
            }
    else:
        if cfg.ffn == "relu2":
            p["mlp"] = {
                "w1": nn.lecun_normal(ks[5], (D, F)),
                "w2": nn.lecun_normal(ks[6], (F, D)),
            }
        else:  # swiglu
            p["mlp"] = {
                "w1": nn.lecun_normal(ks[5], (D, F)),
                "w3": nn.lecun_normal(ks[6], (D, F)),
                "w2": nn.lecun_normal(ks[7], (F, D)),
            }
    return p


def init(cfg: ArchConfig, key) -> dict:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _init_layer(cfg, k))(layer_keys)
    return {
        "embed": nn.trunc_normal(k_emb, (cfg.padded_vocab, cfg.d_model)),
        "layers": layers,
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": nn.lecun_normal(k_head, (cfg.d_model, cfg.padded_vocab)),
    }


# ---------------------------------------------------------------------------
# block
# ---------------------------------------------------------------------------


def _qkv(cfg: ArchConfig, lp, x, positions):
    a = lp["attn"]
    q = nn.dense(x, a["wq"], a.get("bq"))
    k = nn.dense(x, a["wk"], a.get("bk"))
    v = nn.dense(x, a["wv"], a.get("bv"))
    B, S = x.shape[0], x.shape[1]
    q = q.reshape(B, S, cfg.n_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = nn.qk_rms_norm(q, a["q_gamma"])
        k = nn.qk_rms_norm(k, a["k_gamma"])
    q = nn.apply_rope(q, positions, cfg.rope_theta)
    k = nn.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _ffn(cfg: ArchConfig, lp, x):
    if cfg.moe_experts:
        B, S, D = x.shape
        y = nn.moe_ffn(
            x.reshape(B * S, D), lp["moe"],
            nn.MoEConfig(num_experts=cfg.moe_experts, top_k=cfg.moe_top_k,
                         d_model=cfg.d_model, d_ff=cfg.moe_d_ff or cfg.d_ff,
                         capacity_factor=cfg.moe_capacity_factor,
                         constrain_ep=cfg.act_sharding),
        ).reshape(B, S, D)
        if cfg.moe_shared_expert:
            s = lp["shared"]
            y = y + nn.swiglu(x, s["w1"], s["w3"], s["w2"])
        return y
    m = lp["mlp"]
    if cfg.ffn == "relu2":
        return nn.dense(jnp.square(jax.nn.relu(nn.dense(x, m["w1"]))), m["w2"])
    return nn.swiglu(x, m["w1"], m["w3"], m["w2"])


def block(cfg: ArchConfig, lp, x, positions):
    x = _csc(x, cfg)
    h = nn.rms_norm(x, lp["ln1"])
    q, k, v = _qkv(cfg, lp, h, positions)
    q, k, v = _csc(q, cfg), _csc(k, cfg), _csc(v, cfg)
    o = nn.flash_attention(q, k, v, causal=True, window=cfg.window,
                           bf16_mm=cfg.attn_bf16_mm,
                           causal_skip=cfg.causal_skip)
    o = nn.dense(_csc(o, cfg).reshape(*x.shape[:2], cfg.q_dim),
                 lp["attn"]["wo"])
    x = x + _csc(o, cfg)
    x = x + _csc(_ffn(cfg, lp, nn.rms_norm(x, lp["ln2"])), cfg)
    return x


def block_decode(cfg: ArchConfig, lp, x, kv, lengths):
    """One-token decode; kv is the per-layer cache slice dict; returns
    (x, new kv).  int8 caches use the fully-integer attention path."""
    B = x.shape[0]
    h = nn.rms_norm(x, lp["ln1"])
    positions = (lengths - 1)[:, None]  # (B, 1) absolute position of new token
    q, k, v = _qkv(cfg, lp, h, positions)
    bidx = jnp.arange(B)
    if cfg.kv_cache_dtype == "int8":
        k8, ks = nn.quantize_kv_rows(k[:, 0])
        v8, vs = nn.quantize_kv_rows(v[:, 0])
        kv = dict(kv)
        kv["k"] = kv["k"].at[bidx, lengths - 1].set(k8)
        kv["v"] = kv["v"].at[bidx, lengths - 1].set(v8)
        kv["k_scale"] = kv["k_scale"].at[bidx, lengths - 1].set(ks)
        kv["v_scale"] = kv["v_scale"].at[bidx, lengths - 1].set(vs)
        o = nn.decode_attention_int8(q, kv["k"], kv["v"], kv["k_scale"],
                                     kv["v_scale"], lengths,
                                     window=cfg.window)
    else:
        kv = dict(kv)
        kv["k"] = kv["k"].at[bidx, lengths - 1].set(
            k[:, 0].astype(kv["k"].dtype))
        kv["v"] = kv["v"].at[bidx, lengths - 1].set(
            v[:, 0].astype(kv["v"].dtype))
        o = nn.decode_attention(q, kv["k"], kv["v"], lengths,
                                window=cfg.window, bf16_mm=cfg.attn_bf16_mm)
    o = nn.dense(o.reshape(B, 1, cfg.q_dim), lp["attn"]["wo"])
    x = x + o
    x = x + _ffn(cfg, lp, nn.rms_norm(x, lp["ln2"]))
    return x, kv


# ---------------------------------------------------------------------------
# forward / decode
# ---------------------------------------------------------------------------


def _embed_inputs(cfg, params, tokens, prefix_embeds, dtype):
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    if prefix_embeds is not None:  # VLM stub frontend (internvl2)
        x = jnp.concatenate([prefix_embeds.astype(dtype), x], axis=1)
    return x


def forward(cfg: ArchConfig, params, tokens, prefix_embeds=None,
            unroll: bool = False, remat: bool = True):
    """tokens: (B, S) -> logits (B, S_total, padded_vocab)."""
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_inputs(cfg, params, tokens, prefix_embeds, dtype)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]

    def body(x, lp):
        return block(cfg, lp, x, positions), None

    if unroll:
        L = cfg.n_layers
        for i in range(L):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            x, _ = body(x, lp)
    else:
        if remat and cfg.remat_policy == "dots":
            f = jax.checkpoint(
                body,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        elif remat:
            f = jax.checkpoint(body)
        else:
            f = body
        x, _ = jax.lax.scan(f, x, params["layers"])
    x = nn.rms_norm(x, params["final_norm"])
    return nn.dense(x, params["lm_head"])


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=jnp.bfloat16):
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1]
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(sshape, jnp.float32),
            "v_scale": jnp.zeros(sshape, jnp.float32),
            "lengths": jnp.zeros((batch,), jnp.int32),
        }
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def decode_step(cfg: ArchConfig, params, cache, tokens):
    """tokens: (B, 1). Returns (logits (B, 1, V), new cache)."""
    dtype = jnp.dtype(cfg.dtype)
    lengths = cache["lengths"] + 1  # include the new token
    x = nn.embed(tokens, params["embed"]).astype(dtype)
    kv_layers = {k: v for k, v in cache.items() if k != "lengths"}

    def body(x, xs):
        lp, kv = xs
        x, kv = block_decode(cfg, lp, x, kv, lengths)
        return x, kv

    x, kv_new = jax.lax.scan(body, x, (params["layers"], kv_layers))
    x = nn.rms_norm(x, params["final_norm"])
    logits = nn.dense(x, params["lm_head"])
    return logits, {**kv_new, "lengths": lengths}


def prefill(cfg: ArchConfig, params, cache, tokens, prefix_embeds=None,
            lengths=None):
    """Fill the cache from a prompt; returns (last-token logits, cache).

    Implemented as forward + cache writeback (the flash path computes k/v per
    layer; for serving-scale prefill we re-project k/v into the cache via a
    scan identical to forward's but emitting kv).

    ``lengths`` (B,) enables RAGGED batched prefill: prompts are
    right-padded to a common S, per-row logits are read at position
    ``lengths-1``, and cache ``lengths`` record the true prompt sizes.  The
    pad rows beyond a prompt's length hold garbage k/v but sit at positions
    >= length, which decode attention masks out — and causality keeps them
    out of every valid row's receptive field during the prefill itself.
    """
    dtype = jnp.dtype(cfg.dtype)
    x = _embed_inputs(cfg, params, tokens, prefix_embeds, dtype)
    B, S = x.shape[0], x.shape[1]
    positions = jnp.arange(S)[None, :]
    T = cache["k"].shape[2]

    kv_layers = {k: v for k, v in cache.items() if k != "lengths"}

    def body(x, xs):
        lp, kv = xs
        h = nn.rms_norm(x, lp["ln1"])
        q, k, v = _qkv(cfg, lp, h, positions)
        kv = dict(kv)
        if cfg.kv_cache_dtype == "int8":
            k8, ks = nn.quantize_kv_rows(k)
            v8, vs = nn.quantize_kv_rows(v)
            kv["k"] = jax.lax.dynamic_update_slice(kv["k"], k8, (0, 0, 0, 0))
            kv["v"] = jax.lax.dynamic_update_slice(kv["v"], v8, (0, 0, 0, 0))
            kv["k_scale"] = jax.lax.dynamic_update_slice(
                kv["k_scale"], ks, (0, 0, 0))
            kv["v_scale"] = jax.lax.dynamic_update_slice(
                kv["v_scale"], vs, (0, 0, 0))
        else:
            kv["k"] = jax.lax.dynamic_update_slice(
                kv["k"], k.astype(kv["k"].dtype), (0, 0, 0, 0))
            kv["v"] = jax.lax.dynamic_update_slice(
                kv["v"], v.astype(kv["v"].dtype), (0, 0, 0, 0))
        o = nn.flash_attention(q, k, v, causal=True, window=cfg.window,
                               bf16_mm=cfg.attn_bf16_mm,
                               causal_skip=cfg.causal_skip)
        o = nn.dense(o.reshape(B, S, cfg.q_dim), lp["attn"]["wo"])
        x = x + o
        x = x + _ffn(cfg, lp, nn.rms_norm(x, lp["ln2"]))
        return x, kv

    x, kv_new = jax.lax.scan(body, x, (params["layers"], kv_layers))
    if lengths is None:
        x_last = x[:, -1:]
        new_lengths = jnp.full((B,), S, jnp.int32)
    else:
        new_lengths = jnp.asarray(lengths, jnp.int32)
        x_last = x[jnp.arange(B), new_lengths - 1][:, None]
    x_last = nn.rms_norm(x_last, params["final_norm"])
    logits = nn.dense(x_last, params["lm_head"])
    new_cache = {**kv_new, "lengths": new_lengths}
    return logits, new_cache
