"""Fault-tolerant checkpointing.

* Atomic publish: arrays land in ``step_XXXXXXXX.tmp`` first, the
  manifest is the PUBLISH MARKER (written inside the tmp dir via its own
  tmp file + ``os.replace``, after the arrays are fsync'd — a dir without
  a manifest is invisible to :func:`list_steps`), and the dir itself
  publishes by rename.  A crash at ANY point mid-save never publishes a
  torn step: the reader either sees the previous checkpoint or the
  complete new one, never a partial hybrid.
* Integrity: per-leaf SHA256 in the manifest, verified on restore
  (:class:`ChecksumMismatchError` names the corrupt leaf and both
  digests).
* Async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes in a background thread so the train loop keeps stepping.  A
  background write that FAILS is not silent: the worker's exception is
  re-raised from the next ``wait()`` / ``save_async()``.
* Elastic: leaves are saved *unsharded* (device_get gathers); restore takes
  any target sharding/mesh — a job restarted on a different device count
  just pjits the restored tree with its own specs.
* QTensor-aware: pytrees flatten through registered nodes, so quantized
  serving params checkpoint transparently; structure comes from a template
  tree on load.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"


class ChecksumMismatchError(IOError):
    """A restored leaf's bytes do not hash to the manifest's digest —
    on-disk corruption (or a manifest from a different save).  Carries the
    leaf key and both digests so the error names WHAT rotted."""

    def __init__(self, key: str, expected: str, actual: str):
        super().__init__(
            f"checksum mismatch for leaf {key!r}: manifest sha256 "
            f"{expected[:16]}..., file hashes to {actual[:16]}... — the "
            "checkpoint is corrupt on disk")
        self.key = key
        self.expected = expected
        self.actual = actual


def _fsync_file(path: Path) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save(ckpt_dir, step: int, tree, extra: Optional[dict] = None) -> Path:
    """Synchronous atomic save. Returns the published directory.

    Crash-safe at every point: arrays are written and fsync'd BEFORE the
    manifest exists (a manifest-less dir is invisible to
    :func:`list_steps`), the manifest itself lands via tmp +
    ``os.replace``, and an existing published step is swapped aside —
    never rmtree'd in place — so an overwriting save that dies midway
    leaves the reader a COMPLETE checkpoint (old or new), not a torn one.
    """
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    aside = ckpt_dir / f"step_{step:08d}.old-tmp"
    for stale in (tmp, aside):  # debris from a previous crashed save
        if stale.exists():
            shutil.rmtree(stale)
    tmp.mkdir()
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    arrays = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        name = f"leaf_{i:05d}"
        arrays[name] = arr
        manifest["leaves"].append({
            "key": key, "name": name, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha256": _sha256(arr)})
    np.savez(tmp / "arrays.npz", **arrays)
    _fsync_file(tmp / "arrays.npz")
    # the manifest is the publish marker: atomic even within the tmp dir
    # so a torn manifest write can never be mistaken for a complete save
    mtmp = tmp / (_MANIFEST + ".tmp")
    with open(mtmp, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    os.replace(mtmp, tmp / _MANIFEST)
    if final.exists():
        os.rename(final, aside)  # swap aside, publish, then drop — a
    os.rename(tmp, final)        # crash in between leaves old OR new,
    if aside.exists():           # both complete (neither is ever torn)
        shutil.rmtree(aside)
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread, write in the background; at most one
    in-flight save (a newer request waits for the previous to land)."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.last_saved: Optional[int] = None

    def wait(self):
        """Block until the in-flight save lands.  A background write that
        FAILED re-raises here (and keeps re-raising until acknowledged by
        clearing it) — an async checkpointer must not turn a full disk
        into silently-missing checkpoints."""
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()  # re-raises a previous failed background save
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                self.last_saved = step
                self._gc()
            except BaseException as e:  # surfaced by the next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[: -self.keep]:
            shutil.rmtree(Path(self.ckpt_dir) / f"step_{s:08d}",
                          ignore_errors=True)


def list_steps(ckpt_dir) -> list:
    p = Path(ckpt_dir)
    if not p.exists():
        return []
    out = []
    for d in p.iterdir():
        m = re.fullmatch(r"step_(\d{8})", d.name)
        if m and (d / _MANIFEST).exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_extra(ckpt_dir, step: int) -> dict:
    """The manifest's ``extra`` payload, without touching the arrays —
    keeps the on-disk layout (dir naming, manifest schema) private to this
    module for callers that only need provenance (e.g. recipe artifacts)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / _MANIFEST).read_text())["extra"]


def restore(ckpt_dir, step: int, template, shardings=None, verify: bool = True):
    """Restore into the structure of ``template`` (shapes/dtypes checked).

    ``shardings``: optional matching tree of NamedShardings — leaves are
    device_put with them (elastic restore onto any mesh).
    Returns (tree, extra).
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    data = np.load(d / "arrays.npz")
    by_key = {l["key"]: l for l in manifest["leaves"]}
    tpl_leaves = _leaf_paths(template)
    flat_shardings = None
    if shardings is not None:
        flat_shardings = [s for _, s in _leaf_paths(shardings)]
    out = []
    for i, (key, tpl) in enumerate(tpl_leaves):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = by_key[key]
        arr = data[rec["name"]]
        if verify:
            actual = _sha256(arr)
            if actual != rec["sha256"]:
                raise ChecksumMismatchError(key, rec["sha256"], actual)
        if tuple(arr.shape) != tuple(tpl.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs template "
                f"{tpl.shape}")
        if flat_shardings is not None:
            arr = jax.device_put(arr, flat_shardings[i])
        out.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
