"""Fault-tolerant checkpointing.

* Atomic publish: write to ``step_XXXXXXXX.tmp``, fsync, rename.  A crash
  mid-save never corrupts the latest checkpoint.
* Integrity: per-leaf SHA256 in the manifest, verified on restore.
* Async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes in a background thread so the train loop keeps stepping.
* Elastic: leaves are saved *unsharded* (device_get gathers); restore takes
  any target sharding/mesh — a job restarted on a different device count
  just pjits the restored tree with its own specs.
* QTensor-aware: pytrees flatten through registered nodes, so quantized
  serving params checkpoint transparently; structure comes from a template
  tree on load.
"""
from __future__ import annotations

import hashlib
import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _leaf_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save(ckpt_dir, step: int, tree, extra: Optional[dict] = None) -> Path:
    """Synchronous atomic save. Returns the published directory."""
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f"step_{step:08d}.tmp"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    leaves = _leaf_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    arrays = {}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        name = f"leaf_{i:05d}"
        arrays[name] = arr
        manifest["leaves"].append({
            "key": key, "name": name, "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha256": _sha256(arr)})
    np.savez(tmp / "arrays.npz", **arrays)
    with open(tmp / _MANIFEST, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread, write in the background; at most one
    in-flight save (a newer request waits for the previous to land)."""

    def __init__(self, ckpt_dir, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save_async(self, step: int, tree, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda l: np.asarray(jax.device_get(l)), tree)

        def work():
            save(self.ckpt_dir, step, host_tree, extra)
            self.last_saved = step
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def _gc(self):
        steps = sorted(list_steps(self.ckpt_dir))
        for s in steps[: -self.keep]:
            shutil.rmtree(Path(self.ckpt_dir) / f"step_{s:08d}",
                          ignore_errors=True)


def list_steps(ckpt_dir) -> list:
    p = Path(ckpt_dir)
    if not p.exists():
        return []
    out = []
    for d in p.iterdir():
        m = re.fullmatch(r"step_(\d{8})", d.name)
        if m and (d / _MANIFEST).exists():
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir) -> Optional[int]:
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def read_extra(ckpt_dir, step: int) -> dict:
    """The manifest's ``extra`` payload, without touching the arrays —
    keeps the on-disk layout (dir naming, manifest schema) private to this
    module for callers that only need provenance (e.g. recipe artifacts)."""
    d = Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / _MANIFEST).read_text())["extra"]


def restore(ckpt_dir, step: int, template, shardings=None, verify: bool = True):
    """Restore into the structure of ``template`` (shapes/dtypes checked).

    ``shardings``: optional matching tree of NamedShardings — leaves are
    device_put with them (elastic restore onto any mesh).
    Returns (tree, extra).
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    data = np.load(d / "arrays.npz")
    by_key = {l["key"]: l for l in manifest["leaves"]}
    tpl_leaves = _leaf_paths(template)
    flat_shardings = None
    if shardings is not None:
        flat_shardings = [s for _, s in _leaf_paths(shardings)]
    out = []
    for i, (key, tpl) in enumerate(tpl_leaves):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = by_key[key]
        arr = data[rec["name"]]
        if verify and _sha256(arr) != rec["sha256"]:
            raise IOError(f"checksum mismatch for {key!r}")
        if tuple(arr.shape) != tuple(tpl.shape):
            raise ValueError(
                f"shape mismatch for {key!r}: ckpt {arr.shape} vs template "
                f"{tpl.shape}")
        if flat_shardings is not None:
            arr = jax.device_put(arr, flat_shardings[i])
        out.append(arr)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]
