"""Fault-tolerant training loop (the end-to-end driver behind launch/train.py).

Features exercised by the integration tests and examples:
  * deterministic resumable data (step-indexed), exact-resume semantics
  * async checkpoints every N steps + atomic publish + auto-resume
  * preemption handling (SIGTERM/SIGINT -> final sync save -> clean exit)
  * straggler telemetry: per-step wall time vs running median; slow steps
    are logged (on a real cluster the elastic launcher acts on these)
  * metrics JSONL for the examples/benchmarks to assert loss decreases
"""
from __future__ import annotations

import dataclasses
import json
import os
import signal
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..ckpt import checkpoint as ckpt
from ..data.pipeline import DataConfig, SyntheticLM
from ..models import get_model
from ..models.config import ArchConfig
from ..optim.adamw import AdamW, cosine_schedule
from .step import TrainStepConfig, make_train_step


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    global_batch: int = 8
    seq_len: int = 64
    lr: float = 3e-4
    warmup: int = 20
    ckpt_every: int = 50
    log_every: int = 10
    ckpt_dir: Optional[str] = None
    metrics_path: Optional[str] = None
    microbatches: int = 1
    grad_compression: bool = False
    seed: int = 0
    straggler_factor: float = 3.0
    # deterministic failure injection for the elastic-launcher tests:
    # ``stop_at_step`` exits CLEANLY (rc 0) after that step WITHOUT
    # reaching tc.steps — the clean-but-incomplete worker the launcher
    # must count as a restart; ``crash_at_step`` hard-kills the process
    # (os._exit(3) — no final sync save, the finally block never runs)
    # right after that step's async checkpoint lands
    stop_at_step: Optional[int] = None
    crash_at_step: Optional[int] = None


def train(cfg: ArchConfig, tc: TrainConfig):
    model = get_model(cfg)
    opt = AdamW(lr=cosine_schedule(tc.lr, tc.warmup, tc.steps))
    step_fn = jax.jit(make_train_step(
        cfg, model, opt, TrainStepConfig(microbatches=tc.microbatches,
                                         grad_compression=tc.grad_compression)),
        donate_argnums=(0, 1))
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size,
                                  seq_len=tc.seq_len,
                                  global_batch=tc.global_batch,
                                  seed=tc.seed))

    params = model.init(cfg, jax.random.PRNGKey(tc.seed))
    opt_state = opt.init(params)
    start_step = 0

    saver = ckpt.AsyncCheckpointer(tc.ckpt_dir) if tc.ckpt_dir else None
    if saver and (last := ckpt.latest_step(tc.ckpt_dir)) is not None:
        (params, opt_state), extra = ckpt.restore(
            tc.ckpt_dir, last, (params, opt_state))
        start_step = extra["step"] + 1
        print(f"[train] resumed from step {extra['step']}")

    stop = {"now": False}

    def on_signal(signum, frame):
        stop["now"] = True

    old_handlers = {}
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            old_handlers[sig] = signal.signal(sig, on_signal)
        except ValueError:  # non-main thread (tests)
            pass

    metrics_f = open(tc.metrics_path, "a") if tc.metrics_path else None
    step_times = []
    losses = []
    final_step = start_step
    try:
        for step in range(start_step, tc.steps):
            t0 = time.time()
            batch = jax.tree.map(jnp.asarray, data.batch(step))
            params, opt_state, m = step_fn(params, opt_state, batch)
            loss = float(m["loss"])
            dt = time.time() - t0
            step_times.append(dt)
            losses.append(loss)
            final_step = step
            med = float(np.median(step_times[-50:]))
            straggler = dt > tc.straggler_factor * med and len(step_times) > 10
            if metrics_f and (step % tc.log_every == 0 or straggler):
                metrics_f.write(json.dumps({
                    "step": step, "loss": loss,
                    "grad_norm": float(m["grad_norm"]),
                    "step_time_s": round(dt, 4),
                    "straggler": bool(straggler)}) + "\n")
                metrics_f.flush()
            if saver and step and step % tc.ckpt_every == 0:
                saver.save_async(step, (params, opt_state), {"step": step})
            if tc.crash_at_step is not None and step == tc.crash_at_step:
                if saver:
                    saver.wait()  # the published ckpt survives the crash
                print(f"[train] simulated hard crash at step {step} "
                      "(no final save)", flush=True)
                os._exit(3)
            if tc.stop_at_step is not None and step == tc.stop_at_step:
                print(f"[train] clean early exit at step {step} "
                      f"(before step {tc.steps - 1})", flush=True)
                break
            if stop["now"]:
                print(f"[train] preempted at step {step}; saving")
                break
    finally:
        if saver:
            saver.wait()
            ckpt.save(tc.ckpt_dir, final_step, (params, opt_state),
                      {"step": final_step})
        if metrics_f:
            metrics_f.close()
        for sig, h in old_handlers.items():
            signal.signal(sig, h)
    return params, opt_state, {"losses": losses, "last_step": final_step,
                               "preempted": stop["now"]}
