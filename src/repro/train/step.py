"""train_step / serve_step builders shared by the trainer, the serving
engine, and the multi-pod dry-run.

train_step: CE loss (masked to the unpadded vocab), microbatch gradient
accumulation (lax.scan over microbatches — XLA overlaps each microbatch's
gradient all-reduce with the next microbatch's backward), optional int8
error-feedback gradient compression for the cross-pod reduce, AdamW update.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from ..models.config import ArchConfig
from ..optim.adamw import AdamW, AdamWState


def softmax_xent(logits: jax.Array, labels: jax.Array, vocab_size: int,
                 act_sharding: str = "") -> jax.Array:
    """Mean next-token CE; logits may be vocab-padded (mask the tail).

    Written to stay *vocab-sharded* under SPMD: the label logit is read via
    a one-hot contraction (not take_along_axis, which forces an all-gather
    of the full (B,S,V) logits — observed 106 GB/step on llama4-scout; see
    EXPERIMENTS §Perf), and softmax reductions over the sharded vocab lower
    to (B,S)-sized all-reduces.
    """
    lf = logits.astype(jnp.float32)
    if act_sharding:
        from jax.sharding import PartitionSpec
        axes = tuple(act_sharding.split("+"))
        lf = jax.lax.with_sharding_constraint(
            lf, PartitionSpec(axes, None, "model"))
    V = lf.shape[-1]
    valid = jnp.arange(V) < vocab_size
    lf = jnp.where(valid, lf, -1e30)
    m = jax.lax.stop_gradient(jnp.max(lf, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1)) + m[..., 0]
    onehot = jax.nn.one_hot(labels, V, dtype=lf.dtype)
    label_logit = jnp.sum(lf * onehot, axis=-1)
    return jnp.mean(lse - label_logit)


def make_loss_fn(cfg: ArchConfig, model) -> Callable:
    def loss_fn(params, batch):
        kw = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
        logits = model.forward(cfg, params, batch["tokens"], **kw)
        # align: predict token t+1 from t; prefix (VLM) positions excluded
        S = batch["tokens"].shape[1]
        logits = logits[:, -S:]
        return softmax_xent(logits[:, :-1], batch["labels"][:, 1:],
                            cfg.vocab_size, act_sharding=cfg.act_sharding)

    return loss_fn


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    microbatches: int = 1
    grad_compression: bool = False  # int8 error-feedback cross-pod reduce


def make_train_step(cfg: ArchConfig, model, opt: AdamW,
                    ts: TrainStepConfig = TrainStepConfig()):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics).

    With microbatches > 1, the global batch axis is split and gradients are
    accumulated in f32 via lax.scan (compute/comm overlap falls out of XLA
    pipelining the per-microbatch reduce against the next backward).
    """
    loss_fn = make_loss_fn(cfg, model)

    def step(params, opt_state: AdamWState, batch):
        if ts.microbatches > 1:
            def split(x):
                b = x.shape[0]
                mb = b // ts.microbatches
                return x.reshape(ts.microbatches, mb, *x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, mb)
                grad_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss / ts.microbatches
            grads = jax.tree.map(lambda g: g / ts.microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)

        if ts.grad_compression:
            from ..dist.compression import compress_decompress
            grads = compress_decompress(grads)

        params, opt_state, gnorm = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return step


def make_serve_step(cfg: ArchConfig, model):
    """serve_step(params, cache, tokens) -> (logits, cache): one decode step."""

    def serve_step(params, cache, tokens):
        return model.decode_step(cfg, params, cache, tokens)

    return serve_step


def make_prefill_step(cfg: ArchConfig, model):
    def prefill_step(params, cache, tokens, **kw):
        return model.prefill(cfg, params, cache, tokens, **kw)

    return prefill_step
