"""Gradient compression for bandwidth-limited data-parallel training.

Two codecs, both pytree-wise:

* :func:`compress_decompress` — blockwise symmetric int8 quantization (the
  all-reduce payload shrinks 4x vs f32).  Lossy but unbiased enough for the
  train loop's ``grad_compression`` flag (see train.step).
* :func:`compress_with_feedback` — magnitude top-k sparsification with
  error feedback: what the wire drops accumulates in a residual and is
  re-injected next step, so the compressed stream is exact in the limit
  (``comp + residual == grad + residual_in`` identically, per leaf).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def init_residual(grads):
    """Zero error-feedback state shaped like the gradient tree."""
    return jax.tree.map(jnp.zeros_like, grads)


def _int8_roundtrip(g: jax.Array, block: int = 256) -> jax.Array:
    """Blockwise symmetric int8 quantize -> dequantize of one leaf."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    fb = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(fb), axis=-1, keepdims=True),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(fb / scale), -127, 127).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[:n]
    return deq.reshape(g.shape).astype(g.dtype)


def compress_decompress(grads, block: int = 256):
    """Simulate the int8 wire format: quantize + dequantize every leaf."""
    return jax.tree.map(partial(_int8_roundtrip, block=block), grads)


def _topk_leaf(v: jax.Array, k_ratio: float) -> Tuple[jax.Array, jax.Array]:
    flat = v.reshape(-1)
    k = max(1, int(flat.shape[0] * k_ratio))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    keep = jnp.abs(flat) >= thresh
    comp = jnp.where(keep, flat, 0.0).reshape(v.shape)
    return comp, v - comp


def compress_with_feedback(grads, residual, k_ratio: float = 0.1):
    """Top-k sparsification with error feedback.

    Returns ``(compressed, new_residual)`` where per leaf
    ``compressed + new_residual == grad + residual`` exactly — the residual
    carries precisely what the sparsifier dropped.
    """
    fed = jax.tree.map(lambda g, r: g + r, grads, residual)
    pairs = jax.tree.map(partial(_topk_leaf, k_ratio=k_ratio), fed)
    comp = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return comp, res
