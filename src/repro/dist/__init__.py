# Distribution substrate: sharding rules (dist.sharding) and gradient
# compression for bandwidth-limited data parallelism (dist.compression).
