"""Sharding rules: canonical tree path + shape -> PartitionSpec.

Policy (GSPMD data+model mesh):

* column-parallel on the ``model`` axis for qkv projections, FFN up/gate,
  lm_head and embeddings (output-channel = last dim);
* row-parallel for the projections that contract a model-sharded axis
  (attn/wo, FFN down) so the pair forms the classic Megatron sandwich;
* expert-parallel on the (stacked) expert axis for MoE expert weights;
* optional FSDP: big tensors additionally shard their first free divisible
  dim over ``data``.

QTensor leaves flatten through registered pytree nodes, so param paths grow
numeric child suffixes ("layers/attn/wq/0" = payload, "/1" = scale, ...);
suffixes are stripped before rule matching and each child's own shape
decides divisibility — payloads and per-column scales co-shard on the
filter axis, while int32 index leaves (permutations, lookup tables) always
replicate.  Any indivisible dim falls back to replication on that dim
rather than erroring (reduced demo configs have odd shapes).
"""
from __future__ import annotations

import re
from typing import Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# role patterns matched against the stripped canonical path
_COL_RE = re.compile(
    r"(attn/w[qkv]|mlp/w[13]|shared/w[13]|lm_head|head|embed)$")
_ROW_RE = re.compile(r"(attn/wo|mlp/w2|shared/w2)$")
_EXPERT_RE = re.compile(r"experts/")

# FSDP only pays off above this many elements (small tensors replicate)
_FSDP_MIN_SIZE = 1 << 20


def _strip_child_suffix(path: str) -> str:
    """Drop trailing QTensor child indices: 'layers/attn/wq/0/0' -> '.../wq'."""
    parts = path.split("/")
    while parts and parts[-1].isdigit():
        parts.pop()
    return "/".join(parts)


def _mesh_axes(mesh) -> dict:
    return dict(mesh.shape)


def spec_for_param(path: str, shape, dtype, mesh,
                   fsdp: bool = False) -> P:
    """PartitionSpec for one (possibly QTensor-child) parameter leaf."""
    dt = np.dtype(dtype)
    if dt.kind in "iu" and dt.itemsize >= 4:
        return P()  # permutation / index leaves: always replicated
    axes = _mesh_axes(mesh)
    shape = tuple(shape)
    ndim = len(shape)
    if ndim == 0:
        return P()
    spec = [None] * ndim
    clean = _strip_child_suffix(path)

    def try_set(dim: int, axis: Optional[str]) -> None:
        if (axis in axes and 0 <= dim < ndim and spec[dim] is None
                and shape[dim] > 1 and shape[dim] % axes[axis] == 0):
            spec[dim] = axis

    if _EXPERT_RE.search(clean):
        try_set(ndim - 3, "model")  # (L, E, K, N) -> E; (E, K, N) -> E
    elif _ROW_RE.search(clean):
        try_set(ndim - 2, "model")
    elif _COL_RE.search(clean):
        try_set(ndim - 1, "model")
    if fsdp and int(np.prod(shape)) >= _FSDP_MIN_SIZE:
        for d in range(ndim):
            if spec[d] is None:
                before = spec[d]
                try_set(d, "data")
                if spec[d] is not before:
                    break
    return P(*spec)


def param_specs(params, mesh, fsdp: bool = False):
    """Spec tree mirroring ``params`` (QTensor leaves flatten through)."""
    from ..core.calibrate import path_str

    def visit(path, leaf):
        if not hasattr(leaf, "shape"):
            return P()
        return spec_for_param(path_str(path), leaf.shape,
                              getattr(leaf, "dtype", np.float32), mesh,
                              fsdp=fsdp)

    return jax.tree_util.tree_map_with_path(visit, params)


def batch_specs(batch, mesh):
    """Data-parallel batch: leading dim over 'data' when divisible."""
    axes = _mesh_axes(mesh)

    def visit(leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return P()
        s = [None] * len(leaf.shape)
        if "data" in axes and leaf.shape[0] % axes["data"] == 0:
            s[0] = "data"
        return P(*s)

    return jax.tree.map(visit, batch)


def cache_specs(cache, mesh, shard_model: bool = False):
    """KV/state cache: batch axis over 'data' (axis 0 for per-slot vectors
    like lengths, axis 1 under the stacked layer dim), optionally heads
    over 'model' for attention caches."""
    axes = _mesh_axes(mesh)

    def visit(leaf):
        nd = len(leaf.shape)
        s = [None] * nd
        if nd == 0:
            return P()
        bdim = 0 if nd == 1 else 1
        if "data" in axes and leaf.shape[bdim] % axes["data"] == 0:
            s[bdim] = "data"
        if (shard_model and "model" in axes and nd >= 5
                and leaf.shape[3] % axes["model"] == 0):
            s[3] = "model"  # (L, B, T, H, Dh) heads axis
        return P(*s)

    return jax.tree.map(visit, cache)


def shardings_from_specs(specs, mesh):
    """PartitionSpec tree -> NamedSharding tree (same structure)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def put_global(tree, specs, mesh):
    """Place a host-local tree as GLOBAL sharded ``jax.Array``s.

    The multi-host counterpart of ``jax.device_put(tree, shardings)``:
    on a mesh spanning several processes ``device_put`` rejects
    shardings with non-addressable devices, while
    ``jax.make_array_from_callback`` assembles a global array from the
    shards each process CAN address — every process calls this with the
    same (replicated) host values and keeps only its local shards.  On a
    single-process mesh the result is identical to ``device_put``, so
    callers need no host-count special case.
    """
    shardings = shardings_from_specs(specs, mesh)

    def place(x, s):
        x = np.asarray(x)
        return jax.make_array_from_callback(
            x.shape, s, lambda idx, _x=x: _x[idx])

    return jax.tree.map(place, tree, shardings)
