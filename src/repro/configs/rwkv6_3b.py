"""rwkv6-3b [ssm] — Finch, 32L d2560 (attn-free, 40 heads of 64) dff8960
vocab65536, data-dependent decay. [arXiv:2404.05892]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b", family="rwkv", n_layers=32, d_model=2560,
    vocab_size=65536, d_ff=8960, rwkv_head_dim=64)

REDUCED = CONFIG.replace(
    name="rwkv6-3b-reduced", n_layers=2, d_model=64, vocab_size=512,
    d_ff=224, rwkv_head_dim=16, dtype="float32")
