"""qwen1.5-0.5b [dense] — 24L d1024 16H (MHA kv=16) dff2816 vocab151936,
QKV bias. [hf:Qwen/Qwen1.5-0.5B]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-0.5b", family="dense_lm", n_layers=24, d_model=1024,
    vocab_size=151936, n_heads=16, n_kv_heads=16, head_dim=64, d_ff=2816,
    qkv_bias=True, rope_theta=1_000_000.0)

REDUCED = CONFIG.replace(
    name="qwen1.5-0.5b-reduced", n_layers=2, d_model=64, vocab_size=512,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=176, dtype="float32")
