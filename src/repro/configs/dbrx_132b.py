"""dbrx-132b [moe] — 40L d6144 48H (GQA kv=8) expert dff10752 vocab100352,
MoE 16e top-4 fine-grained. [hf:databricks/dbrx-base]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe_lm", n_layers=40, d_model=6144,
    vocab_size=100352, n_heads=48, n_kv_heads=8, head_dim=128, d_ff=10752,
    moe_experts=16, moe_top_k=4, moe_d_ff=10752, rope_theta=500_000.0)

REDUCED = CONFIG.replace(
    name="dbrx-132b-reduced", n_layers=2, d_model=64, vocab_size=512,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=112, moe_experts=4,
    moe_top_k=2, moe_d_ff=112, moe_capacity_factor=8.0, dtype="float32")
