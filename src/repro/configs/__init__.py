from .registry import ARCHS, REDUCED, get_config, get_reduced, list_archs

__all__ = ["ARCHS", "REDUCED", "get_config", "get_reduced", "list_archs"]
