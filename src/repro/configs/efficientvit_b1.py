"""EfficientViT-B1 (the paper's model) at R224/R256/R288."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="efficientvit-b1-r224", family="efficientvit", n_layers=13,
    d_model=256, widths=(16, 32, 64, 128, 256), depths=(1, 2, 3, 3, 4),
    img_res=224, n_classes=1000, dim_per_head=16)

CONFIG_R256 = CONFIG.replace(name="efficientvit-b1-r256", img_res=256)
CONFIG_R288 = CONFIG.replace(name="efficientvit-b1-r288", img_res=288)

REDUCED = CONFIG.replace(
    name="efficientvit-b1-reduced", widths=(8, 16, 32), depths=(1, 1, 2),
    img_res=32, n_classes=10, dim_per_head=8, dtype="float32")
