"""qwen3-14b [dense] — 40L d5120 40H (GQA kv=8) dff17408 vocab151936,
qk_norm. [hf:Qwen/Qwen3 family]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense_lm", n_layers=40, d_model=5120,
    vocab_size=151936, n_heads=40, n_kv_heads=8, head_dim=128, d_ff=17408,
    qk_norm=True, rope_theta=1_000_000.0)

REDUCED = CONFIG.replace(
    name="qwen3-14b-reduced", n_layers=2, d_model=80, vocab_size=512,
    n_heads=5, n_kv_heads=1, head_dim=16, d_ff=272, dtype="float32")
