"""--arch <id> registry: the 10 assigned architectures + the paper's own."""
from . import (
    dbrx_132b,
    efficientvit_b1,
    efficientvit_b2,
    granite3_8b,
    internvl2_2b,
    llama4_scout_17b_a16e,
    minitron_4b,
    qwen15_05b,
    qwen3_14b,
    recurrentgemma_9b,
    rwkv6_3b,
    whisper_large_v3,
)

_MODULES = {
    "qwen1.5-0.5b": qwen15_05b,
    "qwen3-14b": qwen3_14b,
    "granite-3-8b": granite3_8b,
    "minitron-4b": minitron_4b,
    "internvl2-2b": internvl2_2b,
    "rwkv6-3b": rwkv6_3b,
    "llama4-scout-17b-a16e": llama4_scout_17b_a16e,
    "dbrx-132b": dbrx_132b,
    "whisper-large-v3": whisper_large_v3,
    "recurrentgemma-9b": recurrentgemma_9b,
    "efficientvit-b1-r224": efficientvit_b1,
    "efficientvit-b2-r224": efficientvit_b2,
}

ARCHS = {name: mod.CONFIG.replace(name=name) if name != mod.CONFIG.name
         else mod.CONFIG for name, mod in _MODULES.items()}
ARCHS["efficientvit-b1-r256"] = efficientvit_b1.CONFIG_R256
ARCHS["efficientvit-b1-r288"] = efficientvit_b1.CONFIG_R288
REDUCED = {name: mod.REDUCED for name, mod in _MODULES.items()}

# the 10 assigned LM-pool architectures (the dry-run grid)
ASSIGNED = [
    "qwen1.5-0.5b", "qwen3-14b", "granite-3-8b", "minitron-4b",
    "internvl2-2b", "rwkv6-3b", "llama4-scout-17b-a16e", "dbrx-132b",
    "whisper-large-v3", "recurrentgemma-9b",
]

# archs with sub-quadratic sequence mixing (run the long_500k cell)
SUBQUADRATIC = {"rwkv6-3b", "recurrentgemma-9b"}


def get_config(name: str):
    return ARCHS[name]


def get_reduced(name: str):
    return REDUCED[name]


def list_archs():
    return list(ARCHS)
