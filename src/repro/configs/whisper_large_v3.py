"""whisper-large-v3 [audio] — enc-dec, 32+32L d1280 20H (MHA) dff5120
vocab51866; conv/mel frontend is a STUB. [arXiv:2212.04356]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="whisper", n_layers=32, n_enc_layers=32,
    d_model=1280, vocab_size=51866, n_heads=20, n_kv_heads=20, head_dim=64,
    d_ff=5120, norm="layer", n_audio_ctx=1500)

REDUCED = CONFIG.replace(
    name="whisper-large-v3-reduced", n_layers=2, n_enc_layers=2, d_model=64,
    vocab_size=499, n_heads=4, n_kv_heads=4, head_dim=16, d_ff=256,
    n_audio_ctx=32, dtype="float32")
