"""recurrentgemma-9b [hybrid] — 38L d4096 16H (MQA kv=1, hd256) dff12288
vocab256000, RG-LRU + local attention (window 2048), pattern rec,rec,attn.
[arXiv:2402.19427]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b", family="recurrentgemma", n_layers=38,
    d_model=4096, vocab_size=256000, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12288, window=2048, lru_width=4096, conv1d_width=4)

REDUCED = CONFIG.replace(
    name="recurrentgemma-9b-reduced", n_layers=5, d_model=64, vocab_size=512,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=192, window=8, lru_width=64,
    dtype="float32")
