"""llama4-scout-17b-a16e [moe] — 48L d5120 40H (GQA kv=8) expert dff8192
vocab202048, MoE 16e top-1 + shared expert. [hf:meta-llama/Llama-4-Scout]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe_lm", n_layers=48, d_model=5120,
    vocab_size=202048, n_heads=40, n_kv_heads=8, head_dim=128, d_ff=8192,
    moe_experts=16, moe_top_k=1, moe_d_ff=8192, moe_shared_expert=True,
    rope_theta=500_000.0)

REDUCED = CONFIG.replace(
    name="llama4-scout-reduced", n_layers=2, d_model=64, vocab_size=512,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, moe_experts=4,
    moe_top_k=1, moe_d_ff=128, moe_capacity_factor=8.0, dtype="float32")
