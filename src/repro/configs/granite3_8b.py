"""granite-3-8b [dense] — 40L d4096 32H (GQA kv=8) dff12800 vocab49155.
[hf:ibm-granite/granite-3.0]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense_lm", n_layers=40, d_model=4096,
    vocab_size=49155, n_heads=32, n_kv_heads=8, head_dim=128, d_ff=12800)

REDUCED = CONFIG.replace(
    name="granite-3-8b-reduced", n_layers=2, d_model=64, vocab_size=387,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=200, dtype="float32")
