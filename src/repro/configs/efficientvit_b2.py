"""EfficientViT-B2 (the paper's model) at R224."""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="efficientvit-b2-r224", family="efficientvit", n_layers=16,
    d_model=384, widths=(24, 48, 96, 192, 384), depths=(1, 3, 4, 4, 6),
    img_res=224, n_classes=1000, dim_per_head=32)

REDUCED = CONFIG.replace(
    name="efficientvit-b2-reduced", widths=(8, 16, 32), depths=(1, 1, 2),
    img_res=32, n_classes=10, dim_per_head=8, dtype="float32")
