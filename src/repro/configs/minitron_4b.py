"""minitron-4b [dense] — 32L d3072 24H (GQA kv=8) dff9216 vocab256000,
pruned nemotron (squared-ReLU FFN). [arXiv:2407.14679]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b", family="dense_lm", n_layers=32, d_model=3072,
    vocab_size=256000, n_heads=24, n_kv_heads=8, head_dim=128, d_ff=9216,
    ffn="relu2")

REDUCED = CONFIG.replace(
    name="minitron-4b-reduced", n_layers=2, d_model=96, vocab_size=512,
    n_heads=6, n_kv_heads=2, head_dim=16, d_ff=288, dtype="float32")
