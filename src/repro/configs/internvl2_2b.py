"""internvl2-2b [vlm] — InternLM2 backbone 24L d2048 16H (GQA kv=8) dff8192
vocab92553; InternViT frontend is a STUB (input_specs provides 256 projected
patch embeddings). [arXiv:2404.16821]"""
from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="dense_lm", n_layers=24, d_model=2048,
    vocab_size=92553, n_heads=16, n_kv_heads=8, head_dim=128, d_ff=8192,
    n_patches=256)

REDUCED = CONFIG.replace(
    name="internvl2-2b-reduced", n_layers=2, d_model=64, vocab_size=493,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=256, n_patches=8,
    dtype="float32")
