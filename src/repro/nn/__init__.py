# Framework-owned NN substrate (pure JAX pytrees; no external module lib).
from .layers import (
    conv2d,
    dense,
    dwconv2d,
    embed,
    gelu,
    geglu,
    layer_norm,
    lecun_normal,
    rms_norm,
    silu,
    swiglu,
    tied_head,
    trunc_normal,
)
from .attention import (
    apply_rope,
    decode_attention,
    decode_attention_int8,
    flash_attention,
    qk_rms_norm,
    quantize_kv_rows,
    relu_linear_attention,
)
from .moe import MoEConfig, aux_load_balance_loss, capacity, expert_ffn, moe_ffn
from .ssm import (
    rg_lru,
    rg_lru_step,
    rwkv6_attend,
    rwkv6_attend_step,
    rwkv6_channelmix,
    rwkv6_timemix_inputs,
    temporal_conv1d,
)

__all__ = [
    "conv2d", "dense", "dwconv2d", "embed", "gelu", "geglu", "layer_norm",
    "lecun_normal", "rms_norm", "silu", "swiglu", "tied_head",
    "trunc_normal",
    "apply_rope", "decode_attention", "decode_attention_int8",
    "flash_attention", "qk_rms_norm", "quantize_kv_rows",
    "relu_linear_attention",
    "MoEConfig", "aux_load_balance_loss", "capacity", "expert_ffn",
    "moe_ffn",
    "rg_lru", "rg_lru_step", "rwkv6_attend", "rwkv6_attend_step",
    "rwkv6_channelmix", "rwkv6_timemix_inputs", "temporal_conv1d",
]
