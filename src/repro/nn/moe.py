"""Mixture-of-Experts layer (llama4-scout top-1, dbrx top-4).

Dispatch is capacity-based and fully static-shaped (scatter into an
(E*C, D) buffer + batched expert matmul + gather back), so it lowers
cleanly under pjit and the expert dimension shards as EP (both assigned MoE
archs have exactly 16 experts = the `model` mesh axis).  Overflowed tokens
drop to a sink row (standard Switch behaviour); the router stays float
(KIND_SKIP for quantization — see DESIGN.md).

Expert weights may be float arrays, CalibTensors, or QTensors
(QExpertM2Q / QUniform with per-(expert,filter) scales).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core.calibrate import CalibTensor
from ..core.qtensor import QExpertM2Q, is_qtensor
from .layers import dense, silu


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_model: int
    d_ff: int
    capacity_factor: float = 1.25
    normalize_gates: bool = True  # dbrx-style renormalization of top-k gates
    constrain_ep: str = ""        # dp axes ("data" / "pod+data"): pin the
                                  # expert buffer to EP over 'model' x DP
                                  # over capacity rows — without this each
                                  # device computes its expert's GLOBAL
                                  # capacity (16x waste; EXPERIMENTS §Perf)


def expert_dense(xe: jax.Array, w) -> jax.Array:
    """y[E,C,N] = xe[E,C,K] @ w[E,K,N], any weight leaf type."""
    if isinstance(w, CalibTensor):
        w.record(xe)
        return jnp.einsum("eck,ekn->ecn", xe, w.w.astype(xe.dtype))
    if isinstance(w, QExpertM2Q):
        return w.expert_matmul(xe)
    if is_qtensor(w):
        return jnp.einsum("eck,ekn->ecn", xe, w.dequant(xe.dtype))
    return jnp.einsum("eck,ekn->ecn", xe, w.astype(xe.dtype))


def expert_ffn(xe: jax.Array, params) -> jax.Array:
    """SwiGLU expert FFN over the (E, C, D) buffer."""
    h = silu(expert_dense(xe, params["w1"])) * expert_dense(xe, params["w3"])
    return expert_dense(h, params["w2"])


def capacity(tokens: int, cfg: MoEConfig) -> int:
    c = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 for layout friendliness


def moe_ffn(x: jax.Array, params, cfg: MoEConfig) -> jax.Array:
    """x: (T, D) token-flattened activations -> (T, D)."""
    T, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    C = capacity(T, cfg)

    logits = dense(x, params["router"]).astype(jnp.float32)  # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, K)  # (T, K)
    if cfg.normalize_gates and K > 1:
        top_g = top_g / jnp.sum(top_g, axis=-1, keepdims=True)

    flat_e = top_e.reshape(-1)  # (T*K,), token-major / choice-minor
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (T*K, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position within expert
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    ok = pos_in_e < C
    # NOTE: the buffer is exactly (E*C, D) — a +1 sink row would make the
    # leading dim indivisible and force XLA SPMD to replicate the whole
    # expert computation (observed: 4-5x expert FLOPs; EXPERIMENTS §Perf).
    # Overflowed tokens are zero-masked and scatter-ADDed to row 0 of their
    # expert instead (zeros never corrupt), and masked again on combine.
    slot = jnp.where(ok, flat_e * C + pos_in_e, flat_e * C)
    xrep = jnp.repeat(x, K, axis=0)  # (T*K, D)
    xrep = jnp.where(ok[:, None], xrep, 0)
    buf = jnp.zeros((E * C, D), dtype=x.dtype).at[slot].add(xrep)
    xe = buf.reshape(E, C, D)
    if cfg.constrain_ep:
        from jax.sharding import PartitionSpec
        dp = tuple(cfg.constrain_ep.split("+"))
        # three-stage reshard: (1) the dispatch scatter lands EP-sharded
        # with capacity replicated (an all-reduce — each data shard owns a
        # slice of the contributions); (2) reslicing capacity over data is
        # comm-free; compute then runs at global_work/(model*data); (3) the
        # combine gathers capacity back (C/dp -> C), which is ~13x cheaper
        # than all-reducing the scatter into a 2-D-sharded target directly.
        xe = jax.lax.with_sharding_constraint(
            xe, PartitionSpec("model", None, None))
        xe = jax.lax.with_sharding_constraint(
            xe, PartitionSpec("model", dp, None))

    ye = expert_ffn(xe, params["experts"])  # (E, C, D)
    if cfg.constrain_ep:
        from jax.sharding import PartitionSpec
        dp = tuple(cfg.constrain_ep.split("+"))
        ye = jax.lax.with_sharding_constraint(
            ye, PartitionSpec("model", dp, None))
        ye = jax.lax.with_sharding_constraint(
            ye, PartitionSpec("model", None, None))

    yrep = jnp.take(ye.reshape(E * C, D), slot, axis=0)  # (T*K, D)
    gates = jnp.where(ok, top_g.reshape(-1), 0.0)
    y = jnp.sum(
        yrep.reshape(T, K, D) * gates.reshape(T, K)[..., None].astype(ye.dtype),
        axis=1)
    return y.astype(x.dtype)


def aux_load_balance_loss(logits: jax.Array, top_e: jax.Array,
                          num_experts: int) -> jax.Array:
    """Switch-style auxiliary loss (used by the MoE training examples)."""
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    me = jnp.mean(gates, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], num_experts, dtype=jnp.float32), axis=0)
    return num_experts * jnp.sum(me * ce)
