"""Attention primitives: RoPE, chunked (flash-style) GQA attention, decode
attention over KV caches, sliding-window variants, and EfficientViT's
ReLU-based linear attention (the paper's backbone, Sec. II-A).

The chunked attention is pure JAX (lax.scan online-softmax) so 32k-token
prefill never materializes an (S, S) score matrix; activation memory is
O(q_chunk * kv_chunk).  It is numerically guarded with finite -1e30 masks so
fully-masked rows produce zeros, not NaNs.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    i = jnp.arange(0, head_dim // 2, dtype=jnp.float32)
    return theta ** (-2.0 * i / head_dim)


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def qk_rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head RMSNorm over the head dim (Qwen3 qk_norm)."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * gamma.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked flash-style attention
# ---------------------------------------------------------------------------


def _pad_to(x: jax.Array, axis: int, mult: int) -> Tuple[jax.Array, int]:
    n = x.shape[axis]
    target = -(-n // mult) * mult
    if target == n:
        return x, n
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - n)
    return jnp.pad(x, pad), n


def flash_attention(
    q: jax.Array,  # (B, S, Hq, D)
    k: jax.Array,  # (B, T, Hkv, D)
    v: jax.Array,  # (B, T, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,  # sliding window size (None = unbounded)
    q_offset=0,  # absolute position of q[0] (int or scalar array)
    kv_len: Optional[jax.Array] = None,  # valid kv length (default: T)
    q_chunk: int = 512,
    kv_chunk: int = 512,
    scale: Optional[float] = None,
    bf16_mm: bool = False,   # MXU-native bf16 dots with f32 accumulation
    causal_skip: bool = False,  # triangle scan: skip fully-masked kv chunks
) -> jax.Array:
    """Online-softmax attention; returns (B, S, Hq, D).

    ``bf16_mm`` keeps q/k/v in their (bf16) dtype and accumulates in f32 —
    the MXU-native path (4x the f32-dot rate); the softmax statistics stay
    f32 either way.  ``causal_skip`` replaces the dense (nq x nk) chunk grid
    with a single scan over the lower-triangular (qi, kj<=qi) chunk pairs,
    halving attention FLOPs for causal masks (EXPERIMENTS.md §Perf).
    """
    B, S, Hq, D = q.shape
    T = k.shape[1]
    Hkv = k.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)

    q_chunk = min(q_chunk, max(S, 1))
    kv_chunk = min(kv_chunk, max(T, 1))

    mm_dt = q.dtype if bf16_mm else jnp.float32

    # layouts: q (B, Hkv, G, S, D); kv (B, Hkv, T, D)
    qh = q.reshape(B, S, Hkv, G, D).transpose(0, 2, 3, 1, 4)
    kh = k.transpose(0, 2, 1, 3)
    vh = v.transpose(0, 2, 1, 3)

    qh, s_real = _pad_to(qh, 3, q_chunk)
    kh, t_real = _pad_to(kh, 2, kv_chunk)
    vh, _ = _pad_to(vh, 2, kv_chunk)
    Sp, Tp = qh.shape[3], kh.shape[2]
    nq, nk = Sp // q_chunk, Tp // kv_chunk

    t_valid = jnp.asarray(t_real if kv_len is None else kv_len, jnp.int32)
    q_offset = jnp.asarray(q_offset, jnp.int32)

    qh = qh.reshape(B, Hkv, G, nq, q_chunk, D).transpose(3, 0, 1, 2, 4, 5)
    kh = kh.reshape(B, Hkv, nk, kv_chunk, D).transpose(2, 0, 1, 3, 4)
    vh = vh.reshape(B, Hkv, nk, kv_chunk, D).transpose(2, 0, 1, 3, 4)

    def chunk_update(carry, qi, kj, qc, kc, vc):
        m, l, acc = carry
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qc.astype(mm_dt),
                       kc.astype(mm_dt),
                       preferred_element_type=jnp.float32) * scale
        valid = k_pos[None, :] < t_valid
        if causal:
            valid &= k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            valid &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(valid[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p.astype(mm_dt), vc.astype(mm_dt),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    def zero_carry():
        return (jnp.full((B, Hkv, G, q_chunk), NEG_INF, jnp.float32),
                jnp.zeros((B, Hkv, G, q_chunk), jnp.float32),
                jnp.zeros((B, Hkv, G, q_chunk, D), jnp.float32))

    if causal_skip and causal and nq > 1:
        # one scan over lower-triangular (qi, kj) chunk pairs, qi-major;
        # the carry resets at kj==0 and flushes into the output buffer at
        # kj==qi.  FLOPs: nq(nq+1)/2 chunk pairs instead of nq*nk.
        pairs = [(qi, kj) for qi in range(nq) for kj in range(qi + 1)]
        qi_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
        kj_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)

        def pair_body(carry, inp):
            out_buf, m, l, acc = carry
            qi, kj = inp
            qc = jax.lax.dynamic_index_in_dim(qh, qi, 0, keepdims=False)
            kc = jax.lax.dynamic_index_in_dim(kh, kj, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vh, kj, 0, keepdims=False)
            z = zero_carry()
            fresh = kj == 0
            m = jnp.where(fresh, z[0], m)
            l = jnp.where(fresh, z[1], l)
            acc = jnp.where(fresh, z[2], acc)
            m, l, acc = chunk_update((m, l, acc), qi, kj, qc, kc, vc)
            done = kj == qi
            out = acc / jnp.maximum(l, 1e-20)[..., None]
            out_buf = jax.lax.cond(
                done,
                lambda ob: jax.lax.dynamic_update_index_in_dim(
                    ob, out.astype(ob.dtype), qi, 0),
                lambda ob: ob, out_buf)
            return (out_buf, m, l, acc), None

        out0 = jnp.zeros((nq, B, Hkv, G, q_chunk, D), jnp.float32)
        (outs, _, _, _), _ = jax.lax.scan(
            pair_body, (out0, *zero_carry()), (qi_arr, kj_arr))
    else:
        def one_q_chunk(args):
            qi, qc = args
            def kv_body(carry, inp):
                kj, kc, vc = inp
                return chunk_update(carry, qi, kj, qc, kc, vc), None
            (m, l, acc), _ = jax.lax.scan(
                kv_body, zero_carry(), (jnp.arange(nk), kh, vh))
            return acc / jnp.maximum(l, 1e-20)[..., None]

        outs = jax.lax.map(one_q_chunk, (jnp.arange(nq), qh))

    out = outs.transpose(1, 2, 3, 0, 4, 5).reshape(B, Hkv, G, Sp, D)
    out = out[:, :, :, :s_real]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, s_real, Hq, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode attention over a KV cache (one new token per sequence)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,        # (B, 1, Hq, D)
    k_cache: jax.Array,  # (B, T, Hkv, D)
    v_cache: jax.Array,  # (B, T, Hkv, D)
    lengths: jax.Array,  # (B,) valid entries per sequence (incl. current)
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    bf16_mm: bool = False,
) -> jax.Array:
    B, _, Hq, D = q.shape
    T, Hkv = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qh = q.reshape(B, Hkv, G, D)
    mm_dt = k_cache.dtype if bf16_mm else jnp.float32
    s = jnp.einsum("bhgd,bthd->bhgt", qh.astype(mm_dt),
                   k_cache.astype(mm_dt),
                   preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(T)[None, :]  # (1, T)
    valid = pos < lengths[:, None]
    if window is not None:
        valid &= pos >= (lengths[:, None] - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgt,bthd->bhgd", p.astype(mm_dt),
                     v_cache.astype(mm_dt),
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


def decode_attention_int8(
    q: jax.Array,         # (B, 1, Hq, D) bf16/f32
    k_q: jax.Array,       # (B, T, Hkv, D) int8
    v_q: jax.Array,       # (B, T, Hkv, D) int8
    k_scale: jax.Array,   # (B, T, Hkv) f32 per-row scales
    v_scale: jax.Array,   # (B, T, Hkv) f32
    lengths: jax.Array,   # (B,)
    *,
    window: Optional[int] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Fully-integer KV-cache attention — M2Q's memory-intensive level
    applied to activations-at-rest (beyond-paper; EXPERIMENTS §Perf).

    QK^T runs int8xint8 on the MXU (q quantized per (b,h) on the fly);
    per-row K scales fold into the scores; the softmax weights are re-
    quantized to int8 with the per-row V scales folded in, so PV is also an
    int8 dot.  The cache never dequantizes into an HBM temp — reads are
    1 byte/element.

    With ``kernels.ops.attn_dispatch_enabled()`` the identical computation
    runs as the fused Pallas kernel (one VMEM pass per (batch, kv-head),
    no (B,Hkv,G,T) score round-trips through HBM); this XLA einsum chain
    is the fallback and the kernel's parity oracle.
    """
    from ..kernels import ops as _kops
    if _kops.attn_dispatch_enabled():
        return _kops.decode_attn_int8_op(q, k_q, v_q, k_scale, v_scale,
                                         lengths, window=window, scale=scale)
    B, _, Hq, D = q.shape
    T, Hkv = k_q.shape[1], k_q.shape[2]
    G = Hq // Hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(D)
    qh = q.reshape(B, Hkv, G, D).astype(jnp.float32)
    q_s = jnp.max(jnp.abs(qh), axis=-1, keepdims=True) / 127.0 + 1e-9
    q8 = jnp.clip(jnp.round(qh / q_s), -127, 127).astype(jnp.int8)
    acc = jnp.einsum("bhgd,bthd->bhgt", q8, k_q,
                     preferred_element_type=jnp.int32)
    s = acc.astype(jnp.float32) * q_s * scale \
        * k_scale.transpose(0, 2, 1)[:, :, None, :]
    pos = jnp.arange(T)[None, :]
    valid = pos < lengths[:, None]
    if window is not None:
        valid &= pos >= (lengths[:, None] - window)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    # fold per-row V scales into p, then re-quantize p for the int8 PV dot
    pv = p * v_scale.transpose(0, 2, 1)[:, :, None, :]
    p_s = jnp.max(jnp.abs(pv), axis=-1, keepdims=True) / 127.0 + 1e-12
    p8 = jnp.clip(jnp.round(pv / p_s), -127, 127).astype(jnp.int8)
    out = jnp.einsum("bhgt,bthd->bhgd", p8, v_q,
                     preferred_element_type=jnp.int32)
    out = out.astype(jnp.float32) * p_s
    return out.reshape(B, 1, Hq, D).astype(q.dtype)


def quantize_kv_rows(x: jax.Array):
    """(..., Hkv, D) -> (int8 rows, (..., Hkv) f32 scales), per-(row, head)."""
    s = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-9
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / s[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, s


# ---------------------------------------------------------------------------
# EfficientViT ReLU linear attention (paper Sec. II-A)
# ---------------------------------------------------------------------------


def relu_linear_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                          eps: float = 1e-6) -> jax.Array:
    """Softmax-free global attention with linear complexity.

    q,k,v: (B, N, H, D).  out = (q' (k'^T v)) / (q' sum(k')) with
    q' = relu(q), k' = relu(k) — the associative-property trick that makes
    EfficientViT linear in N.

    With ``kernels.ops.attn_dispatch_enabled()`` the token mixer runs as
    the fused int8 Pallas kernel instead (q/k/v quantized in the kernel
    prologue, kv/ksum accumulated in int32, normalization in the
    epilogue) — the low-precision engine path the M2-ViT accelerator
    dedicates to the attention MatMuls.  NOTE this changes numerics to
    int8-quantization tolerance; the f32 einsums below never quantize.
    """
    from ..kernels import ops as _kops
    if _kops.attn_dispatch_enabled():
        return _kops.relu_attn_op(q, k, v, eps=eps).astype(q.dtype)
    qr = jax.nn.relu(q).astype(jnp.float32)
    kr = jax.nn.relu(k).astype(jnp.float32)
    vf = v.astype(jnp.float32)
    kv = jnp.einsum("bnhd,bnhe->bhde", kr, vf)           # (B,H,D,D)
    num = jnp.einsum("bnhd,bhde->bnhe", qr, kv)          # (B,N,H,D)
    ksum = jnp.sum(kr, axis=1)                           # (B,H,D)
    den = jnp.einsum("bnhd,bhd->bnh", qr, ksum)[..., None]
    return (num / (den + eps)).astype(q.dtype)
