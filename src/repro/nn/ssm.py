"""Attention-free sequence mixers: RWKV6 (Finch) and RG-LRU (Griffin /
RecurrentGemma).

Both are first-order linear recurrences.  RG-LRU has a *diagonal* state so we
use ``jax.lax.associative_scan`` (O(log T) depth, states are the layer output
anyway).  RWKV6 has a rank-1-updated *matrix* state (dk x dv per head), so
materializing all T states is 64x the activation footprint — we run a
chunked sequential scan with per-chunk checkpointing instead (state is stored
only at chunk boundaries; the backward pass recomputes inside chunks).  The
chunkwise-matmul (intra/inter chunk decomposition) variant is a recorded
perf-iteration candidate in EXPERIMENTS.md.

Decode (single token) uses the explicit ``*_step`` functions with carried
state — this is what makes the ``long_500k`` cell O(1) in memory for these
architectures.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import dense, silu

# ---------------------------------------------------------------------------
# RWKV6 time mix (Finch: data-dependent decay via a small LoRA)
# ---------------------------------------------------------------------------


def _lerp(x, x_prev, mu):
    return x + (x_prev - x) * mu.astype(x.dtype)


def rwkv6_decay(x_mixed: jax.Array, params) -> jax.Array:
    """w_t in (0,1): exp(-exp(w0 + tanh(x @ A) @ B)) — data-dependent decay."""
    lora = jnp.tanh(x_mixed @ params["w_lora_a"].astype(x_mixed.dtype))
    logw = params["w0"].astype(jnp.float32) + (
        lora @ params["w_lora_b"].astype(lora.dtype)).astype(jnp.float32)
    return jnp.exp(-jnp.exp(logw))


def rwkv6_timemix_inputs(x: jax.Array, x_prev: jax.Array, params, n_heads: int):
    """Project a (..., D) slice into per-head r,k,v,g,w,u.

    x_prev is the token-shifted x (previous token, or carried decode state).
    """
    D = x.shape[-1]
    hd = D // n_heads
    r = dense(_lerp(x, x_prev, params["mu_r"]), params["wr"])
    k = dense(_lerp(x, x_prev, params["mu_k"]), params["wk"])
    v = dense(_lerp(x, x_prev, params["mu_v"]), params["wv"])
    g = silu(dense(_lerp(x, x_prev, params["mu_g"]), params["wg"]))
    w = rwkv6_decay(_lerp(x, x_prev, params["mu_w"]), params)

    def heads(t):
        return t.reshape(*t.shape[:-1], n_heads, hd)

    return heads(r), heads(k), heads(v), g, heads(w.astype(x.dtype))


def rwkv6_attend_step(state: jax.Array, r, k, v, w, u):
    """One recurrence step.

    state: (B, H, dk, dv);  r,k,v,w: (B, H, d);  u: (H, d) bonus.
    out_t = r . (S + (u*k) (x) v);  S' = diag(w) S + k (x) v
    """
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    rf = r.astype(jnp.float32)
    kv = kf[..., :, None] * vf[..., None, :]  # (B,H,dk,dv)
    out = jnp.einsum("bhk,bhkv->bhv", rf * u[None].astype(jnp.float32), kv) \
        + jnp.einsum("bhk,bhkv->bhv", rf, state)
    new_state = state * w.astype(jnp.float32)[..., :, None] + kv
    return new_state, out


def rwkv6_attend(state: jax.Array, r, k, v, w, u, chunk: int = 128):
    """Sequence recurrence. r,k,v,w: (B, T, H, d). Returns (final_state, out).

    Outer scan over chunks with checkpointed bodies -> O(T/chunk) stored
    states instead of O(T).
    """
    B, T, H, d = r.shape
    chunk = min(chunk, max(T, 1))
    pad = (-T) % chunk
    if pad:
        padder = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = padder(r), padder(k), padder(v)
        w = jnp.pad(w, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=1.0)
    Tp = T + pad
    nc = Tp // chunk

    def to_chunks(t):  # (B,Tp,H,d) -> (nc, chunk, B, H, d)
        return t.transpose(1, 0, 2, 3).reshape(nc, chunk, B, H, d)

    rc, kc, vc, wc = map(to_chunks, (r, k, v, w))

    @jax.checkpoint
    def chunk_body(st, inp):
        rs, ks, vs, ws = inp

        def step(s, xs):
            return rwkv6_attend_step(s, *xs, u)

        st, outs = jax.lax.scan(step, st, (rs, ks, vs, ws))
        return st, outs

    final, outs = jax.lax.scan(chunk_body, state.astype(jnp.float32),
                               (rc, kc, vc, wc))
    out = outs.reshape(Tp, B, H, d).transpose(1, 0, 2, 3)[:, :T]
    return final, out


def rwkv6_channelmix(x: jax.Array, x_prev: jax.Array, params) -> jax.Array:
    xr = _lerp(x, x_prev, params["mu_cr"])
    xk = _lerp(x, x_prev, params["mu_ck"])
    r = jax.nn.sigmoid(dense(xr, params["cw_r"]))
    k = jnp.square(jax.nn.relu(dense(xk, params["cw_k"])))
    return r * dense(k, params["cw_v"])


# ---------------------------------------------------------------------------
# RG-LRU (RecurrentGemma)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def rg_lru_gates(x: jax.Array, params):
    """a_t (decay) and gated input for h_t = a h_{t-1} + sqrt(1-a^2) (i*x)."""
    rgate = jax.nn.sigmoid(dense(x, params["wa"], params.get("ba")))
    igate = jax.nn.sigmoid(dense(x, params["wx"], params.get("bx")))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) \
        * rgate.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * igate.astype(jnp.float32) * x.astype(jnp.float32)
    return a, gated


def rg_lru(x: jax.Array, h0: jax.Array, params):
    """x: (B, T, R); h0: (B, R). Returns (h_final, y (B,T,R)).

    First-order diagonal recurrence -> associative scan over T.
    """
    a, b = rg_lru_gates(x, params)  # (B,T,R) f32
    # fold h0 into the first step: b_0 += a_0 * h0
    b = b.at[:, 0].add(a[:, 0] * h0.astype(jnp.float32))

    def combine(left, right):
        al, bl = left
        ar, br = right
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h[:, -1], h.astype(x.dtype)


def rg_lru_step(x: jax.Array, h: jax.Array, params):
    """Single decode step. x: (B, R); h: (B, R)."""
    a, b = rg_lru_gates(x[:, None], params)
    h_new = a[:, 0] * h.astype(jnp.float32) + b[:, 0]
    return h_new, h_new.astype(x.dtype)


def temporal_conv1d(x: jax.Array, w: jax.Array, b=None,
                    state=None) -> Tuple[jax.Array, jax.Array]:
    """Causal depthwise temporal conv (width W).  x: (B, T, R); w: (W, R).

    Returns (y, new_state) where state is the last W-1 inputs (decode carry).
    """
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i].astype(x.dtype) for i in range(W))
    if b is not None:
        y = y + b.astype(y.dtype)
    new_state = xp[:, -(W - 1):] if W > 1 else jnp.zeros_like(x[:, :0])
    return y, new_state
