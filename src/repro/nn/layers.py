"""Framework layer primitives (pure JAX; params are nested dicts).

Every weight consumer dispatches on the leaf type:
  * jax.Array            — plain float compute
  * core.CalibTensor     — record activation stats (PTQ calibration), float op
  * core.QTensor leaves  — the M2Q serving paths (int8 / packed-int4 / APoT)

so model code is identical in float, calibration, and quantized modes.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..core.calibrate import CalibTensor
from ..core.qtensor import QUniform, is_qtensor, qmatmul

# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def trunc_normal(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def lecun_normal(key, shape, dtype=jnp.float32):
    fan_in = math.prod(shape[:-1]) if len(shape) > 1 else shape[0]
    return jax.random.normal(key, shape, dtype) / math.sqrt(max(fan_in, 1))


# ---------------------------------------------------------------------------
# dense / embedding
# ---------------------------------------------------------------------------


def dense(x: jax.Array, w, b=None) -> jax.Array:
    """y = x @ w (+ b); w may be float, CalibTensor, or QTensor.

    QTensor leaves route through the fused Pallas kernels when the backend
    supports them (kernels.ops.dispatch_enabled — TPU by default, env-
    overridable) and the leaf's kernel computes the identical function;
    otherwise the pure-XLA QTensor path runs.
    """
    if isinstance(w, CalibTensor):
        w.record(x)
        y = x @ w.w.astype(x.dtype)
    elif is_qtensor(w):
        from ..kernels import ops as _kops
        if _kops.dispatch_enabled() and _kops.kernel_supported(w):
            y = _kops.qtensor_matmul(x, w)
        else:
            y = qmatmul(x, w)
    else:
        y = x @ w.astype(x.dtype)
    if b is not None:
        if isinstance(b, CalibTensor):
            b = b.w
        y = y + b.astype(y.dtype)
    return y


def tied_head(x: jax.Array, table) -> jax.Array:
    """Logits via the (possibly quantized) embedding table: x @ table.T."""
    if isinstance(table, CalibTensor):
        table.record(x)
        w = table.w
    elif is_qtensor(table):
        w = table.dequant(x.dtype)
    else:
        w = table
    return x @ w.T.astype(x.dtype)


def embed(ids: jax.Array, table) -> jax.Array:
    if isinstance(table, CalibTensor):
        return jnp.take(table.w, ids, axis=0)
    if isinstance(table, QUniform):
        return table.take(ids, dtype=jnp.float32)
    return jnp.take(table, ids, axis=0)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# convolutions (EfficientViT + conv frontends); NHWC layout
# ---------------------------------------------------------------------------


def _conv_weight(w, dtype):
    if isinstance(w, CalibTensor):
        return w.w.astype(dtype)
    if is_qtensor(w):
        # quantized conv leaves store a flattened 2-D payload; the aux
        # ``shape`` remembers the original HWIO filter
        return w.dequant(dtype).reshape(w.shape)
    return w.astype(dtype)


def _im2col(x: jax.Array, kh: int, kw: int, stride: int,
            padding: str) -> jax.Array:
    """(B,H,W,C) -> (B,HO,WO,kh*kw*C) patches, feature order (i, j, c) —
    the row order of a flattened-HWIO quantized payload, so an im2col'd
    conv is exactly ``patches @ payload``."""
    from ..kernels.dwconv_w4 import same_padding
    H, W = x.shape[1], x.shape[2]
    if padding == "SAME":
        x = jnp.pad(x, ((0, 0), same_padding(H, kh, stride),
                        same_padding(W, kw, stride), (0, 0)))
        HO, WO = -(-H // stride), -(-W // stride)
    else:  # VALID
        HO, WO = (H - kh) // stride + 1, (W - kw) // stride + 1
    s = stride
    taps = [x[:, i:i + (HO - 1) * s + 1:s, j:j + (WO - 1) * s + 1:s]
            for i in range(kh) for j in range(kw)]
    return jnp.concatenate(taps, axis=-1)


def _qconv2d(x: jax.Array, w, stride: int, groups: int, padding: str):
    """Quantized-conv hot path (the M2Q conv execution domain).

    * 1x1 stride-1 un-grouped PWConv == a matmul over B*H*W pixel rows:
      fused Pallas kernels when kernels.ops.conv_dispatch_enabled() and the
      leaf's kernel computes the identical function, else the pure-XLA
      QTensor matmul — either way the weight bytes stay quantized in HBM
      and no f32 dequantized-weight convolution is emitted.
    * 4-bit depthwise filters run the packed-w4 Pallas conv kernel when
      dispatch is enabled — H-tiled, so any feature-map resolution stays
      on the kernel (stride-2 stage entries pad inside the kernel; see
      kernels.dwconv_w4), with only tiler-impossible widths falling back.
    * any other un-grouped KxK filter (the opt-in int8 stem — see
      efficientvit.STEM_RULE) lowers to im2col + the same quantized
      matmul path; the patch extraction materializes f32 activations but
      the weight bytes never dequantize.
    Returns None when only the dequantized-weight XLA convolution (the
    fallback and parity reference) applies.
    """
    from ..kernels import ops as _kops
    shape = tuple(w.shape)
    ints = getattr(w, "payload", None)
    if ints is None:
        ints = getattr(w, "codes", None)
    if len(shape) != 4 or ints is None or ints.ndim != 2:
        return None
    if shape[:2] == (1, 1) and stride == 1 and groups == 1:
        # padding is irrelevant for 1x1 stride-1: SAME == VALID
        if _kops.conv_dispatch_enabled() and _kops.kernel_supported(w):
            return _kops.qtensor_matmul(x, w)
        return qmatmul(x, w)
    if _kops.conv_dispatch_enabled() and \
            _kops.dwconv_kernel_supported(w, x, stride, groups, padding):
        return _kops.qtensor_dwconv(x, w, stride=stride)
    kh, kw, cin_g, _ = shape
    if groups == 1 and padding in ("SAME", "VALID") \
            and x.shape[-1] == cin_g:
        cols = _im2col(x, kh, kw, stride, padding)
        if _kops.conv_dispatch_enabled() and _kops.kernel_supported(w):
            return _kops.qtensor_matmul(cols, w)
        return qmatmul(cols, w)
    return None


def conv2d(x: jax.Array, w, b=None, stride: int = 1, groups: int = 1,
           padding: str = "SAME") -> jax.Array:
    """x: (B,H,W,Cin); w: (kh,kw,Cin//groups,Cout).

    QTensor leaves route through :func:`_qconv2d` (quantized PWConv matmuls
    + the packed-w4 depthwise kernel); everything else — float, calibration,
    and unsupported quantized shapes — runs the XLA convolution (quantized
    weights dequantized through their HWIO shape).
    """
    if isinstance(w, CalibTensor):
        w.record(x)
    elif is_qtensor(w):
        y = _qconv2d(x, w, stride=stride, groups=groups, padding=padding)
        if y is not None:
            if b is not None:
                y = y + b.astype(y.dtype)
            return y
    wv = _conv_weight(w, x.dtype)
    y = jax.lax.conv_general_dilated(
        x, wv, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"), feature_group_count=groups)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y


def dwconv2d(x: jax.Array, w, b=None, stride: int = 1,
             padding: str = "SAME") -> jax.Array:
    """Depthwise conv; w: (kh,kw,1,C).  The paper's memory-intensive layer."""
    c = x.shape[-1]
    return conv2d(x, w, b=b, stride=stride, groups=c, padding=padding)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------


def silu(x):
    return x * jax.nn.sigmoid(x)


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def swiglu(x, w1, w3, w2, b1=None, b3=None, b2=None):
    """SwiGLU FFN: (silu(x@w1) * (x@w3)) @ w2."""
    return dense(silu(dense(x, w1, b1)) * dense(x, w3, b3), w2, b2)


def geglu(x, w1, w3, w2):
    return dense(gelu(dense(x, w1)) * dense(x, w3), w2)
