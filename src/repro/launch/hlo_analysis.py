"""Loop-aware static analysis of optimized (post-SPMD) HLO text.

``compiled.cost_analysis()`` visits each while-loop body ONCE, which
undercounts scanned-layer models by ~L and chunked attention by the chunk
count.  This module re-derives roofline inputs from the HLO text itself:

* computations are segmented; every ``while`` op's body/condition are
  resolved; trip counts are recovered from the loop-bound constant in the
  condition computation; nested loops multiply.
* FLOPs: dot ops contribute 2 * prod(result_dims) * prod(contracting_dims)
  (x trip multiplier), split by operand dtype (int8 dots run at 2x bf16 peak
  on the MXU — the M2Q uniform-half advantage); convolutions are estimated
  from kernel size.
* Traffic: per top-level op (post-fusion), result + operand bytes
  (x multiplier), excluding pure control ops — an HBM-traffic proxy at the
  same altitude XLA's own cost model uses, but loop-aware.
* Collectives: result bytes per opcode (x multiplier).

All numbers are PER PARTITION (the SPMD module is per-device).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_CONTROL_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "while",
    "conditional", "call", "bitcast", "after-all", "partition-id",
    "replica-id", "get-dimension-size", "copy-done", "all-gather-done",
    "all-reduce-done", "collective-permute-done", "opt-barrier",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_TYPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s+(ROOT\s+)?%?([\w.-]+)\s*=\s*"
    r"(\(.*?\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"([a-z][a-z0-9-]*)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.-]+)\s*(?:\(.*\))?\s*->.*{")
_ENTRY_RE = re.compile(r"^ENTRY\s+%?([\w.-]+)", re.M)
_NAME_REF_RE = re.compile(r"%([\w.-]+)")
_CALLEE_ATTR_RE = re.compile(r"(?:calls|to_apply)=%?([\w.-]+)")
_WHILE_COMP_RE = re.compile(r"(?:body|condition)=%?([\w.-]+)")


def _shape_elems(dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _tok_bytes(tok: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(tok):
        total += _shape_elems(dims) * _DTYPE_BYTES.get(dt, 4)
    return total


def _tok_first_shape(tok: str) -> Tuple[str, List[int]]:
    m = _TYPE_RE.search(tok)
    if not m:
        return "f32", []
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return m.group(1), dims


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    result_tok: str
    args: str  # everything after the opening paren (operands + attrs)
    is_root: bool = False

    def split_args(self) -> Tuple[str, str]:
        depth = 1
        for i, ch in enumerate(self.args):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    return self.args[:i], self.args[i + 1:]
        return self.args, ""

    def operand_names(self) -> List[str]:
        ops, _ = self.split_args()
        return _NAME_REF_RE.findall(ops)

    def attrs(self) -> str:
        return self.split_args()[1]


def parse_computations(text: str) -> Dict[str, List[Instr]]:
    comps: Dict[str, List[Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(Instr(name=m.group(2), result_tok=m.group(3),
                                    opcode=m.group(4), args=m.group(5),
                                    is_root=bool(m.group(1))))
    return comps


def _trip_count(comps, cond_name: str) -> int:
    """Loop bound = the largest small-int constant compared in the cond."""
    best = 1
    for ins in comps.get(cond_name, []):
        if ins.opcode == "constant":
            m = re.match(r"\s*(-?\d+)\s*\)?", ins.args)
            if m:
                v = int(m.group(1))
                if 1 <= v <= 10_000_000:
                    best = max(best, v)
    return best


def computation_multipliers(comps) -> Dict[str, int]:
    """Execution-count multiplier per computation (nested loops compose)."""
    mult = {name: 0 for name in comps}
    referenced = set()
    per_comp_callees: Dict[str, List[Tuple[str, int]]] = {n: [] for n in comps}
    for cname, instrs in comps.items():
        for ins in instrs:
            if ins.opcode == "while":
                m_b = re.search(r"body=%?([\w.-]+)", ins.args)
                m_c = re.search(r"condition=%?([\w.-]+)", ins.args)
                if m_b and m_c:
                    trip = _trip_count(comps, m_c.group(1))
                    per_comp_callees[cname].append((m_b.group(1), trip))
                    per_comp_callees[cname].append((m_c.group(1), trip))
                    referenced.update((m_b.group(1), m_c.group(1)))
            else:
                for m in re.finditer(r"(?:to_apply|calls)=%?([\w.-]+)",
                                     ins.args):
                    per_comp_callees[cname].append((m.group(1), 1))
                    referenced.add(m.group(1))
                m = re.search(r"branch_computations=\{([^}]*)\}", ins.args)
                if m:
                    for b in m.group(1).split(","):
                        b = b.strip().lstrip("%")
                        per_comp_callees[cname].append((b, 1))
                        referenced.add(b)
    roots = [n for n in comps if n not in referenced]
    for r in roots:
        mult[r] = 1
    changed = True
    iters = 0
    while changed and iters < 100:
        changed = False
        iters += 1
        for cname, callees in per_comp_callees.items():
            if mult.get(cname, 0) <= 0:
                continue
            for callee, k in callees:
                want = mult[cname] * k
                if callee in mult and mult[callee] < want:
                    mult[callee] = want
                    changed = True
    return mult


def _dot_flops(ins: Instr, shapes: Dict[str, str]) -> Tuple[float, str]:
    _, res = _tok_first_shape(ins.result_tok)
    names = ins.operand_names()
    if not names:
        return 0.0, "f32"
    lhs_tok = shapes.get(names[0], "")
    lhs_dt, lhs_dims = _tok_first_shape(lhs_tok)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs())
    if not m:
        return 0.0, lhs_dt
    k = 1
    for ci in m.group(1).split(","):
        if ci != "" and int(ci) < len(lhs_dims):
            k *= lhs_dims[int(ci)]
    n = 1
    for d in res:
        n *= d
    # dtype classification: prefer int when either side is s8/u8
    rhs_dt = "f32"
    if len(names) > 1:
        rhs_dt, _ = _tok_first_shape(shapes.get(names[1], ""))
    dt = "s8" if ("8" in lhs_dt or "8" in rhs_dt) and (
        lhs_dt.startswith(("s", "u")) or rhs_dt.startswith(("s", "u"))) else lhs_dt
    return 2.0 * n * k, dt


def _conv_flops(ins: Instr, shapes: Dict[str, str]) -> float:
    _, res = _tok_first_shape(ins.result_tok)
    names = ins.operand_names()
    if len(names) < 2 or not res:
        return 0.0
    _, kdims = _tok_first_shape(shapes.get(names[1], ""))
    if not kdims:
        return 0.0
    n = 1
    for d in res:
        n *= d
    out_feat = res[-1]
    k = 1
    for d in kdims:
        k *= d
    if out_feat in kdims:
        k //= out_feat
    else:
        k //= kdims[-1]
    g = 1
    m = re.search(r"feature_group_count=(\d+)", ins.attrs())
    if m:
        g = int(m.group(1))
    return 2.0 * n * max(k, 1) / max(g, 1)


def _fusion_read_write(ins: Instr, comps, shapes) -> Tuple[float, float]:
    """HBM traffic of a fusion op: per-operand reads shrink to the
    dynamic-slice window when the fused computation only slices that
    parameter; dynamic-update-slice roots write only the update."""
    mcall = re.search(r"calls=%?([\w.-]+)", ins.args)
    callee = comps.get(mcall.group(1)) if mcall else None
    operands = ins.operand_names()
    full = [_tok_bytes(shapes.get(nm, "")) for nm in operands]
    write = _tok_bytes(ins.result_tok)
    if callee is None:
        return float(sum(full)), float(write)
    # map parameter index -> local name; find slice/update usage
    param_idx: Dict[str, int] = {}
    sliced: Dict[int, int] = {}
    update_write = None
    local_shapes = {i.name: i.result_tok for i in callee}
    unary_src = {}  # name -> single-operand source (convert/bitcast/copy/...)
    for i in callee:
        if i.opcode == "parameter":
            m = re.match(r"\s*(\d+)", i.args)
            if m:
                param_idx[i.name] = int(m.group(1))
        elif i.opcode in ("convert", "bitcast", "copy", "transpose",
                          "reshape", "broadcast"):
            names = i.operand_names()
            if names:
                unary_src[i.name] = names[0]

    def to_param(name, depth=8):
        while depth and name not in param_idx and name in unary_src:
            name = unary_src[name]
            depth -= 1
        return param_idx.get(name)

    for i in callee:
        if i.opcode == "dynamic-slice":
            names = i.operand_names()
            j = to_param(names[0]) if names else None
            if j is not None:
                sliced[j] = min(sliced.get(j, 1 << 62),
                                _tok_bytes(i.result_tok))
        elif i.opcode in ("dynamic-update-slice", "scatter"):
            names = i.operand_names()
            upd_name = names[1] if i.opcode == "dynamic-update-slice" else (
                names[2] if len(names) > 2 else None)
            if upd_name:
                upd = _tok_bytes(local_shapes.get(upd_name, "")) or \
                    _tok_bytes(shapes.get(upd_name, ""))
                if upd:
                    update_write = (update_write or 0) + upd
            j = to_param(names[0]) if names else None
            if j is not None:
                sliced.setdefault(j, 0)  # aliased buffer: not fully re-read
    reads = 0.0
    for j, fb in enumerate(full):
        reads += min(fb, sliced[j]) if j in sliced else fb
    if update_write is not None:
        write = update_write
    return reads, float(write)


def op_histogram(text: str, weighted: bool = True,
                 include_fused: bool = False) -> Dict[str, int]:
    """Loop-aware opcode histogram.

    Default counts STANDALONE (top-level, post-fusion) ops — fusion-interior
    instructions are registers, not HBM-visible ops, so callee computations
    of fusions/custom-calls are excluded.  ``include_fused=True`` counts the
    interiors too (strictest check: "no gather exists ANYWHERE in this
    module", fused or not).  ``weighted`` multiplies by while-loop trip
    counts (a gather inside an L-layer scan counts L times).  Used by the
    kernel benchmarks to prove the fused M2Q path emits zero
    gather/concatenate per quantized layer."""
    comps = parse_computations(text)
    mult = computation_multipliers(comps)
    # exclude fusion/custom-call interiors AND applied computations (reduce/
    # sort/scatter bodies) — none are HBM-visible ops; while bodies stay in
    fused_callees = set()
    for instrs in comps.values():
        for ins in instrs:
            if ins.opcode in ("fusion", "custom-call"):
                mcall = re.search(r"calls=%?([\w.-]+)", ins.args)
                if mcall:
                    fused_callees.add(mcall.group(1))
            for m in re.finditer(r"to_apply=%?([\w.-]+)", ins.args):
                fused_callees.add(m.group(1))
    hist: Dict[str, int] = {}
    for cname, instrs in comps.items():
        m = mult.get(cname, 0)
        if cname in fused_callees:
            if not include_fused:
                continue
            m = max(m, 1)  # callees carry no trip multiplier of their own
        if m <= 0:
            continue
        for ins in instrs:
            hist[ins.opcode] = hist.get(ins.opcode, 0) + (m if weighted else 1)
    return hist


def analyze(text: str) -> dict:
    comps = parse_computations(text)
    mult = computation_multipliers(comps)
    # name -> result type token (instruction names are unique module-wide in
    # optimized HLO; last-write-wins is fine for our purposes)
    shapes: Dict[str, str] = {}
    producers: Dict[str, Instr] = {}
    for instrs in comps.values():
        for ins in instrs:
            shapes[ins.name] = ins.result_tok
            producers[ins.name] = ins

    def bf16_promoted(name: str, depth: int = 4) -> bool:
        """True if an f32 value is the CPU backend's promotion of a bf16
        tensor (XLA CPU has no native bf16 GEMM/reduce, so it wraps them in
        convert fusions / '_promoted' reducers; a TPU build keeps bf16).
        Detected by a convert-ish producer whose operands — or, for fusions,
        whose callee parameters / interior converts — are bf16."""
        while depth > 0:
            ins = producers.get(name)
            if ins is None:
                return False
            if ins.opcode == "fusion" and "convert" in ins.name:
                m = re.search(r"calls=%?([\w.-]+)", ins.args)
                for ci in comps.get(m.group(1), []) if m else []:
                    dt, _ = _tok_first_shape(ci.result_tok)
                    if ci.opcode == "parameter" and dt == "bf16":
                        return True
                    if ci.opcode == "convert":
                        src = ci.operand_names()
                        sdt, _ = _tok_first_shape(
                            shapes.get(src[0], "") if src else "")
                        # local names resolve within the callee
                        for cj in comps.get(m.group(1), []):
                            if src and cj.name == src[0]:
                                sdt, _ = _tok_first_shape(cj.result_tok)
                        if sdt == "bf16":
                            return True
            if ins.opcode in ("convert", "bitcast", "copy") or (
                    ins.opcode == "fusion" and "convert" in ins.name):
                for nm in ins.operand_names():
                    dt, _ = _tok_first_shape(shapes.get(nm, ""))
                    if dt == "bf16":
                        return True
                names = ins.operand_names()
                if not names:
                    return False
                name = names[0]
                depth -= 1
                continue
            return False
        return False
    flops = 0.0
    flops_by_dtype: Dict[str, float] = {}
    traffic = 0.0
    coll_bytes = {c: 0.0 for c in _COLLECTIVES}
    coll_counts = {c: 0 for c in _COLLECTIVES}
    fused_callees = set()
    for instrs in comps.values():
        for ins in instrs:
            if ins.opcode in ("fusion", "custom-call"):
                mcall = re.search(r"calls=%?([\w.-]+)", ins.args)
                if mcall:
                    fused_callees.add(mcall.group(1))
    for cname, instrs in comps.items():
        m = mult.get(cname, 0)
        if m <= 0:
            continue
        in_fused = cname in fused_callees
        for ins in instrs:
            op = ins.opcode
            if op == "dot":
                f, dt = _dot_flops(ins, shapes)
                if dt in ("f32", "f64"):
                    names = ins.operand_names()
                    if any(bf16_promoted(nm) for nm in names[:2]):
                        dt = "bf16"  # CPU-promoted; TPU runs this dot in bf16
                flops += m * f
                flops_by_dtype[dt] = flops_by_dtype.get(dt, 0.0) + m * f
            elif op == "convolution":
                f = _conv_flops(ins, shapes)
                flops += m * f
                flops_by_dtype["conv"] = flops_by_dtype.get("conv", 0.0) + m * f
            if op in _CONTROL_OPS or in_fused:
                continue  # fused interiors are registers, not HBM traffic
            for c in _COLLECTIVES:
                if op == c or op == c + "-start":
                    b = _tok_bytes(ins.result_tok)
                    # promoted-from-bf16 collectives move bf16 on TPU
                    dt, _ = _tok_first_shape(ins.result_tok)
                    if dt in ("f32", "f64") and (
                            "promoted" in ins.args
                            or any(bf16_promoted(nm)
                                   for nm in ins.operand_names()[:2])):
                        b //= 2
                    coll_bytes[c] += m * b
                    coll_counts[c] += m
                    break
            rb = _tok_bytes(ins.result_tok)
            obs = [_tok_bytes(shapes.get(nm, "")) for nm in ins.operand_names()]
            if op == "fusion":
                r, w = _fusion_read_write(ins, comps, shapes)
                traffic += m * (r + w)
            elif op in ("dynamic-update-slice", "scatter"):
                # in-place: write = update ~ operands minus the aliased buffer
                traffic += m * (sum(obs) - (max(obs) if obs else 0))
            elif op in ("dynamic-slice", "gather"):
                traffic += m * rb  # only the window moves
            else:
                traffic += m * (rb + sum(obs))
    return {
        "dot_flops": flops,
        "dot_flops_by_dtype": flops_by_dtype,
        "traffic_bytes": traffic,
        "collective_bytes": coll_bytes,
        "collective_counts": coll_counts,
        "collective_total_bytes": float(sum(coll_bytes.values())),
        "n_computations": len(comps),
    }


# ---------------------------------------------------------------------------
# def-use graph (fusion-boundary-crossing) for the qlint rule engine
# ---------------------------------------------------------------------------

def is_float_dtype(dt: str) -> bool:
    return dt.startswith(("f", "bf")) and dt != "false"


def is_int_dtype(dt: str) -> bool:
    return dt.startswith(("s", "u")) and dt != "u"  # s4/s8/.../u4/u8/...


class Graph:
    """Module-wide def-use graph over optimized HLO text.

    ``op_histogram``/``analyze`` treat fusion interiors as opaque; the
    qlint dtype-flow rules (no-dequant-matmul, no-gather-concat,
    unguarded-act-quant) need to ATTRIBUTE interior instructions back to
    the values that feed them, so this graph stitches call boundaries:

    * caller operand i  ->  callee ``parameter(i)``  (fusions, calls,
      applied computations, while init);
    * callee ROOT       ->  the call instruction's result (so users of a
      fusion see through to the producing interior instruction);
    * while body ROOT   ->  body/condition parameters (loop carry).

    Instruction names are unique module-wide in optimized HLO, so edges
    are keyed by bare names.  ``edges`` maps a value name to the
    instructions consuming it (crossing boundaries); ``redges`` is the
    inverse.  The binding is positional and conservative: an over-
    approximate reachability, which is the right polarity for "no X is
    reachable from a quantized parameter" rules.
    """

    def __init__(self, text: str):
        self.comps = parse_computations(text)
        m = _ENTRY_RE.search(text)
        self.entry: Optional[str] = m.group(1) if m else (
            next(iter(self.comps)) if self.comps else None)
        self.shapes: Dict[str, str] = {}
        self.producers: Dict[str, Instr] = {}
        self.comp_of: Dict[str, str] = {}
        self.params: Dict[str, List[Optional[str]]] = {}
        self.roots: Dict[str, Optional[str]] = {}
        for cname, instrs in self.comps.items():
            plist: List[Optional[str]] = []
            root = None
            for ins in instrs:
                self.shapes[ins.name] = ins.result_tok
                self.producers[ins.name] = ins
                self.comp_of[ins.name] = cname
                if ins.is_root:
                    root = ins.name
                if ins.opcode == "parameter":
                    mp = re.match(r"\s*(\d+)", ins.args)
                    idx = int(mp.group(1)) if mp else len(plist)
                    while len(plist) <= idx:
                        plist.append(None)
                    plist[idx] = ins.name
            if root is None and instrs:
                root = instrs[-1].name  # ROOT is conventionally last
            self.params[cname] = plist
            self.roots[cname] = root
        # callsites first: tuple_element() resolves parameters through them
        self.callsites: Dict[str, List[str]] = {}  # comp -> caller instrs
        for cname, instrs in self.comps.items():
            for ins in instrs:
                for k in self._callees(ins):
                    if k in self.comps:
                        self.callsites.setdefault(k, []).append(ins.name)
        self.edges: Dict[str, List[str]] = {}
        self.redges: Dict[str, List[str]] = {}
        for cname, instrs in self.comps.items():
            for ins in instrs:
                operands = ins.operand_names()
                if ins.opcode == "get-tuple-element":
                    # element-precise edge: a gte consumes ONE tuple slot,
                    # not the whole loop-carried state — without this every
                    # value in a while body is "reachable" from every other
                    mi = re.search(r"index=(\d+)", ins.args)
                    srcs = (self.tuple_element(operands[0], int(mi.group(1)))
                            if mi and operands else [])
                    for s in srcs or operands:
                        self._edge(s, ins.name)
                    continue
                for o in operands:
                    self._edge(o, ins.name)
                for k in self._callees(ins):
                    if k not in self.comps:
                        continue
                    for i, p in enumerate(self.params.get(k, [])):
                        if p is not None and i < len(operands):
                            self._edge(operands[i], p)
                    root = self.roots.get(k)
                    if root:
                        self._edge(root, ins.name)

    @staticmethod
    def _callees(ins: Instr) -> List[str]:
        if ins.opcode == "while":
            return _WHILE_COMP_RE.findall(ins.args)
        out = _CALLEE_ATTR_RE.findall(ins.args)
        mb = re.search(r"branch_computations=\{([^}]*)\}", ins.args)
        if mb:
            out += [b.strip().lstrip("%")
                    for b in mb.group(1).split(",") if b.strip()]
        return out

    def tuple_element(self, name: str, k: int, _depth: int = 0,
                      _seen=None) -> List[str]:
        """Producing value name(s) of element ``k`` of tuple value
        ``name``, looking through tuple/gte/while/fusion plumbing.  A
        loop-carried tuple resolves to BOTH the init element and the
        body-root element (the value of any iteration).  Empty when
        unresolvable."""
        if _depth > 24:
            return []
        if _seen is None:
            _seen = set()
        if (name, k) in _seen:
            return []
        _seen.add((name, k))
        ins = self.producers.get(name)
        if ins is None:
            return []
        operands = ins.operand_names()
        if ins.opcode == "tuple":
            return [operands[k]] if k < len(operands) else []
        if ins.opcode == "while":
            out = []
            if operands:
                out += self.tuple_element(operands[0], k, _depth + 1, _seen)
            mb = re.search(r"body=%?([\w.-]+)", ins.args)
            root = self.roots.get(mb.group(1)) if mb else None
            if root:
                out += self.tuple_element(root, k, _depth + 1, _seen)
            return out
        if ins.opcode == "parameter":
            comp = self.comp_of.get(name, "")
            try:
                idx = self.params.get(comp, []).index(name)
            except ValueError:
                return []
            out = []
            for cs in self.callsites.get(comp, []):
                ci = self.producers[cs]
                cops = ci.operand_names()
                if ci.opcode == "while":
                    if cops:
                        out += self.tuple_element(cops[0], k, _depth + 1,
                                                  _seen)
                    mb = re.search(r"body=%?([\w.-]+)", ci.args)
                    root = self.roots.get(mb.group(1)) if mb else None
                    if root:
                        out += self.tuple_element(root, k, _depth + 1, _seen)
                elif idx < len(cops):
                    out += self.tuple_element(cops[idx], k, _depth + 1, _seen)
            return out
        if ins.opcode in ("fusion", "call", "conditional", "custom-call"):
            out = []
            for kk in self._callees(ins):
                root = self.roots.get(kk)
                if root:
                    out += self.tuple_element(root, k, _depth + 1, _seen)
            return out or [name]
        if ins.opcode == "get-tuple-element":
            mi = re.search(r"index=(\d+)", ins.args)
            if operands and mi:
                out = []
                for nm in self.tuple_element(operands[0], int(mi.group(1)),
                                             _depth + 1, _seen):
                    out += self.tuple_element(nm, k, _depth + 1, _seen)
                return out
            return [name]
        if ins.opcode in ("copy", "bitcast", "optimization-barrier",
                          "opt-barrier", "copy-start", "copy-done"):
            if operands:
                return self.tuple_element(operands[0], k, _depth + 1, _seen)
        return [name]  # opaque producer: the whole value stands in

    def _edge(self, src: str, dst: str) -> None:
        if src == dst:
            return
        lst = self.edges.setdefault(src, [])
        if not lst or lst[-1] != dst:
            lst.append(dst)
        self.redges.setdefault(dst, []).append(src)

    def dtype_of(self, name: str) -> str:
        return _tok_first_shape(self.shapes.get(name, ""))[0]

    def entry_params(self) -> List[Optional[str]]:
        """Entry-computation parameter names ordered by parameter index
        (index i lines up with the i-th flattened jit argument leaf)."""
        return self.params.get(self.entry or "", [])

    def loop_comps(self) -> set:
        """Computations executing inside any ``while`` (bodies, conds, and
        everything they transitively call — fusion interiors included)."""
        stack: List[str] = []
        for instrs in self.comps.values():
            for ins in instrs:
                if ins.opcode == "while":
                    stack.extend(_WHILE_COMP_RE.findall(ins.args))
        out: set = set()
        while stack:
            c = stack.pop()
            if c in out or c not in self.comps:
                continue
            out.add(c)
            for ins in self.comps[c]:
                stack.extend(_CALLEE_ATTR_RE.findall(ins.args))
                stack.extend(_WHILE_COMP_RE.findall(ins.args))
                mb = re.search(r"branch_computations=\{([^}]*)\}", ins.args)
                if mb:
                    stack.extend(b.strip().lstrip("%")
                                 for b in mb.group(1).split(",") if b.strip())
        return out
