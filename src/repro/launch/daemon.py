"""Serving-daemon driver CLI: wall-clock serving with SLO classes,
streaming, and (multi-)host mesh launch.

Single host — quantize (unless ``--no-quant``) and serve mixed
interactive + batch wall-clock traffic through the background
:class:`~repro.serving.daemon.ServingDaemon`, streaming the first
interactive request token by token:

  PYTHONPATH=src python -m repro.launch.daemon --arch qwen1.5-0.5b \
      --reduced --requests 8 --stream

``--smoke`` is the CI fast path (check.sh): tiny reduced config, one
streamed request with a tight timeout, clean drain, exact outcome
reconciliation — exits non-zero on any of those failing.

Multi-host — every process runs the same command with its own
``--process-id``; ``jax.distributed.initialize`` joins them into one
global device world, the ``--mesh`` spans it, and params/cache land via
``dist.sharding.put_global`` (cross-process placement, where
``jax.device_put`` cannot).  On backends without multiprocess execution
(the CPU backend) this is a DRY-RUN: distributed init, global mesh,
spec-conformant placement, and lowering of the prefill computation are
all verified, then the process reports and exits — the serve loop
itself runs only where the runtime can execute cross-process programs:

  python -m repro.launch.daemon --arch qwen1.5-0.5b --reduced \
      --mesh 2x4 --coordinator 127.0.0.1:9911 --num-processes 2 \
      --process-id 0   # and the same with --process-id 1

Supervision (docs/serving.md, "Supervision & recovery"):

* ``--health-file PATH`` runs the single-host serve path under a
  :class:`~repro.serving.supervisor.Supervisor` and writes its
  ``health()`` probe snapshot to PATH (atomic tmp + ``os.replace``)
  twice a second — poll it from outside the process.  On the multi-host
  path the same flag writes a per-process readiness marker
  ``PATH.p<process_id>`` once placement + lowering verify, and each
  process waits for ALL peers' markers before reporting
  ``peers-ready`` — a cross-host readiness barrier.
* ``--recovery-smoke`` is the crash-recovery CI stage: a journal-backed
  supervisor serving under an injected ``crash@decode`` fault — asserts
  the watchdog restarted the daemon, every request completed, the
  replayed results MATCH a fault-free reference, and the journal
  reconciles exactly.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

# NOTE: repro imports are deliberately LAZY (inside functions) in this
# module: multi-host launch must call jax.distributed.initialize()
# before ANY jax computation executes, and several repro modules run
# small computations at import time.  `import jax` alone is safe.
import jax
import numpy as np


def build_engine(args, mesh=None):
    from ..configs.registry import ARCHS, REDUCED
    from ..models import get_model
    from ..serving.engine import Engine
    from .serve import quantize_for_serving
    cfg = (REDUCED if args.reduced else ARCHS)[args.arch]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    engine_kw = dict(max_batch=args.max_batch, max_len=args.max_len,
                     mesh=mesh)
    if args.no_quant:
        return Engine(cfg, params, **engine_kw)
    qm = quantize_for_serving(cfg, params)
    print(f"[daemon] quantized {len(qm.report)} layers")
    return qm.serve(**engine_kw)


def _prompts(cfg, n, rng):
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 13)),
                         dtype=np.int32) for _ in range(n)]


def _write_json_atomic(path, obj) -> None:
    tmp = f"{path}.tmp"
    with open(tmp, "w", encoding="utf-8") as f:
        json.dump(obj, f, sort_keys=True)
    os.replace(tmp, path)


class _HealthWriter:
    """Background thread dumping ``snapshot()`` JSON to ``path`` (atomic
    replace, so readers never see a torn file)."""

    def __init__(self, path: str, snapshot, interval_s: float = 0.5):
        self.path = path
        self._snapshot = snapshot
        self._interval = interval_s
        self._stop = threading.Event()
        self._th = threading.Thread(target=self._run, daemon=True,
                                    name="repro-health-writer")

    def _run(self):
        while True:
            _write_json_atomic(self.path, self._snapshot())
            if self._stop.wait(self._interval):
                return

    def __enter__(self):
        self._th.start()
        return self

    def __exit__(self, *exc):
        self._stop.set()
        self._th.join()
        _write_json_atomic(self.path, self._snapshot())  # final state


def serve_traffic(daemon, args) -> bool:
    """Submit mixed interactive/batch wall-clock traffic from a foreign
    thread, stream the first interactive request, report per-class
    latency.  Returns True when every outcome reconciled."""
    eng = daemon.engine
    cfg = eng.cfg
    rng = np.random.default_rng(0)
    n_inter = max(1, args.requests // 2)
    n_batch = args.requests - n_inter
    results = []

    def submitter():
        for p in _prompts(cfg, n_batch, rng):
            results.append(daemon.submit(p, slo="batch",
                                         max_new_tokens=args.max_new))
        for p in _prompts(cfg, n_inter - 1, rng):
            results.append(daemon.submit(p, slo="interactive",
                                         max_new_tokens=args.max_new))

    th = threading.Thread(target=submitter)
    th.start()
    streamed = []
    first = daemon.submit(_prompts(cfg, 1, rng)[0], slo="interactive",
                          max_new_tokens=args.max_new, stream=True)
    for tok in first.handle.tokens(timeout=args.timeout):
        streamed.append(tok)
        if args.stream:
            print(f"[daemon] stream tok={tok}", flush=True)
    th.join()
    results.append(first)
    for r in results:
        r.handle.result(timeout=args.timeout)
    daemon.shutdown(drain=True, timeout=args.timeout)
    if streamed != first.handle.result():
        print(f"[daemon] FAIL: streamed {streamed} != result "
              f"{first.handle.result()}")
        return False
    s = eng.stats
    if s.submitted != s.resolved:
        print(f"[daemon] FAIL: submitted={s.submitted} != "
              f"resolved={s.resolved}")
        return False
    cls = daemon.stats_summary()["classes"]
    for name, row in cls.items():
        print(f"[daemon] class={name} completed={row['completed']} "
              f"p50={row['p50_ms']:.1f}ms p99={row['p99_ms']:.1f}ms")
    print(f"[daemon] reconciled {s.submitted} requests; "
          f"streamed_tokens={s.streamed_tokens} "
          f"preemptions={s.preemptions}")
    return True


def serve_supervised(args, mesh=None) -> int:
    """Single-host serve path under a Supervisor, health snapshots on
    disk (``--health-file``): same mixed traffic as :func:`serve_traffic`
    but submitted through ``Supervisor.submit`` — restart-transparent —
    with supervisor-level outcome reconciliation."""
    from ..serving.supervisor import Supervisor
    sup = Supervisor(lambda: build_engine(args, mesh=mesh)).start()
    cfg = sup._daemon.engine.cfg
    rng = np.random.default_rng(0)
    n_inter = max(1, args.requests // 2)
    n_batch = args.requests - n_inter
    ok = True
    with _HealthWriter(args.health_file, sup.health):
        handles = [sup.submit(p, slo="batch", max_new_tokens=args.max_new)
                   for p in _prompts(cfg, n_batch, rng)]
        handles += [sup.submit(p, slo="interactive",
                               max_new_tokens=args.max_new)
                    for p in _prompts(cfg, n_inter - 1, rng)]
        streamed = []
        first = sup.submit(_prompts(cfg, 1, rng)[0], slo="interactive",
                           max_new_tokens=args.max_new, stream=True)
        for tok in first.tokens(timeout=args.timeout):
            streamed.append(tok)
            if args.stream:
                print(f"[daemon] stream tok={tok}", flush=True)
        handles.append(first)
        for h in handles:
            h.result(timeout=args.timeout)
        if streamed != first.result():
            print(f"[daemon] FAIL: streamed {streamed} != result "
                  f"{first.result()}")
            ok = False
        sup.shutdown(drain=True, timeout=args.timeout)
        s = sup.stats
        if s.submitted != s.resolved:
            print(f"[daemon] FAIL: submitted={s.submitted} != "
                  f"resolved={s.resolved}")
            ok = False
    health = sup.health()
    print(f"[daemon] supervised: {s.submitted} requests reconciled, "
          f"restarts={health['restarts']}, health -> {args.health_file}")
    return 0 if ok else 1


def recovery_smoke(args) -> int:
    """CI crash-recovery stage: journal-backed supervisor, first engine
    build armed with ``crash@decode`` AFTER a fault-free warmup (a cold
    first step would trip the hang watchdog) — assert restart happened,
    goodput is total, replayed results match a fault-free reference, and
    the journal reconciles exactly."""
    import tempfile
    from ..serving.engine import Engine
    from ..serving.faults import FaultInjector, FaultSpec
    from ..serving.journal import RequestJournal
    from ..serving.supervisor import RestartPolicy, Supervisor
    t0 = time.monotonic()
    eng0 = build_engine(args)
    cfg, params = eng0.cfg, eng0.params
    rng = np.random.default_rng(0)
    prompts = _prompts(cfg, max(2, args.requests), rng)

    refs = [eng0.submit(p, max_new_tokens=args.max_new) for p in prompts]
    eng0.run()
    expected = [r.handle.result() for r in refs]

    builds = []

    def factory():
        eng = Engine(cfg, params, max_batch=args.max_batch,
                     max_len=args.max_len)
        for p in prompts:  # warm every shape, fault-free, then arm
            eng.submit(p, max_new_tokens=args.max_new)
        eng.run()
        if not builds:
            eng.faults = FaultInjector(
                [FaultSpec.parse(f"crash@decode:{args.max_new}")])
        builds.append(1)
        return eng

    jpath = os.path.join(tempfile.mkdtemp(prefix="repro-recovery-"),
                         "journal.jsonl")
    sup = Supervisor(
        factory, journal=RequestJournal(jpath),
        policy=RestartPolicy(hang_threshold_s=max(10.0, args.timeout / 4),
                             backoff_base_s=0.02, poll_interval_s=0.05))
    sup.start()
    handles = [sup.submit(p, request_id=f"smoke-{i}",
                          max_new_tokens=args.max_new)
               for i, p in enumerate(prompts)]
    outs = [h.result(timeout=args.timeout) for h in handles]
    rec = sup.journal.reconcile()
    health = sup.health()
    sup.shutdown(drain=True, timeout=args.timeout)
    completed = sum(1 for o in outs if o is not None)
    goodput = completed / len(prompts)
    match = all(list(a) == list(b) for a, b in zip(outs, expected))
    ok = (sup.restarts >= 1 and goodput == 1.0 and match
          and rec["exact"] and rec["pending"] == 0
          and health["ready"]["ready"])
    if not ok:
        print(f"[daemon] RECOVERY SMOKE FAIL: restarts={sup.restarts} "
              f"goodput={goodput} match={match} reconcile={rec} "
              f"ready={health['ready']}")
        return 1
    print(f"[daemon] recovery smoke ok: crash@decode -> "
          f"{sup.restarts} restart(s), {sup.replayed} replayed, "
          f"goodput={goodput:.0%}, results match fault-free reference, "
          f"journal exact ({rec['submitted']} submits == "
          f"{rec['terminal']} terminals) in "
          f"{time.monotonic() - t0:.1f}s")
    return 0


def _peer_barrier(args, pid: int, info: dict) -> bool:
    """Multi-host readiness barrier over ``--health-file``: write this
    process's marker, wait for every peer's."""
    _write_json_atomic(f"{args.health_file}.p{pid}",
                       {"pid": pid, "ready": True, **info})
    want = [f"{args.health_file}.p{i}" for i in range(args.num_processes)]
    deadline = time.monotonic() + args.timeout
    while time.monotonic() < deadline:
        seen = sum(1 for p in want if os.path.exists(p))
        if seen == args.num_processes:
            print(f"[daemon:{pid}] peers-ready: {seen}/"
                  f"{args.num_processes} readiness markers", flush=True)
            return True
        time.sleep(0.1)
    print(f"[daemon:{pid}] FAIL: peer readiness barrier timed out "
          f"({seen}/{args.num_processes})")
    return False


def multihost_dryrun(args) -> int:
    """Distributed init + global mesh + cross-process placement +
    lowering; executes the serve loop only on backends that support
    multiprocess computations (not CPU)."""
    jax.distributed.initialize(coordinator_address=args.coordinator,
                               num_processes=args.num_processes,
                               process_id=args.process_id)
    pid = jax.process_index()
    n_global = len(jax.devices())
    n_local = len(jax.local_devices())
    print(f"[daemon:{pid}] distributed up: {args.num_processes} processes, "
          f"{n_global} global / {n_local} local devices", flush=True)
    from ..configs.registry import ARCHS, REDUCED
    from ..dist import sharding as shd
    from ..models import get_model
    from ..serving.daemon import ServingDaemon
    from .serve import parse_mesh
    cfg = (REDUCED if args.reduced else ARCHS)[args.arch]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    mesh = parse_mesh(args.mesh)
    pspecs = shd.param_specs(params, mesh)
    gparams = shd.put_global(params, pspecs, mesh)
    # placement check: every leaf's sharding is exactly its spec, and
    # this process holds only shards on its own devices
    n_leaves = n_sharded = 0
    from jax.sharding import NamedSharding
    for leaf, spec in zip(jax.tree.leaves(gparams), jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec))):
        n_leaves += 1
        want = NamedSharding(mesh, spec)
        if not leaf.sharding.is_equivalent_to(want, leaf.ndim):
            print(f"[daemon:{pid}] FAIL: leaf sharding {leaf.sharding} "
                  f"!= spec {want}")
            return 1
        if any(sh.data is None for sh in leaf.addressable_shards):
            print(f"[daemon:{pid}] FAIL: unmaterialized local shard")
            return 1
        if len(leaf.addressable_shards) < leaf.sharding.num_devices:
            n_sharded += 1
    print(f"[daemon:{pid}] placement-ok: {n_leaves} leaves on-spec, "
          f"{n_sharded} with non-addressable remote shards", flush=True)
    cache = model.init_cache(cfg, args.max_batch, args.max_len)
    gcache = shd.put_global(cache, shd.cache_specs(cache, mesh,
                                                   shard_model=True), mesh)
    toks = np.zeros((args.max_batch, 8), np.int32)
    gtoks = shd.put_global(toks, shd.batch_specs(toks, mesh), mesh)

    def prefill(p, c, t):
        return model.prefill(cfg, p, c, t)

    lowered = jax.jit(prefill).lower(gparams, gcache, gtoks)
    print(f"[daemon:{pid}] lowering-ok: prefill lowered over "
          f"mesh={dict(mesh.shape)}", flush=True)
    if args.health_file:
        # cross-host readiness barrier: all peers verified placement +
        # lowering before anyone proceeds (or reports dry-run success)
        if not _peer_barrier(args, pid, {
                "leaves": n_leaves, "sharded": n_sharded,
                "mesh": dict(mesh.shape), "unix_time": time.time()}):
            return 1
    if jax.default_backend() == "cpu" and args.num_processes > 1:
        # the CPU runtime raises "Multiprocess computations aren't
        # implemented on the CPU backend" at compile time — placement
        # and lowering above are the verifiable dry-run surface
        print(f"[daemon:{pid}] dry-run complete (CPU backend has no "
              "multiprocess execution; serve loop skipped)", flush=True)
        return 0
    lowered.compile()
    eng = build_engine(args, mesh=mesh)
    with ServingDaemon(eng) as daemon:
        ok = serve_traffic(daemon, args)
    return 0 if ok else 1


def smoke(args) -> int:
    """check.sh fast path: one streamed request end to end, wall-clock,
    with a tight timeout and a clean reconciled shutdown."""
    from ..serving.daemon import ServingDaemon
    t0 = time.monotonic()
    eng = build_engine(args)
    daemon = ServingDaemon(eng).start()
    streamed = []
    req = daemon.submit(np.arange(1, 9, dtype=np.int32),
                        slo="interactive", max_new_tokens=args.max_new,
                        stream=True)
    try:
        for tok in req.handle.tokens(timeout=args.timeout):
            streamed.append(tok)
    except TimeoutError as e:
        print(f"[daemon] SMOKE FAIL: {e}")
        return 1
    daemon.shutdown(drain=True, timeout=args.timeout)
    s = eng.stats
    ok = (streamed == req.handle.result()
          and len(streamed) == args.max_new
          and s.submitted == s.resolved == 1
          and not daemon.running)
    if not ok:
        print(f"[daemon] SMOKE FAIL: streamed={streamed} "
              f"result={req.handle.result()} submitted={s.submitted} "
              f"resolved={s.resolved} running={daemon.running}")
        return 1
    print(f"[daemon] smoke ok: {len(streamed)} tokens streamed "
          f"wall-clock in {time.monotonic() - t0:.1f}s, clean shutdown")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="per-wait timeout (seconds) for streaming/"
                         "results/drain")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--stream", action="store_true",
                    help="print each streamed token of the first "
                         "interactive request")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast path: one streamed request, tight "
                         "timeout, reconciled shutdown")
    ap.add_argument("--recovery-smoke", action="store_true",
                    help="CI crash-recovery stage: journal-backed "
                         "supervisor under an injected crash@decode "
                         "fault; asserts restart + replay + exact "
                         "journal reconciliation")
    ap.add_argument("--health-file", default=None,
                    help="write health()/readiness JSON here: periodic "
                         "supervisor snapshots (single host) or "
                         "per-process readiness markers + peer barrier "
                         "(multi-host)")
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL over the GLOBAL device world")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 (multi-host launch)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator is not None:
        sys.exit(multihost_dryrun(args))
    if args.recovery_smoke:
        sys.exit(recovery_smoke(args))
    if args.smoke:
        sys.exit(smoke(args))
    from ..serving.daemon import ServingDaemon
    from .serve import parse_mesh
    mesh = parse_mesh(args.mesh) if args.mesh else None
    if args.health_file:
        sys.exit(serve_supervised(args, mesh=mesh))
    eng = build_engine(args, mesh=mesh)
    with ServingDaemon(eng) as daemon:
        ok = serve_traffic(daemon, args)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
