"""Serving-daemon driver CLI: wall-clock serving with SLO classes,
streaming, and (multi-)host mesh launch.

Single host — quantize (unless ``--no-quant``) and serve mixed
interactive + batch wall-clock traffic through the background
:class:`~repro.serving.daemon.ServingDaemon`, streaming the first
interactive request token by token:

  PYTHONPATH=src python -m repro.launch.daemon --arch qwen1.5-0.5b \
      --reduced --requests 8 --stream

``--smoke`` is the CI fast path (check.sh): tiny reduced config, one
streamed request with a tight timeout, clean drain, exact outcome
reconciliation — exits non-zero on any of those failing.

Multi-host — every process runs the same command with its own
``--process-id``; ``jax.distributed.initialize`` joins them into one
global device world, the ``--mesh`` spans it, and params/cache land via
``dist.sharding.put_global`` (cross-process placement, where
``jax.device_put`` cannot).  On backends without multiprocess execution
(the CPU backend) this is a DRY-RUN: distributed init, global mesh,
spec-conformant placement, and lowering of the prefill computation are
all verified, then the process reports and exits — the serve loop
itself runs only where the runtime can execute cross-process programs:

  python -m repro.launch.daemon --arch qwen1.5-0.5b --reduced \
      --mesh 2x4 --coordinator 127.0.0.1:9911 --num-processes 2 \
      --process-id 0   # and the same with --process-id 1
"""
from __future__ import annotations

import argparse
import sys
import threading
import time

# NOTE: repro imports are deliberately LAZY (inside functions) in this
# module: multi-host launch must call jax.distributed.initialize()
# before ANY jax computation executes, and several repro modules run
# small computations at import time.  `import jax` alone is safe.
import jax
import numpy as np


def build_engine(args, mesh=None):
    from ..configs.registry import ARCHS, REDUCED
    from ..models import get_model
    from ..serving.engine import Engine
    from .serve import quantize_for_serving
    cfg = (REDUCED if args.reduced else ARCHS)[args.arch]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    engine_kw = dict(max_batch=args.max_batch, max_len=args.max_len,
                     mesh=mesh)
    if args.no_quant:
        return Engine(cfg, params, **engine_kw)
    qm = quantize_for_serving(cfg, params)
    print(f"[daemon] quantized {len(qm.report)} layers")
    return qm.serve(**engine_kw)


def _prompts(cfg, n, rng):
    return [rng.integers(0, cfg.vocab_size, int(rng.integers(4, 13)),
                         dtype=np.int32) for _ in range(n)]


def serve_traffic(daemon, args) -> bool:
    """Submit mixed interactive/batch wall-clock traffic from a foreign
    thread, stream the first interactive request, report per-class
    latency.  Returns True when every outcome reconciled."""
    eng = daemon.engine
    cfg = eng.cfg
    rng = np.random.default_rng(0)
    n_inter = max(1, args.requests // 2)
    n_batch = args.requests - n_inter
    results = []

    def submitter():
        for p in _prompts(cfg, n_batch, rng):
            results.append(daemon.submit(p, slo="batch",
                                         max_new_tokens=args.max_new))
        for p in _prompts(cfg, n_inter - 1, rng):
            results.append(daemon.submit(p, slo="interactive",
                                         max_new_tokens=args.max_new))

    th = threading.Thread(target=submitter)
    th.start()
    streamed = []
    first = daemon.submit(_prompts(cfg, 1, rng)[0], slo="interactive",
                          max_new_tokens=args.max_new, stream=True)
    for tok in first.handle.tokens(timeout=args.timeout):
        streamed.append(tok)
        if args.stream:
            print(f"[daemon] stream tok={tok}", flush=True)
    th.join()
    results.append(first)
    for r in results:
        r.handle.result(timeout=args.timeout)
    daemon.shutdown(drain=True, timeout=args.timeout)
    if streamed != first.handle.result():
        print(f"[daemon] FAIL: streamed {streamed} != result "
              f"{first.handle.result()}")
        return False
    s = eng.stats
    if s.submitted != s.resolved:
        print(f"[daemon] FAIL: submitted={s.submitted} != "
              f"resolved={s.resolved}")
        return False
    cls = daemon.stats_summary()["classes"]
    for name, row in cls.items():
        print(f"[daemon] class={name} completed={row['completed']} "
              f"p50={row['p50_ms']:.1f}ms p99={row['p99_ms']:.1f}ms")
    print(f"[daemon] reconciled {s.submitted} requests; "
          f"streamed_tokens={s.streamed_tokens} "
          f"preemptions={s.preemptions}")
    return True


def multihost_dryrun(args) -> int:
    """Distributed init + global mesh + cross-process placement +
    lowering; executes the serve loop only on backends that support
    multiprocess computations (not CPU)."""
    jax.distributed.initialize(coordinator_address=args.coordinator,
                               num_processes=args.num_processes,
                               process_id=args.process_id)
    pid = jax.process_index()
    n_global = len(jax.devices())
    n_local = len(jax.local_devices())
    print(f"[daemon:{pid}] distributed up: {args.num_processes} processes, "
          f"{n_global} global / {n_local} local devices", flush=True)
    from ..configs.registry import ARCHS, REDUCED
    from ..dist import sharding as shd
    from ..models import get_model
    from ..serving.daemon import ServingDaemon
    from .serve import parse_mesh
    cfg = (REDUCED if args.reduced else ARCHS)[args.arch]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    mesh = parse_mesh(args.mesh)
    pspecs = shd.param_specs(params, mesh)
    gparams = shd.put_global(params, pspecs, mesh)
    # placement check: every leaf's sharding is exactly its spec, and
    # this process holds only shards on its own devices
    n_leaves = n_sharded = 0
    from jax.sharding import NamedSharding
    for leaf, spec in zip(jax.tree.leaves(gparams), jax.tree.leaves(
            pspecs, is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec))):
        n_leaves += 1
        want = NamedSharding(mesh, spec)
        if not leaf.sharding.is_equivalent_to(want, leaf.ndim):
            print(f"[daemon:{pid}] FAIL: leaf sharding {leaf.sharding} "
                  f"!= spec {want}")
            return 1
        if any(sh.data is None for sh in leaf.addressable_shards):
            print(f"[daemon:{pid}] FAIL: unmaterialized local shard")
            return 1
        if len(leaf.addressable_shards) < leaf.sharding.num_devices:
            n_sharded += 1
    print(f"[daemon:{pid}] placement-ok: {n_leaves} leaves on-spec, "
          f"{n_sharded} with non-addressable remote shards", flush=True)
    cache = model.init_cache(cfg, args.max_batch, args.max_len)
    gcache = shd.put_global(cache, shd.cache_specs(cache, mesh,
                                                   shard_model=True), mesh)
    toks = np.zeros((args.max_batch, 8), np.int32)
    gtoks = shd.put_global(toks, shd.batch_specs(toks, mesh), mesh)

    def prefill(p, c, t):
        return model.prefill(cfg, p, c, t)

    lowered = jax.jit(prefill).lower(gparams, gcache, gtoks)
    print(f"[daemon:{pid}] lowering-ok: prefill lowered over "
          f"mesh={dict(mesh.shape)}", flush=True)
    if jax.default_backend() == "cpu" and args.num_processes > 1:
        # the CPU runtime raises "Multiprocess computations aren't
        # implemented on the CPU backend" at compile time — placement
        # and lowering above are the verifiable dry-run surface
        print(f"[daemon:{pid}] dry-run complete (CPU backend has no "
              "multiprocess execution; serve loop skipped)", flush=True)
        return 0
    lowered.compile()
    eng = build_engine(args, mesh=mesh)
    with ServingDaemon(eng) as daemon:
        ok = serve_traffic(daemon, args)
    return 0 if ok else 1


def smoke(args) -> int:
    """check.sh fast path: one streamed request end to end, wall-clock,
    with a tight timeout and a clean reconciled shutdown."""
    from ..serving.daemon import ServingDaemon
    t0 = time.monotonic()
    eng = build_engine(args)
    daemon = ServingDaemon(eng).start()
    streamed = []
    req = daemon.submit(np.arange(1, 9, dtype=np.int32),
                        slo="interactive", max_new_tokens=args.max_new,
                        stream=True)
    try:
        for tok in req.handle.tokens(timeout=args.timeout):
            streamed.append(tok)
    except TimeoutError as e:
        print(f"[daemon] SMOKE FAIL: {e}")
        return 1
    daemon.shutdown(drain=True, timeout=args.timeout)
    s = eng.stats
    ok = (streamed == req.handle.result()
          and len(streamed) == args.max_new
          and s.submitted == s.resolved == 1
          and not daemon.running)
    if not ok:
        print(f"[daemon] SMOKE FAIL: streamed={streamed} "
              f"result={req.handle.result()} submitted={s.submitted} "
              f"resolved={s.resolved} running={daemon.running}")
        return 1
    print(f"[daemon] smoke ok: {len(streamed)} tokens streamed "
          f"wall-clock in {time.monotonic() - t0:.1f}s, clean shutdown")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--timeout", type=float, default=120.0,
                    help="per-wait timeout (seconds) for streaming/"
                         "results/drain")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--stream", action="store_true",
                    help="print each streamed token of the first "
                         "interactive request")
    ap.add_argument("--smoke", action="store_true",
                    help="CI fast path: one streamed request, tight "
                         "timeout, reconciled shutdown")
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL over the GLOBAL device world")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of process 0 (multi-host launch)")
    ap.add_argument("--num-processes", type=int, default=1)
    ap.add_argument("--process-id", type=int, default=0)
    args = ap.parse_args()

    if args.coordinator is not None:
        sys.exit(multihost_dryrun(args))
    if args.smoke:
        sys.exit(smoke(args))
    from ..serving.daemon import ServingDaemon
    from .serve import parse_mesh
    mesh = parse_mesh(args.mesh) if args.mesh else None
    eng = build_engine(args, mesh=mesh)
    with ServingDaemon(eng) as daemon:
        ok = serve_traffic(daemon, args)
    sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
