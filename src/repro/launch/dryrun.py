import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape x mesh) cell this lowers + compiles
the real step function (train_step for train shapes, prefill/serve_step for
inference shapes, with M2Q-quantized serving weights), prints
memory/cost analyses, parses collective bytes out of the optimized HLO, and
appends a JSON record consumed by benchmarks/roofline.py and EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b \
      --shape train_4k --mesh single --out results/dryrun.jsonl
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import re
import time
from pathlib import Path

import numpy as np

import jax

from ..configs.registry import ARCHS, ASSIGNED
from ..dist import sharding as shd
from ..recipe import abstract_quantize
from ..models import get_model
from ..optim.adamw import AdamW
from ..train.step import TrainStepConfig, make_train_step, make_serve_step
from .mesh import make_production_mesh
from .hlo_analysis import analyze as analyze_hlo
from .specs import SHAPES, cell_is_skipped, decode_inputs, prefill_inputs, train_inputs

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"=\s+(?:\([^)]*\)|([a-z0-9]+)\[([0-9,]*)\])")
_TUPLE_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_stats(hlo_text: str) -> dict:
    """Sum result-operand bytes of every collective op in optimized HLO.

    while-loop bodies appear once in the text; their trip counts are
    recovered separately (see _loop_multiplier) by the caller via the
    known layer counts — here we return raw per-opcode byte sums plus op
    counts, tagging ops that live inside fusions/loops is out of scope for
    text parsing, so the caller applies the scan multiplier to the
    'in_loop' bucket heuristically.
    """
    out = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.lstrip()
        m = re.match(r"%?[\w.-]+\s*=\s*(.*)", ls)
        if not m:
            continue
        rhs = m.group(1)
        opm = re.match(r"(?:\([^)]*\)\s*|[a-z0-9]+\[[0-9,]*\][^ ]*\s*)"
                       r"([a-z-]+)\(", rhs)
        if not opm:
            continue
        op = opm.group(1)
        if op.rstrip("-start").rstrip("-done") in _COLLECTIVES:
            op_base = op
            for c in _COLLECTIVES:
                if op.startswith(c):
                    op_base = c
                    break
            else:
                continue
            if op.endswith("-done"):
                continue  # counted at -start
            total = 0
            for dt, dims in _TUPLE_SHAPE_RE.findall(rhs.split(")")[0] + ")")[:8]:
                total += _shape_bytes(dt, dims)
            if total == 0:
                for dt, dims in _TUPLE_SHAPE_RE.findall(rhs)[:4]:
                    total += _shape_bytes(dt, dims)
            out[op_base] += total
            counts[op_base] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def count_params(tree) -> int:
    n = 0
    for leaf in jax.tree.leaves(tree):
        if hasattr(leaf, "shape") and len(leaf.shape) >= 2:
            n += int(np.prod(leaf.shape))
    return n


def active_params(cfg, params_abs) -> int:
    """6*N*D-style N: expert weights scaled by top_k/E."""
    from ..core.calibrate import path_str
    total = 0

    def visit(path, leaf):
        nonlocal total
        if not hasattr(leaf, "shape") or len(leaf.shape) < 2:
            return
        n = int(np.prod(leaf.shape))
        if "experts" in path_str(path) and cfg.moe_experts:
            n = n * cfg.moe_top_k // cfg.moe_experts
        total += n

    jax.tree_util.tree_map_with_path(visit, params_abs)
    return total


def build_cell(cfg, shape, mesh, quantize_serving=True, fsdp=True,
               microbatches=1, cache_shard_model=False):
    """Returns (jitted_fn, arg_specs_tree, args_abstract, meta)."""
    model = get_model(cfg)
    params_abs = jax.eval_shape(lambda: model.init(cfg, jax.random.PRNGKey(0)))
    meta = {"n_params": count_params(params_abs),
            "n_active_params": active_params(cfg, params_abs)}

    if shape.kind == "train":
        opt = AdamW(lr=1e-4)
        step = make_train_step(cfg, model, opt,
                               TrainStepConfig(microbatches=microbatches))
        opt_abs = jax.eval_shape(opt.init, params_abs)
        batch = train_inputs(cfg, shape.batch, shape.seq)
        pspec = shd.param_specs(params_abs, mesh, fsdp=fsdp)
        # optimizer state mirrors param specs for m/v; scalar count replicated
        from jax.sharding import PartitionSpec as P
        opt_spec = type(opt_abs)(count=P(), m=pspec, v=pspec)
        in_specs = (pspec, opt_spec, shd.batch_specs(batch, mesh))
        fn = jax.jit(step,
                     in_shardings=shd.shardings_from_specs(in_specs, mesh),
                     donate_argnums=(0, 1))
        args = (params_abs, opt_abs, batch)
        return fn, args, meta

    # serving shapes: quantized weights (the paper's deployment scenario)
    tokens_per_step = shape.batch * (shape.seq if shape.kind == "prefill" else 1)
    if quantize_serving:
        qparams = abstract_quantize(cfg, params_abs,
                                    tokens_per_step=tokens_per_step)
    else:
        qparams = params_abs
    meta["serving_weight_bytes"] = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree.leaves(qparams) if hasattr(l, "shape"))
    pspec = shd.param_specs(qparams, mesh, fsdp=False)

    if shape.kind == "prefill":
        inp, cache = prefill_inputs(cfg, shape.batch, shape.seq)
        meta["cache_bytes"] = sum(
            int(np.prod(l.shape)) * l.dtype.itemsize
            for l in jax.tree.leaves(cache))
        from ..train.step import make_prefill_step
        step = make_prefill_step(cfg, model)

        def fn_impl(params, cache, inp):
            return step(params, cache, **inp)

        in_specs = (pspec,
                    shd.cache_specs(cache, mesh,
                                    shard_model=cache_shard_model),
                    shd.batch_specs(inp, mesh))
        fn = jax.jit(fn_impl,
                     in_shardings=shd.shardings_from_specs(in_specs, mesh),
                     donate_argnums=(1,))
        args = (qparams, cache, inp)
        return fn, args, meta

    # decode
    cache, tokens = decode_inputs(cfg, shape.batch, shape.seq)
    meta["cache_bytes"] = sum(
        int(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree.leaves(cache))
    step = make_serve_step(cfg, model)
    in_specs = (pspec,
                shd.cache_specs(cache, mesh, shard_model=cache_shard_model),
                shd.batch_specs(tokens, mesh))
    fn = jax.jit(step, in_shardings=shd.shardings_from_specs(in_specs, mesh),
                 donate_argnums=(1,))
    args = (qparams, cache, tokens)
    return fn, args, meta


OPTIMIZED_OVERRIDES = dict(attn_bf16_mm=True, causal_skip=True,
                           remat_policy="dots")


def run_cell(arch: str, shape_name: str, mesh_name: str, out_path=None,
             quantize_serving=True, fsdp=True, microbatches=1,
             save_hlo_dir=None, cache_shard_model=False, cfg_overrides=None,
             tag=None, optimized=False):
    cfg = ARCHS[arch]
    if optimized:
        ov = dict(OPTIMIZED_OVERRIDES)
        ov["act_sharding"] = "data" if mesh_name == "single" else "pod+data"
        if SHAPES[shape_name].kind in ("decode", "prefill"):
            ov["kv_cache_dtype"] = "int8"
        cfg = cfg.replace(**ov)
        # rwkv's recurrence state is tiny; model-sharding it only adds
        # per-chunk reshards (measured 0.6x on prefill_32k) — skip it there
        cache_shard_model = cfg.family != "rwkv"
        tag = tag or "optimized"
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    shape = SHAPES[shape_name]
    skip = cell_is_skipped(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "seq": shape.seq, "batch": shape.batch}
    if tag:
        rec["tag"] = tag
    if cfg_overrides:
        rec["cfg_overrides"] = {k: str(v) for k, v in cfg_overrides.items()}
    if cache_shard_model:
        rec["cache_shard_model"] = True
    if skip:
        rec.update({"status": "skipped", "reason": skip})
        _emit(rec, out_path)
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    t0 = time.time()
    with mesh:
        fn, args, meta = build_cell(cfg, shape, mesh,
                                    quantize_serving=quantize_serving,
                                    fsdp=fsdp, microbatches=microbatches,
                                    cache_shard_model=cache_shard_model)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    rec.update(meta)
    rec["status"] = "ok"
    rec["lower_s"] = round(t_lower, 1)
    rec["compile_s"] = round(t_compile, 1)
    try:
        ca = compiled.cost_analysis()
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if np.isscalar(v) and not isinstance(v, str)}
    except Exception as e:  # pragma: no cover
        rec["cost_analysis"] = {"error": str(e)}
    try:
        ma = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(ma, k)) for k in (
                "argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:  # pragma: no cover
        rec["memory_analysis"] = {"error": str(e)}
    hlo = compiled.as_text()
    rec["hlo"] = analyze_hlo(hlo)
    rec["hlo_bytes_len"] = len(hlo)
    if save_hlo_dir:
        p = Path(save_hlo_dir)
        p.mkdir(parents=True, exist_ok=True)
        (p / f"{arch}_{shape_name}_{mesh_name}.hlo.txt").write_text(hlo)
    _emit(rec, out_path)
    return rec


def _emit(rec, out_path):
    line = json.dumps(rec)
    print(line[:2000])
    if out_path:
        Path(out_path).parent.mkdir(parents=True, exist_ok=True)
        with open(out_path, "a") as f:
            f.write(line + "\n")


def _already_done(out_path):
    done = set()
    if out_path and Path(out_path).exists():
        for line in open(out_path):
            try:
                r = json.loads(line)
                if r.get("status") in ("ok", "skipped"):
                    done.add((r["arch"], r["shape"], r["mesh"]))
            except json.JSONDecodeError:
                pass
    return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every assigned (arch x shape)")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--no-quant", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--resume", action="store_true",
                    help="skip cells already in --out")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the EXPERIMENTS §Perf optimization set")
    ap.add_argument("--shapes", default=None,
                    help="comma-separated shape filter (e.g. serve shapes)")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    if args.shapes:
        shapes = args.shapes.split(",")
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    done = _already_done(args.out) if args.resume else set()

    failures = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                if (arch, shape_name, mesh_name) in done:
                    continue
                try:
                    run_cell(arch, shape_name, mesh_name, out_path=args.out,
                             quantize_serving=not args.no_quant,
                             fsdp=not args.no_fsdp,
                             microbatches=args.microbatches,
                             save_hlo_dir=args.save_hlo,
                             optimized=args.optimized)
                except Exception as e:
                    failures += 1
                    _emit({"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "failed",
                           "error": repr(e)[:500]}, args.out)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
