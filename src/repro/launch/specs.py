"""Canonical input/cache spec builders for every (arch x shape) cell.

Used concretely by the smoke tests and abstractly (ShapeDtypeStruct via
jax.eval_shape — no allocation) by the multi-pod dry-run.

Assigned LM shapes:
  train_4k     seq 4096,  global_batch 256   -> train_step
  prefill_32k  seq 32768, global_batch 32    -> prefill (inference)
  decode_32k   seq 32768, global_batch 128   -> serve_step (1 new token)
  long_500k    seq 524288, global_batch 1    -> serve_step, sub-quadratic only

Modality frontends are stubs per the task spec: whisper gets precomputed
frame embeddings (B, n_audio_ctx, D); internvl2 gets projected patch
embeddings (B, n_patches, D).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models import get_model
from ..models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str  # train | prefill | decode
    seq: int
    batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_is_skipped(cfg: ArchConfig, shape: ShapeSpec) -> Optional[str]:
    """Returns a reason string if this (arch x shape) cell is skipped."""
    from ..configs.registry import SUBQUADRATIC
    if shape.name == "long_500k" and cfg.name not in SUBQUADRATIC \
            and cfg.name.split("-reduced")[0] not in SUBQUADRATIC:
        return ("pure full-attention arch: 524k dense-KV decode is the "
                "quadratic regime the paper's efficient-ViT focus avoids")
    return None


def _rng_tokens(shape, vocab, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).integers(0, vocab, size=shape, dtype=np.int32))


def train_inputs(cfg: ArchConfig, batch: int, seq: int, concrete: bool = False):
    """Inputs for train_step / forward: {tokens, labels, [frames]}."""
    mk_tok = (lambda s: _rng_tokens(s, cfg.vocab_size)) if concrete else (
        lambda s: jax.ShapeDtypeStruct(s, jnp.int32))
    mk_f32 = (lambda s: jnp.zeros(s, jnp.bfloat16)) if concrete else (
        lambda s: jax.ShapeDtypeStruct(s, jnp.bfloat16))
    out = {}
    if cfg.family == "whisper":
        out["frames"] = mk_f32((batch, cfg.n_audio_ctx, cfg.d_model))
        out["tokens"] = mk_tok((batch, seq))
        out["labels"] = mk_tok((batch, seq))
    elif cfg.n_patches:
        text_len = max(seq - cfg.n_patches, 1)
        out["prefix_embeds"] = mk_f32((batch, cfg.n_patches, cfg.d_model))
        out["tokens"] = mk_tok((batch, text_len))
        out["labels"] = mk_tok((batch, text_len))
    else:
        out["tokens"] = mk_tok((batch, seq))
        out["labels"] = mk_tok((batch, seq))
    return out


def prefill_inputs(cfg: ArchConfig, batch: int, seq: int, concrete: bool = False,
                   cache_dtype=jnp.bfloat16):
    model = get_model(cfg)
    inp = train_inputs(cfg, batch, seq, concrete=concrete)
    inp.pop("labels")
    if concrete:
        cache = model.init_cache(cfg, batch, seq, dtype=cache_dtype)
    else:
        cache = jax.eval_shape(
            lambda: model.init_cache(cfg, batch, seq, dtype=cache_dtype))
    return inp, cache


def decode_inputs(cfg: ArchConfig, batch: int, seq: int, concrete: bool = False,
                  cache_dtype=jnp.bfloat16):
    """serve_step inputs: one new token against a cache of length seq."""
    model = get_model(cfg)
    if concrete:
        cache = model.init_cache(cfg, batch, seq, dtype=cache_dtype)
        cache["lengths"] = jnp.full((batch,), seq - 1, jnp.int32)
        tokens = _rng_tokens((batch, 1), cfg.vocab_size)
    else:
        cache = jax.eval_shape(
            lambda: model.init_cache(cfg, batch, seq, dtype=cache_dtype))
        tokens = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    return cache, tokens
