"""Serving driver CLI: PTQ-quantize a model with M2Q and serve batched
requests through the continuous-batching engine (scheduler-core admission,
optional sharded execution).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --requests 8 --max-new 16

Sharded serving (the device world must exist before jax initializes, e.g.
XLA_FLAGS=--xla_force_host_platform_device_count=16 for a virtual mesh):

  ... python -m repro.launch.serve --arch qwen1.5-0.5b --reduced --mesh 4x4
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from ..configs.registry import ARCHS, REDUCED
from ..models import get_model
from ..recipe import QuantizedModel, as_recipe, quantize
from ..serving.engine import Engine


def quantize_for_serving(cfg, params, batch: int = 2, calib_len: int = 32,
                         recipe="m2q-w8a8") -> QuantizedModel:
    """Offline PTQ via the recipe API: calibrate on random prompts, apply
    M2Q, return the persistable artifact (reduced demo configs get the
    taxonomy-pinning arch defaults from QuantRecipe.resolve).  Only the
    prompt shape is overridden; the recipe's other CalibSpec fields
    (batches, seed) are kept."""
    rec = as_recipe(recipe)
    rec = rec.replace(calib=dataclasses.replace(
        rec.calib, batch_size=batch, seq_len=calib_len))
    return quantize(cfg, params, rec)


def parse_mesh(spec: str):
    """'DATAxMODEL' (e.g. '4x4') -> jax Mesh over (data, model).  The
    process must already expose data*model devices."""
    try:
        n_data, n_model = (int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh wants DATAxMODEL (e.g. 4x4), got {spec!r}")
    n_dev = len(jax.devices())
    if n_data * n_model > n_dev:
        raise SystemExit(
            f"--mesh {spec} needs {n_data * n_model} devices but only "
            f"{n_dev} exist (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_data * n_model} "
            "before launch for a virtual mesh)")
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--max-delay-ms", type=float, default=0.0,
                    help="admission deadline: >0 coalesces prefills until "
                         "the batch fills or the oldest request ages out")
    ap.add_argument("--mesh", default=None,
                    help="DATAxMODEL (e.g. 4x4): sharded execution via "
                         "repro.dist.sharding")
    ap.add_argument("--no-quant", action="store_true")
    args = ap.parse_args()

    cfg = (REDUCED if args.reduced else ARCHS)[args.arch]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    mesh = parse_mesh(args.mesh) if args.mesh else None
    engine_kw = dict(max_batch=args.max_batch, max_len=args.max_len,
                     max_delay_ms=args.max_delay_ms, mesh=mesh)
    if not args.no_quant:
        qm = quantize_for_serving(cfg, params)
        bits = {r.path: r.bits for r in qm.report}
        print(f"[serve] quantized {len(qm.report)} layers; "
              f"avg bits={np.mean(list(bits.values())):.2f}")
        eng = qm.serve(**engine_kw)
    else:
        eng = Engine(cfg, params, **engine_kw)
    rng = np.random.default_rng(1)
    for i in range(args.requests):
        plen = int(rng.integers(4, 17))
        eng.submit(rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
                   max_new_tokens=args.max_new)
    t0 = time.time()
    stats = eng.run()
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} requests={stats.finished} "
          f"decoded={stats.decoded_tokens} steps={stats.steps} "
          f"tok/s={stats.decoded_tokens / max(dt, 1e-9):.1f}"
          + (f" mesh={dict(mesh.shape)}" if mesh is not None else ""))
    print(f"[serve] queue p50={stats.p50_ms:.2f}ms p99={stats.p99_ms:.2f}ms "
          f"prefill-occupancy={stats.batch_occupancy:.2f} "
          f"padded-fraction={stats.padded_fraction:.2f} "
          f"flushes={stats.flush_reasons}")


if __name__ == "__main__":
    main()
