"""Serving driver CLI: PTQ-quantize a model with M2Q and serve batched
requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --reduced \
      --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import ARCHS, REDUCED
from ..core import M2QPolicy, ShapeCtx, quantize_model, wrap_for_calibration
from ..core.calibrate import rule_matcher
from ..models import get_model
from ..serving.engine import Engine


def quantize_for_serving(cfg, params, batch: int = 2, calib_len: int = 32,
                         policy: M2QPolicy = None):
    """Offline PTQ: calibrate on random prompts, then apply M2Q."""
    model = get_model(cfg)
    wrapped, store = wrap_for_calibration(params, rule_matcher(model.QUANT_RULES))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, calib_len),
                                    dtype=np.int32))
    model.forward(cfg, wrapped, toks, unroll=True)
    ctx = ShapeCtx(tokens_per_step=batch,  # decode deployment shape
                   moe_top_k=max(cfg.moe_top_k, 1),
                   moe_num_experts=max(cfg.moe_experts, 1))
    if policy is None and cfg.d_model <= 256:
        # reduced demo configs: everything is memory-bound at tiny dims;
        # lower the threshold so the mixed-scheme path is exercised
        policy = M2QPolicy(intensity_threshold=0.5)
    qparams, report = quantize_model(
        params, model.QUANT_RULES, ctx, policy, act_stats=store,
        ffn_groups=getattr(model, "FFN_FOLD_GROUPS", None))
    return qparams, report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--no-quant", action="store_true")
    args = ap.parse_args()

    cfg = (REDUCED if args.reduced else ARCHS)[args.arch]
    model = get_model(cfg)
    params = model.init(cfg, jax.random.PRNGKey(0))
    if not args.no_quant:
        params, report = quantize_for_serving(cfg, params)
        bits = {r.path: r.bits for r in report}
        print(f"[serve] quantized {len(report)} layers; "
              f"avg bits={np.mean(list(bits.values())):.2f}")
    eng = Engine(cfg, params, max_batch=args.max_batch, max_len=args.max_len)
    rng = np.random.default_rng(1)
    for i in range(args.requests):
        plen = int(rng.integers(4, 17))
        eng.submit(rng.integers(0, cfg.vocab_size, plen, dtype=np.int32),
                   max_new_tokens=args.max_new)
    t0 = time.time()
    stats = eng.run()
    dt = time.time() - t0
    print(f"[serve] arch={cfg.name} requests={stats.finished} "
          f"decoded={stats.decoded_tokens} steps={stats.steps} "
          f"tok/s={stats.decoded_tokens / max(dt, 1e-9):.1f}")


if __name__ == "__main__":
    main()
