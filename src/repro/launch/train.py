"""Training driver CLI.

Reduced configs run end-to-end on CPU; full configs are for real clusters
(the multi-pod dry-run proves their distribution).  Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --reduced \
      --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

from ..configs.registry import ARCHS, REDUCED
from ..train.loop import TrainConfig, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the smoke-scale config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--metrics", default=None)
    ap.add_argument("--stop-at-step", type=int, default=None,
                    help="exit cleanly (rc 0) after this step without "
                         "completing — elastic-launcher fault injection")
    ap.add_argument("--crash-at-step", type=int, default=None,
                    help="hard-kill (os._exit) after this step's async "
                         "checkpoint lands — elastic-launcher fault "
                         "injection")
    args = ap.parse_args()

    cfg = (REDUCED if args.reduced else ARCHS)[args.arch]
    tc = TrainConfig(steps=args.steps, global_batch=args.batch,
                     seq_len=args.seq, lr=args.lr,
                     microbatches=args.microbatches,
                     grad_compression=args.grad_compression,
                     ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                     log_every=args.log_every,
                     metrics_path=args.metrics,
                     stop_at_step=args.stop_at_step,
                     crash_at_step=args.crash_at_step)
    _, _, info = train(cfg, tc)
    if info["losses"]:
        print(f"[train] arch={cfg.name} steps={info['last_step'] + 1} "
              f"first_loss={info['losses'][0]:.4f} "
              f"last_loss={info['losses'][-1]:.4f}")


if __name__ == "__main__":
    main()
