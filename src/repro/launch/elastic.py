"""Elastic launcher: supervise training across failures and preemptions.

Cluster posture (DESIGN.md §6): a real deployment runs one of these per job
controller; workers heartbeat and the controller restarts lost ranks from
the latest atomic checkpoint, re-balancing data shards onto the surviving
rank set (deterministic step-indexed data makes that a pure function of
(step, new_rank_count)).  In this single-host container the launcher
demonstrates the full restart path: it runs launch.train as a subprocess,
kills it mid-run (simulated preemption / node failure), restarts, and
verifies exact resume from the published checkpoint.

  PYTHONPATH=src python -m repro.launch.elastic --arch qwen1.5-0.5b \
      --steps 120 --kill-at 7
"""
from __future__ import annotations

import argparse
import json
import signal
import subprocess
import sys
import time
from pathlib import Path


def run_supervised(arch: str, steps: int, ckpt_dir: str, metrics: str,
                   kill_after_s: float = None, max_restarts: int = 3,
                   batch: int = 4, seq: int = 32) -> int:
    cmd = [sys.executable, "-m", "repro.launch.train", "--arch", arch,
           "--reduced", "--steps", str(steps), "--batch", str(batch),
           "--seq", str(seq), "--ckpt-dir", ckpt_dir, "--ckpt-every", "5",
           "--metrics", metrics]
    restarts = 0
    while True:
        proc = subprocess.Popen(cmd)
        if kill_after_s is not None and restarts == 0:
            time.sleep(kill_after_s)
            proc.send_signal(signal.SIGTERM)  # simulated preemption
        rc = proc.wait()
        if rc == 0:
            # completed? check metrics for the final step
            done = False
            if Path(metrics).exists():
                lines = Path(metrics).read_text().strip().splitlines()
                if lines:
                    done = json.loads(lines[-1])["step"] >= steps - 1
            if done or kill_after_s is None or restarts > 0:
                return restarts
        restarts += 1
        if restarts > max_restarts:
            raise RuntimeError("too many restarts")
        print(f"[elastic] restart #{restarts} (resume from checkpoint)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_elastic_ckpt")
    ap.add_argument("--metrics", default="/tmp/repro_elastic_metrics.jsonl")
    ap.add_argument("--kill-at", type=float, default=None,
                    help="seconds until simulated preemption")
    args = ap.parse_args()
    restarts = run_supervised(args.arch, args.steps, args.ckpt_dir,
                              args.metrics, kill_after_s=args.kill_at)
    print(f"[elastic] finished with {restarts} restart(s)")


if __name__ == "__main__":
    main()
