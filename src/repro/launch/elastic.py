"""Elastic launcher: supervise training across failures and preemptions.

Cluster posture (DESIGN.md §6): a real deployment runs one of these per job
controller; workers heartbeat and the controller restarts lost ranks from
the latest atomic checkpoint, re-balancing data shards onto the surviving
rank set (deterministic step-indexed data makes that a pure function of
(step, new_rank_count)).  In this single-host container the launcher
demonstrates the full restart path: it runs launch.train as a subprocess,
kills it mid-run (simulated preemption / node failure), restarts, and
verifies exact resume from the published checkpoint.

  PYTHONPATH=src python -m repro.launch.elastic --arch qwen1.5-0.5b \
      --steps 120 --kill-at 7
"""
from __future__ import annotations

import argparse
import signal
import subprocess
import sys
import time
from typing import Optional


def _latest_ckpt_step(ckpt_dir) -> Optional[int]:
    # lazy: the launcher itself should not pay the jax import unless it
    # needs to inspect checkpoints
    from ..ckpt import checkpoint as ckpt
    return ckpt.latest_step(ckpt_dir)


def run_supervised(arch: str, steps: int, ckpt_dir: str, metrics: str,
                   kill_after_s: Optional[float] = None,
                   max_restarts: int = 3,
                   batch: int = 4, seq: int = 32,
                   ckpt_every: int = 5, log_every: int = 10,
                   stop_at_step: Optional[int] = None,
                   crash_at_step: Optional[int] = None) -> int:
    """Run ``launch.train`` under restart supervision until the final
    step's checkpoint is PUBLISHED; returns the restart count.

    Completion is judged by the checkpoint, not the exit code: the train
    loop's final sync save publishes ``steps - 1`` exactly when it ran to
    the end, so a worker that exits rc==0 WITHOUT that checkpoint (a
    ``--stop-at-step`` early exit, a preemption save) is a
    clean-but-incomplete worker — counted and logged as a restart like
    any crash.  Failure injection (first attempt only, so the job can
    finish): ``kill_after_s`` SIGTERMs mid-run, ``stop_at_step`` /
    ``crash_at_step`` forward to ``launch.train``.
    """
    base = [sys.executable, "-m", "repro.launch.train", "--arch", arch,
            "--reduced", "--steps", str(steps), "--batch", str(batch),
            "--seq", str(seq), "--ckpt-dir", ckpt_dir,
            "--ckpt-every", str(ckpt_every),
            "--log-every", str(log_every), "--metrics", metrics]
    restarts = 0
    while True:
        cmd = list(base)
        if restarts == 0:  # injected faults fire once, on the first run
            if stop_at_step is not None:
                cmd += ["--stop-at-step", str(stop_at_step)]
            if crash_at_step is not None:
                cmd += ["--crash-at-step", str(crash_at_step)]
        proc = subprocess.Popen(cmd)
        if kill_after_s is not None and restarts == 0:
            time.sleep(kill_after_s)
            proc.send_signal(signal.SIGTERM)  # simulated preemption
        rc = proc.wait()
        latest = _latest_ckpt_step(ckpt_dir)
        if rc == 0 and latest is not None and latest >= steps - 1:
            return restarts
        restarts += 1
        if restarts > max_restarts:
            raise RuntimeError(
                f"too many restarts ({restarts} > {max_restarts}); "
                f"latest checkpoint step {latest}")
        if rc == 0:
            print(f"[elastic] worker exited cleanly (rc=0) without "
                  f"reaching step {steps - 1} (latest checkpoint: "
                  f"{latest}); counted restart #{restarts}")
        else:
            print(f"[elastic] worker died (rc={rc}); restart #{restarts} "
                  "(resume from checkpoint)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_elastic_ckpt")
    ap.add_argument("--metrics", default="/tmp/repro_elastic_metrics.jsonl")
    ap.add_argument("--kill-at", type=float, default=None,
                    help="seconds until simulated preemption")
    ap.add_argument("--stop-at-step", type=int, default=None,
                    help="first run exits cleanly after this step "
                         "(clean-but-incomplete worker)")
    ap.add_argument("--crash-at-step", type=int, default=None,
                    help="first run hard-crashes after this step")
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args()
    restarts = run_supervised(args.arch, args.steps, args.ckpt_dir,
                              args.metrics, kill_after_s=args.kill_at,
                              max_restarts=args.max_restarts,
                              stop_at_step=args.stop_at_step,
                              crash_at_step=args.crash_at_step)
    print(f"[elastic] finished with {restarts} restart(s)")


if __name__ == "__main__":
    main()
