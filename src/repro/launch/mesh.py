"""Production mesh factory.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state.  The dry-run launcher sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import;
everything else in the repo sees the real single device.

Mesh geometry (TPU v5e posture):
  single pod:  (data, model) = (16, 16)        — 256 chips
  multi pod:   (pod, data, model) = (2, 16, 16) — 512 chips
``model`` is the intra-pod TP/EP axis (ICI-local); ``data`` carries
DP/FSDP; ``pod`` carries cross-pod DP (optionally pipeline stages via
dist.pipeline_parallel).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (device count set by caller)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))
