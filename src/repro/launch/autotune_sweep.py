"""autotune_sweep: warm the kernel autotune cache OFFLINE for a
deployment's shape set, so serving-time traces are pure cache hits.

Lazy-at-trace tuning (the PR 1 posture) re-pays candidate timing on the
first request per shape — at serving scale that is a real p99 tail.  This
CLI enumerates every kernel-launch shape a deployment's hot paths request
(registry configs x recipes x resolutions, via
``analysis.traces.shape_requests`` — block choices resolve at Python trace
time, so LOWERING alone walks every ``blocks_for``/``note_shape`` call
site), tunes each shape for the current backend, and writes the per-backend
cache file that ``kernels.autotune`` consults FIRST on every launch.

On an accelerator each shape is tuned against synthetic operands (the
request's recorded geometry rebuilds a real launch); on CPU/interpret —
where timing the Python interpreter is meaningless — the heuristic triple
is committed instead, which is byte-identical to what lazy tuning would
have chosen there (the offline-vs-lazy equivalence tests pin this).

``--smoke`` is the CI gate: re-enumerate the pinned CI shape set against
the COMMITTED cache and FAIL on any missing key (a missing shape must fail
loudly, never silently re-tune at serving time), asserting zero tuning
probes ran during the trace walk.  ``--bench`` appends per-shape wall-clock
rows to the kernel bench report, making the sweep double as the
kernel-regression harness.

Usage:
  PYTHONPATH=src python -m repro.launch.autotune_sweep \
      --cache results/autotune/cpu.json          # warm the committed cache
  PYTHONPATH=src python -m repro.launch.autotune_sweep --smoke
  PYTHONPATH=src python -m repro.launch.autotune_sweep \
      --configs efficientvit-b1-r224 --bench benchmarks/BENCH_kernels.json

Exit codes: 0 ok; 1 smoke found missing shapes / tuning probes; 2 usage.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from pathlib import Path
from typing import Callable, List, Optional, Sequence, Tuple

# the CI shape set: pinned small configs whose committed-cache completeness
# the --smoke stage asserts (one vision config exercising the H-tiled
# dwconv shapes incl. the R384/R512 hi-res traces, one token config
# exercising prefill/decode matmul + attention shapes)
CI_CONFIGS: Tuple[str, ...] = ("efficientvit-b1-r224", "qwen1.5-0.5b")
CI_RECIPES: Tuple[str, ...] = ("m2q-w8a8", "uniform8")

DEFAULT_CACHE_DIR = "results/autotune"


def committed_cache_path(backend: Optional[str] = None) -> str:
    import jax
    b = backend or jax.default_backend()
    return os.path.join(DEFAULT_CACHE_DIR, f"{b}.json")


def _bench_fn(req, interpret: bool) -> Optional[Callable]:
    """Rebuild a real launch of the request's shape from synthetic operands
    (values are irrelevant to timing; dtypes/shapes are not).  Returns a
    ``blocks -> result`` closure for the tuner, or None when the request
    cannot be reconstructed (missing geometry, non-tunable kernel)."""
    import jax.numpy as jnp

    from ..kernels import ops

    M, N, K = req.M, req.N, req.K
    meta = dict(req.meta)
    if req.kernel == "m2q_matmul":
        x = jnp.ones((M, K), jnp.float32)
        payload = jnp.zeros((K, N), jnp.int8)
        v1 = jnp.ones((N,), jnp.float32)
        v0 = jnp.zeros((N,), jnp.float32)
        return lambda b: ops.m2q_matmul_op(
            x, jnp.float32(1.0), payload, v1, v0, v1,
            interpret=interpret, blocks=b)
    if req.kernel == "int8_matmul":
        x = jnp.ones((M, K), jnp.float32)
        wq = jnp.zeros((K, N), jnp.int8)
        v1 = jnp.ones((N,), jnp.float32)
        v0 = jnp.zeros((N,), jnp.float32)
        return lambda b: ops.int8_matmul_op(
            x, wq, jnp.float32(1.0), v1, v0, interpret=interpret, blocks=b)
    if req.kernel == "int4_matmul" and N % 2 == 0:
        x = jnp.ones((M, K), jnp.float32)
        packed = jnp.zeros((K, N // 2), jnp.uint8)
        v1 = jnp.ones((N,), jnp.float32)
        v0 = jnp.zeros((N,), jnp.float32)
        return lambda b: ops.int4_matmul_op(
            x, packed, v1, v0, interpret=interpret, blocks=b)
    if req.kernel == "apot_matmul":
        x = jnp.ones((M, K), jnp.float32)
        codes = jnp.full((K, N), 0x80, jnp.uint8)  # zero-flag byte
        return lambda b: ops.apot_matmul_op(
            x, codes, jnp.ones((N,), jnp.float32),
            interpret=interpret, blocks=b)
    if req.kernel == "dwconv_w4" and {"B", "H", "W", "C", "kh", "kw",
                                      "stride"} <= meta.keys():
        B, H, W, C = meta["B"], meta["H"], meta["W"], meta["C"]
        kh, kw, stride = meta["kh"], meta["kw"], meta["stride"]
        if C % 2:
            return None
        x = jnp.ones((B, H, W, C), jnp.float32)
        packed = jnp.zeros((kh * kw, C // 2), jnp.uint8)
        scale = jnp.ones((C,), jnp.float32)
        zp = jnp.zeros((C,), jnp.float32)
        return lambda b: ops.dwconv_w4_op(
            x, packed, scale, zp, kh=kh, kw=kw, stride=stride,
            interpret=interpret, blocks=b)
    if req.kernel == "relu_attn" and {"B", "N", "H", "D"} <= meta.keys():
        q = jnp.ones((meta["B"], meta["N"], meta["H"], meta["D"]),
                     jnp.float32)
        return lambda b: ops.relu_attn_op(q, q, q, interpret=interpret,
                                          blocks=b)
    return None


def discover(configs: Sequence[str], recipes: Sequence[str],
             hires: Optional[Sequence[int]] = None, progress=print):
    """Enumerate the deployment's shape set (lower-only trace walk).
    ``hires`` overrides the default high-resolution vision trace set
    (tests pass ``()`` to skip the slow R384/R512 lowerings)."""
    from ..analysis.traces import VISION_HIRES, shape_requests
    t0 = time.time()
    reqs, per_trace = shape_requests(
        configs, recipes=recipes,
        hires=VISION_HIRES if hires is None else hires)
    for name, n in per_trace.items():
        progress(f"  {name:<44} {n} request(s)")
    progress(f"  {len(reqs)} unique shape(s) across {len(per_trace)} "
             f"trace(s) ({time.time() - t0:.1f}s)")
    return reqs


def warm(requests, cache_path: str, *, force_tune: bool = False,
         progress=print) -> Tuple[int, int]:
    """Tune (accelerator) or heuristically seed (CPU) every tunable
    request into ``cache_path``.  Returns (written, skipped-as-cached)."""
    import jax

    from ..kernels import autotune

    cache = autotune.AutotuneCache(cache_path).load()
    interpret = jax.default_backend() != "tpu"
    live = force_tune or jax.default_backend() != "cpu"
    wrote = skipped = 0
    for req in requests:
        if not req.tunable:
            continue
        key = req.key()
        if not force_tune and cache.get(key) is not None:
            skipped += 1
            continue
        if live:
            blocks = autotune.blocks_for(
                req.kernel, req.M, req.N, req.K, interpret=interpret,
                bench_fn=_bench_fn(req, interpret), cache_path=cache_path,
                force_tune=force_tune)
        else:
            # CPU: candidate timing measures the Python interpreter, so
            # commit what lazy tuning would have chosen here — the
            # heuristic (byte-identical by the equivalence tests)
            blocks = autotune.heuristic_blocks(req.M, req.N, req.K)
        cache.put(key, blocks, save=False)
        wrote += 1
        progress(f"  {key:<52} -> {tuple(blocks)}")
    cache.save()
    return wrote, skipped


def smoke(configs: Sequence[str], recipes: Sequence[str],
          cache_path: str, hires: Optional[Sequence[int]] = None,
          progress=print) -> int:
    """CI gate: the committed cache must cover every tunable shape of the
    pinned CI set, and walking the traces must run ZERO tuning probes."""
    from ..kernels import autotune

    autotune.reset_probe_count()
    reqs = discover(configs, recipes, hires=hires, progress=progress)
    cache = autotune.AutotuneCache(cache_path).load()
    tunable = [r for r in reqs if r.tunable]
    missing = [r for r in tunable if cache.get(r.key()) is None]
    probes = autotune.tuning_probe_count()
    if missing:
        progress(f"autotune_sweep: FAIL — {len(missing)} shape(s) missing "
                 f"from {cache_path} (run the sweep and commit the cache; "
                 f"a missing shape must not silently re-tune at serving "
                 f"time):")
        for r in missing:
            progress(f"  MISSING {r.key()}")
        return 1
    if probes:
        progress(f"autotune_sweep: FAIL — {probes} tuning probe(s) ran "
                 f"during the trace walk; a warmed cache must make traces "
                 f"pure cache hits")
        return 1
    progress(f"autotune_sweep: smoke ok — {len(tunable)} tunable shape(s) "
             f"all present in {cache_path} "
             f"({len(reqs) - len(tunable)} note-only), 0 tuning probes")
    return 0


def bench_rows(requests, cache_path: str, limit: int,
               progress=print) -> List[dict]:
    """Per-shape wall-clock rows at the cached block choice — the sweep's
    kernel-regression output."""
    import jax

    from ..kernels import autotune

    cache = autotune.AutotuneCache(cache_path).load()
    interpret = jax.default_backend() != "tpu"
    rows: List[dict] = []
    for req in requests:
        if len(rows) >= limit > 0:
            progress(f"  (bench limit {limit} reached; "
                     f"{len(requests) - len(rows)} request(s) not timed)")
            break
        fn = _bench_fn(req, interpret)
        if fn is None:
            continue
        blocks = (cache.get(req.key())
                  or autotune.heuristic_blocks(req.M, req.N, req.K))
        t = autotune.measure(fn, tuple(blocks), reps=2)
        rows.append({"name": f"{req.kernel}:{req.M}x{req.N}x{req.K}",
                     "kernel": req.kernel, "blocks": list(blocks),
                     "backend": jax.default_backend(),
                     "interpret": interpret, "time_s": t})
        progress(f"  {rows[-1]['name']:<40} {t * 1e3:9.3f} ms "
                 f"blocks={tuple(blocks)}")
    return rows


def append_bench(path: str, rows: List[dict]) -> None:
    p = Path(path)
    report = json.loads(p.read_text()) if p.exists() else {}
    report["autotune_sweep"] = rows
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(json.dumps(report, indent=1))


def main(argv=None) -> int:
    from ..analysis.traces import DEFAULT_SWEEP

    ap = argparse.ArgumentParser(
        prog="autotune_sweep",
        description="offline kernel autotune: warm the per-backend cache "
                    "for a deployment's shape set")
    ap.add_argument("--configs", default=",".join(DEFAULT_SWEEP),
                    help="comma-joined registry config names (reduced "
                         "shapes are used)")
    ap.add_argument("--recipes", default="m2q-w8a8,uniform8",
                    help="comma-joined quantization recipes")
    ap.add_argument("--cache", default=None,
                    help="cache file to warm/check (default "
                         f"{DEFAULT_CACHE_DIR}/<backend>.json)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: assert the committed cache covers the "
                         "pinned CI shape set (no warming; missing shapes "
                         "FAIL)")
    ap.add_argument("--force-tune", action="store_true",
                    help="re-tune shapes already cached (and tune even on "
                         "CPU, timing interpret-mode bodies — tests only)")
    ap.add_argument("--bench", default=None,
                    help="append per-shape wall-clock rows to this bench "
                         "report (e.g. benchmarks/BENCH_kernels.json)")
    ap.add_argument("--bench-limit", type=int, default=12,
                    help="max shapes to time for --bench (interpret-mode "
                         "rows are slow); <=0 means no limit")
    args = ap.parse_args(argv)

    cache_path = args.cache or committed_cache_path()
    # point trace-time lookups at the same file we warm/check, so the walk
    # exercises exactly the committed serving posture
    os.environ["REPRO_AUTOTUNE_CACHE"] = cache_path
    configs = [c.strip() for c in args.configs.split(",") if c.strip()]
    recipes = [r.strip() for r in args.recipes.split(",") if r.strip()]
    if not configs or not recipes:
        ap.error("--configs / --recipes must be non-empty")

    if args.smoke:
        return smoke(CI_CONFIGS, CI_RECIPES, cache_path)

    print(f"autotune_sweep: discovering shapes for {len(configs)} "
          f"config(s) x {len(recipes)} recipe(s)...")
    reqs = discover(configs, recipes)
    wrote, skipped = warm(reqs, cache_path, force_tune=args.force_tune)
    print(f"autotune_sweep: {wrote} shape(s) warmed, {skipped} already "
          f"cached -> {cache_path}")
    if args.bench:
        rows = bench_rows([r for r in reqs if r.tunable], cache_path,
                          args.bench_limit)
        append_bench(args.bench, rows)
        print(f"autotune_sweep: {len(rows)} bench row(s) -> {args.bench}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
