import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Perf-iteration driver (EXPERIMENTS.md §Perf): compile ONE dry-run cell
with a set of optimization knobs and print its roofline terms next to the
recorded baseline — the measure step of the hypothesis->change->measure
loop.

  PYTHONPATH=src python -m repro.launch.perf_cell --arch qwen3-14b \
      --shape train_4k --mesh single --set attn_bf16_mm=1 --set causal_skip=1 \
      --tag bf16mm+triangle

Knobs: any ArchConfig field via --set k=v (ints/bools/floats inferred),
--cache-shard (model-axis cache sharding), --microbatches, --no-fsdp.
Records land in results/perf.jsonl with the tag.
"""
import argparse

from .dryrun import run_cell


def _parse_val(v: str):
    if v.lower() in ("true", "false"):
        return v.lower() == "true"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig override k=v (repeatable)")
    ap.add_argument("--cache-shard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--tag", default="perf")
    ap.add_argument("--out", default="results/perf.jsonl")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = _parse_val(v)
        if k in ("attn_bf16_mm", "causal_skip"):
            overrides[k] = bool(_parse_val(v))

    rec = run_cell(args.arch, args.shape, args.mesh, out_path=args.out,
                   fsdp=not args.no_fsdp, microbatches=args.microbatches,
                   cache_shard_model=args.cache_shard,
                   cfg_overrides=overrides or None, tag=args.tag,
                   save_hlo_dir=args.save_hlo)

    # print roofline terms for this record vs the recorded baseline
    import sys
    sys.path.insert(0, ".")
    from benchmarks.roofline import load_cells, terms_for
    t_new = terms_for(rec)
    base = load_cells().get((args.arch, args.shape, args.mesh))
    print("\n=== perf cell summary ===")
    if base is not None and base.get("status") == "ok":
        t_old = terms_for(base)
        for k in ("compute_s", "memory_s", "collective_s",
                  "roofline_fraction"):
            delta = (t_new[k] / t_old[k] - 1) * 100 if t_old[k] else 0.0
            print(f"{k:20s} baseline={t_old[k]:.4g}  now={t_new[k]:.4g} "
                  f"({delta:+.1f}%)")
        print(f"dominant: {t_old['dominant']} -> {t_new['dominant']}")
    else:
        for k in ("compute_s", "memory_s", "collective_s",
                  "roofline_fraction", "dominant"):
            print(f"{k:20s} {t_new[k]}")


if __name__ == "__main__":
    main()
