import os
import sys

if "jax" not in sys.modules:  # more virtual devices for the sharded trace
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""qlint: sweep the config registry and verify quantization / transfer /
sharding invariants against the compiled (post-SPMD) HLO.

For every config in the sweep this abstract-quantizes (no weights
materialized), lowers + compiles the forward/prefill/decode hot paths with
kernel dispatch ON, and runs the ``repro.analysis`` rule engine over the
optimized HLO text.  Violations are diffed against the committed baseline
ledger — by-design deviations (the M2Q APoT f32 SAT dot, the packed-w4
DWConv dequant, today's unguarded activation quantizes) live THERE, once,
reviewed; the exit code is nonzero only for violations the baseline does
not know about.

Usage:
  PYTHONPATH=src python -m repro.launch.qlint \
      --baseline results/qlint_baseline.json
  PYTHONPATH=src python -m repro.launch.qlint --update-baseline
  PYTHONPATH=src python -m repro.launch.qlint --configs qwen1.5-0.5b
  PYTHONPATH=src python -m repro.launch.qlint --list-rules

Exit codes: 0 clean / baseline-known only; 1 new violations; 2 usage or
missing baseline.
"""
import argparse
import time
from pathlib import Path

from ..analysis import (DEFAULT_RULES, baseline as bl, run_rules)

DEFAULT_BASELINE = "results/qlint_baseline.json"


def build_traces(configs, sharded=True, sharded_arch="qwen1.5-0.5b",
                 progress=print):
    from ..analysis.traces import registry_traces, sharded_decode_trace
    traces = []
    for arch in configs:
        t0 = time.time()
        got = registry_traces(arch)
        traces += got
        progress(f"  {arch}: {len(got)} traces ({time.time() - t0:.1f}s)")
    if sharded:
        t0 = time.time()
        traces.append(sharded_decode_trace(sharded_arch, n_data=2,
                                           n_model=4))
        progress(f"  {sharded_arch} (sharded): 1 trace "
                 f"({time.time() - t0:.1f}s)")
    return traces


def main(argv=None) -> int:
    from ..analysis.traces import DEFAULT_SWEEP
    ap = argparse.ArgumentParser(
        prog="qlint", description="static HLO invariant linter")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    help=f"known-violation ledger (default "
                         f"{DEFAULT_BASELINE})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the ledger from this run's violations")
    ap.add_argument("--configs", default=",".join(DEFAULT_SWEEP),
                    help="comma-joined registry config names (reduced "
                         "shapes are used)")
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the mesh-sharded conformance trace")
    ap.add_argument("--fail-on-gone", action="store_true",
                    help="exit nonzero when baseline entries are no "
                         "longer observed (CI keeps the ledger tight: "
                         "fixed violations must be ratcheted out with "
                         "--update-baseline, not left as dead rows)")
    ap.add_argument("--list-rules", action="store_true")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in DEFAULT_RULES:
            print(f"{r.name:<22} [{r.severity}] {r.doc}")
            if r.suppress:
                print(f"{'':<22} default suppressions: "
                      f"{', '.join(r.suppress)}")
        return 0

    configs = [c.strip() for c in args.configs.split(",") if c.strip()]
    if not configs:
        ap.error("--configs is empty")
    print(f"qlint: tracing {len(configs)} registry config(s)...")
    traces = build_traces(configs, sharded=not args.no_sharded)

    violations, suppressed = [], []
    for tr in traces:
        vs, supp = run_rules(tr)
        violations += vs
        suppressed += supp
        n_err = sum(v.severity == "error" for v in vs)
        n_warn = len(vs) - n_err
        print(f"  {tr.name:<44} {n_err} error(s), {n_warn} warn(s), "
              f"{len(supp)} suppressed")
    ledger = bl.to_ledger(violations)

    if args.update_baseline:
        bl.save(args.baseline, ledger)
        print(f"qlint: wrote {sum(len(p) for t in ledger.values() for p in t.values())} "
              f"ledger entries to {args.baseline}")
        return 0

    if not Path(args.baseline).exists():
        print(f"qlint: baseline {args.baseline} not found — run with "
              f"--update-baseline to create it", file=sys.stderr)
        return 2
    base = bl.load(args.baseline)
    regressions = bl.diff(ledger, base)
    gone = bl.improvements(ledger, base)
    if gone:
        print(f"qlint: {len(gone)} baseline entr(ies) no longer observed "
              f"(ratchet with --update-baseline):")
        for line in gone:
            print(f"  {line}")
    if regressions:
        print(f"qlint: {len(regressions)} NEW violation(s) vs "
              f"{args.baseline}:")
        for line in regressions:
            print(f"  {line}")
        for v in violations:
            key = f"{v.trace} :: {v.rule}"
            if any(key in line for line in regressions):
                print(f"    detail: [{v.severity}] {key} :: "
                      f"{v.path or '<module>'}: {v.message}")
        return 1
    if gone and args.fail_on_gone:
        # NOTE: only meaningful on the full sweep — a partial --configs
        # run trivially "loses" every untraced config's entries
        print(f"qlint: FAIL — {len(gone)} stale baseline entr(ies) "
              f"(--fail-on-gone): re-tighten the ledger with "
              f"--update-baseline", file=sys.stderr)
        return 1
    print(f"qlint: clean — {len(traces)} trace(s), "
          f"{len(DEFAULT_RULES)} rules, {len(violations)} baseline-known "
          f"violation(s), {len(suppressed)} suppressed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
