"""Static analysis of compiled (post-SPMD) HLO: the qlint rule engine.

Public surface:

* :mod:`repro.analysis.rules` — ``Rule`` / ``Violation`` / ``Trace`` and
  the default rule registry (pure text, no jax import);
* :mod:`repro.analysis.traces` — registry-config -> compiled ``Trace``
  builders (abstract lowering, kernel dispatch scoped on);
* :mod:`repro.analysis.baseline` — the committed known-violation ledger
  and its regression diff;
* ``python -m repro.launch.qlint`` — the sweep CLI.

``rules``/``baseline`` import lazily-cheap modules only, so seeded-
violation tests can run without touching jax.
"""
from .rules import (DEFAULT_RULES, RULES_BY_NAME, Rule, Trace,
                    Violation, lint, run_rules)
from .baseline import diff, improvements, load, save, to_ledger

__all__ = [
    "DEFAULT_RULES", "RULES_BY_NAME", "Rule", "Trace", "Violation", "lint",
    "run_rules", "diff", "improvements", "load", "save", "to_ledger",
]
