"""qlint rule engine: named invariants checked against optimized HLO.

A :class:`Trace` is one compiled hot path (post-SPMD HLO text plus the
metadata needed to judge it: which invariants apply, how entry parameters
map back to pytree paths, what the sharding specs were).  A :class:`Rule`
is one invariant — it declares its severity, whether it applies to a given
trace (``applies(meta)``), and produces :class:`Violation`s.  Rules carry
default per-path suppressions (regexes over the violation path) and
callers can add more; suppressed violations are returned separately, never
silently dropped.

The rules formalize the invariants the paper's speedups rest on (and that
used to live as ad-hoc ``op_histogram`` asserts in four test files):

====================  ========  ==================================================
rule                  severity  invariant
====================  ========  ==================================================
no-f32-dot            error     a quantized hot path runs zero f32/f64 dots
no-gather-concat      error     no gather/concat epilogue on quantized weights
conv-budget           error     exactly the declared unquantized convolutions
no-dequant-matmul     error     no f32 dot/conv fed by a dequantized weight
no-d2h-in-loop        error     no host transfers inside while bodies
unguarded-act-quant   warn      float->int8 converts dominated by is-finite
sharding-conformance  error     compiled input shardings match dist.sharding
====================  ========  ==================================================

This module works on HLO *text* only (no jax import) so seeded-violation
tests can feed handcrafted graphs; the jax-side trace builders live in
``analysis.traces``.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..launch import hlo_analysis as H

_QUANT_DTYPES = ("s4", "u4", "s8", "u8")


@dataclasses.dataclass
class Trace:
    """One compiled hot path under analysis.

    ``meta`` keys the rules understand:

    * ``quantized`` (bool, default True) — quantized-weights rules apply
    * ``expect_no_f32_dot`` (bool) — the trace promises zero f32 dots
    * ``expect_dots`` (bool, default True) — guard against vacuity: a
      trace with no dots at all fails ``no-f32-dot`` instead of passing
    * ``conv_budget`` (int or None) — exact allowed convolution count
    * ``param_paths`` (list[str]) — i-th flattened jit argument leaf path;
      used to attribute violations to parameters
    * ``sharding`` (list[dict]) — {path, expected, actual} spec strings
      recorded by the sharded trace builder
    """

    name: str
    text: str
    meta: dict = dataclasses.field(default_factory=dict)
    compiled: object = None  # the jax Compiled, when built by traces.py
    _graph: Optional[H.Graph] = dataclasses.field(default=None, repr=False)

    @property
    def graph(self) -> H.Graph:
        if self._graph is None:
            self._graph = H.Graph(self.text)
        return self._graph

    def param_path(self, idx: int) -> str:
        """Pytree path of entry parameter ``idx``.  XLA drops unused
        argument leaves and renumbers, so the flat leaf list is aligned
        to the surviving parameters by (dtype, shape) order — both are
        subsequences of the original flattening."""
        aligned = self._aligned_paths()
        if aligned is not None and idx < len(aligned):
            return aligned[idx]
        return f"param{idx}"

    def _aligned_paths(self) -> Optional[List[str]]:
        if "_aligned_paths" in self.meta:
            return self.meta["_aligned_paths"]
        leaves = self.meta.get("param_leaves")
        if leaves is None:  # no shape info recorded: trust 1:1 if counts fit
            paths = self.meta.get("param_paths") or []
            eps = self.graph.entry_params()
            out = paths if len(paths) == len(eps) else None
            self.meta["_aligned_paths"] = out
            return out
        g = self.graph

        def matches(leaf, dt, dims):
            # post-SPMD parameter shapes are PER-PARTITION: each dim of
            # the HLO param evenly divides the global leaf dim
            ldt, ldims = leaf[1], list(leaf[2])
            return (ldt == dt and len(ldims) == len(dims)
                    and all(d > 0 and ld % d == 0
                            for d, ld in zip(dims, ldims)))

        out = []
        j = 0
        for pname in g.entry_params():
            tok = g.shapes.get(pname, "") if pname else ""
            dt, dims = H._tok_first_shape(tok)
            while j < len(leaves) and not matches(leaves[j], dt, dims):
                j += 1
            if j >= len(leaves):
                self.meta["_aligned_paths"] = None
                return None
            out.append(leaves[j][0])
            j += 1
        self.meta["_aligned_paths"] = out
        return out


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    severity: str  # "error" | "warn"
    trace: str
    path: str      # what the suppression regexes match against
    message: str


@dataclasses.dataclass(frozen=True)
class Rule:
    """One named invariant over a :class:`Trace`."""

    name: str
    severity: str
    doc: str
    applies: Callable[[dict], bool]
    check: Callable[["Rule", Trace], List[Violation]]
    suppress: Tuple[str, ...] = ()  # default path-regex suppressions

    def violation(self, trace: Trace, path: str, message: str) -> Violation:
        return Violation(rule=self.name, severity=self.severity,
                         trace=trace.name, path=path, message=message)


def run_rules(trace: Trace, rules: Optional[Sequence[Rule]] = None,
              suppressions: Optional[Dict[str, Sequence[str]]] = None,
              ) -> Tuple[List[Violation], List[Violation]]:
    """Run every applicable rule; returns (violations, suppressed)."""
    out: List[Violation] = []
    supp: List[Violation] = []
    for rule in DEFAULT_RULES if rules is None else rules:
        if not rule.applies(trace.meta):
            continue
        pats = tuple(rule.suppress) + tuple(
            (suppressions or {}).get(rule.name, ()))
        for v in rule.check(rule, trace):
            if any(re.search(p, v.path) for p in pats):
                supp.append(v)
            else:
                out.append(v)
    return out, supp


# ---------------------------------------------------------------------------
# graph walks shared by the dtype-flow rules
# ---------------------------------------------------------------------------


def _quantized_param_seeds(trace: Trace) -> List[Tuple[int, str]]:
    """(flat index, instr name) of low-bit integer entry parameters —
    quantized payloads (weights, int8 KV cache planes)."""
    g = trace.graph
    seeds = []
    for idx, pname in enumerate(g.entry_params()):
        if pname is not None and g.dtype_of(pname) in _QUANT_DTYPES:
            seeds.append((idx, pname))
    return seeds


def _check_no_gather_concat(rule: Rule, trace: Trace) -> List[Violation]:
    g = trace.graph
    out = []
    # stop once the value is consumed by a contraction / opaque call: the
    # epilogue invariant is about what happens to the weight BEFORE it is
    # contracted, not about ops downstream of the product
    stop = {"dot", "convolution", "reduce", "custom-call", "scatter", "sort"}
    for idx, seed in _quantized_param_seeds(trace):
        path = trace.param_path(idx)
        seen = {seed}
        frontier = [seed]
        hits: Dict[str, int] = {}
        while frontier:
            n = frontier.pop()
            for s in g.edges.get(n, ()):
                if s in seen:
                    continue
                seen.add(s)
                ins = g.producers.get(s)
                if ins is None:
                    continue
                if ins.opcode in ("gather", "concatenate"):
                    hits[ins.opcode] = hits.get(ins.opcode, 0) + 1
                if ins.opcode not in stop:
                    frontier.append(s)
        for op, k in sorted(hits.items()):
            out.append(rule.violation(
                trace, path,
                f"{k} {op} op(s) reachable from quantized param {path!r} "
                f"before any contraction (the M2Q epilogue must be fused "
                f"away)"))
    return out


def _check_no_dequant_matmul(rule: Rule, trace: Trace) -> List[Violation]:
    g = trace.graph
    out = []
    for idx, seed in _quantized_param_seeds(trace):
        path = trace.param_path(idx)
        # state = (value name, passed-through-a-dequantize?)
        seen = {(seed, False)}
        frontier: List[Tuple[str, bool]] = [(seed, False)]
        hits: List[str] = []
        while frontier:
            n, dq = frontier.pop()
            n_dt = g.dtype_of(n)
            for s in g.edges.get(n, ()):
                ins = g.producers.get(s)
                if ins is None:
                    continue
                s_dq = dq
                if ins.opcode == "convert":
                    s_dt = g.dtype_of(s)
                    if H.is_float_dtype(s_dt) and H.is_int_dtype(n_dt):
                        # int -> float BEFORE any contraction is a
                        # dequantize.  The legitimate int->float convert on
                        # the integer path is the s32 accumulator rescale
                        # AFTER the dot — and this walk never crosses a
                        # contraction.  (Source dtype is deliberately any
                        # int: XLA widens s8->f32 into s8->s32->f32.)
                        s_dq = True
                    elif H.is_int_dtype(s_dt):
                        s_dq = False  # re-quantized: no longer a float weight
                if ins.opcode in ("dot", "convolution"):
                    if dq and H.is_float_dtype(n_dt):
                        hits.append(f"{ins.opcode} %{ins.name}")
                    continue  # never walk past a contraction
                if ins.opcode in ("reduce", "custom-call", "scatter", "sort"):
                    continue
                if (s, s_dq) not in seen:
                    seen.add((s, s_dq))
                    frontier.append((s, s_dq))
        for h in sorted(set(hits)):
            out.append(rule.violation(
                trace, path,
                f"float {h} consumes a dequantized value of quantized "
                f"param {path!r} (the low-bit payload is decoded to float "
                f"and contracted at full precision)"))
    return out


def _check_no_f32_dot(rule: Rule, trace: Trace) -> List[Violation]:
    by_dtype = H.analyze(trace.text)["dot_flops_by_dtype"]
    out = []
    total = sum(v for k, v in by_dtype.items() if k != "conv")
    if trace.meta.get("expect_dots", True) and total == 0:
        out.append(rule.violation(
            trace, "", "vacuous: the trace contains no dot ops at all "
            "(expected a quantized contraction hot path)"))
    for dt in ("f32", "f64"):
        if by_dtype.get(dt, 0.0) > 0.0:
            out.append(rule.violation(
                trace, "",
                f"{by_dtype[dt]:.3g} {dt} dot FLOPs on a path declared "
                f"fully quantized (expect_no_f32_dot)"))
    return out


def _check_conv_budget(rule: Rule, trace: Trace) -> List[Violation]:
    budget = trace.meta["conv_budget"]
    n = H.op_histogram(trace.text, weighted=True,
                       include_fused=True).get("convolution", 0)
    if n == budget:
        return []
    why = ("a quantized conv fell back to a dequantized f32 convolution"
           if n > budget else "fewer convs than declared: update the budget")
    return [rule.violation(
        trace, "",
        f"{n} convolution(s) in the module, budget is exactly {budget} "
        f"({why})")]


_HOST_OPS = {"outfeed", "infeed", "send", "recv", "send-done", "recv-done"}


def _check_no_d2h_in_loop(rule: Rule, trace: Trace) -> List[Violation]:
    g = trace.graph
    out = []
    for cname in sorted(g.loop_comps()):
        for ins in g.comps.get(cname, []):
            is_host_call = ins.opcode == "custom-call" and re.search(
                r"custom_call_target=\"[^\"]*[Hh]ost", ins.args)
            if ins.opcode in _HOST_OPS or is_host_call:
                out.append(rule.violation(
                    trace, _comp_bucket(cname),
                    f"host transfer {ins.opcode} %{ins.name} inside while "
                    f"body {cname!r}: decode must stay device-resident "
                    f"(one d2h per completion)"))
    return out


def _comp_bucket(comp: str) -> str:
    """Computation name with uniquing digits stripped — a stable key for
    baselines across recompiles."""
    return re.sub(r"[.\d]+", "", comp) or comp


def _check_unguarded_act_quant(rule: Rule, trace: Trace) -> List[Violation]:
    g = trace.graph
    buckets: Dict[str, int] = {}
    for name, ins in g.producers.items():
        if ins.opcode != "convert" or g.dtype_of(name) not in ("s8", "u8"):
            continue
        srcs = ins.operand_names()
        if not srcs or not H.is_float_dtype(g.dtype_of(srcs[0])):
            continue
        # bounded backward walk: is the quantized value dominated by a
        # finiteness check anywhere in its ancestry?
        guarded = False
        seen = {name}
        frontier = [name]
        depth = 0
        while frontier and not guarded and depth < 16:
            depth += 1
            nxt = []
            for n in frontier:
                for p in g.redges.get(n, ()):
                    if p in seen:
                        continue
                    seen.add(p)
                    pi = g.producers.get(p)
                    if pi is not None and pi.opcode == "is-finite":
                        guarded = True
                        break
                    nxt.append(p)
            frontier = nxt
        if not guarded:
            b = _comp_bucket(g.comp_of.get(name, ""))
            buckets[b] = buckets.get(b, 0) + 1
    return [rule.violation(
        trace, b,
        f"{k} float->int8 convert(s) in computation(s) {b!r} with no "
        f"dominating is-finite: a NaN activation quantizes to finite "
        f"garbage the logits check cannot flag")
        for b, k in sorted(buckets.items())]


def _check_sharding_conformance(rule: Rule, trace: Trace) -> List[Violation]:
    out = []
    for rec in trace.meta.get("sharding", ()):
        if rec["expected"] != rec["actual"]:
            out.append(rule.violation(
                trace, rec["path"],
                f"input sharding for {rec['path']!r} is {rec['actual']} "
                f"but dist.sharding specs say {rec['expected']}"))
    return out


def lint(trace: Trace, *rule_names: str,
         suppressions: Optional[Dict[str, Sequence[str]]] = None,
         ) -> List[Violation]:
    """Violations from the named rules (all of ``DEFAULT_RULES`` when no
    names are given) — the shared assertion surface the test suite uses
    in place of ad-hoc ``op_histogram`` checks.  A rule name is looked up
    strictly (KeyError on typos: a misspelled rule must not pass
    vacuously).  Suppressed violations are dropped here — tests assert on
    what a CI run would actually report."""
    rules = ([RULES_BY_NAME[n] for n in rule_names] if rule_names else None)
    return run_rules(trace, rules=rules, suppressions=suppressions)[0]


DEFAULT_RULES: Tuple[Rule, ...] = (
    Rule(name="no-f32-dot", severity="error",
         doc="A hot path declared fully quantized runs zero f32/f64 dot "
             "FLOPs (and is non-vacuous: it runs SOME dots).",
         applies=lambda m: bool(m.get("expect_no_f32_dot")),
         check=_check_no_f32_dot),
    Rule(name="no-gather-concat", severity="error",
         doc="No gather/concatenate is reachable from a quantized "
             "parameter before its contraction (the deleted M2Q "
             "permutation epilogue must not creep back).",
         applies=lambda m: bool(m.get("quantized", True)),
         check=_check_no_gather_concat,
         # embedding tables are looked up BY gather — that is the op's
         # definition, not an epilogue regression
         suppress=(r"(^|/)embed",)),
    Rule(name="conv-budget", severity="error",
         doc="The module contains exactly the declared number of "
             "convolutions (the unquantized stem); any extra conv is a "
             "quantized conv that fell back to f32.",
         applies=lambda m: m.get("conv_budget") is not None,
         check=_check_conv_budget),
    Rule(name="no-dequant-matmul", severity="error",
         doc="No f32 dot/convolution consumes a value reached from a "
             "quantized parameter through a dequantizing convert "
             "(fusion interiors included).",
         applies=lambda m: bool(m.get("quantized", True)),
         check=_check_no_dequant_matmul),
    Rule(name="no-d2h-in-loop", severity="error",
         doc="No host transfer (outfeed/infeed/send/recv, host custom "
             "calls) inside a while body.",
         applies=lambda m: True,
         check=_check_no_d2h_in_loop),
    Rule(name="unguarded-act-quant", severity="warn",
         doc="Every float->int8 convert should be dominated by an "
             "is-finite check; unguarded converts launder NaN into "
             "finite int8 garbage (see docs/serving.md).",
         applies=lambda m: bool(m.get("quantized", True)),
         check=_check_unguarded_act_quant),
    Rule(name="sharding-conformance", severity="error",
         doc="Compiled input shardings match the dist.sharding specs the "
             "trace was built with.",
         applies=lambda m: bool(m.get("sharding")),
         check=_check_sharding_conformance),
)

RULES_BY_NAME: Dict[str, Rule] = {r.name: r for r in DEFAULT_RULES}
